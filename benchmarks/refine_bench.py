"""Incremental vs recompute refinement: the O(NK)-per-turn claim.

Two claims measured (ISSUE 2 acceptance criteria):

  1. **Per-turn cost** — ``refine_traced`` on the incremental path
     (aggregate carried, rank-1 updates, exact-potential deltas) vs the
     recompute path (O(N^2 K) aggregate matmul + two O(N^2) potential
     passes per turn).  Timed over a fixed-length scan so per-turn cost is
     wall/T regardless of convergence; the incremental per-turn cost must
     grow sublinearly vs the recompute path's O(N^2) from N=256 -> 4096
     (>= 5x speedup at N=4096, K=8).

  2. **Agreement** — the incremental path must reproduce the recompute
     path's move sequence EXACTLY (same turns, nodes, destinations) and
     both potentials to <= 1e-3 relative over a 512-turn trace, for both
     cost frameworks.  Asserted here (and by the CI bench-smoke job at
     N=256) on every run.  By default the incremental side runs through
     the batched sweep runtime (DESIGN.md §12) over several seeds — one
     vmapped program per framework, each element checked against its own
     looped recompute oracle (``--no-batched`` restores the seed-0-only
     looped check).

The timing sweep below stays a Python loop over sizes by design: mixed
(N, K) shapes are separate compiles, hence separate stacks (§12.1).
Results are emitted machine-readably to BENCH_refine.json.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro import sweeps
from repro.core.refine import refine_traced
from repro.graphs.generators import random_degree_graph, random_weights
from repro.core.problem import make_problem

from .common import (cli_telemetry, section, table, telemetry_recorder,
                     timed, write_bench_json)

AGREE_TOL = 1e-3          # max relative potential deviation, ISSUE 2
SPEEDUP_FLOOR = 5.0       # at the largest size, full (non-quick) runs


def _instance(n: int, k: int, seed: int = 0):
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    prob = make_problem(c, b, np.ones(k) / k, mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, r0


def _assert_trace_agreement(fw: str, tr_i, tr_r, res_i, res_r, tag: str = ""):
    for field in ("moved", "node", "source", "dest"):
        a = np.asarray(getattr(tr_i, field))
        b = np.asarray(getattr(tr_r, field))
        assert np.array_equal(a, b), \
            f"{fw}{tag}: incremental {field} sequence diverged at " \
            f"turns {np.flatnonzero(a != b)[:5]}"
    assert np.array_equal(np.asarray(res_i.assignment),
                          np.asarray(res_r.assignment))
    rel = {}
    for pot in ("c0", "ct0"):
        a = np.asarray(getattr(tr_i, pot), np.float64)
        b = np.asarray(getattr(tr_r, pot), np.float64)
        rel[pot] = float(np.max(np.abs(a - b) / np.abs(b)))
        assert rel[pot] <= AGREE_TOL, \
            f"{fw}{tag}: {pot} drifted {rel[pot]:.2e} > {AGREE_TOL}"
    return rel


def check_agreement(n: int = 256, k: int = 8, max_turns: int = 512,
                    recorder=None):
    """Assert the ISSUE-2 acceptance contract at one size; return stats."""
    prob, r0 = _instance(n, k)
    out = {"n": n, "k": k, "turns": max_turns, "frameworks": {}}
    for fw in ("c", "ct"):
        res_i, tr_i = refine_traced(prob, r0, fw, max_turns=max_turns,
                                    recorder=recorder)
        res_r, tr_r = refine_traced(prob, r0, fw, max_turns=max_turns,
                                    incremental=False)
        rel = _assert_trace_agreement(fw, tr_i, tr_r, res_i, res_r)
        out["frameworks"][fw] = {
            "moves": int(res_i.num_moves),
            "moves_equal": True,
            "rel_potential_diff": rel,
        }
    return out


def check_agreement_batched(seeds=(0, 1, 2), n: int = 256, k: int = 8,
                            max_turns: int = 512, recorder=None):
    """The same contract, incremental side batched: every (seed, framework)
    cell of a sweep-runtime fleet vs its own looped recompute oracle —
    gating the §10 incremental contract AND the §12.2 vmap-vs-loop
    contract in one pass."""
    instances = [_instance(n, k, seed=seed) for seed in seeds]
    cases = [sweeps.SweepCase(problem=p, assignment=r0, framework=fw,
                              label=f"s{seed}/{fw}")
             for seed, (p, r0) in zip(seeds, instances)
             for fw in ("c", "ct")]
    res = sweeps.run_sweep(sweeps.make_spec(cases, mode="traced",
                                            max_turns=max_turns),
                           recorder=recorder)
    out = {"n": n, "k": k, "turns": max_turns, "seeds": list(seeds),
           "frameworks": {}}
    for i, case in enumerate(cases):
        res_r, tr_r = refine_traced(case.problem,
                                    jnp.asarray(case.assignment),
                                    case.framework, max_turns=max_turns,
                                    incremental=False)
        rel = _assert_trace_agreement(case.framework, res.traces[i], tr_r,
                                      res.results[i], res_r,
                                      tag=f"[{case.label}]")
        st = out["frameworks"].setdefault(
            case.framework, {"moves": [], "moves_equal": True,
                             "rel_potential_diff": {"c0": 0.0, "ct0": 0.0}})
        st["moves"].append(int(res.results[i].num_moves))
        for pot in ("c0", "ct0"):
            st["rel_potential_diff"][pot] = max(
                st["rel_potential_diff"][pot], rel[pot])
    return out


def run(quick: bool = False, batched: bool = True, telemetry=None):
    k = 8
    sizes = [256, 1024] if quick else [256, 1024, 4096]
    timing_turns = 48 if quick else 64
    recorder = telemetry_recorder(telemetry, "refine")

    # ---- acceptance: exact moves + <=1e-3 potentials, both frameworks ----
    if batched:
        section("Incremental (batched sweep) vs recompute oracle (512 turns)")
        agreement = check_agreement_batched(seeds=(0, 1) if quick
                                            else (0, 1, 2), k=k,
                                            recorder=recorder)
    else:
        section("Incremental refinement: move/potential agreement (512 turns)")
        agreement = check_agreement(n=256, k=k, recorder=recorder)
    for fw, st in agreement["frameworks"].items():
        print(f"  [{fw}] moves {st['moves']} identical; "
              f"max rel potential diff "
              f"c0={st['rel_potential_diff']['c0']:.2e} "
              f"ct0={st['rel_potential_diff']['ct0']:.2e}")

    # ---- per-turn cost scaling ------------------------------------------
    section("Per-turn cost: O(NK) incremental vs O(N^2 K) recompute")
    rows = []
    results = []
    for n in sizes:
        prob, r0 = _instance(n, k)
        t_inc = timed(lambda: refine_traced(prob, r0, "c",
                                            max_turns=timing_turns),
                      iters=2)
        t_rec = timed(lambda: refine_traced(prob, r0, "c",
                                            max_turns=timing_turns,
                                            incremental=False),
                      iters=2)
        per_inc = t_inc / timing_turns * 1e3
        per_rec = t_rec / timing_turns * 1e3
        speedup = t_rec / t_inc
        rows.append([n, k, f"{per_inc:.3f}", f"{per_rec:.3f}",
                     f"{speedup:.1f}x"])
        results.append({"n": n, "k": k,
                        "per_turn_incremental_ms": per_inc,
                        "per_turn_recompute_ms": per_rec,
                        "speedup": speedup})
    table(["N", "K", "incremental ms/turn", "recompute ms/turn", "speedup"],
          rows)

    # sublinearity: incremental per-turn growth across the sweep must stay
    # far below the recompute path's quadratic growth
    if len(results) > 1:
        lo, hi = results[0], results[-1]
        ratio = hi["n"] / lo["n"]
        inc_growth = (hi["per_turn_incremental_ms"]
                      / lo["per_turn_incremental_ms"])
        rec_growth = (hi["per_turn_recompute_ms"]
                      / lo["per_turn_recompute_ms"])
        print(f"\nN x{ratio:.0f}: incremental per-turn cost grew "
              f"{inc_growth:.1f}x, recompute {rec_growth:.1f}x "
              f"(quadratic would be {ratio * ratio:.0f}x)")
        assert inc_growth < rec_growth, \
            "incremental per-turn cost did not grow sublinearly vs recompute"
    if not quick:
        top = results[-1]
        assert top["speedup"] >= SPEEDUP_FLOOR, \
            f"speedup {top['speedup']:.1f}x < {SPEEDUP_FLOOR}x " \
            f"at N={top['n']}, K={k}"

    if recorder is not None:
        recorder.close()
    payload = {"agreement": agreement, "scaling": results,
               "timing_turns": timing_turns, "batched": batched}
    write_bench_json("refine", payload)
    return payload


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv,
        batched="--no-batched" not in sys.argv,
        telemetry=cli_telemetry(sys.argv))
