"""Paper §5.1 batch study: 50 random graph realizations — as ONE vmap.

The whole study (initial partition + traced refinement under both cost
frameworks + discrepancy counting) is a single vmapped JAX program over the
stacked problem instances (DESIGN.md §3.1: the archetype and the game are
dense masked dataflow, so experiment batching is free).

Counts (a) in how many runs the C_i framework converges to better values of
both global costs, and (b) the average number of C_0-discrepancies vs
Ct_0-discrepancies — a discrepancy is a refinement move that *increases*
the other framework's global potential.

Paper's numbers: C_i better in 49/50 runs; ~0.2 C_0-discrepancies vs ~5.2
Ct_0-discrepancies per run.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.initial import initial_partition
from repro.core.problem import PartitionProblem, make_problem
from repro.core.refine import count_discrepancies, refine_traced
from repro.graphs.generators import random_degree_graph, random_weights

from .common import section


def _stack_problems(runs: int, n: int, k: int = 5):
    adjs, cs, bs, speeds, mus = [], [], [], [], []
    for s in range(runs):
        adj = random_degree_graph(n, seed=500 + s, dmin=3, dmax=6)
        b, c = random_weights(adj, seed=1500 + s, mean=5.0)
        rng = np.random.default_rng(2500 + s)
        mus.append(float(rng.choice([4.0, 8.0, 16.0])))
        sp = rng.uniform(0.5, 2.0, size=k)
        speeds.append(sp / sp.sum())
        adjs.append(adj)
        cs.append(c)
        bs.append(b)
    probs = PartitionProblem(
        adjacency=jnp.asarray(np.stack(cs)),
        node_weights=jnp.asarray(np.stack(bs)),
        speeds=jnp.asarray(np.stack(speeds), jnp.float32),
        mu=jnp.asarray(mus, jnp.float32),
    )
    return jnp.asarray(np.stack(adjs)), probs


def run(quick: bool = False):
    section("§5.1 batch study — 50 realizations as one vmap")
    runs = 10 if quick else 50
    n = 120 if quick else 230
    max_turns = 384 if quick else 768

    adjs, probs = _stack_problems(runs, n)
    keys = jax.random.split(jax.random.PRNGKey(0), runs)
    r0 = jax.vmap(lambda a, key: initial_partition(a, 5, key))(adjs, keys)

    def one(prob, r0):
        res_c, trace_c = refine_traced(prob, r0, "c", max_turns=max_turns)
        res_ct, trace_ct = refine_traced(prob, r0, "ct",
                                         max_turns=max_turns)
        metrics = jnp.stack([
            costs.global_cost_c0(prob, res_c.assignment),
            costs.global_cost_ct0(prob, res_c.assignment),
            costs.global_cost_c0(prob, res_ct.assignment),
            costs.global_cost_ct0(prob, res_ct.assignment),
        ])
        disc_ct0 = count_discrepancies(
            trace_c, "c", costs.global_cost_ct0(prob, r0))
        disc_c0 = count_discrepancies(
            trace_ct, "ct", costs.global_cost_c0(prob, r0))
        conv = res_c.converged & res_ct.converged
        return metrics, disc_c0, disc_ct0, conv

    metrics, c0_disc, ct0_disc, conv = jax.jit(jax.vmap(one))(probs, r0)
    m = np.asarray(metrics)
    c_wins = int(np.sum((m[:, 0] <= m[:, 2]) & (m[:, 1] <= m[:, 3])))
    ct_wins_own = int(np.sum((m[:, 3] < m[:, 1])
                             & ~((m[:, 0] <= m[:, 2])
                                 & (m[:, 1] <= m[:, 3]))))
    unconverged = int(runs - np.sum(np.asarray(conv)))

    print(f"runs = {runs} (graph N={n}, one vmapped program)")
    print(f"C_i better on BOTH costs:      {c_wins}/{runs}   "
          f"(paper: 49/50)")
    print(f"Ct_i better only on its own:   {ct_wins_own}/{runs} "
          f"(paper: 1/50)")
    print(f"avg C_0-discrepancies  (using Ct_i): "
          f"{float(np.mean(np.asarray(c0_disc))):.2f}  (paper: ~0.2)")
    print(f"avg Ct_0-discrepancies (using C_i):  "
          f"{float(np.mean(np.asarray(ct0_disc))):.2f}  (paper: ~5.2)")
    if unconverged:
        print(f"[note] {unconverged} runs hit the turn cap")
    return {"c_wins": c_wins, "runs": runs,
            "c0_disc": float(np.mean(np.asarray(c0_disc))),
            "ct0_disc": float(np.mean(np.asarray(ct0_disc)))}


if __name__ == "__main__":
    run()
