"""Paper Figs. 7 & 8: total simulation execution time vs refinement
frequency, on the preferential-attachment (Fig. 7) and specialized
geometric (Fig. 8) graph models, with moving hot-spot flood workloads.

Paper's claim: simulation time decreases as refinement frequency increases
(i.e., as the refinement period shrinks), and the C_i framework outperforms
Ct_i.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.initial import initial_partition
from repro.des.engine import DESConfig, make_initial_state, run_simulation
from repro.des.workload import flooded_packet_workload
from repro.graphs.generators import (preferential_attachment,
                                     specialized_geometric)

from .common import section, table


def simulate(adj: np.ndarray, seed: int, refine_freq: int, framework: str,
             num_machines: int = 4, num_threads: int = 24,
             max_ticks: int = 120_000):
    n = adj.shape[0]
    spec = flooded_packet_workload(adj, seed, num_threads=num_threads,
                                   num_windows=4, scope=2,
                                   window_sim_time=60.0, max_per_lp=3)
    deg = int((adj > 0).sum(1).max())
    cfg = DESConfig(
        num_lps=n, num_machines=num_machines, num_threads=num_threads,
        event_capacity=max(48, 2 * deg + 8),
        history_capacity=max(96, 4 * deg + 16),
        inter_delay=8, intra_delay=1,
        refine_freq=refine_freq, refine_framework=framework,
        max_ticks=max_ticks)
    m0 = initial_partition(jnp.asarray(adj), num_machines,
                           jax.random.PRNGKey(seed))
    state = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
    out = run_simulation(cfg, jnp.asarray(adj, jnp.float32), state)
    return out


def run_model(name: str, gen, quick: bool):
    n = 48 if quick else 96
    adj = gen(n, 7)
    freqs = [0, 2000, 500] if quick else [0, 4000, 1000, 500, 250]
    rows = []
    for fw in ("c", "ct"):
        for freq in freqs:
            out = simulate(adj, seed=11, refine_freq=freq, framework=fw)
            rows.append([fw, freq if freq else "never",
                         int(out.tick), int(out.rollbacks),
                         int(out.refines), int(out.moves),
                         "yes" if bool(out.done) else "NO"])
    table(["framework", "refine period", "sim time (ticks)", "rollbacks",
           "refines", "migrations", "drained"], rows)
    return rows


def run(quick: bool = False):
    section("Fig. 7 — sim time vs refinement frequency "
            "(preferential attachment)")
    r7 = run_model("pa", lambda n, s: preferential_attachment(n, s, m=2),
                   quick)
    section("Fig. 8 — sim time vs refinement frequency "
            "(specialized geometric)")
    r8 = run_model("geo", lambda n, s: specialized_geometric(n, s), quick)

    def best_vs_never(rows, fw):
        mine = [r for r in rows if r[0] == fw and r[6] == "yes"]
        never = [r[2] for r in mine if r[1] == "never"]
        refined = [r[2] for r in mine if r[1] != "never"]
        if never and refined:
            return never[0], min(refined)
        return None, None

    for name, rows in (("PA", r7), ("geometric", r8)):
        base, best = best_vs_never(rows, "c")
        if base:
            print(f"[{name}] C_i: never-refine {base} ticks -> best refined "
                  f"{best} ticks ({100 * (base - best) / base:.1f}% faster)")
    return {"fig7": r7, "fig8": r8}


if __name__ == "__main__":
    run()
