"""Aggregate the dry-run JSONs into the §Roofline table.

Reads benchmarks/results/dryrun_*.json (produced by repro.launch.dryrun),
prints the per-(arch x shape x mesh) three-term roofline with the dominant
bottleneck, and emits the markdown table (results to BENCH_roofline.json).
"""
from __future__ import annotations

import glob
import json
import os

from .common import section, table

RESULTS = os.path.join(os.path.dirname(__file__), "results")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(mesh: str | None = None, mode: str = "tuned"):
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "dryrun_*.json"))):
        is_baseline = path.endswith("_baseline.json")
        if (mode == "baseline") != is_baseline:
            continue
        with open(path) as f:
            cell = json.load(f)
        if mesh is None or cell.get("mesh") == mesh:
            cells.append(cell)
    cells.sort(key=lambda c: (c["arch"], SHAPE_ORDER.index(c["shape"])
                              if c["shape"] in SHAPE_ORDER else 99,
                              c.get("mesh", "")))
    return cells


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


HEADER = ["arch", "shape", "mesh", "status", "compute", "mem floor",
          "mem xla-ub", "collective", "dominant", "roof frac", "temp GB",
          "useful FLOPs"]


def row_for(cell):
    if cell["status"] == "SKIP":
        return [cell["arch"], cell["shape"], cell["mesh"], "SKIP"] \
            + ["-"] * (len(HEADER) - 4)
    if cell["status"] != "OK":
        return [cell["arch"], cell["shape"], cell["mesh"], "FAIL"] \
            + ["-"] * (len(HEADER) - 4)
    frac = cell.get("useful_flop_ratio")
    floor_mem = cell.get("analytic_memory_term_s")
    if floor_mem is not None:
        dom = cell.get("dominant_floor", cell["dominant"])
        roof = cell.get("roofline_fraction_floor")
    else:   # older records (pre-floor-model)
        dom = cell["dominant"]
        dom_s = {"compute": cell["compute_term_s"],
                 "memory": cell["memory_term_s"],
                 "collective": cell["collective_term_s"]}[dom]
        roof = cell["compute_term_s"] / max(dom_s, 1e-30)
    temp = cell.get("memory_analysis", {}).get("temp_size_in_bytes")
    return [cell["arch"], cell["shape"], cell["mesh"], "OK",
            fmt_s(cell["compute_term_s"]),
            fmt_s(floor_mem) if floor_mem is not None else "-",
            fmt_s(cell["memory_term_s"]),
            fmt_s(cell["collective_term_s"]), dom,
            f"{roof * 100:.1f}%" if roof is not None else "-",
            f"{temp / 1e9:.1f}" if temp else "-",
            f"{frac * 100:.0f}%" if frac else "-"]


def run(quick: bool = False, mesh: str = "16x16", mode: str = "tuned"):
    section(f"Roofline table from dry-run artifacts ({mesh} mesh, {mode})")
    cells = load_cells(mesh, mode)
    if not cells:
        print("no dry-run results found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all "
              "--mesh both --out benchmarks/results [--mode baseline]")
        return {}
    rows = [row_for(c) for c in cells]
    table(HEADER, rows)
    ok = [c for c in cells if c["status"] == "OK"]
    doms = {}
    for c in ok:
        d = c.get("dominant_floor", c["dominant"])
        doms[d] = doms.get(d, 0) + 1
    print(f"\n{len(ok)} OK cells; dominant terms (floor view): {doms}")
    fracs = [(c.get("roofline_fraction_floor", 0.0), c["arch"], c["shape"])
             for c in ok]
    fracs.sort()
    print("worst roofline fractions:", [(a, s) for _, a, s in fracs[:3]])
    # before/after comparison when both sweeps exist
    base = {(c["arch"], c["shape"], c["mesh"]): c
            for c in load_cells(mesh, "baseline") if c["status"] == "OK"}
    if base and mode == "tuned":
        print("\nbaseline -> tuned (collective_term_s | memory_term_s):")
        for c in ok:
            b = base.get((c["arch"], c["shape"], c["mesh"]))
            if b is None:
                continue
            print(f"  {c['arch']:>22} {c['shape']:<12} "
                  f"coll {b['collective_term_s']:9.2f} -> "
                  f"{c['collective_term_s']:8.2f}   "
                  f"mem {b['memory_term_s']:9.2f} -> "
                  f"{c['memory_term_s']:8.2f}")
    return {"cells": len(cells), "ok": len(ok)}


def markdown(mesh: str = "16x16", mode: str = "tuned") -> str:
    cells = load_cells(mesh, mode)
    lines = ["| " + " | ".join(HEADER[:3] + HEADER[3:]) + " |",
             "|" + "---|" * len(HEADER)]
    for c in cells:
        lines.append("| " + " | ".join(str(x) for x in row_for(c)) + " |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    mode = sys.argv[1] if len(sys.argv) > 1 else "tuned"
    run(mode=mode)
    print()
    run(mesh="2x16x16", mode=mode)
