"""Dynamic rebalancing under churn: heterogeneity x hysteresis (DESIGN.md §11).

The scenario family the paper is *about* — machines whose effective
capacity drifts while the workload's hot spots move — run end to end
through the DES engine on a grid of

  scenarios : static heterogeneous speeds / mid-run slowdown+recovery /
              sustained random churn (repro.des.scenarios)
  modes     : refinement off  |  theta=0 (migration treated as free)  |
              state-sized theta (hysteresis priced by the records a
              migration must ship)

with migration freezes ON for both refining modes, so thrashing costs what
it costs.  Reported per cell: time-averaged cross-machine CV of the
SPEED-NORMALIZED machine backlog Q_k/w_k (the engine's ``trace_wload``;
equal Q_k/w_k = equal time-to-drain, the L_k/w_k balance of Eq. 8 —
raw queue-length balance would penalize a speeds-aware partitioner for
correctly loading fast machines more), LP migrations, rollbacks, ticks.

Hard gates (run every time, CI smoke included):

  1. **theta=0 oracle** — theta=0 refinement must reproduce the
     recompute-path oracle's move sequence bitwise, single AND
     distributed (the hysteresis path may not perturb the game).
  2. **wire flatness** — per-round distributed exchange bytes stay flat
     as N grows 16x at fixed K, with per-node thresholds in play (theta
     is shard-local, never on the wire).

The grid's scenario axis runs as one batched DES program per mode by
default (``run_simulation_batch``, DESIGN.md §12.4; per-element states
are the looped states bitwise — ``--no-batched`` restores the loop).

Full runs additionally assert the headline claim: state-sized hysteresis
beats refine-off on load CV and theta=0 on migration count at comparable
CV.  Results land in BENCH_dynamics.json.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro import sweeps
from repro.core.refine import refine_traced
from repro.core.problem import make_problem
from repro.des import scenarios
from repro.des.engine import (DESConfig, make_initial_state, run_simulation,
                              run_simulation_batch)
from repro.des.workload import flooded_packet_workload
from repro.distributed import (boundary_stats, ledger_for_run,
                               refine_distributed,
                               refine_distributed_traced)
from repro.distributed import protocol
from repro.graphs.generators import (preferential_attachment,
                                     random_degree_graph, random_weights)

from .common import section, table, write_bench_json

# theta_i = scale * live state size (records).  Calibration: node weights
# are event-list lengths, so dissatisfaction gains run O(b_i * load-gap /
# w) — hundreds to thousands — while live state sizes are O(1..50)
# records; scale 25 prices the median marginal move (~100s) out of the
# game and keeps the large imbalance-fixing wins (~1000s).
THETA_SCALE = 25.0
FREEZE = 0.25            # freeze ticks = FREEZE * state size * inter_delay
BASE_SPEEDS = (1.0, 0.8, 0.6, 0.4)      # static heterogeneity (K = 4)


def _cv(trace: np.ndarray) -> float:
    """Time-averaged cross-machine coefficient of variation (active ticks)."""
    mean = trace.mean(axis=1)
    active = mean > 1e-6
    if not active.any():
        return 0.0
    std = trace[active].std(axis=1)
    return float(np.mean(std / np.maximum(mean[active], 1e-6)))


# ---------------------------------------------------------------------------
# gate 1: theta=0 == recompute oracle, bitwise, single + distributed
# ---------------------------------------------------------------------------

def check_theta_oracle(n: int = 96, k: int = 4, max_turns: int = 256):
    """Assert the theta=0 bitwise contract on a heterogeneous-speed
    instance; returns the stats for the JSON payload."""
    adj = random_degree_graph(n, seed=5)
    b, c = random_weights(adj, seed=6, mean=5.0)
    prob = make_problem(c, b, np.asarray(BASE_SPEEDS[:k]), mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(7).integers(0, k, n), jnp.int32)
    out = {"n": n, "k": k, "frameworks": {}}
    for fw in ("c", "ct"):
        _, tr_oracle = refine_traced(prob, r0, fw, max_turns=max_turns,
                                     incremental=False)
        res_t, tr_theta = refine_traced(prob, r0, fw, max_turns=max_turns,
                                        theta=0.0)
        _, tr_dist = refine_distributed_traced(
            prob, r0, fw, num_shards=k, max_turns=max_turns,
            theta=jnp.zeros(n))
        for name, tr in (("theta0", tr_theta), ("distributed", tr_dist)):
            for field in ("moved", "node", "source", "dest"):
                a = np.asarray(getattr(tr_oracle, field))
                bb = np.asarray(getattr(tr, field))
                assert np.array_equal(a, bb), \
                    f"{fw}/{name}: theta=0 diverged from the recompute " \
                    f"oracle in '{field}' at turns " \
                    f"{np.flatnonzero(a != bb)[:5]}"
        out["frameworks"][fw] = {"moves": int(res_t.num_moves),
                                 "oracle_agrees": True}
    return out


# ---------------------------------------------------------------------------
# gate 2: wire bytes/round flat in N with shard-local theta
# ---------------------------------------------------------------------------

def _candidate_wire_bytes(n: int, k: int) -> int:
    """MEASURED per-shard candidate payload with theta in play: the byte
    size of everything :func:`protocol.local_candidate_from_aggregate`
    returns (exactly what each shard ships per turn), via ``eval_shape``
    on representative shard shapes.  Falsifiable where the analytic ledger
    constant is not: if theta — or anything N-sized — ever leaked into the
    message, this number would grow with N."""
    ns = -(-n // k)
    cand = jax.eval_shape(
        lambda agg, b, ids, valid, r, loads, speeds, mu, tot, th:
            protocol.local_candidate_from_aggregate(
                agg, b, ids, valid, r, loads, speeds, mu, tot,
                jnp.int32(0), "c", theta_local=th),
        jax.ShapeDtypeStruct((ns, k), jnp.float32),      # block aggregate
        jax.ShapeDtypeStruct((ns,), jnp.float32),        # b_local
        jax.ShapeDtypeStruct((ns,), jnp.int32),          # ids
        jax.ShapeDtypeStruct((ns,), bool),               # valid
        jax.ShapeDtypeStruct((n,), jnp.int32),           # assignment mirror
        jax.ShapeDtypeStruct((k,), jnp.float32),         # loads
        jax.ShapeDtypeStruct((k,), jnp.float32),         # speeds
        jax.ShapeDtypeStruct((), jnp.float32),           # mu
        jax.ShapeDtypeStruct((), jnp.float32),           # total_b
        jax.ShapeDtypeStruct((ns,), jnp.float32),        # theta (local!)
    )
    return sum(int(np.prod(leaf.shape)) * leaf.dtype.itemsize
               for leaf in jax.tree.leaves(cand))


def check_wire_flat(k: int = 4, sizes=(64, 256, 1024)):
    rows, results = [], []
    for n in sizes:
        adj = random_degree_graph(n, seed=11)
        b, c = random_weights(adj, seed=12, mean=5.0)
        prob = make_problem(c, b, np.asarray(BASE_SPEEDS[:k]), mu=8.0)
        r0 = jnp.asarray(np.random.default_rng(13).integers(0, k, n),
                         jnp.int32)
        theta = jnp.asarray(
            np.random.default_rng(14).uniform(0, 5, n), jnp.float32)
        res = refine_distributed(prob, r0, "c", num_shards=k,
                                 max_turns=2048, theta=theta)
        cand_bytes = _candidate_wire_bytes(n, k)
        led = ledger_for_run(boundary_stats(prob, k), k,
                             rounds=int(res.num_turns))
        rows.append([n, int(res.num_moves), led.rounds, cand_bytes,
                     f"{led.per_round_bytes:.0f}"])
        results.append({"n": n, "candidate_bytes_measured": cand_bytes,
                        "per_round_bytes": led.per_round_bytes,
                        "rounds": led.rounds})
    table(["N", "moves", "rounds", "candidate B (measured)",
           "B/round (ledger)"], rows)
    # the real gate: the measured candidate message must stay the 16-byte
    # Candidate the accounting charges for — independent of N, theta on
    measured = [r["candidate_bytes_measured"] for r in results]
    assert max(measured) == min(measured) \
        == protocol.CANDIDATE_BYTES, \
        f"candidate wire payload not flat in N / not {protocol.CANDIDATE_BYTES} B: " \
        f"{measured} (did a per-node input leak into the message?)"
    return results


# ---------------------------------------------------------------------------
# the scenario x mode grid
# ---------------------------------------------------------------------------

def _grid_workload(n, quick: bool):
    adj = preferential_attachment(n, 5, m=2)
    t = 24 if quick else 32
    spec = flooded_packet_workload(adj, 9, num_threads=t, num_windows=4,
                                   scope=2, window_sim_time=60.0,
                                   max_per_lp=3)
    return adj, t, spec


REFINE_FREQ = 300        # repartition cadence (wall ticks)


def _schedules(quick: bool):
    k = len(BASE_SPEEDS)
    return {
        # static heterogeneity as a one-segment schedule: identical speeds
        # to passing None (the engine reads the same (K,) row every tick),
        # and stackable with the churn scenarios for the batched grid
        "hetero-static": scenarios.constant(k, BASE_SPEEDS),
        "slowdown-recover": scenarios.slowdown(
            k, machine=0, at_tick=400, factor=0.25,
            recover_tick=1600, base=BASE_SPEEDS),
        # machine 0 truly DOWN (speed exactly 0, DESIGN.md §15.5): its
        # queue freezes and holds GVT back; refinement sees ~zero
        # capacity and re-homes the LPs, so the refined modes ride out
        # what the static partition must wait through
        "fail-recover": scenarios.true_failure(
            k, machine=0, fail_tick=400, recover_tick=1600,
            base=BASE_SPEEDS),
        # churn slow enough that a refinement cadence can track it —
        # sub-cadence churn is unlearnable by ANY repartitioner
        "random-churn": scenarios.random_churn(
            k, num_segments=8, segment_ticks=700, seed=17,
            low=0.3, high=1.0),
    }


MODES = {
    # refinement off: the static initial partition rides out the churn
    "off": dict(refine_freq=0),
    # migration treated as free (theta = 0) but transfers still cost
    "theta0": dict(refine_freq=REFINE_FREQ, refine_theta_scale=0.0,
                   migration_freeze=FREEZE),
    # hysteresis: moves must beat the state-transfer price
    "theta-state": dict(refine_freq=REFINE_FREQ,
                        refine_theta_scale=THETA_SCALE,
                        migration_freeze=FREEZE),
}


def _cell_stats(out, max_trace: int) -> dict:
    ptr = int(out.trace_ptr)
    assert ptr <= max_trace
    return {
        "load_cv": _cv(np.asarray(out.trace_wload)[:ptr]),
        "migrations": int(out.moves),
        "rollbacks": int(out.rollbacks),
        "refines": int(out.refines),
        "ticks": int(out.tick),
    }


def run_grid(quick: bool, batched: bool = True):
    """The scenario x mode grid.  ``batched=True`` (default) runs each
    mode's scenarios as ONE batched DES program (DESIGN.md §12.4; modes
    stay separate — a mode's DESConfig is compile-time structure); per
    element the states are the looped states bitwise, so the grid values
    and the CI gates are mode-independent."""
    n = 48 if quick else 96
    adj, t, spec = _grid_workload(n, quick)
    deg = int((adj > 0).sum(1).max())
    k = len(BASE_SPEEDS)
    m0 = jnp.asarray(np.arange(n) % k, jnp.int32)
    adjj = jnp.asarray(adj, jnp.float32)
    schedules = _schedules(quick)
    cells = {}
    for mname, overrides in MODES.items():
        cfg = DESConfig(
            num_lps=n, num_machines=k, num_threads=t,
            event_capacity=max(48, 2 * deg + 8),
            history_capacity=max(96, 4 * deg + 16),
            inter_delay=8, intra_delay=1, trace_stride=25,
            max_ticks=120_000, machine_speeds=BASE_SPEEDS,
            **overrides)
        state = make_initial_state(cfg, m0, spec.src, spec.time,
                                   spec.count)
        if batched:
            stacked = scenarios.stack_schedules(list(schedules.values()))
            bsz = len(schedules)
            outb = run_simulation_batch(
                cfg, jnp.stack([adjj] * bsz),
                sweeps.stack_pytrees([state] * bsz), stacked)
            outs = {sname: sweeps.unstack_pytree(outb, i)
                    for i, sname in enumerate(schedules)}
        else:
            outs = {sname: run_simulation(cfg, adjj, state, sched)
                    for sname, sched in schedules.items()}
        for sname, out in outs.items():
            assert bool(out.done), \
                f"{sname}/{mname} not drained after {int(out.tick)} ticks"
            cells[f"{sname}/{mname}"] = _cell_stats(out, cfg.max_trace)
    rows = [[sname, mname, f"{cell['load_cv']:.3f}", cell["migrations"],
             cell["rollbacks"], cell["ticks"]]
            for sname in schedules for mname in MODES
            for cell in [cells[f"{sname}/{mname}"]]]
    table(["scenario", "mode", "load CV", "migrations", "rollbacks",
           "ticks"], rows)
    return cells


def run(quick: bool = False, batched: bool = True):
    section("theta=0 vs recompute oracle (bitwise, single + distributed)")
    oracle = check_theta_oracle(n=64 if quick else 96)
    for fw, st in oracle["frameworks"].items():
        print(f"  [{fw}] {st['moves']} moves, oracle agrees bitwise")

    section("Distributed wire bytes/round with shard-local theta (flat in N)")
    wire = check_wire_flat(sizes=(64, 256) if quick else (64, 256, 1024))

    section("Churn x heterogeneity x hysteresis grid (DES engine, "
            + ("batched" if batched else "python loop") + ")")
    cells = run_grid(quick, batched=batched)

    # headline: state-sized hysteresis balances like theta=0 but without
    # the thrashing — and both beat leaving the initial partition alone
    summary = {}
    for sname in _schedules(quick):
        off = cells[f"{sname}/off"]
        t0 = cells[f"{sname}/theta0"]
        ts = cells[f"{sname}/theta-state"]
        summary[sname] = {
            "cv_off": off["load_cv"], "cv_theta0": t0["load_cv"],
            "cv_theta_state": ts["load_cv"],
            "migrations_theta0": t0["migrations"],
            "migrations_theta_state": ts["migrations"],
        }
        print(f"  {sname}: CV off={off['load_cv']:.3f} "
              f"theta0={t0['load_cv']:.3f} state={ts['load_cv']:.3f}; "
              f"migrations theta0={t0['migrations']} "
              f"state={ts['migrations']}")
        if not quick:
            assert ts["load_cv"] < off["load_cv"], \
                f"{sname}: hysteresis refinement did not beat refine-off " \
                f"({ts['load_cv']:.3f} vs {off['load_cv']:.3f})"
            assert ts["migrations"] < t0["migrations"], \
                f"{sname}: state-sized theta did not cut migrations " \
                f"({ts['migrations']} vs {t0['migrations']})"
            assert ts["load_cv"] <= 1.5 * t0["load_cv"] + 0.05, \
                f"{sname}: hysteresis CV not comparable to theta=0 " \
                f"({ts['load_cv']:.3f} vs {t0['load_cv']:.3f})"

    payload = {"oracle": oracle, "wire": wire, "grid": cells,
               "summary": summary,
               "params": {"theta_scale": THETA_SCALE, "freeze": FREEZE,
                          "base_speeds": list(BASE_SPEEDS),
                          "quick": quick, "batched": batched}}
    write_bench_json("dynamics", payload)
    return payload


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv,
        batched="--no-batched" not in sys.argv)
