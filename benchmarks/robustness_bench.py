"""Fault-tolerance benchmark: recovery time + wire overhead (DESIGN.md §15).

Four gates, all asserted on every run (CI runs ``--quick``):

  1. **Do no harm** — pushing an all-clear ``zero_fault_plan`` through
     the faulty drivers reproduces the fault-free drivers bitwise
     (assignment/loads everywhere; the sweep driver's self-move counters
     are the one documented exemption, DESIGN.md §15.1).
  2. **Recover or raise** — every cell of a fault-severity grid either
     closes with ``recovered=True`` within the ≤ 1e-3 repair budget or
     raises a typed :class:`FaultToleranceError`; a permanent outage
     must raise :class:`DeadShardError`.
  3. **Measured wire, byte-exact** — every fault-injected run's
     retry/repair traffic is accumulated on device and must reconcile
     byte-exactly against the host-side plan ledger
     (``accounting.ledger_for_run(..., fault_bytes=...)``).
  4. **O(K) stays O(K)** — the steady-state per-round exchange under
     retry-only fault load is byte-identical across a 4x N sweep; fault
     traffic rides on top of the O(K) protocol, it never inflates the
     per-turn message size.

Headline metrics: rounds-to-recovery (first clear round after the last
fault, from the degraded-mode schedule) and wire overhead fraction
(fault bytes / total payload) per severity.  Results land in
BENCH_robustness.json (CI uploads it as an artifact).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.problem import make_problem
from repro.distributed import (DeadShardError, FaultToleranceError, faults,
                               ledger_for_run, reconcile, refine_distributed,
                               refine_distributed_simultaneous,
                               refine_distributed_traced, zero_fault_plan)
from repro.distributed.views import boundary_stats
from repro.graphs.generators import random_degree_graph, random_weights

from .common import (cli_telemetry, section, table, telemetry_recorder,
                     write_bench_json)

K, S = 4, 4
PLAN_ROUNDS = 128

#: severity grid: probabilities per (round, shard); "outage" adds real
#: shard downtime, "nan-storm" is pure carried-state bit corruption
SEVERITIES = (
    ("light", dict(p_lost=0.05, p_dup=0.02)),
    ("moderate", dict(p_lost=0.2, p_dup=0.08, p_omit=0.05, p_corrupt=0.02)),
    ("outage", dict(p_down=0.04, down_length=(2, 5), p_lost=0.2,
                    p_omit=0.05, p_corrupt=0.04)),
    ("nan-storm", dict(p_corrupt=0.15, nan_frac=1.0)),
)


def _instance(n: int, seed: int = 0):
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    prob = make_problem(c, b, np.ones(K) / K, mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, K, n),
                     jnp.int32)
    return prob, r0


def _plan(seed: int, n: int, **kwargs):
    return faults.make_fault_plan(PLAN_ROUNDS, S, seed,
                                  num_machines=K, num_nodes=n, **kwargs)


def check_zero_fault_bitwise(n: int):
    """Gate 1: the fault-free path is untouched, driver by driver."""
    prob, r0 = _instance(n)
    zp = zero_fault_plan(PLAN_ROUNDS, S)
    out = {}

    ref = refine_distributed(prob, r0, costs.C_FRAMEWORK, num_shards=S)
    res, rep = refine_distributed(prob, r0, costs.C_FRAMEWORK,
                                  num_shards=S, fault_plan=zp)
    assert np.array_equal(np.asarray(ref.assignment),
                          np.asarray(res.assignment)), "plain: assignment"
    assert np.array_equal(np.asarray(ref.loads), np.asarray(res.loads))
    assert int(ref.num_moves) == int(res.num_moves), "plain: moves"
    assert rep.recovered and rep.retries == 0
    out["plain"] = {"turns": int(res.num_turns), "bitwise": True}

    ref, rtr = refine_distributed_traced(prob, r0, costs.C_FRAMEWORK,
                                         num_shards=S, max_turns=256)
    res, tr, rep = refine_distributed_traced(
        prob, r0, costs.C_FRAMEWORK, num_shards=S, max_turns=256,
        fault_plan=zp)
    assert np.array_equal(np.asarray(ref.assignment),
                          np.asarray(res.assignment)), "traced: assignment"
    for a, b in zip(rtr, tr):
        assert np.array_equal(np.asarray(a), np.asarray(b)), "traced: trace"
    out["traced"] = {"turns": int(res.num_turns), "bitwise": True}

    ref, (c0s, ct0s, _) = refine_distributed_simultaneous(
        prob, r0, costs.C_FRAMEWORK, num_shards=S, max_sweeps=96)
    res, (f0s, ft0s, _), rep = refine_distributed_simultaneous(
        prob, r0, costs.C_FRAMEWORK, num_shards=S, max_sweeps=96,
        fault_plan=zp)
    # sweep exemption (DESIGN.md §15.1): ULP fusion noise can elect
    # zero-gain SELF-moves under the baseline election, so num_moves /
    # num_turns are not compared; the state and potential traces are.
    assert np.array_equal(np.asarray(ref.assignment),
                          np.asarray(res.assignment)), "sweep: assignment"
    assert np.array_equal(np.asarray(ref.loads), np.asarray(res.loads))
    assert np.array_equal(np.asarray(c0s), np.asarray(f0s)), "sweep: c0s"
    assert np.array_equal(np.asarray(ct0s), np.asarray(ft0s))
    out["sweep"] = {"turns": int(res.num_turns), "bitwise": True,
                    "exempt": ["num_moves", "num_turns", "converged"]}
    return out


def severity_grid(n: int, seeds, recorder=None):
    """Gates 2+3: recover-or-raise + byte-exact wire, per severity."""
    prob, r0 = _instance(n)
    stats = boundary_stats(prob, S)
    msg = faults.message_bytes(traced=False, simultaneous=False,
                               num_machines=K)
    rows, results = [], []
    for name, kwargs in SEVERITIES:
        for seed in seeds:
            plan = _plan(seed, n, **kwargs)
            # instrument one cell that cannot hit DeadShardError (no
            # p_down) so the CI telemetry replay sees a recovered run
            rec = (recorder if name == "moderate" and seed == seeds[0]
                   else None)
            entry = {"severity": name, "seed": seed}
            try:
                res, wire, report = refine_distributed(
                    prob, r0, costs.C_FRAMEWORK, num_shards=S,
                    fault_plan=plan, measure_wire=True, recorder=rec)
            except FaultToleranceError as err:
                entry.update(verdict=type(err).__name__,
                             recovered=False,
                             report=err.report._asdict()
                             if err.report else None)
                results.append(entry)
                rows.append([name, seed, type(err).__name__, "-", "-", "-"])
                continue
            rounds = int(res.num_turns)
            extra = faults.plan_extra_bytes(plan, rounds, msg)
            led = ledger_for_run(stats, K, rounds, fault_bytes=extra)
            check = reconcile(led, wire)
            assert check.ok, f"{name}/{seed}: wire mismatch {check}"
            assert report.recovered, f"{name}/{seed}: not recovered " \
                f"(drift {report.recovery_drift:g}) and no raise"
            overhead = extra / max(int(wire.payload_bytes), 1)
            entry.update(
                verdict="recovered", recovered=True, rounds=rounds,
                recovery_round=report.recovery_round,
                recovery_drift=report.recovery_drift,
                retries=report.retries, repairs=report.repairs,
                repaired_cols=report.repaired_cols,
                down_rounds=report.down_rounds,
                quarantined_rounds=report.quarantined_rounds,
                payload_bytes=int(wire.payload_bytes),
                fault_bytes=extra, wire_overhead=overhead,
                wire_reconciled=True)
            results.append(entry)
            rows.append([name, seed, "recovered", rounds,
                         report.recovery_round,
                         f"{100 * overhead:.1f}%"])
    table(["severity", "seed", "verdict", "rounds", "recovery@",
           "wire overhead"], rows)
    recovered = [e for e in results if e.get("recovered")]
    assert recovered, "no grid cell recovered — fault layer is broken"
    return results


def check_dead_shard_raises(n: int):
    """Gate 2b: an unrecoverable outage must raise, never return."""
    prob, r0 = _instance(n)
    rounds = PLAN_ROUNDS
    z = np.zeros((rounds, S), bool)
    down = z.copy()
    down[:, 0] = True
    plan = faults._assemble(down, z, np.zeros((rounds, S), np.int32), z, z,
                            np.zeros((rounds, S), np.int32),
                            np.zeros((rounds, S), np.float32),
                            faults.DEFAULT_DEGRADED, 0)
    try:
        refine_distributed(prob, r0, costs.C_FRAMEWORK, num_shards=S,
                           fault_plan=plan, max_turns=rounds // 2)
    except DeadShardError as err:
        assert err.report is not None and err.report.dead
        return {"raised": "DeadShardError", "dead": True}
    raise AssertionError("permanent shard outage did not raise")


def recovery_vs_outage_length(n: int, lengths):
    """Headline: rounds-to-recovery as a single outage grows longer.

    One shard goes down at round 8 for exactly L rounds; the degraded
    schedule then prices the catch-up (replay within the staleness
    window, full resync beyond it) and reports the first all-clear
    round.  Recovery cost grows with L; the budget verdict must hold at
    every length."""
    prob, r0 = _instance(n)
    msg = faults.message_bytes(traced=False, simultaneous=False,
                               num_machines=K)
    rows, results = [], []
    for length in lengths:
        z = np.zeros((PLAN_ROUNDS, S), bool)
        down = z.copy()
        down[8:8 + length, 0] = True
        plan = faults._assemble(down, z,
                                np.zeros((PLAN_ROUNDS, S), np.int32), z, z,
                                np.zeros((PLAN_ROUNDS, S), np.int32),
                                np.zeros((PLAN_ROUNDS, S), np.float32),
                                faults.DEFAULT_DEGRADED, n)
        res, wire, report = refine_distributed(
            prob, r0, costs.C_FRAMEWORK, num_shards=S, fault_plan=plan,
            measure_wire=True)
        assert report.recovered, f"L={length}: drift {report.recovery_drift}"
        extra = faults.plan_extra_bytes(plan, int(res.num_turns), msg)
        entry = {"outage_rounds": length,
                 "recovery_round": report.recovery_round,
                 "rounds_to_recover": (report.recovery_round - 8
                                       if report.recovery_round else None),
                 "total_rounds": int(res.num_turns),
                 "recovery_drift": report.recovery_drift,
                 "fault_bytes": extra,
                 "full_resync": length > faults.DEFAULT_DEGRADED
                 .max_staleness}
        results.append(entry)
        rows.append([length, report.recovery_round,
                     entry["rounds_to_recover"], extra,
                     "resync" if entry["full_resync"] else "replay"])
    table(["outage L", "recovery@", "rounds to recover", "fault bytes",
           "repair mode"], rows)
    return results


def wire_flatness(sizes):
    """Gate 4: per-round payload under retry load is flat in N (byte-
    identical — the O(K) protocol claim survives the fault layer)."""
    per_round, rows, results = [], [], []
    for n in sizes:
        prob, r0 = _instance(n)
        plan = _plan(5, n, p_lost=0.25)       # retry-only: no resyncs
        res, wire, report = refine_distributed(
            prob, r0, costs.C_FRAMEWORK, num_shards=S, fault_plan=plan,
            measure_wire=True)
        rounds = int(res.num_turns)
        extra = faults.plan_extra_bytes(plan, rounds, faults.message_bytes(
            traced=False, simultaneous=False, num_machines=K))
        led = ledger_for_run(boundary_stats(prob, S), K, rounds,
                             fault_bytes=extra)
        assert reconcile(led, wire).ok
        per_round.append(led.per_round_bytes)
        results.append({"n": n, "rounds": rounds,
                        "per_round_bytes": led.per_round_bytes,
                        "fault_bytes": extra,
                        "retries": report.retries})
        rows.append([n, rounds, led.per_round_bytes, extra, report.retries])
    assert len(set(per_round)) == 1, \
        f"per-round payload is not flat across N: {per_round}"
    table(["N", "rounds", "per-round B", "fault B", "retries"], rows)
    print("  per-round payload byte-identical across the N sweep: "
          "retry traffic is O(K) per event, never O(N)")
    return results


def run(quick: bool = False, telemetry=None):
    n = 96 if quick else 192
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    lengths = (2, 4, 8) if quick else (2, 4, 8, 16)
    sizes = (64, 128, 256) if quick else (64, 128, 256, 512)
    recorder = telemetry_recorder(telemetry, "robustness")

    section("Gate 1: zero-fault plans are bitwise no-ops")
    bitwise = check_zero_fault_bitwise(n)
    for mode, cell in bitwise.items():
        print(f"  [{mode}] {cell['turns']} turns, bitwise"
              + (f" (exempt: {', '.join(cell['exempt'])})"
                 if "exempt" in cell else ""))

    section("Gates 2+3: severity grid — recover-or-raise, wire byte-exact")
    grid = severity_grid(n, seeds, recorder=recorder)

    section("Gate 2b: permanent outage raises DeadShardError")
    dead = check_dead_shard_raises(n)
    print(f"  raised {dead['raised']} with report.dead=True")

    section("Recovery time vs outage length")
    recovery = recovery_vs_outage_length(n, lengths)

    section("Gate 4: per-round wire stays O(K) under fault load")
    flat = wire_flatness(sizes)

    if recorder is not None:
        recorder.close()
    payload = {"bitwise_gate": bitwise, "grid": grid,
               "dead_shard_gate": dead, "recovery": recovery,
               "wire_flatness": flat,
               "backend_devices": jax.device_count()}
    write_bench_json("robustness", payload)
    return payload


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv, telemetry=cli_telemetry(sys.argv))
