"""Batched sweep runtime vs the Python loop (DESIGN.md §12).

Two hard gates, run every time (CI bench-smoke included):

  1. **Game agreement** — a mixed fleet (graph families × frameworks ×
     theta on/off) through ``repro.sweeps.run_sweep`` must reproduce
     each element's looped ``refine_traced`` run: move sequences,
     assignments, loads and gains BITWISE; carried potentials within the
     incremental path's ≤1e-3 relative budget (§12.2).
  2. **DES agreement** — a schedule fleet through
     ``run_simulation_batch`` must reproduce each element's looped
     ``run_simulation`` final state — traces included — BITWISE, with
     refinement, state-sized hysteresis and migration freezes on.

Throughput: one ``refine_traced`` fleet — vmapped, and vmapped+sharded
across devices (``sweeps.shard_across_devices``, §12.5) — vs B
sequential jitted calls at B ∈ {8, 32, 128} (quick: {8, 32}), same
(N, K, T) so the loop pays one compile too.  The vmap-only ratio is a
HARDWARE-PARALLELISM meter, not an algorithmic constant: XLA CPU runs
both the loop and the batch at memory bandwidth on one core, so on a
1-device host the ratio hovers near 1×, while each additional device
the batch shards over adds ~0.8× (measured 1.5× on 2 forced host CPU
devices; a TPU/GPU or any ≥4-device host clears the ISSUE-4 ≥3× floor,
asserted whenever ``jax.device_count() >= 4``).  Run CPU-parallel with::

    XLA_FLAGS=--xla_force_host_platform_device_count=$(nproc) \
        python -m benchmarks.sweep_bench

A DES fleet ratio is recorded alongside (never gated: DES wall-clock is
bounded by the slowest element, §12.4).  Results → BENCH_sweeps.json.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro import sweeps
from repro.core.problem import make_problem
from repro.core.refine import refine_traced
from repro.des import scenarios
from repro.des.engine import (DESConfig, make_initial_state, run_simulation,
                              run_simulation_batch)
from repro.des.workload import flooded_packet_workload
from repro.graphs.generators import (preferential_attachment,
                                     random_degree_graph, random_weights)

from .common import (cli_telemetry, section, table, telemetry_recorder,
                     timed, write_bench_json)

POTENTIAL_TOL = 1e-3      # §10.3 / §12.2 carried-potential budget
SPEEDUP_FLOOR = 3.0       # at B=32, full (non-quick) runs — ISSUE 4
MOVE_FIELDS = ("moved", "node", "source", "dest", "gain", "active")


def _mixed_cases(num: int, n: int, k: int, seed0: int = 0):
    """A deliberately heterogeneous fleet: alternating graph families,
    per-case speeds/weights/assignments, both frameworks, theta on/off."""
    gens = (random_degree_graph, preferential_attachment)
    cases = []
    for s in range(num):
        adj = gens[s % 2](n, seed0 + s)
        b, c = random_weights(adj, seed=seed0 + s + 100, mean=5.0)
        rng = np.random.default_rng(seed0 + s)
        speeds = rng.uniform(0.5, 2.0, k)
        prob = make_problem(c, b, speeds / speeds.sum(), mu=8.0)
        cases.append(sweeps.SweepCase(
            problem=prob,
            assignment=rng.integers(0, k, n),
            framework="c" if s % 4 < 2 else "ct",
            theta=None if s % 2 == 0 else float(rng.uniform(0.0, 4.0)),
            label=f"{gens[s % 2].__name__}/s{s}"))
    return cases


def check_game_agreement(num: int = 8, n: int = 96, k: int = 4,
                         max_turns: int = 192, recorder=None):
    """Gate 1: run_sweep vs per-case looped refine_traced."""
    from repro.sweeps.runtime import _group_key

    cases = _mixed_cases(num, n, k)
    # compile-count gate (DESIGN.md §16.5): each sweep group must lower
    # exactly once — a case that breaks its group's jit signature would
    # silently multiply compile time, which repro.analysis flags
    # statically and this cache-miss counter catches at runtime
    groups = len({_group_key(c) for c in cases})
    cache_before = sweeps.refine_traced_batched._cache_size()
    res = sweeps.run_sweep(sweeps.make_spec(cases, mode="traced",
                                            max_turns=max_turns),
                           recorder=recorder)
    compiled = sweeps.refine_traced_batched._cache_size() - cache_before
    assert compiled == groups, \
        f"sweep compiled {compiled} programs for {groups} case groups — " \
        f"a group is recompiling (run python -m repro.analysis --check)"
    max_rel = 0.0
    for i, case in enumerate(cases):
        r_l, t_l = refine_traced(case.problem,
                                 jnp.asarray(case.assignment, jnp.int32),
                                 case.framework, max_turns=max_turns,
                                 theta=case.theta)
        for field in MOVE_FIELDS:
            a = np.asarray(getattr(t_l, field))
            b = np.asarray(getattr(res.traces[i], field))
            assert np.array_equal(a, b), \
                f"[{case.label}] batched '{field}' diverged from the " \
                f"looped run at turns {np.flatnonzero(a != b)[:5]}"
        assert np.array_equal(np.asarray(r_l.assignment),
                              np.asarray(res.results[i].assignment)), \
            f"[{case.label}] batched final assignment diverged"
        assert np.array_equal(np.asarray(r_l.loads),
                              np.asarray(res.results[i].loads)), \
            f"[{case.label}] batched final loads diverged"
        for pot in ("c0", "ct0"):
            a = np.asarray(getattr(t_l, pot), np.float64)
            b = np.asarray(getattr(res.traces[i], pot), np.float64)
            rel = float(np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-9)))
            max_rel = max(max_rel, rel)
            assert rel <= POTENTIAL_TOL, \
                f"[{case.label}] {pot} drifted {rel:.2e} > {POTENTIAL_TOL}"
    return {"cases": num, "n": n, "k": k, "turns": max_turns,
            "moves": res.moves.tolist(), "groups": groups,
            "compiled_programs": compiled,
            "max_rel_potential_diff": max_rel, "bitwise_moves": True}


def _des_setup(n: int, k: int, threads: int):
    adj = preferential_attachment(n, 5, m=2)
    deg = int((adj > 0).sum(1).max())
    spec = flooded_packet_workload(adj, 9, num_threads=threads,
                                   num_windows=2, scope=2,
                                   window_sim_time=40.0, max_per_lp=3)
    cfg = DESConfig(
        num_lps=n, num_machines=k, num_threads=threads,
        event_capacity=max(48, 2 * deg + 8),
        history_capacity=max(96, 4 * deg + 16),
        inter_delay=6, intra_delay=1, trace_stride=10, max_ticks=20_000,
        machine_speeds=(1.0, 0.7, 0.5)[:k],
        refine_freq=80, refine_theta_scale=5.0, migration_freeze=0.25)
    m0 = jnp.asarray(np.arange(n) % k, jnp.int32)
    state0 = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
    return jnp.asarray(adj, jnp.float32), cfg, state0


def _des_schedules(k: int, num: int):
    base = (1.0, 0.7, 0.5)[:k]
    scheds = [scenarios.constant(k, base),
              scenarios.slowdown(k, machine=0, at_tick=120, factor=0.3,
                                 recover_tick=400, base=base)]
    for s in range(max(0, num - 2)):
        scheds.append(scenarios.random_churn(
            k, num_segments=4, segment_ticks=160, seed=17 + s,
            low=0.3, high=1.0))
    return scheds[:num]


def check_des_agreement(num: int = 3, n: int = 20, k: int = 3,
                        threads: int = 8, recorder=None):
    """Gate 2: run_simulation_batch vs per-schedule looped runs, full
    final-state pytrees compared bitwise.

    ``recorder`` instruments both sides — the batched fleet and the
    first looped scenario — so the bitwise comparison below doubles as
    the telemetry no-perturbation check on real bench workloads."""
    adjj, cfg, state0 = _des_setup(n, k, threads)
    scheds = _des_schedules(k, num)
    stacked = scenarios.stack_schedules(scheds)
    padded = [scenarios.pad_segments(s, int(stacked.times.shape[1]))
              for s in scheds]
    states = sweeps.stack_pytrees([state0] * num)
    adjs = jnp.stack([adjj] * num)
    outb = run_simulation_batch(cfg, adjs, states, stacked,
                                recorder=recorder)
    ticks = []
    for i, sched in enumerate(padded):
        out_l = run_simulation(cfg, adjj, state0, sched,
                               recorder=recorder if i == 0 else None)
        assert bool(out_l.done), f"scenario {i} did not drain"
        ticks.append(int(out_l.tick))
        flat_l = jax.tree_util.tree_leaves_with_path(out_l)
        flat_b = jax.tree.leaves(outb)
        assert len(flat_l) == len(flat_b), (len(flat_l), len(flat_b))
        for (path, a), b in zip(flat_l, flat_b):
            a = np.asarray(a)
            b = np.asarray(b)[i]
            assert np.array_equal(a, b), \
                f"scenario {i}: batched DES state diverged at " \
                f"{jax.tree_util.keystr(path)}"
    return {"scenarios": num, "n": n, "k": k, "ticks": ticks,
            "bitwise_state": True}


def _timing_fleet(num: int, n: int, k: int, seed0: int = 1000):
    """One-group fleet (framework c, no theta) so batched mode is exactly
    ONE compiled vmap program."""
    problems, r0s = [], []
    for s in range(num):
        adj = random_degree_graph(n, seed0 + s)
        b, c = random_weights(adj, seed=seed0 + s + 500, mean=5.0)
        problems.append(make_problem(c, b, np.ones(k) / k, mu=8.0))
        r0s.append(np.random.default_rng(seed0 + s).integers(0, k, n))
    return problems, [jnp.asarray(r, jnp.int32) for r in r0s]


def time_game_fleet(sizes, n: int = 256, k: int = 8, max_turns: int = 256):
    rows, results = [], []
    ndev = jax.device_count()
    for bsz in sizes:
        problems, r0s = _timing_fleet(bsz, n, k)
        stacked = sweeps.stack_problems(problems)
        r0 = jnp.stack(r0s)

        def looped():
            return [refine_traced(p, r, "c", max_turns=max_turns)
                    for p, r in zip(problems, r0s)]

        def batched():
            return sweeps.refine_traced_batched(stacked, r0, "c",
                                                max_turns=max_turns)

        t_loop = timed(looped, iters=2)
        t_batch = timed(batched, iters=2)
        t_shard = None
        if ndev > 1 and bsz % ndev == 0:
            st_sh = sweeps.shard_across_devices(stacked)
            r0_sh = sweeps.shard_across_devices(r0)

            def sharded():
                return sweeps.refine_traced_batched(st_sh, r0_sh, "c",
                                                    max_turns=max_turns)

            # sharding must not change results: per-element programs are
            # untouched SPMD (§12.5)
            np.testing.assert_array_equal(
                np.asarray(batched()[0].assignment),
                np.asarray(sharded()[0].assignment))
            t_shard = timed(sharded, iters=2)
        best = t_shard if t_shard is not None else t_batch
        ratio = t_loop / best
        rows.append([bsz, n, k, f"{t_loop * 1e3:.0f}",
                     f"{t_batch * 1e3:.0f}",
                     "-" if t_shard is None else f"{t_shard * 1e3:.0f}",
                     f"{ratio:.1f}x"])
        results.append({"batch": bsz, "n": n, "k": k,
                        "turns": max_turns,
                        "looped_ms": t_loop * 1e3,
                        "batched_ms": t_batch * 1e3,
                        "sharded_ms":
                            None if t_shard is None else t_shard * 1e3,
                        "devices": ndev,
                        "speedup": ratio})
    table(["B", "N", "K", "looped ms", "vmap ms",
           f"vmap+shard ms ({ndev} dev)", "speedup"], rows)
    return results


def time_des_fleet(num: int = 4, n: int = 20, k: int = 3, threads: int = 8):
    adjj, cfg, state0 = _des_setup(n, k, threads)
    scheds = _des_schedules(k, num)
    stacked = scenarios.stack_schedules(scheds)
    padded = [scenarios.pad_segments(s, int(stacked.times.shape[1]))
              for s in scheds]
    states = sweeps.stack_pytrees([state0] * num)
    adjs = jnp.stack([adjj] * num)

    def looped():
        return [run_simulation(cfg, adjj, state0, s) for s in padded]

    def batched():
        return run_simulation_batch(cfg, adjs, states, stacked)

    t_loop = timed(looped, iters=1)
    t_batch = timed(batched, iters=1)
    return {"batch": num, "n": n, "k": k, "looped_ms": t_loop * 1e3,
            "batched_ms": t_batch * 1e3, "speedup": t_loop / t_batch}


def run(quick: bool = False, telemetry=None):
    recorder = telemetry_recorder(telemetry, "sweeps")
    section("Gate: batched sweep vs looped refine_traced (bitwise moves)")
    game = check_game_agreement(num=6 if quick else 8,
                                n=64 if quick else 96, recorder=recorder)
    print(f"  {game['cases']} mixed cases agree bitwise; max rel "
          f"potential diff {game['max_rel_potential_diff']:.2e}")

    section("Gate: batched DES fleet vs looped run_simulation (bitwise)")
    des = check_des_agreement(num=2 if quick else 3, recorder=recorder)
    print(f"  {des['scenarios']} scenarios agree bitwise "
          f"(ticks {des['ticks']})")

    section("Throughput: one batched fleet vs B sequential calls")
    sizes = (8, 32) if quick else (8, 32, 128)
    game_timing = time_game_fleet(sizes)
    at32 = next(r for r in game_timing if r["batch"] == 32)
    if not quick and jax.device_count() >= 4:
        # the ISSUE-4 floor presumes batch-parallel hardware; on a
        # 1-device host the ratio is a bandwidth statement, not a batching
        # one (see module docstring) — recorded, not asserted
        assert at32["speedup"] >= SPEEDUP_FLOOR, \
            f"batched speedup {at32['speedup']:.1f}x < {SPEEDUP_FLOOR}x " \
            f"at B=32 (N={at32['n']}, K={at32['k']}, " \
            f"{jax.device_count()} devices)"
    else:
        print(f"  [B=32: {at32['speedup']:.1f}x on {jax.device_count()} "
              f"device(s); the {SPEEDUP_FLOOR}x floor is asserted on "
              f">=4-device hardware — see module docstring]")

    des_timing = None
    if not quick:
        section("Throughput: batched DES fleet (recorded, not gated)")
        des_timing = time_des_fleet()
        print(f"  B={des_timing['batch']}: looped "
              f"{des_timing['looped_ms']:.0f} ms, batched "
              f"{des_timing['batched_ms']:.0f} ms "
              f"({des_timing['speedup']:.1f}x)")

    if recorder is not None:
        recorder.close()
    payload = {"game_agreement": game, "des_agreement": des,
               "game_timing": game_timing, "des_timing": des_timing,
               "quick": quick}
    write_bench_json("sweeps", payload)
    return payload


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv, telemetry=cli_telemetry(sys.argv))
