"""Refinement hot-spot benchmark: fused cost-matrix evaluation.

On CPU the Pallas kernel runs in interpret mode (orders of magnitude slower
than compiled XLA — that is expected and not the signal); the meaningful
CPU-side numbers are (a) the jnp reference throughput, which the kernel is
validated against, and (b) the arithmetic-intensity analysis of the fused
kernel, which predicts TPU behaviour: one adjacency read per sweep instead
of the reference's adjacency read + (N,K) intermediate round-trips.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .common import section, table, timed


def run(quick: bool = False):
    section("Refinement hot-spot: fused cost kernel (dissatisfaction)")
    sizes = [(256, 8), (1024, 16)] if quick else [(256, 8), (1024, 16),
                                                  (4096, 64)]
    rows = []
    results = []
    for n, k in sizes:
        rng = np.random.default_rng(n)
        adj = jnp.asarray(rng.uniform(0, 1, (n, n)) * (rng.random((n, n)) < 0.05),
                          jnp.float32)
        adj = 0.5 * (adj + adj.T)
        r = jnp.asarray(rng.integers(0, k, n), jnp.int32)
        b = jnp.asarray(rng.uniform(0.1, 1, n), jnp.float32)
        loads = jnp.zeros((k,), jnp.float32).at[r].add(b)
        speeds = jnp.full((k,), 1.0 / k, jnp.float32)

        t_ref = timed(lambda: jax.block_until_ready(
            ops.cost_matrix_reference(adj, r, b, loads, speeds, 8.0, "c")))
        flops = 2 * n * n * k                      # A = C @ onehot(r)
        # fused kernel HBM traffic (TPU): adjacency once + cost out
        fused_bytes = 4 * (n * n + n * k)
        # reference traffic: adjacency + onehot + aggregate + cost matrices
        ref_bytes = 4 * (n * n + n * k * 4)
        rows.append([f"{n}x{n} K={k}",
                     f"{t_ref * 1e3:.2f} ms",
                     f"{flops / t_ref / 1e9:.1f}",
                     f"{fused_bytes / 1e6:.2f} MB",
                     f"{ref_bytes / fused_bytes:.2f}x"])
        results.append({"n": n, "k": k, "jnp_ref_ms": t_ref * 1e3,
                        "gflops_cpu": flops / t_ref / 1e9,
                        "fused_hbm_bytes": fused_bytes,
                        "traffic_saving": ref_bytes / fused_bytes})
    table(["problem", "jnp ref (CPU)", "GFLOP/s (CPU)",
           "fused HBM/sweep (TPU)", "traffic saving"], rows)
    print("\nPallas kernel vs jnp oracle correctness: "
          "tests/test_kernels.py (shape/dtype sweeps, hypothesis).")
    return {"cost_matrix": results}


if __name__ == "__main__":
    run()
