"""Distributed refinement runtime: wall-clock + bytes-exchanged scaling.

Two claims measured:

  1. **Wall-clock** — single-controller ``refine`` vs the emulated sharded
     ``refine_distributed`` on the same instances (the protocol overhead
     on one device), plus the real ``shard_map`` driver when this process
     has enough devices (run under
     ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to see it).

  2. **Exchange scaling** — the paper's central claim: per-round
     inter-machine payload is O(K + boundary), independent of N.  We run
     N = 256 → 4096 at fixed K and print measured bytes/round (flat, and
     asserted within 2x) next to the O(N) strawman that re-broadcasts the
     assignment vector every round (grows 16x over the same sweep).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.refine import refine
from repro.distributed import (boundary_stats, ledger_for_run, reconcile,
                               refine_distributed,
                               refine_distributed_shard_map)
from repro.distributed.accounting import naive_broadcast_bytes
from repro.graphs.generators import random_degree_graph, random_weights
from repro.core.problem import make_problem

from .common import cli_telemetry, section, table, telemetry_recorder, timed


def _instance(n: int, k: int, seed: int = 0):
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    prob = make_problem(c, b, np.ones(k) / k, mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, r0


def run(quick: bool = False, telemetry=None):
    k = 8
    sizes = [256, 1024] if quick else [256, 1024, 4096]
    max_turns = 2048
    recorder = telemetry_recorder(telemetry, "distributed")
    payload = {"wall_clock": [], "exchange": []}

    # ---- wall-clock: controller vs sharded ---------------------------------
    section("Distributed refinement: wall-clock (controller vs sharded)")
    rows = []
    for n in sizes:
        prob, r0 = _instance(n, k)
        t_ctrl = timed(lambda: refine(prob, r0, "c", max_turns=max_turns))
        t_dist = timed(lambda: refine_distributed(prob, r0, "c",
                                                  num_shards=k,
                                                  max_turns=max_turns))
        res = refine_distributed(prob, r0, "c", num_shards=k,
                                 max_turns=max_turns)
        rows.append([n, k, f"{t_ctrl * 1e3:.1f}", f"{t_dist * 1e3:.1f}",
                     f"{t_dist / t_ctrl:.2f}x", int(res.num_moves),
                     bool(res.converged)])
        payload["wall_clock"].append(
            {"n": n, "k": k, "controller_ms": t_ctrl * 1e3,
             "sharded_ms": t_dist * 1e3, "moves": int(res.num_moves),
             "converged": bool(res.converged)})
    table(["N", "K", "controller ms", "sharded ms", "ratio", "moves",
           "converged"], rows)

    if len(jax.devices()) >= k:
        rows = []
        for n in sizes[:2]:
            prob, r0 = _instance(n, k)
            t_sm = timed(lambda: refine_distributed_shard_map(
                prob, r0, "c", num_shards=k, max_turns=max_turns))
            rows.append([n, k, f"{t_sm * 1e3:.1f}"])
        table(["N", "K", "shard_map ms"], rows)
    else:
        print(f"[shard_map driver skipped: {len(jax.devices())} device(s); "
              f"run with XLA_FLAGS=--xla_force_host_platform_device_count={k}]")

    # ---- exchange scaling: O(K) vs the O(N) strawman -----------------------
    # bytes/round here are MEASURED from the staged exchange buffers
    # (measure_wire=True) and reconciled against the analytic ledger —
    # a mismatch at any size fails the suite (DESIGN.md §14.5).
    section("Exchange scaling at fixed K: bytes/round vs N (the O(K) claim)")
    rows = []
    per_round = []
    for n in sizes:
        prob, r0 = _instance(n, k)
        res, wire = refine_distributed(prob, r0, "c", num_shards=k,
                                       max_turns=max_turns,
                                       measure_wire=True, recorder=recorder)
        stats = boundary_stats(prob, k)
        led = ledger_for_run(stats, k, rounds=int(res.num_turns))
        check = reconcile(led, wire)
        assert check.ok, f"n={n}: {check.summary()}"
        measured_per_round = (int(wire.payload_bytes)
                              / max(int(wire.rounds), 1))
        per_round.append(measured_per_round)
        rows.append([n, int(res.num_turns), f"{measured_per_round:.0f}",
                     led.ghost_sync_bytes,
                     naive_broadcast_bytes(n, k),
                     f"{naive_broadcast_bytes(n, k) / measured_per_round:.0f}x"])
        payload["exchange"].append(
            {"n": n, "rounds": int(res.num_turns),
             "bytes_per_round": measured_per_round,
             "predicted_bytes_per_round": led.per_round_bytes,
             "measured_matches_ledger": check.ok,
             "ghost_sync_bytes": led.ghost_sync_bytes,
             "naive_bytes_per_round": naive_broadcast_bytes(n, k)})
    table(["N", "rounds", "B/round (measured)", "ghost sync B (one-time)",
           "B/round (naive O(N))", "naive/ours"], rows)
    print("measured bytes/round == analytic ledger at every size "
          f"(reconciled, N={sizes})")
    spread = max(per_round) / min(per_round)
    print(f"bytes/round spread over {sizes[0]}->{sizes[-1]}: "
          f"{spread:.2f}x (claim: <= 2x, N-independent)")
    assert spread <= 2.0, f"per-round payload not flat: {per_round}"
    payload["bytes_per_round_spread"] = spread
    if recorder is not None:
        recorder.close()
    return payload


if __name__ == "__main__":
    import sys
    run(quick=True, telemetry=cli_telemetry(sys.argv))
