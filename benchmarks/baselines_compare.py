"""Game-theoretic refinement vs the §2 literature baselines.

Compares C_0 / Ct_0 / cut / weighted load imbalance at convergence against:
random, greedy LPT (load-only), Kernighan–Lin (cut-only), spectral
bisection, and Nandy–Loucks gain-only single-migration (the paper's closest
prior work).  Also measures the §4.4 escape mechanisms (annealing, cluster
moves) on top of the Nash equilibrium.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.annealing import simulated_annealing
from repro.core.cluster import cluster_move_pass
from repro.core.initial import initial_partition
from repro.core.problem import make_problem
from repro.core.refine import refine
from repro.graphs.generators import random_degree_graph, random_weights
from repro.partitioners import baselines

from .common import section, table


def _metrics(prob, assignment):
    a = jnp.asarray(assignment, jnp.int32)
    c0 = float(costs.global_cost_c0(prob, a))
    ct0 = float(costs.global_cost_ct0(prob, a))
    cut = float(costs.total_cut(prob.adjacency, a))
    imb = float(costs.load_imbalance(prob, a)) * prob.num_machines
    return c0, ct0, cut, imb


def run(quick: bool = False):
    section("Game refinement vs centralized baselines (§2)")
    n = 120 if quick else 230
    k = 5
    adj = random_degree_graph(n, seed=1, dmin=3, dmax=6)
    b, c = random_weights(adj, seed=2, mean=5.0)
    prob = make_problem(c, b, np.ones(k) / k, mu=8.0)
    r0 = np.asarray(initial_partition(jnp.asarray(adj), k,
                                      jax.random.PRNGKey(0)))

    game = refine(prob, jnp.asarray(r0), "c", max_turns=4000)
    game_r = np.asarray(game.assignment)

    anneal = simulated_annealing(prob, game.assignment,
                                 jax.random.PRNGKey(1),
                                 steps=512 if quick else 2048)
    cluster = cluster_move_pass(prob, game.assignment, "c", hops=1)

    candidates = {
        "initial (App. A expansion)": r0,
        "random": baselines.random_partition(n, k, 3),
        "greedy LPT (load only)": baselines.greedy_load_partition(
            np.asarray(prob.node_weights), np.ones(k) / k),
        "Kernighan-Lin (cut only)": baselines.kernighan_lin_refine(
            np.asarray(prob.adjacency), r0),
        "spectral bisection": baselines.spectral_bisection(
            np.asarray(prob.adjacency), k),
        "Nandy-Loucks 1993": baselines.nandy_loucks_refine(
            np.asarray(prob.adjacency), r0),
        "GAME refine (C_i)": game_r,
        "GAME + annealing (§4.4)": np.asarray(anneal.assignment),
        "GAME + cluster move (§7)": np.asarray(cluster.assignment),
    }
    rows = []
    for name, r in candidates.items():
        c0, ct0, cut, imb = _metrics(prob, r)
        rows.append([name, f"{c0:.0f}", f"{ct0:.0f}", f"{cut:.0f}",
                     f"{imb:.2f}"])
    table(["partitioner", "C_0", "Ct_0", "cut", "max-load/ideal"], rows)
    print("\nthe game descends C_0 with machine-level state only; "
          "cut-only baselines ignore load and load-only ignores the cut.")
    return dict(zip(candidates, rows))


if __name__ == "__main__":
    run()
