"""Beyond-paper benchmark: the partition game as an MoE expert placer and
pipeline-stage balancer (DESIGN.md §4).

Expert placement: skewed (Zipf) expert loads with block co-activation;
reports weighted-load imbalance and cross-group co-activation traffic
before/after the game, vs a random and a greedy (sorted round-robin)
placement.  Pipeline stages: heterogeneous layer costs vs the interval-DP
optimum.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.sharding.planner import expert_placement, stage_assignment

from .common import section, table


def _imbalance(load, assign, g):
    per = np.zeros(g)
    np.add.at(per, assign, load)
    return per.max() / (load.sum() / g)


def _cross_traffic(coact, assign):
    diff = assign[:, None] != assign[None, :]
    return float((coact * diff).sum() / 2)


def run(quick: bool = False):
    section("Expert placement via the partition game (MoE EP)")
    rng = np.random.default_rng(0)
    e, g = (32, 4) if quick else (128, 16)
    # Zipf-skewed loads + block co-activation (correlated expert pairs)
    load = (1.0 / np.arange(1, e + 1) ** 1.1).astype(np.float32)
    load = load / load.sum() * e
    coact = np.zeros((e, e), np.float32)
    for blk in range(0, e, 8):
        idx = np.arange(blk, min(blk + 8, e))
        coact[np.ix_(idx, idx)] = rng.uniform(0.5, 1.0, (idx.size, idx.size))
    np.fill_diagonal(coact, 0)
    coact = 0.5 * (coact + coact.T)

    naive = np.arange(e) % g                          # hot experts colocated
    greedy = np.empty(e, np.int64)                    # sorted round-robin
    order = np.argsort(-load)
    per = np.zeros(g)
    for i in order:
        j = int(np.argmin(per))
        greedy[i] = j
        per[j] += load[i]

    perm, game, stats = expert_placement(jnp.asarray(load),
                                         jnp.asarray(coact), g, mu=1.0,
                                         current=jnp.asarray(naive, jnp.int32))
    game = np.asarray(game)
    rows = []
    for name, a in (("naive (id % G)", naive), ("greedy LPT", greedy),
                    ("GAME (Nash refine + repair)", game)):
        rows.append([name, f"{_imbalance(load, a, g):.3f}",
                     f"{_cross_traffic(coact, a):.1f}"])
    table(["placement", "weighted imbalance (1.0 = perfect)",
           "cross-group co-activation"], rows)
    print(f"game moves: {stats['moves']}; imbalance "
          f"{stats['imbalance_before']:.3f} -> {stats['imbalance_after']:.3f}")

    section("Pipeline-stage assignment via the partition game (PP)")
    L, S = (24, 4) if quick else (94, 8)
    cost = rng.uniform(1.0, 1.2, L).astype(np.float32)
    cost[:: max(L // 6, 1)] *= 3.0                    # heavy layers
    assign, game_max, dp_max = stage_assignment(cost, 4.0, S)
    rows = [["interval DP (oracle)", f"{dp_max:.2f}", "-"],
            ["GAME (contiguous projection)", f"{game_max:.2f}",
             f"{100 * (game_max / dp_max - 1):.1f}%"]]
    table(["stage balancer", "max stage load", "gap vs optimal"], rows)
    return {"imbalance_game": _imbalance(load, game, g),
            "pp_gap": game_max / dp_max - 1}


if __name__ == "__main__":
    run()
