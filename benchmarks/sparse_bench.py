"""Sparse edge-list runtime vs dense: agreement + lifting the O(N^2) ceiling.

Two claims measured (ISSUE 5 acceptance criteria, DESIGN.md §13):

  1. **Agreement** — sparse refinement (``SparseProblem`` through
     ``refine_traced``) must reproduce the dense path's ACCEPTED-MOVE
     sequence exactly (same turns, nodes, destinations — matched §7
     tie-breaking) on the bench grid (N = 256..4096, K = 8, both
     frameworks, theta on and off), with both carried potentials within
     the repo's standing ≤ 1e-3 relative budget.  The fused edge-block
     kernel (``make_edge_dissat_fn``) is additionally gated against the
     jnp sparse path at the smallest size.  Asserted on every run (CI
     runs ``--quick``); any residual divergence policy is documented in
     DESIGN.md §13.3.

  2. **Scaling** — per-turn sparse refinement cost from N=4096 to
     N=262144 (quick: to 16384).  The dense path is measured where its
     (N, N) adjacency is cheap, and recorded as infeasible where the
     adjacency alone exceeds host memory: at N=262144 it needs ~275 GB —
     no amount of patience recovers that on this class of host, which is
     the ceiling this runtime removes.  The full run asserts the top
     size is dense-infeasible, or — on a >256 GiB host where it would
     fit — that sparse is ≥5x faster end to end (incl. setup) at the
     largest size where the dense path is actually measured.

Results land in BENCH_sparse.json (CI uploads it as an artifact).
"""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.problem import make_problem
from repro.core.refine import refine, refine_traced
from repro.core.sparse import make_sparse_problem, sparse_from_dense
from repro.graphs.generators import (random_degree_graph,
                                     random_degree_graph_edges,
                                     random_weights, random_weights_edges)
from repro.kernels.ops import make_edge_dissat_fn

from .common import (cli_telemetry, section, table, telemetry_recorder,
                     timed, write_bench_json)

AGREE_TOL = 1e-3          # max relative potential deviation (repo budget)
SPEEDUP_FLOOR = 5.0       # dense must be infeasible or 5x slower on top size
THETAS = (None, 0.5)


def _host_memory_bytes() -> int:
    try:
        return os.sysconf("SC_PHYS_PAGES") * os.sysconf("SC_PAGE_SIZE")
    except (ValueError, OSError):  # pragma: no cover - exotic hosts
        return 1 << 34


def _dense_instance(n: int, k: int, seed: int = 0):
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    prob = make_problem(c, b, np.ones(k) / k, mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, r0


def _sparse_instance(n: int, k: int, seed: int = 0):
    s, r = random_degree_graph_edges(n, seed=seed)
    b, w = random_weights_edges(n, s, seed=seed + 1, mean=5.0)
    prob = make_sparse_problem(s, r, w, b, np.ones(k) / k, mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, r0


def check_agreement(sizes=(256, 1024), k: int = 8, max_turns: int = 256,
                    recorder=None):
    """Gate 1: sparse == dense accepted-move sequences on the grid.

    ``recorder`` instruments the sparse side of the smallest
    (theta=None, framework=c) cell — enough to replay the sparse
    convergence trace from the log without multiplying the grid's event
    volume."""
    out = []
    for n in sizes:
        prob, r0 = _dense_instance(n, k)
        sp = sparse_from_dense(prob)
        for fw in ("c", "ct"):
            for theta in THETAS:
                rec = (recorder if n == sizes[0] and fw == "c"
                       and theta is None else None)
                res_d, tr_d = refine_traced(prob, r0, fw,
                                            max_turns=max_turns, theta=theta)
                res_s, tr_s = refine_traced(sp, r0, fw,
                                            max_turns=max_turns, theta=theta,
                                            recorder=rec)
                tag = f"n={n} fw={fw} theta={theta}"
                for field in ("moved", "node", "source", "dest"):
                    a = np.asarray(getattr(tr_s, field))
                    b = np.asarray(getattr(tr_d, field))
                    assert np.array_equal(a, b), \
                        f"{tag}: sparse {field} sequence diverged at " \
                        f"turns {np.flatnonzero(a != b)[:5]}"
                assert np.array_equal(np.asarray(res_s.assignment),
                                      np.asarray(res_d.assignment)), tag
                rel = {}
                for pot in ("c0", "ct0"):
                    a = np.asarray(getattr(tr_s, pot), np.float64)
                    b = np.asarray(getattr(tr_d, pot), np.float64)
                    rel[pot] = float(np.max(np.abs(a - b)
                                            / np.maximum(np.abs(b), 1.0)))
                    assert rel[pot] <= AGREE_TOL, \
                        f"{tag}: {pot} drifted {rel[pot]:.2e} > {AGREE_TOL}"
                out.append({"n": n, "k": k, "framework": fw,
                            "theta": theta, "moves": int(res_s.num_moves),
                            "moves_equal": True,
                            "rel_potential_diff": rel})
    # the fused edge-block kernel must reproduce the jnp sparse path
    prob, r0 = _dense_instance(sizes[0], k, seed=7)
    sp = sparse_from_dense(prob)
    res_j = refine(sp, r0, "c")
    res_k = refine(sp, r0, "c", dissat_fn=make_edge_dissat_fn(sp))
    assert int(res_j.num_moves) == int(res_k.num_moves), \
        (int(res_j.num_moves), int(res_k.num_moves))
    assert np.array_equal(np.asarray(res_j.assignment),
                          np.asarray(res_k.assignment)), \
        "edge-block kernel diverged from the jnp sparse path"
    return {"grid": out, "edge_kernel_moves": int(res_k.num_moves),
            "edge_kernel_equal": True}


def scaling(sizes, k: int = 8, timing_turns: int = 16,
            dense_limit: int = 16384):
    """Gate 2: sparse per-turn cost vs N; dense measured where cheap,
    recorded infeasible where the adjacency exceeds host memory."""
    mem = _host_memory_bytes()
    rows, results = [], []
    for n in sizes:
        sp, r0 = _sparse_instance(n, k)
        t_sparse = timed(lambda: refine_traced(sp, r0, "c",
                                               max_turns=timing_turns),
                         iters=2)
        per_sparse = t_sparse / timing_turns * 1e3
        sparse_bytes = sum(int(np.asarray(x).nbytes) for x in
                           (sp.senders, sp.receivers, sp.edge_weights,
                            sp.row_start, sp.node_weights))
        dense_bytes = 4 * n * n
        entry = {"n": n, "k": k,
                 "edges_padded": sp.num_edges,
                 "max_degree": sp.max_degree,
                 "per_turn_sparse_ms": per_sparse,
                 "sparse_problem_bytes": sparse_bytes,
                 "dense_adjacency_bytes": dense_bytes,
                 "host_memory_bytes": mem,
                 "dense_feasible": dense_bytes < mem}
        if n <= dense_limit and entry["dense_feasible"]:
            prob, r0d = _dense_instance(n, k)
            t_dense = timed(lambda: refine_traced(prob, r0d, "c",
                                                  max_turns=timing_turns),
                            iters=2)
            entry["per_turn_dense_ms"] = t_dense / timing_turns * 1e3
            dense_cell = f"{entry['per_turn_dense_ms']:.2f}"
        else:
            entry["per_turn_dense_ms"] = None
            dense_cell = (f"OOM ({dense_bytes / 2**30:.0f} GiB adj "
                          f"> {mem / 2**30:.0f} GiB RAM)"
                          if not entry["dense_feasible"] else "skipped")
        rows.append([n, sp.num_edges, f"{per_sparse:.2f}", dense_cell,
                     f"{sparse_bytes / 2**20:.1f}",
                     f"{dense_bytes / 2**20:.0f}"])
        results.append(entry)
    table(["N", "E(pad)", "sparse ms/turn", "dense ms/turn",
           "sparse MiB", "dense adj MiB"], rows)
    print(f"ms/turn = wall / {timing_turns} turns, so the one-time "
          "aggregate init is amortized in — that O(N^2 K) matmul (vs the "
          "sparse path's O(E K) segment sum) is most of the dense gap "
          "here; steady-state per-turn work is O(N K) either way "
          "(DESIGN.md §13.3).")
    return results


def run(quick: bool = False, telemetry=None):
    k = 8
    agree_sizes = (256, 1024) if quick else (256, 1024, 4096)
    scale_sizes = [4096, 16384] if quick else [4096, 16384, 65536, 262144]
    recorder = telemetry_recorder(telemetry, "sparse")

    section("Sparse vs dense: accepted-move agreement (grid)")
    agreement = check_agreement(sizes=agree_sizes, k=k, recorder=recorder)
    for st in agreement["grid"]:
        print(f"  [n={st['n']} {st['framework']} theta={st['theta']}] "
              f"moves {st['moves']} identical; rel potential diff "
              f"c0={st['rel_potential_diff']['c0']:.2e} "
              f"ct0={st['rel_potential_diff']['ct0']:.2e}")
    print(f"  edge-block kernel: {agreement['edge_kernel_moves']} moves, "
          "identical to jnp sparse path")

    section("Scaling: per-turn refinement cost, sparse vs dense ceiling")
    results = scaling(scale_sizes, k=k)

    if not quick:
        top = results[-1]
        assert top["n"] >= 65536, top["n"]
        if not top["dense_feasible"]:
            print(f"\nN={top['n']}: dense adjacency alone needs "
                  f"{top['dense_adjacency_bytes'] / 2**30:.0f} GiB "
                  f"(> {top['host_memory_bytes'] / 2**30:.0f} GiB host "
                  f"RAM); sparse ran at "
                  f"{top['per_turn_sparse_ms']:.2f} ms/turn in "
                  f"{top['sparse_problem_bytes'] / 2**20:.1f} MiB")
        else:
            # a host with > 256 GiB RAM CAN hold the top-size adjacency;
            # the dense run is still not measured there (generation alone
            # materializes several (N, N) temporaries), so gate on the
            # largest size where dense WAS measured instead
            measured = [e for e in results
                        if e["per_turn_dense_ms"] is not None]
            assert measured, "dense feasible at top size but measured " \
                             "nowhere — raise dense_limit"
            ref = measured[-1]
            ratio = ref["per_turn_dense_ms"] / ref["per_turn_sparse_ms"]
            assert ratio >= SPEEDUP_FLOOR, \
                f"dense only {ratio:.1f}x slower (< {SPEEDUP_FLOOR}x) " \
                f"at N={ref['n']} and feasible at N={top['n']}"
            print(f"\nhuge host: dense fits at N={top['n']} but is "
                  f"{ratio:.1f}x slower at the largest measured size "
                  f"(N={ref['n']})")

    if recorder is not None:
        recorder.close()
    payload = {"agreement": agreement, "scaling": results,
               "backend_devices": jax.device_count()}
    write_bench_json("sparse", payload)
    return payload


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv, telemetry=cli_telemetry(sys.argv))
