"""Sparse edge-list runtime vs dense: agreement + lifting the O(N^2) ceiling.

Two claims measured (ISSUE 5 acceptance criteria, DESIGN.md §13):

  1. **Agreement** — sparse refinement (``SparseProblem`` through
     ``refine_traced``) must reproduce the dense path's ACCEPTED-MOVE
     sequence exactly (same turns, nodes, destinations — matched §7
     tie-breaking) on the bench grid (N = 256..4096, K = 8, both
     frameworks, theta on and off), with both carried potentials within
     the repo's standing ≤ 1e-3 relative budget.  The fused edge-block
     kernel (``make_edge_dissat_fn``) is additionally gated against the
     jnp sparse path at the smallest size.  Asserted on every run (CI
     runs ``--quick``); any residual divergence policy is documented in
     DESIGN.md §13.3.

  2. **Scaling** — per-turn sparse refinement cost from N=4096 to
     N=262144 (quick: to 16384).  The dense path is measured where its
     (N, N) adjacency is cheap, and recorded as infeasible where the
     adjacency alone exceeds host memory: at N=262144 it needs ~275 GB —
     no amount of patience recovers that on this class of host, which is
     the ceiling this runtime removes.  The full run asserts the top
     size is dense-infeasible, or — on a >256 GiB host where it would
     fit — that sparse is ≥5x faster end to end (incl. setup) at the
     largest size where the dense path is actually measured.

  3. **Sweeps** (ISSUE 9, DESIGN.md §17) — the multi-move probabilistic
     sweep mode: (a) the degenerate config (one move/machine, move_prob
     1, ε=0) reproduces ``refine_simultaneous`` BITWISE on dense and
     sparse problems, looped and batched; (b) unbounded multi-move
     sweeps reach an ε-equilibrium in fewer sweeps than the
     one-move-per-machine rule (quick: ratio > 1 at N=16384; full:
     ratio ≥ 5 at N=65536); (c) full runs equilibrate an N=10^6
     ``SparseProblem`` in ≤ 10 s wall-clock on one device, recorded as
     a scaling row with sweeps-to-equilibrium and moves/sweep.

Results land in BENCH_sparse.json (CI uploads it as an artifact).
"""
from __future__ import annotations

import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.batch import (refine_simultaneous_batched,
                              refine_sweeps_batched, stack_problems)
from repro.core.problem import make_problem
from repro.core.refine import (refine, refine_simultaneous, refine_sweeps,
                               refine_traced)
from repro.core.sparse import make_sparse_problem, sparse_from_dense
from repro.graphs.generators import (random_degree_graph,
                                     random_degree_graph_edges,
                                     random_weights, random_weights_edges)
from repro.kernels.ops import make_edge_dissat_fn

from .common import (cli_telemetry, section, table, telemetry_recorder,
                     timed, write_bench_json)

AGREE_TOL = 1e-3          # max relative potential deviation (repo budget)
SPEEDUP_FLOOR = 5.0       # dense must be infeasible or 5x slower on top size
THETAS = (None, 0.5)
SWEEP_RATIO_FLOOR = 5.0   # full-run multi-vs-single sweep count at N=65536
MILLION_WALL_S = 10.0     # N=10^6 equilibrium budget (ISSUE 9 acceptance)
SWEEP_CFG = dict(moves_per_machine=None, move_prob=0.5, epsilon=1e-3)


def _host_memory_bytes() -> int:
    try:
        return os.sysconf("SC_PHYS_PAGES") * os.sysconf("SC_PAGE_SIZE")
    except (ValueError, OSError):  # pragma: no cover - exotic hosts
        return 1 << 34


def _dense_instance(n: int, k: int, seed: int = 0):
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    prob = make_problem(c, b, np.ones(k) / k, mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, r0


def _sparse_instance(n: int, k: int, seed: int = 0):
    s, r = random_degree_graph_edges(n, seed=seed)
    b, w = random_weights_edges(n, s, seed=seed + 1, mean=5.0)
    prob = make_sparse_problem(s, r, w, b, np.ones(k) / k, mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, r0


def check_agreement(sizes=(256, 1024), k: int = 8, max_turns: int = 256,
                    recorder=None):
    """Gate 1: sparse == dense accepted-move sequences on the grid.

    ``recorder`` instruments the sparse side of the smallest
    (theta=None, framework=c) cell — enough to replay the sparse
    convergence trace from the log without multiplying the grid's event
    volume."""
    out = []
    for n in sizes:
        prob, r0 = _dense_instance(n, k)
        sp = sparse_from_dense(prob)
        for fw in ("c", "ct"):
            for theta in THETAS:
                rec = (recorder if n == sizes[0] and fw == "c"
                       and theta is None else None)
                res_d, tr_d = refine_traced(prob, r0, fw,
                                            max_turns=max_turns, theta=theta)
                res_s, tr_s = refine_traced(sp, r0, fw,
                                            max_turns=max_turns, theta=theta,
                                            recorder=rec)
                tag = f"n={n} fw={fw} theta={theta}"
                for field in ("moved", "node", "source", "dest"):
                    a = np.asarray(getattr(tr_s, field))
                    b = np.asarray(getattr(tr_d, field))
                    assert np.array_equal(a, b), \
                        f"{tag}: sparse {field} sequence diverged at " \
                        f"turns {np.flatnonzero(a != b)[:5]}"
                assert np.array_equal(np.asarray(res_s.assignment),
                                      np.asarray(res_d.assignment)), tag
                rel = {}
                for pot in ("c0", "ct0"):
                    a = np.asarray(getattr(tr_s, pot), np.float64)
                    b = np.asarray(getattr(tr_d, pot), np.float64)
                    rel[pot] = float(np.max(np.abs(a - b)
                                            / np.maximum(np.abs(b), 1.0)))
                    assert rel[pot] <= AGREE_TOL, \
                        f"{tag}: {pot} drifted {rel[pot]:.2e} > {AGREE_TOL}"
                out.append({"n": n, "k": k, "framework": fw,
                            "theta": theta, "moves": int(res_s.num_moves),
                            "moves_equal": True,
                            "rel_potential_diff": rel})
    # the fused edge-block kernel must reproduce the jnp sparse path
    prob, r0 = _dense_instance(sizes[0], k, seed=7)
    sp = sparse_from_dense(prob)
    res_j = refine(sp, r0, "c")
    res_k = refine(sp, r0, "c", dissat_fn=make_edge_dissat_fn(sp))
    assert int(res_j.num_moves) == int(res_k.num_moves), \
        (int(res_j.num_moves), int(res_k.num_moves))
    assert np.array_equal(np.asarray(res_j.assignment),
                          np.asarray(res_k.assignment)), \
        "edge-block kernel diverged from the jnp sparse path"
    return {"grid": out, "edge_kernel_moves": int(res_k.num_moves),
            "edge_kernel_equal": True}


def scaling(sizes, k: int = 8, timing_turns: int = 16,
            dense_limit: int = 16384):
    """Gate 2: sparse per-turn cost vs N; dense measured where cheap,
    recorded infeasible where the adjacency exceeds host memory."""
    mem = _host_memory_bytes()
    rows, results = [], []
    for n in sizes:
        sp, r0 = _sparse_instance(n, k)
        t_sparse = timed(lambda: refine_traced(sp, r0, "c",
                                               max_turns=timing_turns),
                         iters=2)
        per_sparse = t_sparse / timing_turns * 1e3
        sparse_bytes = sum(int(np.asarray(x).nbytes) for x in
                           (sp.senders, sp.receivers, sp.edge_weights,
                            sp.row_start, sp.node_weights))
        dense_bytes = 4 * n * n
        entry = {"n": n, "k": k,
                 "edges_padded": sp.num_edges,
                 "max_degree": sp.max_degree,
                 "per_turn_sparse_ms": per_sparse,
                 "sparse_problem_bytes": sparse_bytes,
                 "dense_adjacency_bytes": dense_bytes,
                 "host_memory_bytes": mem,
                 "dense_feasible": dense_bytes < mem}
        if n <= dense_limit and entry["dense_feasible"]:
            prob, r0d = _dense_instance(n, k)
            t_dense = timed(lambda: refine_traced(prob, r0d, "c",
                                                  max_turns=timing_turns),
                            iters=2)
            entry["per_turn_dense_ms"] = t_dense / timing_turns * 1e3
            dense_cell = f"{entry['per_turn_dense_ms']:.2f}"
        else:
            entry["per_turn_dense_ms"] = None
            dense_cell = (f"OOM ({dense_bytes / 2**30:.0f} GiB adj "
                          f"> {mem / 2**30:.0f} GiB RAM)"
                          if not entry["dense_feasible"] else "skipped")
        rows.append([n, sp.num_edges, f"{per_sparse:.2f}", dense_cell,
                     f"{sparse_bytes / 2**20:.1f}",
                     f"{dense_bytes / 2**20:.0f}"])
        results.append(entry)
    table(["N", "E(pad)", "sparse ms/turn", "dense ms/turn",
           "sparse MiB", "dense adj MiB"], rows)
    print(f"ms/turn = wall / {timing_turns} turns, so the one-time "
          "aggregate init is amortized in — that O(N^2 K) matmul (vs the "
          "sparse path's O(E K) segment sum) is most of the dense gap "
          "here; steady-state per-turn work is O(N K) either way "
          "(DESIGN.md §13.3).")
    return results


def _assert_bitwise(res_a, aux_a, res_b, aux_b, tag: str):
    """Full bitwise equality of two refinement runs: final assignment,
    move/turn counters, and all three per-sweep traces."""
    assert np.array_equal(np.asarray(res_a.assignment),
                          np.asarray(res_b.assignment)), \
        f"{tag}: assignments diverged"
    for name in ("num_moves", "num_turns", "converged"):
        a = np.asarray(getattr(res_a, name))
        b = np.asarray(getattr(res_b, name))
        assert np.array_equal(a, b), f"{tag}: {name} {a} != {b}"
    for name, a, b in zip(("c0s", "ct0s", "active"), aux_a, aux_b):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"{tag}: {name} trace diverged"


def check_sweeps_degenerate(n: int = 256, k: int = 8, max_sweeps: int = 64):
    """Sweeps gate (a): the degenerate config — one move per machine,
    move_prob 1, ε=0, i.e. ``refine_sweeps``'s defaults — must BITWISE
    reproduce ``refine_simultaneous`` (DESIGN.md §17.2): dense and
    sparse representations, looped and batched."""
    cells = []
    prob, r0 = _dense_instance(n, k)
    sp = sparse_from_dense(prob)
    for rep, problem in (("dense", prob), ("sparse", sp)):
        for fw in ("c", "ct"):
            tag = f"degenerate {rep} fw={fw}"
            res_s, aux_s = refine_simultaneous(problem, r0, fw,
                                               max_sweeps=max_sweeps)
            res_w, aux_w = refine_sweeps(problem, r0, fw,
                                         max_sweeps=max_sweeps)
            _assert_bitwise(res_s, aux_s, res_w, aux_w, tag)
            cells.append({"rep": rep, "framework": fw,
                          "moves": int(res_w.num_moves), "bitwise": True})
    # batched: a dense fleet (independent instances) and a sparse fleet
    # (stack_problems needs one shared edge structure, so vary weights)
    dense = [_dense_instance(n, k, seed=s) for s in (0, 3, 6)]
    probs_d = stack_problems([p for p, _ in dense])
    r0s_d = jnp.stack([r for _, r in dense])
    s_idx, r_idx = random_degree_graph_edges(n, seed=0)
    sparse, r0s_s = [], []
    for ws in (1, 11, 21):
        b, w = random_weights_edges(n, s_idx, seed=ws, mean=5.0)
        sparse.append(make_sparse_problem(s_idx, r_idx, w, b,
                                          np.ones(k) / k, mu=8.0))
        r0s_s.append(np.random.default_rng(ws + 1).integers(0, k, n))
    probs_s = stack_problems(sparse)
    r0s_s = jnp.asarray(np.stack(r0s_s), jnp.int32)
    for rep, probs, r0s in (("dense", probs_d, r0s_d),
                            ("sparse", probs_s, r0s_s)):
        for fw in ("c", "ct"):
            tag = f"degenerate batched {rep} fw={fw}"
            res_s, aux_s = refine_simultaneous_batched(
                probs, r0s, fw, max_sweeps=max_sweeps)
            res_w, aux_w = refine_sweeps_batched(
                probs, r0s, fw, max_sweeps=max_sweeps)
            _assert_bitwise(res_s, aux_s, res_w, aux_w, tag)
            cells.append({"rep": f"batched-{rep}", "framework": fw,
                          "moves": [int(m) for m in
                                    np.asarray(res_w.num_moves)],
                          "bitwise": True})
    return {"n": n, "k": k, "max_sweeps": max_sweeps, "cells": cells,
            "bitwise_equal": True}


def sweeps_ratio(n: int, k: int = 8, multi_cap: int = 128,
                 single_cap: int = 512, floor: float = 1.0):
    """Sweeps gate (b): unbounded multi-move sweeps vs the
    one-move-per-machine rule, sweeps to the SAME ε-equilibrium
    (ε=1e-3; single-move runs ``refine_sweeps`` with M=1, p=1 so both
    modes stop at the identical no-improving-move-above-ε test).

    The start is the paper's dynamic-load-balancing scenario: a load
    shift has left 65% of the nodes on one machine, so a θ(N)
    migration is required.  One-move-per-machine admits at most K
    moves per sweep — O(N/K) sweeps — while the multi-move mode moves
    whole cohorts per sweep.  If the single-move run exhausts its cap
    unconverged, the cap is a LOWER bound on its sweep count — the
    reported ratio only understates."""
    sp, _ = _sparse_instance(n, k)
    pvals = np.full(k, 0.35 / (k - 1))
    pvals[0] = 0.65
    r0 = jnp.asarray(np.random.default_rng(2).choice(k, size=n, p=pvals),
                     jnp.int32)
    res_m, _ = refine_sweeps(sp, r0, "c", max_sweeps=multi_cap,
                             key=jax.random.PRNGKey(0), **SWEEP_CFG)
    sweeps_m = int(res_m.num_turns)
    assert bool(res_m.converged), \
        f"multi-move unconverged in {multi_cap} sweeps at n={n}"
    res_1, _ = refine_sweeps(sp, r0, "c", max_sweeps=single_cap,
                             epsilon=SWEEP_CFG["epsilon"])
    sweeps_1 = int(res_1.num_turns)
    ratio = sweeps_1 / max(1, sweeps_m)
    entry = {"n": n, "k": k, "epsilon": SWEEP_CFG["epsilon"],
             "multi_sweeps": sweeps_m, "multi_moves": int(res_m.num_moves),
             "single_sweeps": sweeps_1,
             "single_converged": bool(res_1.converged),
             "single_sweeps_is_lower_bound": not bool(res_1.converged),
             "ratio": ratio, "floor": floor}
    assert ratio > floor, \
        f"multi-move only {ratio:.1f}x fewer sweeps (need > {floor}) " \
        f"at n={n}: {sweeps_m} vs {sweeps_1}"
    bound = "" if entry["single_converged"] else " (>=, cap hit)"
    print(f"  n={n}: multi-move {sweeps_m} sweeps "
          f"({entry['multi_moves']} moves) vs single-move "
          f"{sweeps_1}{bound} -> {ratio:.1f}x fewer (floor {floor})")
    return entry


def million_row(k: int = 8):
    """Sweeps gate (c): N=10^6 to ε-equilibrium in ≤ 10 s wall on one
    device (ISSUE 9 acceptance).  The first call pays compilation and
    instance setup; the recorded wall is the steady re-run, matching
    the per-turn convention of the scaling table."""
    n = 1_000_000
    sp, r0 = _sparse_instance(n, k)
    key = jax.random.PRNGKey(0)

    def go():
        res, aux = refine_sweeps(sp, r0, "c", max_sweeps=24, key=key,
                                 **SWEEP_CFG)
        jax.block_until_ready(res.assignment)
        return res, aux

    go()  # compile
    t0 = time.perf_counter()
    res, _ = go()
    wall = time.perf_counter() - t0
    sweeps = int(res.num_turns)
    moves = int(res.num_moves)
    assert bool(res.converged), \
        f"N=1e6 unconverged after {sweeps} sweeps ({moves} moves)"
    assert wall <= MILLION_WALL_S, \
        f"N=1e6 equilibrium took {wall:.2f}s > {MILLION_WALL_S}s"
    row = {"n": n, "k": k, "edges_padded": sp.num_edges,
           "max_degree": sp.max_degree, "mode": "sweeps-unbounded",
           "move_prob": SWEEP_CFG["move_prob"],
           "epsilon": SWEEP_CFG["epsilon"],
           "sweeps_to_equilibrium": sweeps, "moves": moves,
           "moves_per_sweep": moves / max(1, sweeps),
           "wall_s": wall, "converged": True}
    print(f"  N={n}: equilibrium in {sweeps} sweeps ({moves} moves, "
          f"{row['moves_per_sweep']:.1f}/sweep), {wall:.2f}s wall "
          f"(budget {MILLION_WALL_S:.0f}s)")
    return row


def run(quick: bool = False, telemetry=None):
    k = 8
    agree_sizes = (256, 1024) if quick else (256, 1024, 4096)
    scale_sizes = [4096, 16384] if quick else [4096, 16384, 65536, 262144]
    recorder = telemetry_recorder(telemetry, "sparse")

    section("Sparse vs dense: accepted-move agreement (grid)")
    agreement = check_agreement(sizes=agree_sizes, k=k, recorder=recorder)
    for st in agreement["grid"]:
        print(f"  [n={st['n']} {st['framework']} theta={st['theta']}] "
              f"moves {st['moves']} identical; rel potential diff "
              f"c0={st['rel_potential_diff']['c0']:.2e} "
              f"ct0={st['rel_potential_diff']['ct0']:.2e}")
    print(f"  edge-block kernel: {agreement['edge_kernel_moves']} moves, "
          "identical to jnp sparse path")

    section("Scaling: per-turn refinement cost, sparse vs dense ceiling")
    results = scaling(scale_sizes, k=k)

    if not quick:
        top = results[-1]
        assert top["n"] >= 65536, top["n"]
        if not top["dense_feasible"]:
            print(f"\nN={top['n']}: dense adjacency alone needs "
                  f"{top['dense_adjacency_bytes'] / 2**30:.0f} GiB "
                  f"(> {top['host_memory_bytes'] / 2**30:.0f} GiB host "
                  f"RAM); sparse ran at "
                  f"{top['per_turn_sparse_ms']:.2f} ms/turn in "
                  f"{top['sparse_problem_bytes'] / 2**20:.1f} MiB")
        else:
            # a host with > 256 GiB RAM CAN hold the top-size adjacency;
            # the dense run is still not measured there (generation alone
            # materializes several (N, N) temporaries), so gate on the
            # largest size where dense WAS measured instead
            measured = [e for e in results
                        if e["per_turn_dense_ms"] is not None]
            assert measured, "dense feasible at top size but measured " \
                             "nowhere — raise dense_limit"
            ref = measured[-1]
            ratio = ref["per_turn_dense_ms"] / ref["per_turn_sparse_ms"]
            assert ratio >= SPEEDUP_FLOOR, \
                f"dense only {ratio:.1f}x slower (< {SPEEDUP_FLOOR}x) " \
                f"at N={ref['n']} and feasible at N={top['n']}"
            print(f"\nhuge host: dense fits at N={top['n']} but is "
                  f"{ratio:.1f}x slower at the largest measured size "
                  f"(N={ref['n']})")

    section("Multi-move probabilistic sweeps (DESIGN.md §17)")
    degenerate = check_sweeps_degenerate(n=256, k=k)
    print(f"  degenerate config == refine_simultaneous bitwise across "
          f"{len(degenerate['cells'])} cells (dense/sparse x c/ct, "
          "looped and batched)")
    if quick:
        ratio = sweeps_ratio(16384, k=k, single_cap=256, floor=1.0)
        million = None
    else:
        ratio = sweeps_ratio(65536, k=k, floor=SWEEP_RATIO_FLOOR)
        million = million_row(k=k)
        results.append(million)
    sweeps = {"degenerate": degenerate, "ratio": ratio,
              "million_node": million}

    if recorder is not None:
        recorder.close()
    payload = {"agreement": agreement, "scaling": results,
               "sweeps": sweeps,
               "backend_devices": jax.device_count()}
    write_bench_json("sparse", payload)
    return payload


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv, telemetry=cli_telemetry(sys.argv))
