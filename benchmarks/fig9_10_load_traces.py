"""Paper Figs. 9 & 10: per-machine load traces with and without periodic
refinement.  The paper shows visibly more balanced loads with refinement;
we quantify with the time-averaged cross-machine coefficient of variation
of the mean event-list length.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.initial import initial_partition
from repro.des.engine import DESConfig, make_initial_state, run_simulation
from repro.des.workload import flooded_packet_workload
from repro.graphs.generators import preferential_attachment

from .common import section, table


def trace_run(adj, refine_freq: int, seed: int = 3, num_machines: int = 4):
    n = adj.shape[0]
    t = 24
    spec = flooded_packet_workload(adj, seed, num_threads=t, num_windows=4,
                                   scope=2, window_sim_time=60.0,
                                   max_per_lp=3)
    deg = int((adj > 0).sum(1).max())
    cfg = DESConfig(num_lps=n, num_machines=num_machines, num_threads=t,
                    event_capacity=max(48, 2 * deg + 8),
                    history_capacity=max(96, 4 * deg + 16),
                    inter_delay=8, intra_delay=1,
                    refine_freq=refine_freq, trace_stride=25,
                    max_ticks=120_000)
    m0 = initial_partition(jnp.asarray(adj), num_machines,
                           jax.random.PRNGKey(seed))
    state = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
    out = run_simulation(cfg, jnp.asarray(adj, jnp.float32), state)
    ptr = int(out.trace_ptr)
    assert ptr <= cfg.max_trace, \
        f"trace_ptr {ptr} exceeds max_trace {cfg.max_trace}"
    tr = np.asarray(out.trace)[:ptr]
    return out, tr


def cv(trace: np.ndarray) -> float:
    """Time-averaged coefficient of variation across machines (only ticks
    with any load)."""
    mean = trace.mean(axis=1)
    active = mean > 1e-6
    if not active.any():
        return 0.0
    std = trace[active].std(axis=1)
    return float(np.mean(std / np.maximum(mean[active], 1e-6)))


def run(quick: bool = False):
    section("Figs. 9/10 — machine load balance without/with refinement")
    n = 48 if quick else 96
    adj = preferential_attachment(n, 5, m=2)
    rows = []
    out0, tr0 = trace_run(adj, refine_freq=0)
    out1, tr1 = trace_run(adj, refine_freq=500)
    for name, out, tr in (("no refinement (Fig. 9)", out0, tr0),
                          ("refine every 500 ticks (Fig. 10)", out1, tr1)):
        rows.append([name, int(out.tick), int(out.refines),
                     int(out.moves), f"{cv(tr):.3f}"])
    table(["run", "sim time", "refines", "migrations",
           "load CV (lower = more balanced)"], rows)
    print("\npaper claim: the refined run's load trace is visibly more "
          "balanced; we check CV(refined) < CV(static).")
    return {"cv_static": cv(tr0), "cv_refined": cv(tr1)}


if __name__ == "__main__":
    run()
