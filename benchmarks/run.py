"""Benchmark orchestrator — one section per paper table/figure plus the
framework-level benches.

  PYTHONPATH=src python -m benchmarks.run            # full
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes
  PYTHONPATH=src python -m benchmarks.run --only table1,roofline

Every suite that returns a payload gets it persisted as BENCH_<name>.json
in the repo root (refine_bench also writes its own file directly so the
CI bench-smoke job tracks it standalone), so the perf trajectory is
machine-readable across PRs.
"""
from __future__ import annotations

import argparse
import time
import traceback

from . import (baselines_compare, batch_study, distributed_bench,
               dynamics_bench, fig7_8_simtime, fig9_10_load_traces,
               kernel_bench, planner_bench, refine_bench, robustness_bench,
               roofline, sparse_bench, sweep_bench, table1_cost_frameworks,
               train_bench)
from .common import write_bench_json

SUITES = {
    "table1": table1_cost_frameworks.run,
    "batch": batch_study.run,
    "fig7_8": fig7_8_simtime.run,
    "fig9_10": fig9_10_load_traces.run,
    "baselines": baselines_compare.run,
    "planner": planner_bench.run,
    "kernel": kernel_bench.run,
    "train": train_bench.run,
    "roofline": roofline.run,
    "distributed": distributed_bench.run,
    "refine": refine_bench.run,
    "dynamics": dynamics_bench.run,
    "sweeps": sweep_bench.run,
    "sparse": sparse_bench.run,
    "robustness": robustness_bench.run,
}

# these write their BENCH_<name>.json themselves (they must also do so
# when invoked standalone by the CI smoke jobs)
_SELF_WRITING = {"refine", "dynamics", "sweeps", "sparse", "robustness"}

# these accept a telemetry dir and emit JSONL run logs (DESIGN.md §14)
_TELEMETRY = {"refine", "sweeps", "sparse", "distributed", "robustness"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="write per-suite telemetry JSONL run logs to DIR "
                         "(suites: " + ", ".join(sorted(_TELEMETRY)) + ")")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    t0 = time.time()
    failures = []
    for name in names:
        t = time.time()
        try:
            kwargs = {"quick": args.quick}
            if args.telemetry and name in _TELEMETRY:
                kwargs["telemetry"] = args.telemetry
            payload = SUITES[name](**kwargs)
            if payload is not None and name not in _SELF_WRITING:
                write_bench_json(name, payload)
        except Exception:
            failures.append(name)
            print(f"[FAIL] suite {name}:")
            traceback.print_exc()
        print(f"[{name}: {time.time() - t:.1f}s]")
    print(f"\ntotal: {time.time() - t0:.1f}s; "
          f"{len(names) - len(failures)}/{len(names)} suites OK"
          + (f"; FAILED: {failures}" if failures else ""))
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
