"""Paper Table I: compare the two cost frameworks on 5 random realizations.

Setup (§5.1): N=230 LPs, K=5 machines, degree ~ U{3..6}, node/edge weights
mean 5, w = (0.1, 0.2, 0.3, 0.3, 0.1), mu = 8.  Same initial partition and
machine turn order for both frameworks; report C_0, Ct_0 and iterations
(= node transfers) at convergence.

Paper's claim to reproduce: the C_i framework converges to better values of
BOTH global costs, while Ct_i converges in fewer iterations.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.initial import initial_partition
from repro.core.problem import make_problem
from repro.core.refine import refine
from repro.graphs.generators import random_degree_graph, random_weights

from .common import section, table

SPEEDS = (0.1, 0.2, 0.3, 0.3, 0.1)
MU = 8.0


def one_trial(seed: int, n: int = 230):
    adj = random_degree_graph(n, seed=seed, dmin=3, dmax=6)
    b, c = random_weights(adj, seed=seed + 1000, mean=5.0)
    prob = make_problem(c, b, SPEEDS, mu=MU)
    r0 = initial_partition(jnp.asarray(adj), len(SPEEDS),
                           jax.random.PRNGKey(seed))
    out = {}
    for fw in costs.FRAMEWORKS:
        res = refine(prob, r0, fw, max_turns=4000)
        out[fw] = dict(
            c0=float(costs.global_cost_c0(prob, res.assignment)),
            ct0=float(costs.global_cost_ct0(prob, res.assignment)),
            iters=int(res.num_moves),
            converged=bool(res.converged),
        )
    return out


def run(quick: bool = False):
    section("Table I — two cost frameworks at convergence (paper §5.1)")
    trials = 3 if quick else 5
    rows = []
    c_wins_both = 0
    for t in range(trials):
        r = one_trial(seed=10 + t)
        a, b = r["c"], r["ct"]
        if a["c0"] <= b["c0"] and a["ct0"] <= b["ct0"]:
            c_wins_both += 1
        rows.append([t + 1,
                     f"{a['c0']:.0f}", f"{a['ct0']:.0f}", a["iters"],
                     f"{b['c0']:.0f}", f"{b['ct0']:.0f}", b["iters"]])
    table(["trial", "C_i: C0", "C_i: Ct0", "C_i iters",
           "Ct_i: C0", "Ct_i: Ct0", "Ct_i iters"], rows)
    print(f"\nC_i framework better on BOTH global costs in "
          f"{c_wins_both}/{trials} trials "
          f"(paper Table I: 5/5).")
    return {"c_wins_both": c_wins_both, "trials": trials}


if __name__ == "__main__":
    run()
