"""Paper Table I: compare the two cost frameworks on 5 random realizations.

Setup (§5.1): N=230 LPs, K=5 machines, degree ~ U{3..6}, node/edge weights
mean 5, w = (0.1, 0.2, 0.3, 0.3, 0.1), mu = 8.  Same initial partition and
machine turn order for both frameworks; report C_0, Ct_0 and iterations
(= node transfers) at convergence.

Paper's claim to reproduce: the C_i framework converges to better values of
BOTH global costs, while Ct_i converges in fewer iterations.

By default the trials run through the batched sweep runtime (DESIGN.md
§12): all realizations of a framework execute as ONE vmapped program
(``--no-batched`` restores the per-trial Python loop; per-element
results are the looped results bitwise, so the table is identical).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro import sweeps
from repro.core import costs
from repro.core.initial import initial_partition
from repro.core.problem import make_problem
from repro.core.refine import refine
from repro.graphs.generators import random_degree_graph, random_weights

from .common import section, table

SPEEDS = (0.1, 0.2, 0.3, 0.3, 0.1)
MU = 8.0
MAX_TURNS = 4000


def _instance(seed: int, n: int = 230):
    adj = random_degree_graph(n, seed=seed, dmin=3, dmax=6)
    b, c = random_weights(adj, seed=seed + 1000, mean=5.0)
    prob = make_problem(c, b, SPEEDS, mu=MU)
    r0 = initial_partition(jnp.asarray(adj), len(SPEEDS),
                           jax.random.PRNGKey(seed))
    return prob, r0


def one_trial(seed: int, n: int = 230):
    prob, r0 = _instance(seed, n)
    out = {}
    for fw in costs.FRAMEWORKS:
        res = refine(prob, r0, fw, max_turns=MAX_TURNS)
        out[fw] = dict(
            c0=float(costs.global_cost_c0(prob, res.assignment)),
            ct0=float(costs.global_cost_ct0(prob, res.assignment)),
            iters=int(res.num_moves),
            converged=bool(res.converged),
        )
    return out


def batched_trials(seeds: list[int], n: int = 230):
    """All (trial, framework) cells via the sweep runtime: one compiled
    vmap per framework (the framework is a compile-time group key)."""
    instances = [_instance(seed, n) for seed in seeds]
    cases = [sweeps.SweepCase(problem=p, assignment=r0, framework=fw,
                              label=f"seed{seed}/{fw}")
             for seed, (p, r0) in zip(seeds, instances)
             for fw in costs.FRAMEWORKS]
    result = sweeps.run_sweep(sweeps.make_spec(cases, mode="refine",
                                               max_turns=MAX_TURNS))
    c0s, ct0s = result.final_potentials()
    trials = []
    for t in range(len(seeds)):
        out = {}
        for f, fw in enumerate(costs.FRAMEWORKS):
            i = t * len(costs.FRAMEWORKS) + f
            out[fw] = dict(c0=float(c0s[i]), ct0=float(ct0s[i]),
                           iters=int(result.moves[i]),
                           converged=bool(result.converged[i]))
        trials.append(out)
    return trials


def run(quick: bool = False, batched: bool = True):
    mode = "batched sweep" if batched else "python loop"
    section(f"Table I — two cost frameworks at convergence ({mode})")
    num = 3 if quick else 5
    seeds = [10 + t for t in range(num)]
    if batched:
        trials = batched_trials(seeds)
    else:
        trials = [one_trial(seed) for seed in seeds]
    rows = []
    c_wins_both = 0
    for t, r in enumerate(trials):
        a, b = r["c"], r["ct"]
        if a["c0"] <= b["c0"] and a["ct0"] <= b["ct0"]:
            c_wins_both += 1
        rows.append([t + 1,
                     f"{a['c0']:.0f}", f"{a['ct0']:.0f}", a["iters"],
                     f"{b['c0']:.0f}", f"{b['ct0']:.0f}", b["iters"]])
    table(["trial", "C_i: C0", "C_i: Ct0", "C_i iters",
           "Ct_i: C0", "Ct_i: Ct0", "Ct_i iters"], rows)
    print(f"\nC_i framework better on BOTH global costs in "
          f"{c_wins_both}/{num} trials "
          f"(paper Table I: 5/5).")
    return {"c_wins_both": c_wins_both, "trials": num, "batched": batched}


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv,
        batched="--no-batched" not in sys.argv)
