"""Shared benchmark utilities."""
from __future__ import annotations

import datetime
import json
import os
import platform
import subprocess
import time

import numpy as np

import jax

# BENCH_*.json files land in the repo root so the perf trajectory is
# tracked across PRs next to the sources that produced it.
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git_sha() -> str | None:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        return None


def provenance() -> dict:
    """What produced a BENCH file: code version + toolchain + hardware.

    Stamped into every ``write_bench_json`` document so a perf number is
    never compared against one from a different commit, jax version, or
    device kind without noticing — the overwrite diff below prints
    exactly which of these changed.
    """
    import jaxlib
    dev = jax.devices()[0]
    return {
        "git_sha": _git_sha(),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }


def _provenance_diff(old: dict, new: dict) -> list[str]:
    """Changed provenance keys (timestamp excluded — it always differs)."""
    keys = (set(old) | set(new)) - {"timestamp_utc"}
    return [f"{k}: {old.get(k)} -> {new.get(k)}"
            for k in sorted(keys) if old.get(k) != new.get(k)]


def write_bench_json(name: str, payload) -> str:
    """Persist a suite's machine-readable results as BENCH_<name>.json.

    Overwriting an existing file prints the provenance diff (commit,
    toolchain, device) so a regressed-looking number that merely came
    from different hardware or jax version is visible at a glance.
    """
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    prov = provenance()
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f).get("provenance", {})
        except (OSError, json.JSONDecodeError):
            old = {}
        diff = _provenance_diff(old, prov)
        if diff:
            print(f"[bench overwrite {path}: provenance changed — "
                  + "; ".join(diff) + "]")
    doc = {
        "bench": name,
        "unix_time": time.time(),
        "backend": jax.default_backend(),
        "provenance": prov,
        "results": payload,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[bench results -> {path}]")
    return path


def telemetry_recorder(out_dir, name: str):
    """A Recorder writing <out_dir>/<name>.jsonl, or None when no dir.

    The shared ``--telemetry DIR`` plumbing for the bench suites: each
    suite opens one recorder, threads it through its instrumented entry
    points, and closes it on exit; the CI bench-smoke job then replays
    the logs with ``python -m repro.obs.report --check`` (DESIGN.md §14.4).
    """
    if out_dir is None:
        return None
    from repro.obs import JsonlSink, Recorder
    return Recorder([JsonlSink(os.path.join(out_dir, f"{name}.jsonl"))])


def cli_telemetry(argv) -> str | None:
    """Extract the standalone suites' ``--telemetry DIR`` argument."""
    if "--telemetry" not in argv:
        return None
    try:
        return argv[argv.index("--telemetry") + 1]
    except IndexError:
        raise SystemExit("--telemetry needs a directory argument")


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of ``fn(*args)`` after ``warmup`` calls."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def section(title: str):
    bar = "=" * max(8, 74 - len(title))
    print(f"\n==== {title} {bar[:74 - 6 - len(title)]}")


def table(header: list[str], rows: list[list]):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(header)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print(fmt.format(*header))
    print(fmt.format(*["-" * w for w in widths]))
    for r in rows:
        print(fmt.format(*[str(c) for c in r]))
