"""Shared benchmark utilities."""
from __future__ import annotations

import time

import numpy as np

import jax


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of ``fn(*args)`` after ``warmup`` calls."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def section(title: str):
    bar = "=" * max(8, 74 - len(title))
    print(f"\n==== {title} {bar[:74 - 6 - len(title)]}")


def table(header: list[str], rows: list[list]):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(header)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print(fmt.format(*header))
    print(fmt.format(*["-" * w for w in widths]))
    for r in rows:
        print(fmt.format(*[str(c) for c in r]))
