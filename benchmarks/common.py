"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time

import numpy as np

import jax

# BENCH_*.json files land in the repo root so the perf trajectory is
# tracked across PRs next to the sources that produced it.
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def write_bench_json(name: str, payload) -> str:
    """Persist a suite's machine-readable results as BENCH_<name>.json."""
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    doc = {
        "bench": name,
        "unix_time": time.time(),
        "backend": jax.default_backend(),
        "results": payload,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[bench results -> {path}]")
    return path


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of ``fn(*args)`` after ``warmup`` calls."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def section(title: str):
    bar = "=" * max(8, 74 - len(title))
    print(f"\n==== {title} {bar[:74 - 6 - len(title)]}")


def table(header: list[str], rows: list[list]):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(header)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print(fmt.format(*header))
    print(fmt.format(*["-" * w for w in widths]))
    for r in rows:
        print(fmt.format(*[str(c) for c in r]))
