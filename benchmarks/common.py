"""Shared benchmark utilities."""
from __future__ import annotations

import json
import math
import os
import time

import numpy as np

import jax

# the one shared provenance implementation — the analysis CLI stamps the
# identical block into findings.json (DESIGN.md §14.5)
from repro.provenance import provenance  # noqa: F401  (re-exported)

# BENCH_*.json files land in the repo root so the perf trajectory is
# tracked across PRs next to the sources that produced it.
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _provenance_diff(old: dict, new: dict) -> list[str]:
    """Changed provenance keys (timestamp excluded — it always differs)."""
    keys = (set(old) | set(new)) - {"timestamp_utc"}
    return [f"{k}: {old.get(k)} -> {new.get(k)}"
            for k in sorted(keys) if old.get(k) != new.get(k)]


class BenchPayloadError(ValueError):
    """A BENCH document failed schema validation — nothing was written."""


_REQUIRED_PROVENANCE = ("git_sha", "jax", "jaxlib", "backend",
                        "device_kind")
_LEAF_TYPES = (str, bool, int, float, type(None), np.integer, np.floating)


def _walk_leaves(obj, path):
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _walk_leaves(v, f"{path}.{k}")
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            yield from _walk_leaves(v, f"{path}[{i}]")
    else:
        yield path, obj


def validate_bench_payload(doc: dict) -> None:
    """Minimal schema gate before a BENCH_*.json file is (over)written.

    A committed artifact with a NaN/Inf leaf or a missing provenance
    block poisons every later cross-PR comparison, so refuse to write
    one: the provenance block must carry the toolchain keys, and every
    leaf must be a finite JSON-serializable scalar (json.dump would
    happily emit a bare ``NaN`` token, which is not even legal JSON).
    Raises :class:`BenchPayloadError`.
    """
    prov = doc.get("provenance")
    if not isinstance(prov, dict):
        raise BenchPayloadError("bench document has no provenance block")
    missing = [k for k in _REQUIRED_PROVENANCE if k not in prov]
    if missing:
        raise BenchPayloadError(f"provenance block missing keys: {missing}")
    for path, leaf in _walk_leaves(doc, "$"):
        if not isinstance(leaf, _LEAF_TYPES):
            raise BenchPayloadError(
                f"non-JSON leaf at {path}: {type(leaf).__name__}")
        if isinstance(leaf, (float, np.floating)) and not math.isfinite(leaf):
            raise BenchPayloadError(f"non-finite value at {path}: {leaf}")


def write_bench_json(name: str, payload) -> str:
    """Persist a suite's machine-readable results as BENCH_<name>.json.

    The document is schema-validated first (provenance present, every
    leaf finite — :func:`validate_bench_payload`), so a bad run can
    never clobber a committed artifact.  Overwriting an existing file
    prints the provenance diff (commit, toolchain, device) so a
    regressed-looking number that merely came from different hardware or
    jax version is visible at a glance.
    """
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    prov = provenance()
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f).get("provenance", {})
        except (OSError, json.JSONDecodeError):
            old = {}
        diff = _provenance_diff(old, prov)
        if diff:
            print(f"[bench overwrite {path}: provenance changed — "
                  + "; ".join(diff) + "]")
    doc = {
        "bench": name,
        "unix_time": time.time(),
        "backend": jax.default_backend(),
        "provenance": prov,
        "results": payload,
    }
    validate_bench_payload(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[bench results -> {path}]")
    return path


def telemetry_recorder(out_dir, name: str):
    """A Recorder writing <out_dir>/<name>.jsonl, or None when no dir.

    The shared ``--telemetry DIR`` plumbing for the bench suites: each
    suite opens one recorder, threads it through its instrumented entry
    points, and closes it on exit; the CI bench-smoke job then replays
    the logs with ``python -m repro.obs.report --check`` (DESIGN.md §14.4).
    """
    if out_dir is None:
        return None
    from repro.obs import JsonlSink, Recorder
    return Recorder([JsonlSink(os.path.join(out_dir, f"{name}.jsonl"))])


def cli_telemetry(argv) -> str | None:
    """Extract the standalone suites' ``--telemetry DIR`` argument."""
    if "--telemetry" not in argv:
        return None
    try:
        return argv[argv.index("--telemetry") + 1]
    except IndexError:
        raise SystemExit("--telemetry needs a directory argument")


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of ``fn(*args)`` after ``warmup`` calls."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def section(title: str):
    bar = "=" * max(8, 74 - len(title))
    print(f"\n==== {title} {bar[:74 - 6 - len(title)]}")


def table(header: list[str], rows: list[list]):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(header)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print(fmt.format(*header))
    print(fmt.format(*["-" * w for w in widths]))
    for r in rows:
        print(fmt.format(*[str(c) for c in r]))
