"""End-to-end trainer benchmark on CPU (smoke configs): steps/s per family
plus the expert-replanning path, and serving throughput.  The real-scale
performance story lives in the dry-run roofline (benchmarks/roofline.py);
this suite proves the full drivers run end to end.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.train import train
from repro.models import init_params
from repro.serving import Request, ServeConfig, ServingEngine

from .common import section, table


def run(quick: bool = False):
    section("End-to-end training (smoke configs, CPU)")
    steps = 12 if quick else 30
    archs = ["qwen1.5-4b", "granite-moe-1b-a400m", "mamba2-1.3b",
             "zamba2-7b"]
    if quick:
        archs = archs[:2]
    rows = []
    train_results = []
    for arch in archs:
        t0 = time.time()
        _, losses = train(arch, smoke=True, steps=steps, global_batch=8,
                          seq_len=64, log_every=10**9)
        wall = time.time() - t0
        rows.append([arch, steps, f"{losses[0]:.3f}", f"{losses[-1]:.3f}",
                     f"{steps / wall:.2f}"])
        train_results.append({"arch": arch, "steps": steps,
                              "loss_first": float(losses[0]),
                              "loss_last": float(losses[-1]),
                              "steps_per_s": steps / wall})
    table(["arch (smoke)", "steps", "loss[0]", "loss[-1]", "steps/s"], rows)

    section("Serving throughput (continuous batching, smoke config, CPU)")
    cfg = configs.get_smoke_config("qwen1.5-4b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, ServeConfig(max_batch=4, max_len=96,
                                                 cache_dtype="float32"))
    rng = np.random.default_rng(0)
    n_req = 6 if quick else 12
    for i in range(n_req):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size,
                                           int(rng.integers(4, 16))
                                           ).astype(np.int32),
                           max_new_tokens=16))
    stats = eng.run()
    table(["requests", "decode steps", "generated tokens", "tok/s (CPU)"],
          [[stats["requests"], stats["decode_steps"],
            stats["generated_tokens"], f"{stats['tok_per_s']:.1f}"]])
    return {"train": train_results,
            "serving": {k: float(v) for k, v in stats.items()}}


if __name__ == "__main__":
    run()
