"""Batched sweep runtime (DESIGN.md §12): vmap-vs-loop bitwise contracts.

The load-bearing promise: every element of a batched run reproduces its
own looped run — move sequences, assignments, loads and gains bitwise
for all three refinement entry points; complete final states (traces
included) bitwise for the DES engine — with the carried potentials
inside the §10.3 ≤1e-3 relative budget.  Exercised across mixed graph
generators, both frameworks, theta on/off, and (for DES) churn schedules
with refinement, hysteresis and migration freezes enabled.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import sweeps
from repro.core import costs
from repro.core.batch import (batch_size, refine_batched,
                              refine_simultaneous_batched,
                              refine_sweeps_batched, refine_traced_batched,
                              stack_problems, stack_pytrees, unstack_pytree)
from repro.core.problem import make_problem
from repro.core.refine import (refine, refine_simultaneous, refine_sweeps,
                               refine_traced)
from repro.des import scenarios
from repro.des.engine import (DESConfig, make_initial_state, run_simulation,
                              run_simulation_batch)
from repro.des.workload import flooded_packet_workload
from repro.graphs.generators import (preferential_attachment,
                                     random_degree_graph, random_weights,
                                     specialized_geometric)

POTENTIAL_TOL = 1e-3
GENERATORS = (random_degree_graph,
              lambda n, s: preferential_attachment(n, s, m=2),
              specialized_geometric)


def _mixed_problems(num: int, n: int = 40, k: int = 4, seed0: int = 0):
    problems, r0s = [], []
    for s in range(num):
        adj = GENERATORS[s % len(GENERATORS)](n, seed0 + s)
        b, c = random_weights(adj, seed=seed0 + s + 77, mean=5.0)
        rng = np.random.default_rng(seed0 + s)
        speeds = rng.uniform(0.5, 2.0, k)
        problems.append(make_problem(c, b, speeds / speeds.sum(), mu=8.0))
        r0s.append(jnp.asarray(rng.integers(0, k, n), jnp.int32))
    return problems, r0s


def _tree_equal_at(tree_loop, tree_batch, index: int, context: str):
    flat_l = jax.tree_util.tree_leaves_with_path(tree_loop)
    flat_b = jax.tree.leaves(tree_batch)
    assert len(flat_l) == len(flat_b)
    for (path, a), b in zip(flat_l, flat_b):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)[index],
            err_msg=f"{context}[{index}] diverged at "
                    f"{jax.tree_util.keystr(path)}")


# ---------------------------------------------------------------------------
# stacking primitives
# ---------------------------------------------------------------------------

def test_stack_problems_is_a_problem_with_leading_axis():
    problems, _ = _mixed_problems(3)
    stacked = stack_problems(problems)
    assert stacked.adjacency.shape == (3, 40, 40)
    assert stacked.node_weights.shape == (3, 40)
    assert stacked.speeds.shape == (3, 4)
    assert stacked.mu.shape == (3,)
    assert batch_size(stacked) == 3
    elem = unstack_pytree(stacked, 1)
    np.testing.assert_array_equal(np.asarray(elem.adjacency),
                                  np.asarray(problems[1].adjacency))


def test_stack_problems_rejects_mixed_shapes():
    a, _ = _mixed_problems(1, n=16)
    b, _ = _mixed_problems(1, n=24)
    with pytest.raises(ValueError, match="one shape signature"):
        stack_problems(a + b)


def test_stack_pytrees_empty_raises():
    with pytest.raises(ValueError):
        stack_pytrees([])


# ---------------------------------------------------------------------------
# vmap-vs-loop bitwise: all three refinement entry points
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("framework", ["c", "ct"])
@pytest.mark.parametrize("theta_on", [False, True])
def test_refine_traced_batched_bitwise(framework, theta_on):
    problems, r0s = _mixed_problems(4)
    stacked = stack_problems(problems)
    r0 = jnp.stack(r0s)
    theta = None
    thetas = [None] * 4
    if theta_on:
        thetas = [np.random.default_rng(9 + i).uniform(0, 3, 40)
                  for i in range(4)]
        theta = jnp.stack([jnp.asarray(t, jnp.float32) for t in thetas])
    res_b, tr_b = refine_traced_batched(stacked, r0, framework,
                                        max_turns=96, theta=theta)
    for i in range(4):
        res_l, tr_l = refine_traced(problems[i], r0s[i], framework,
                                    max_turns=96, theta=thetas[i])
        for field in ("moved", "node", "source", "dest", "gain", "active"):
            np.testing.assert_array_equal(
                np.asarray(getattr(tr_l, field)),
                np.asarray(getattr(tr_b, field))[i],
                err_msg=f"trace.{field} diverged for element {i}")
        np.testing.assert_array_equal(np.asarray(res_l.assignment),
                                      np.asarray(res_b.assignment)[i])
        np.testing.assert_array_equal(np.asarray(res_l.loads),
                                      np.asarray(res_b.loads)[i])
        for pot in ("c0", "ct0"):
            a = np.asarray(getattr(tr_l, pot), np.float64)
            b = np.asarray(getattr(tr_b, pot), np.float64)[i]
            rel = np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-9))
            assert rel <= POTENTIAL_TOL, (pot, i, rel)


@pytest.mark.parametrize("framework", ["c", "ct"])
def test_refine_batched_bitwise(framework):
    problems, r0s = _mixed_problems(4, seed0=20)
    stacked = stack_problems(problems)
    res_b = refine_batched(stacked, jnp.stack(r0s), framework,
                           max_turns=2000)
    for i in range(4):
        res_l = refine(problems[i], r0s[i], framework, max_turns=2000)
        _tree_equal_at(res_l, res_b, i, f"refine[{framework}]")
    assert np.asarray(res_b.converged).all()


def test_refine_batched_scalar_theta_broadcasts():
    problems, r0s = _mixed_problems(3, seed0=31)
    stacked = stack_problems(problems)
    res_b = refine_batched(stacked, jnp.stack(r0s), "c", max_turns=2000,
                           theta=2.5)
    for i in range(3):
        res_l = refine(problems[i], r0s[i], "c", max_turns=2000, theta=2.5)
        _tree_equal_at(res_l, res_b, i, "refine[theta-scalar]")


@pytest.mark.parametrize("framework", ["c", "ct"])
def test_refine_simultaneous_batched_bitwise(framework):
    problems, r0s = _mixed_problems(4, seed0=40)
    stacked = stack_problems(problems)
    res_b, (c0_b, ct0_b, act_b) = refine_simultaneous_batched(
        stacked, jnp.stack(r0s), framework, max_sweeps=48)
    for i in range(4):
        res_l, (c0_l, ct0_l, act_l) = refine_simultaneous(
            problems[i], r0s[i], framework, max_sweeps=48)
        _tree_equal_at(res_l, res_b, i, f"simultaneous[{framework}]")
        np.testing.assert_array_equal(np.asarray(act_l),
                                      np.asarray(act_b)[i])
        for name, a, b in (("c0", c0_l, c0_b), ("ct0", ct0_l, ct0_b)):
            aa = np.asarray(a, np.float64)
            bb = np.asarray(b, np.float64)[i]
            rel = np.max(np.abs(aa - bb) / np.maximum(np.abs(aa), 1e-9))
            assert rel <= POTENTIAL_TOL, (name, i, rel)


# ---------------------------------------------------------------------------
# multi-move probabilistic sweeps (DESIGN.md §17): conformance suite
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("framework", ["c", "ct"])
@pytest.mark.parametrize("theta", [None, 0.5])
def test_refine_sweeps_degenerate_bitwise(framework, theta):
    """moves_per_machine=1, move_prob=1, epsilon=0 stages the SAME program
    as refine_simultaneous (no PRNG op, same election, same apply), so the
    whole result — assignment, loads, move counts, the per-sweep potential
    traces — must agree bitwise, not just within tolerance."""
    problems, r0s = _mixed_problems(3, seed0=60)
    for prob, r0 in zip(problems, r0s):
        res_s, (c0_s, ct0_s, act_s) = refine_simultaneous(
            prob, r0, framework, max_sweeps=48, theta=theta)
        res_w, (c0_w, ct0_w, act_w) = refine_sweeps(
            prob, r0, framework, max_sweeps=48, theta=theta)
        for a, b in zip(jax.tree.leaves(res_s), jax.tree.leaves(res_w)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(act_s), np.asarray(act_w))
        np.testing.assert_array_equal(np.asarray(c0_s), np.asarray(c0_w))
        np.testing.assert_array_equal(np.asarray(ct0_s), np.asarray(ct0_w))


@pytest.mark.parametrize("framework", ["c", "ct"])
def test_refine_sweeps_multimove_descends(framework):
    """Elected multi-move sweeps (M=2, flat coin) under a fixed seed reach
    an equilibrium below the starting potential — the §17.1 expected-drop
    argument, checked empirically per DESIGN.md §17."""
    problems, r0s = _mixed_problems(3, seed0=70)
    for i, (prob, r0) in enumerate(zip(problems, r0s)):
        res, (c0s, ct0s, active) = refine_sweeps(
            prob, r0, framework, max_sweeps=256, moves_per_machine=2,
            move_prob=0.5, epsilon=1e-3, key=jax.random.PRNGKey(100 + i))
        assert bool(res.converged), f"element {i} did not converge"
        pots = np.asarray(c0s if framework == "c" else ct0s, np.float64)
        start = float(costs.global_cost(prob, r0, framework))
        n_active = int(np.asarray(active).sum())
        assert n_active >= 1
        assert pots[n_active - 1] < start
        # descent in expectation: the mean per-sweep drop over the active
        # prefix is strictly negative (individual sweeps may ascend)
        if n_active >= 2:
            assert (pots[n_active - 1] - pots[0]) / (n_active - 1) < 0.0


@pytest.mark.parametrize("framework", ["c", "ct"])
def test_refine_sweeps_batched_bitwise(framework):
    """Probabilistic multi-move fleets == looped per-element, coins
    included: each element folds its own key, so the batched coin
    sequences are the looped ones."""
    problems, r0s = _mixed_problems(4, seed0=80)
    stacked = stack_problems(problems)
    keys = jax.random.split(jax.random.PRNGKey(7), 4)
    res_b, (c0_b, ct0_b, act_b) = refine_sweeps_batched(
        stacked, jnp.stack(r0s), framework, max_sweeps=96,
        moves_per_machine=2, move_prob=0.5, epsilon=1e-3, keys=keys)
    for i in range(4):
        res_l, (c0_l, ct0_l, act_l) = refine_sweeps(
            problems[i], r0s[i], framework, max_sweeps=96,
            moves_per_machine=2, move_prob=0.5, epsilon=1e-3, key=keys[i])
        _tree_equal_at(res_l, res_b, i, f"sweeps[{framework}]")
        np.testing.assert_array_equal(np.asarray(act_l),
                                      np.asarray(act_b)[i])
        for name, a, b in (("c0", c0_l, c0_b), ("ct0", ct0_l, ct0_b)):
            aa = np.asarray(a, np.float64)
            bb = np.asarray(b, np.float64)[i]
            rel = np.max(np.abs(aa - bb) / np.maximum(np.abs(aa), 1e-9))
            assert rel <= POTENTIAL_TOL, (name, i, rel)


def test_refine_sweeps_batched_requires_keys():
    problems, r0s = _mixed_problems(2, seed0=80)
    stacked = stack_problems(problems)
    with pytest.raises(ValueError, match="keys"):
        refine_sweeps_batched(stacked, jnp.stack(r0s), "c", move_prob=0.5)


# ---------------------------------------------------------------------------
# the SweepSpec -> SweepResult runtime
# ---------------------------------------------------------------------------

def _mixed_cases(num: int = 6):
    problems, r0s = _mixed_problems(num, seed0=50)
    return [sweeps.SweepCase(
        problem=p, assignment=r,
        framework="c" if i % 2 == 0 else "ct",
        theta=None if i % 3 == 0 else float(i),
        label=f"case{i}") for i, (p, r) in enumerate(zip(problems, r0s))]


def test_run_sweep_groups_and_preserves_case_order():
    cases = _mixed_cases()
    res = sweeps.run_sweep(sweeps.make_spec(cases, mode="traced",
                                            max_turns=64))
    assert len(res) == len(cases)
    # every case's result must equal ITS OWN looped run (ordering survived
    # the group-by-static round trip)
    for i, case in enumerate(cases):
        res_l, tr_l = refine_traced(case.problem,
                                    jnp.asarray(case.assignment, jnp.int32),
                                    case.framework, max_turns=64,
                                    theta=case.theta)
        np.testing.assert_array_equal(np.asarray(res_l.assignment),
                                      np.asarray(res.results[i].assignment),
                                      err_msg=case.label)
        np.testing.assert_array_equal(np.asarray(tr_l.node),
                                      np.asarray(res.traces[i].node),
                                      err_msg=case.label)
    labels = [s["label"] for s in res.summary()]
    assert labels == [c.label for c in cases]


def test_run_sweep_refine_mode_kernel_matches_jnp():
    cases = [c for c in _mixed_cases() if c.theta is None]
    jnp_res = sweeps.run_sweep(sweeps.make_spec(cases, mode="refine",
                                                max_turns=2000))
    ker_res = sweeps.run_sweep(sweeps.make_spec(cases, mode="refine",
                                                max_turns=2000,
                                                use_kernel=True))
    np.testing.assert_array_equal(jnp_res.assignments, ker_res.assignments)
    np.testing.assert_array_equal(jnp_res.moves, ker_res.moves)


def test_run_sweep_simultaneous_mode_and_potentials():
    cases = _mixed_cases(4)
    res = sweeps.run_sweep(sweeps.make_spec(cases, mode="simultaneous",
                                            max_turns=32))
    c0, ct0 = res.final_potentials()
    assert c0.shape == (4,) and np.isfinite(c0).all()
    assert ct0.shape == (4,) and np.isfinite(ct0).all()


def test_run_sweep_multimove_mode_matches_looped():
    """Fleet multimove results == looped refine_sweeps with the per-case
    fold_in key, regardless of how the runtime groups the cases."""
    cases = _mixed_cases(4)
    spec = sweeps.make_spec(cases, mode="multimove", max_turns=96,
                            moves_per_machine=2, move_prob=0.5,
                            epsilon=1e-3, seed=11)
    res = sweeps.run_sweep(spec)
    for i, case in enumerate(cases):
        key = jax.random.fold_in(jax.random.PRNGKey(11), i)
        res_l, _ = refine_sweeps(
            case.problem, jnp.asarray(case.assignment, jnp.int32),
            case.framework, max_sweeps=96, theta=case.theta,
            moves_per_machine=2, move_prob=0.5, epsilon=1e-3, key=key)
        np.testing.assert_array_equal(np.asarray(res_l.assignment),
                                      np.asarray(res.results[i].assignment),
                                      err_msg=case.label)
        assert int(res_l.num_moves) == int(res.results[i].num_moves), \
            case.label
    c0, ct0 = res.final_potentials()
    assert np.isfinite(c0).all() and np.isfinite(ct0).all()


def test_sweep_spec_validation():
    cases = _mixed_cases(2)
    with pytest.raises(ValueError, match="unknown sweep mode"):
        sweeps.make_spec(cases, mode="bogus")
    with pytest.raises(ValueError, match="use_kernel"):
        sweeps.make_spec(cases, mode="traced", use_kernel=True)
    with pytest.raises(ValueError, match="multimove"):
        sweeps.make_spec(cases, mode="traced", move_prob=0.5)
    with pytest.raises(ValueError, match="multimove"):
        sweeps.make_spec(cases, mode="simultaneous", moves_per_machine=None)


def test_sweep_metrics_cv_and_trace():
    cases = _mixed_cases(3)
    res = sweeps.run_sweep(sweeps.make_spec(cases, mode="traced",
                                            max_turns=96))
    cv = res.load_cv()
    assert cv.shape == (3,) and (cv >= 0).all()
    traces = res.load_cv_traces()
    for i, tr in enumerate(traces):
        assert tr.shape == (96,)
        # replayed final CV agrees with the device loads' CV (f64 replay
        # vs f32 carry: close, not bitwise)
        np.testing.assert_allclose(tr[-1], cv[i], rtol=1e-4, atol=1e-6)
    # refinement descends load imbalance in these instances
    assert np.all([t[-1] <= t[0] + 1e-9 for t in traces])


def test_metrics_load_cv_balanced_is_zero():
    assert sweeps.load_cv(np.array([2.0, 1.0]), np.array([2.0, 1.0])) == 0.0
    out = sweeps.load_cv(np.array([[1.0, 1.0], [3.0, 1.0]]),
                         np.array([1.0, 1.0]))
    assert out[0] == 0.0 and out[1] > 0.0


# ---------------------------------------------------------------------------
# batched DES engine
# ---------------------------------------------------------------------------

def _des_fixture(n=16, k=3, threads=6, refine_freq=60, theta_scale=5.0,
                 freeze=0.25):
    adj = preferential_attachment(n, 3, m=2)
    deg = int((adj > 0).sum(1).max())
    spec = flooded_packet_workload(adj, 7, num_threads=threads,
                                   num_windows=2, scope=2,
                                   window_sim_time=30.0, max_per_lp=3)
    cfg = DESConfig(
        num_lps=n, num_machines=k, num_threads=threads,
        event_capacity=max(32, 2 * deg + 8),
        history_capacity=max(64, 4 * deg + 16),
        inter_delay=5, intra_delay=1, trace_stride=10, max_ticks=8_000,
        machine_speeds=(1.0, 0.7, 0.5)[:k],
        refine_freq=refine_freq, refine_theta_scale=theta_scale,
        migration_freeze=freeze)
    m0 = jnp.asarray(np.arange(n) % k, jnp.int32)
    state0 = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
    return jnp.asarray(adj, jnp.float32), cfg, state0


def _des_scheds(k=3):
    base = (1.0, 0.7, 0.5)[:k]
    return [scenarios.constant(k, base),
            scenarios.slowdown(k, machine=0, at_tick=90, factor=0.3,
                               recover_tick=300, base=base),
            scenarios.random_churn(k, num_segments=3, segment_ticks=120,
                                   seed=3, low=0.3, high=1.0)]


def test_des_batch_bitwise_with_refine_theta_freeze():
    adjj, cfg, state0 = _des_fixture()
    scheds = _des_scheds()
    stacked = scenarios.stack_schedules(scheds)
    padded = [scenarios.pad_segments(s, int(stacked.times.shape[1]))
              for s in scheds]
    states = stack_pytrees([state0] * len(scheds))
    adjs = jnp.stack([adjj] * len(scheds))
    outb = run_simulation_batch(cfg, adjs, states, stacked)
    for i, sched in enumerate(padded):
        out_l = run_simulation(cfg, adjj, state0, sched)
        assert bool(out_l.done)
        _tree_equal_at(out_l, outb, i, "des")


def test_des_batch_no_schedules_no_refine():
    adjj, cfg0, state0 = _des_fixture(refine_freq=0, theta_scale=0.0,
                                      freeze=0.0)
    states = stack_pytrees([state0] * 2)
    adjs = jnp.stack([adjj] * 2)
    outb = run_simulation_batch(cfg0, adjs, states, None, chunk=64)
    out_l = run_simulation(cfg0, adjj, state0, None)
    assert bool(out_l.done)
    for i in range(2):
        _tree_equal_at(out_l, outb, i, "des-noref")


def test_pad_segments_preserves_speeds_at():
    sched = scenarios.slowdown(3, machine=1, at_tick=50, factor=0.5,
                               recover_tick=120)
    padded = scenarios.pad_segments(sched, 6)
    assert padded.times.shape == (6,)
    for tick in (0, 49, 50, 119, 120, 5000):
        np.testing.assert_array_equal(
            np.asarray(scenarios.speeds_at(sched, jnp.int32(tick))),
            np.asarray(scenarios.speeds_at(padded, jnp.int32(tick))))
    with pytest.raises(ValueError):
        scenarios.pad_segments(padded, 2)


def test_stack_schedules_shapes_and_mismatch():
    scheds = _des_scheds()
    stacked = scenarios.stack_schedules(scheds)
    assert stacked.times.shape[0] == 3
    assert stacked.speeds.shape[0] == 3
    assert stacked.times.shape[1] == stacked.speeds.shape[1]
    with pytest.raises(ValueError, match="machine count"):
        scenarios.stack_schedules([scenarios.constant(2),
                                   scenarios.constant(3)])
    with pytest.raises(ValueError):
        scenarios.stack_schedules([])


def test_sweep_time_averaged_cv():
    flat = np.ones((5, 4))
    assert sweeps.time_averaged_cv(flat) == 0.0
    skew = np.array([[4.0, 0.0, 0.0, 0.0]] * 5)
    assert sweeps.time_averaged_cv(skew) > 1.0
    assert sweeps.time_averaged_cv(np.zeros((3, 4))) == 0.0
