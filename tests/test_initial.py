"""Initial partitioning (paper §4.1 + Appendix A) and graph generators."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.initial import (bfs_distances, er_cluster_growth,
                                expand_partitions, initial_partition,
                                select_focal_nodes)
from repro.graphs.generators import (erdos_renyi, preferential_attachment,
                                     random_degree_graph, random_weights,
                                     specialized_geometric)


def _numpy_bfs(adj: np.ndarray, src: int) -> np.ndarray:
    n = adj.shape[0]
    INF = 0x3FFFFFFF
    dist = np.full(n, INF, np.int64)
    dist[src] = 0
    frontier = [src]
    hop = 0
    while frontier:
        hop += 1
        nxt = []
        for u in frontier:
            for v in np.flatnonzero(adj[u] > 0):
                if dist[v] == INF:
                    dist[v] = hop
                    nxt.append(v)
        frontier = nxt
    return dist


@given(st.integers(5, 30), st.integers(0, 10_000))
def test_bfs_matches_numpy_oracle(n, seed):
    adj = random_degree_graph(n, seed=seed, dmin=1, dmax=3)
    srcs = np.arange(min(n, 4))
    got = np.asarray(bfs_distances(jnp.asarray(adj), jnp.asarray(srcs)))
    for i, s in enumerate(srcs):
        np.testing.assert_array_equal(got[i], _numpy_bfs(adj, int(s)),
                                      err_msg=f"src={s}")


def test_focal_nodes_distinct_and_far():
    adj = specialized_geometric(80, seed=1)
    focals = np.asarray(select_focal_nodes(jnp.asarray(adj), 4,
                                           jax.random.PRNGKey(0)))
    assert len(set(focals.tolist())) == 4
    # the heuristic should beat a random focal set's min pairwise distance
    # on average; just require a sane (>= 2 hops) separation here
    d = np.asarray(bfs_distances(jnp.asarray(adj), jnp.asarray(focals)))
    pair = d[:, focals] + np.where(np.eye(4, dtype=bool), 10**9, 0)
    assert pair.min() >= 2


@pytest.mark.parametrize("gen,kwargs", [
    (random_degree_graph, {}),
    (preferential_attachment, {"m": 2}),
    (specialized_geometric, {}),
])
def test_expansion_covers_graph(gen, kwargs):
    adj = gen(60, 3, **kwargs)
    owner = np.asarray(initial_partition(jnp.asarray(adj), 4,
                                         jax.random.PRNGKey(1)))
    assert owner.shape == (60,)
    assert owner.min() >= 0 and owner.max() < 4
    # all four machines own something, and sizes are not absurdly skewed
    counts = np.bincount(owner, minlength=4)
    assert (counts > 0).all()
    assert counts.max() <= 60 * 0.7


def test_expansion_respects_focals():
    adj = random_degree_graph(40, seed=5)
    focals = jnp.asarray([0, 13, 27], jnp.int32)
    owner = np.asarray(expand_partitions(jnp.asarray(adj), focals,
                                         jax.random.PRNGKey(2), 3))
    assert owner[0] == 0 and owner[13] == 1 and owner[27] == 2


def test_expansion_handles_disconnected():
    adj = np.zeros((10, 10), np.float32)
    adj[0, 1] = adj[1, 0] = 1.0     # tiny component
    adj[2:, 2:][np.triu_indices(8, 1)] = 1.0
    adj = np.maximum(adj, adj.T)
    owner = np.asarray(expand_partitions(
        jnp.asarray(adj), jnp.asarray([0, 2], jnp.int32),
        jax.random.PRNGKey(0), 2))
    assert (owner >= 0).all()


# ---------------------------------------------------------------------------
# Theorem A.1 — E-R cluster-growth recursion vs Monte-Carlo BFS
# ---------------------------------------------------------------------------

def test_theorem_a1_recursion_properties():
    sizes = np.asarray(er_cluster_growth(200, 0.03, hops=12))
    assert sizes[0] == 1.0
    assert np.all(np.diff(sizes) >= -1e-9)       # monotone non-decreasing
    assert np.all(sizes <= 200.0 + 1e-6)         # bounded by |V|
    # eventually saturates near |V| for supercritical p
    assert sizes[-1] > 150.0


@pytest.mark.parametrize("n,p", [(150, 0.04), (300, 0.02)])
def test_theorem_a1_matches_monte_carlo(n, p):
    """Expected BFS-frontier growth on G(n,p) follows the Thm A.1 recursion
    (within Monte-Carlo noise) for the early hops where the independence
    approximation holds."""
    hops = 3
    expect = np.asarray(er_cluster_growth(n, p, hops))
    rng = np.random.default_rng(0)
    trials = 60
    acc = np.zeros(hops + 1)
    for t in range(trials):
        adj = erdos_renyi(n, p, seed=int(rng.integers(1 << 30)))
        src = int(rng.integers(n))
        dist = _numpy_bfs(adj, src)
        for h in range(hops + 1):
            acc[h] += (dist <= h).sum()
    acc /= trials
    # hop 0 exact; hops 1..3 within 20% relative
    np.testing.assert_allclose(acc[0], expect[0])
    np.testing.assert_allclose(acc[1:], expect[1:], rtol=0.20)


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gen,kwargs", [
    (random_degree_graph, {"dmin": 3, "dmax": 6}),
    (preferential_attachment, {"m": 2}),
    (specialized_geometric, {}),
])
def test_generator_invariants(gen, kwargs):
    adj = gen(50, 7, **kwargs)
    assert adj.shape == (50, 50)
    np.testing.assert_array_equal(adj, adj.T)            # symmetric
    assert np.all(np.diag(adj) == 0)                      # no self loops
    # connected (generators stitch components)
    dist = _numpy_bfs(adj, 0)
    assert (dist < 0x3FFFFFFF).all()


def test_degree_graph_degrees_in_range():
    adj = random_degree_graph(100, seed=0, dmin=3, dmax=6)
    deg = (adj > 0).sum(1)
    assert deg.min() >= 3                 # each node initiated >= dmin edges


def test_preferential_attachment_is_scale_free_ish():
    adj = preferential_attachment(400, seed=0, m=2)
    deg = (adj > 0).sum(1)
    # heavy tail: max degree far above the median
    assert deg.max() >= 6 * np.median(deg)


def test_random_weights_stats():
    adj = random_degree_graph(200, seed=1)
    b, c = random_weights(adj, seed=2, mean=5.0)
    assert abs(b.mean() - 5.0) < 0.75
    edges = c[adj > 0]
    assert abs(edges.mean() - 5.0) < 0.75
    np.testing.assert_array_equal(c, c.T)
    assert np.all(c[adj == 0] == 0)
