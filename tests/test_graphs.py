"""Generator property suite + the connectivity/union-find regressions
(ISSUE 5 satellites).

Covers both output forms of every model in
:mod:`repro.graphs.generators` — dense (N, N) adjacencies and the
edge-list ``*_edges`` variants — with the invariants the refinement
stack relies on: symmetry, zero diagonal, CONNECTIVITY (the paper's §3
assumption — ``erdos_renyi`` previously skipped the stitch and handed
the game disconnected graphs), degree bounds, and the pinned guarantee
that the union-find ``_ensure_connected`` rewrite produces output
identical to the old O(N^2·iters) label-propagation implementation.
"""
from __future__ import annotations

import numpy as np
import pytest

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.graphs import generators as gen


def _bfs_reaches_all(adj: np.ndarray) -> bool:
    n = adj.shape[0]
    seen = np.zeros(n, bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        nxt = []
        for u in frontier:
            for v in np.flatnonzero(adj[u] > 0):
                if not seen[v]:
                    seen[v] = True
                    nxt.append(v)
        frontier = nxt
    return bool(seen.all())


def _adj_from_edges(n: int, s: np.ndarray, r: np.ndarray) -> np.ndarray:
    adj = np.zeros((n, n), np.float32)
    adj[s, r] = 1.0
    adj[r, s] = 1.0
    return adj


DENSE_GENERATORS = [
    ("random_degree", lambda n, seed: gen.random_degree_graph(
        n, seed, dmin=2, dmax=4)),
    ("pref_attach", lambda n, seed: gen.preferential_attachment(
        n, seed, m=2)),
    ("geometric", lambda n, seed: gen.specialized_geometric(n, seed)),
    ("erdos_renyi", lambda n, seed: gen.erdos_renyi(n, 0.05, seed)),
]

EDGE_GENERATORS = [
    ("random_degree", lambda n, seed: gen.random_degree_graph_edges(
        n, seed, dmin=2, dmax=4)),
    ("pref_attach", lambda n, seed: gen.preferential_attachment_edges(
        n, seed, m=2)),
    ("geometric", lambda n, seed: gen.specialized_geometric_edges(n, seed)),
    ("erdos_renyi", lambda n, seed: gen.erdos_renyi_edges(n, 0.05, seed)),
]


# ---------------------------------------------------------------------------
# property suite: symmetry, zero diagonal, connectivity, degree bounds
# ---------------------------------------------------------------------------

@given(n=st.integers(8, 60), seed=st.integers(0, 10_000))
@settings(max_examples=10)
def test_dense_generator_properties(n, seed):
    for name, fn in DENSE_GENERATORS:
        adj = fn(n, seed)
        assert adj.shape == (n, n), name
        np.testing.assert_array_equal(adj, adj.T, err_msg=name)
        assert np.all(np.diag(adj) == 0), name
        assert _bfs_reaches_all(adj), f"{name} produced a disconnected graph"


@given(n=st.integers(8, 60), seed=st.integers(0, 10_000))
@settings(max_examples=10)
def test_edge_generator_properties(n, seed):
    for name, fn in EDGE_GENERATORS:
        s, r = fn(n, seed)
        assert s.shape == r.shape, name
        assert np.all(s < r), f"{name}: pairs must be canonical (s < r)"
        assert s.min(initial=0) >= 0 and r.max(initial=0) < n, name
        # each undirected edge listed exactly once
        assert np.unique(np.stack([s, r], 1), axis=0).shape[0] == s.size, \
            name
        assert _bfs_reaches_all(_adj_from_edges(n, s, r)), \
            f"{name} edges disconnected"


def test_degree_bounds_both_forms():
    adj = gen.random_degree_graph(100, seed=0, dmin=3, dmax=6)
    assert (adj > 0).sum(1).min() >= 3
    s, r = gen.random_degree_graph_edges(100, seed=0, dmin=3, dmax=6)
    deg = np.bincount(s, minlength=100) + np.bincount(r, minlength=100)
    assert deg.min() >= 3          # every node initiated >= dmin edges


# ---------------------------------------------------------------------------
# regression: erdos_renyi connectivity (fails on pre-fix code)
# ---------------------------------------------------------------------------

def test_erdos_renyi_connected_at_small_p():
    """Pre-fix, erdos_renyi was the ONE generator not routed through
    _ensure_connected; at p = 1/n a G(n, p) draw is disconnected with
    probability ~1, so this fails on the old code for essentially every
    seed (checked across 10)."""
    for seed in range(10):
        adj = gen.erdos_renyi(80, p=1 / 80, seed=seed)
        assert _bfs_reaches_all(adj), f"seed {seed} disconnected"


def test_erdos_renyi_stitch_preserves_gnp_core():
    """Stitching only ADDS unit edges: removing none, the original draw
    is a subgraph (same RNG, same (n, p) sampling)."""
    rng = np.random.default_rng(3)
    raw = np.triu(rng.random((60, 60)) < 0.03, 1).astype(np.float32)
    raw = raw + raw.T
    fixed = gen.erdos_renyi(60, 0.03, seed=3)
    assert np.all(fixed[raw > 0] > 0)
    assert fixed.sum() >= raw.sum()


# ---------------------------------------------------------------------------
# regression: union-find stitching == old label-propagation, fixed seeds
# ---------------------------------------------------------------------------

def _old_ensure_connected(adj: np.ndarray,
                          rng: np.random.Generator) -> np.ndarray:
    """Reference copy of the pre-ISSUE-5 label-propagation implementation
    (O(N^2 * iters)); the union-find rewrite must reproduce its stitched
    output bit for bit."""
    n = adj.shape[0]
    labels = np.arange(n)
    nbr = adj > 0
    changed = True
    while changed:
        changed = False
        for i in range(n):
            m = labels[nbr[i]].min(initial=labels[i])
            if m < labels[i]:
                labels[i] = m
                changed = True
    roots = np.unique(labels)
    if roots.size > 1:
        counts = np.array([(labels == r).sum() for r in roots])
        giant = roots[np.argmax(counts)]
        for r in roots:
            if r == giant:
                continue
            a = rng.choice(np.flatnonzero(labels == r))
            b = rng.choice(np.flatnonzero(labels == giant))
            adj[a, b] = adj[b, a] = 1.0
            labels[labels == r] = giant
    return adj


@pytest.mark.parametrize("seed", [0, 1, 2, 7, 19])
def test_union_find_stitch_identical_to_label_prop(seed):
    rng = np.random.default_rng(seed)
    raw = np.triu(rng.random((70, 70)) < 0.015, 1).astype(np.float32)
    raw = raw + raw.T                      # sparse => many components
    old = _old_ensure_connected(raw.copy(), np.random.default_rng(seed + 50))
    new = gen._ensure_connected(raw.copy(), np.random.default_rng(seed + 50))
    np.testing.assert_array_equal(old, new)


def test_component_labels_are_min_ids():
    # two triangles + an isolated node
    s = np.array([0, 1, 2, 4, 5, 6])
    r = np.array([1, 2, 0, 5, 6, 4])
    labels = gen._component_labels(8, s, r)
    np.testing.assert_array_equal(labels, [0, 0, 0, 3, 4, 4, 4, 7])


# ---------------------------------------------------------------------------
# weights
# ---------------------------------------------------------------------------

def test_random_weights_edges_stats():
    s, r = gen.random_degree_graph_edges(400, seed=1)
    b, w = gen.random_weights_edges(400, s, seed=2, mean=5.0)
    assert b.shape == (400,) and w.shape == s.shape
    assert abs(b.mean() - 5.0) < 0.75
    assert abs(w.mean() - 5.0) < 0.75
    assert b.min() >= 0 and w.min() >= 0
