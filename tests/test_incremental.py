"""Incremental aggregate-state refinement vs the recompute oracle (§10).

Acceptance contract (ISSUE 2): the incremental path reproduces the
recompute path's move sequence EXACTLY and both potentials to <= 1e-3
relative over a 512-turn trace, for both cost frameworks; the
``verify_every`` cross-check observes only f32-drift-sized deviations.

Plus targeted coverage for ``count_discrepancies`` (ascent counting under
both frameworks, rel_tol edge cases) that ISSUE 2 calls out as missing.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import aggregate as agg_mod
from repro.core import costs
from repro.core.problem import machine_loads
from repro.core.refine import (Trace, count_discrepancies, refine,
                               refine_simultaneous, refine_traced)

from conftest import small_problem

AGREE_TOL = 1e-3


def _rand_assignment(prob, seed):
    return jnp.asarray(np.random.default_rng(seed).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)


# ---------------------------------------------------------------------------
# acceptance: incremental == recompute (moves exact, potentials <= 1e-3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
def test_traced_incremental_matches_recompute(framework, paper_problem):
    """512-turn trace: identical move sequence, potentials <= 1e-3 rel."""
    adj, prob = paper_problem
    r0 = _rand_assignment(prob, 42)
    res_i, tr_i = refine_traced(prob, r0, framework, max_turns=512)
    res_r, tr_r = refine_traced(prob, r0, framework, max_turns=512,
                                incremental=False)
    for field in ("moved", "node", "source", "dest", "active"):
        np.testing.assert_array_equal(
            np.asarray(getattr(tr_i, field)),
            np.asarray(getattr(tr_r, field)), err_msg=field)
    np.testing.assert_array_equal(np.asarray(res_i.assignment),
                                  np.asarray(res_r.assignment))
    assert int(res_i.num_moves) == int(res_r.num_moves)
    assert int(res_i.num_turns) == int(res_r.num_turns)
    for pot in ("c0", "ct0"):
        a = np.asarray(getattr(tr_i, pot), np.float64)
        b = np.asarray(getattr(tr_r, pot), np.float64)
        rel = np.max(np.abs(a - b) / np.abs(b))
        assert rel <= AGREE_TOL, f"{pot} drifted {rel:.2e}"


@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
def test_refine_incremental_matches_recompute(framework, paper_problem):
    adj, prob = paper_problem
    r0 = _rand_assignment(prob, 7)
    res_i = refine(prob, r0, framework)
    res_r = refine(prob, r0, framework, incremental=False)
    np.testing.assert_array_equal(np.asarray(res_i.assignment),
                                  np.asarray(res_r.assignment))
    assert int(res_i.num_moves) == int(res_r.num_moves)
    assert int(res_i.num_turns) == int(res_r.num_turns)
    np.testing.assert_allclose(np.asarray(res_i.loads),
                               np.asarray(res_r.loads), rtol=1e-5)


def test_traced_incremental_potentials_vs_true_costs(paper_problem):
    """Carried potentials track the TRUE global costs of the evolving
    assignment (replayed from the move sequence) to <= 1e-3 relative —
    a stronger check than recompute-trace agreement because the oracle
    here is evaluated per-prefix from the original problem."""
    adj, prob = paper_problem
    r0 = _rand_assignment(prob, 3)
    res, tr = refine_traced(prob, r0, "c", max_turns=256)
    r = np.asarray(r0).copy()
    moved = np.asarray(tr.moved)
    nodes = np.asarray(tr.node)
    dests = np.asarray(tr.dest)
    check_at = [0, 1, 5, 25, 100, 255]
    for t in range(256):
        if moved[t]:
            r[nodes[t]] = dests[t]
        if t in check_at:
            np.testing.assert_allclose(
                float(tr.c0[t]),
                float(costs.global_cost_c0(prob, jnp.asarray(r))),
                rtol=AGREE_TOL, err_msg=f"c0 at turn {t}")
            np.testing.assert_allclose(
                float(tr.ct0[t]),
                float(costs.global_cost_ct0(prob, jnp.asarray(r))),
                rtol=AGREE_TOL, err_msg=f"ct0 at turn {t}")


# ---------------------------------------------------------------------------
# AggregateState invariants + verify_every cross-check
# ---------------------------------------------------------------------------

def test_apply_move_invariants():
    """After a chain of unilateral moves: aggregate == rebuilt, loads exact,
    potentials match the global definitions (I1-I3 of DESIGN.md §10)."""
    adj, prob = small_problem(n=30, k=4, seed=11)
    rng = np.random.default_rng(5)
    r = jnp.asarray(rng.integers(0, 4, 30), jnp.int32)
    total_b = jnp.sum(prob.node_weights)
    agg = agg_mod.init_aggregate_state(prob, r)
    for step in range(40):
        node = jnp.asarray(int(rng.integers(0, 30)), jnp.int32)
        dest = jnp.asarray(int(rng.integers(0, 4)), jnp.int32)
        source = agg.assignment[node]
        do_move = source != dest
        agg = agg_mod.apply_move(prob, agg, node, source, dest, do_move,
                                 total_b)
    fresh = agg_mod.init_aggregate_state(prob, agg.assignment)
    np.testing.assert_allclose(np.asarray(agg.aggregate),
                               np.asarray(fresh.aggregate),
                               rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(agg.loads), np.asarray(fresh.loads),
                               rtol=1e-5)
    np.testing.assert_allclose(float(agg.c0), float(fresh.c0), rtol=AGREE_TOL)
    np.testing.assert_allclose(float(agg.ct0), float(fresh.ct0),
                               rtol=AGREE_TOL)
    # drift/resync report the same deviation the asserts above bound
    assert float(agg_mod.drift(prob, agg)) < 1.0


def test_exact_potential_identity_deltas():
    """potential_deltas == the brute-force global-cost differences
    (Thm. 3.1 / 5.1 — the identities the incremental trace relies on)."""
    adj, prob = small_problem(n=24, k=3, seed=2)
    rng = np.random.default_rng(9)
    r = jnp.asarray(rng.integers(0, 3, 24), jnp.int32)
    total_b = jnp.sum(prob.node_weights)
    agg = agg_mod.init_aggregate_state(prob, r)
    for node, dest in [(0, 1), (5, 2), (17, 0), (23, 2)]:
        node = jnp.asarray(node, jnp.int32)
        dest = jnp.asarray(dest, jnp.int32)
        source = r[node]
        dc0, dct0 = agg_mod.potential_deltas(
            agg.aggregate[node], prob.node_weights[node], source, dest,
            agg.loads, prob.speeds, prob.mu, total_b)
        r_new = r.at[node].set(dest)
        np.testing.assert_allclose(
            float(dc0),
            float(costs.global_cost_c0(prob, r_new)
                  - costs.global_cost_c0(prob, r)), rtol=1e-3, atol=5e-2)
        np.testing.assert_allclose(
            float(dct0),
            float(costs.global_cost_ct0(prob, r_new)
                  - costs.global_cost_ct0(prob, r)), rtol=1e-3, atol=5e-2)


def test_verify_every_bounds_drift(paper_problem):
    """The verify_every cross-check: observed drift is f32-noise-sized and
    the resynced run still reproduces the recompute oracle exactly."""
    adj, prob = paper_problem
    r0 = _rand_assignment(prob, 42)
    res_v, tr_v = refine_traced(prob, r0, "c", max_turns=512,
                                verify_every=64)
    # drift at the checkpoints is tiny relative to the O(1e6) potentials /
    # O(1e3) aggregate entries involved
    assert float(res_v.aggregate_drift) < 1.0
    res_r, tr_r = refine_traced(prob, r0, "c", max_turns=512,
                                incremental=False)
    np.testing.assert_array_equal(np.asarray(tr_v.node), np.asarray(tr_r.node))
    np.testing.assert_array_equal(np.asarray(res_v.assignment),
                                  np.asarray(res_r.assignment))
    # while_loop driver exposes the same knob
    res_w = refine(prob, r0, "c", verify_every=64)
    assert float(res_w.aggregate_drift) < 1.0
    np.testing.assert_array_equal(np.asarray(res_w.assignment),
                                  np.asarray(res_r.assignment))


def test_cut_from_aggregate_identity():
    """Invariant I4: the O(N) cut identity equals the O(N^2) definition."""
    adj, prob = small_problem(n=28, k=3, seed=4)
    r = jnp.asarray(np.random.default_rng(1).integers(0, 3, 28), jnp.int32)
    agg = costs.adjacency_aggregate(prob.adjacency, r, 3)
    np.testing.assert_allclose(
        float(agg_mod.cut_from_aggregate(agg, r)),
        float(costs.total_cut(prob.adjacency, r)), rtol=1e-5)


def test_potentials_closed_form_matches_global():
    adj, prob = small_problem(n=26, k=4, seed=8)
    r = jnp.asarray(np.random.default_rng(2).integers(0, 4, 26), jnp.int32)
    b = prob.node_weights
    loads = machine_loads(b, r, 4)
    sq_loads = machine_loads(b * b, r, 4)
    cut = costs.total_cut(prob.adjacency, r)
    c0, ct0 = agg_mod.potentials_closed_form(loads, sq_loads, cut,
                                             prob.speeds, prob.mu,
                                             jnp.sum(b))
    np.testing.assert_allclose(float(c0),
                               float(costs.global_cost_c0(prob, r)),
                               rtol=1e-4)
    np.testing.assert_allclose(float(ct0),
                               float(costs.global_cost_ct0(prob, r)),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# §4.5 simultaneous mode: honest move accounting + carried potentials
# ---------------------------------------------------------------------------

def test_simultaneous_counts_actual_moves(paper_problem):
    """num_moves is sum(will_move) per sweep, not the K*sweeps bound."""
    adj, prob = paper_problem
    k = prob.num_machines
    # perturb an equilibrium by one node: the fixup needs FAR fewer than
    # K moves per sweep, which the old upper-bound accounting reported
    eq = refine(prob, _rand_assignment(prob, 1), "c").assignment
    r_pert = eq.at[0].set((eq[0] + 1) % k)
    res, (c0s, ct0s, active) = refine_simultaneous(prob, r_pert, "c")
    assert int(res.num_turns) >= 1
    assert int(res.num_moves) >= 1
    assert int(res.num_moves) < k * int(res.num_turns), \
        "num_moves still reports the K*sweeps upper bound"


def test_simultaneous_potentials_match_assignment(paper_problem):
    """The per-sweep closed-form potentials equal the true global costs of
    the final assignment."""
    adj, prob = paper_problem
    r0 = _rand_assignment(prob, 5)
    res, (c0s, ct0s, active) = refine_simultaneous(prob, r0, "c")
    np.testing.assert_allclose(
        float(c0s[-1]), float(costs.global_cost_c0(prob, res.assignment)),
        rtol=1e-4)
    np.testing.assert_allclose(
        float(ct0s[-1]), float(costs.global_cost_ct0(prob, res.assignment)),
        rtol=1e-4)


# ---------------------------------------------------------------------------
# count_discrepancies coverage (both frameworks, rel_tol edges)
# ---------------------------------------------------------------------------

def _mk_trace(moved, c0, ct0):
    n = len(moved)
    return Trace(moved=jnp.asarray(moved),
                 node=jnp.zeros(n, jnp.int32),
                 source=jnp.zeros(n, jnp.int32),
                 dest=jnp.zeros(n, jnp.int32),
                 gain=jnp.zeros(n),
                 c0=jnp.asarray(c0, jnp.float32),
                 ct0=jnp.asarray(ct0, jnp.float32),
                 active=jnp.ones(n, bool))


def test_count_discrepancies_c_framework_counts_ct0_ascents():
    """Criterion C_i -> ascents of the OTHER potential (Ct_0) count."""
    tr = _mk_trace([True, True, True, False],
                   c0=[10.0, 9.0, 8.0, 8.0],
                   ct0=[5.0, 6.0, 7.0, 7.0])       # two Ct_0 ascents
    n = count_discrepancies(tr, costs.C_FRAMEWORK,
                            initial_other=jnp.asarray(5.5))
    assert int(n) == 2


def test_count_discrepancies_ct_framework_counts_c0_ascents():
    tr = _mk_trace([True, True, False, True],
                   c0=[10.0, 12.0, 12.0, 11.0],    # ascent at turn 1
                   ct0=[5.0, 4.0, 4.0, 3.0])
    n = count_discrepancies(tr, costs.CT_FRAMEWORK,
                            initial_other=jnp.asarray(11.0))
    assert int(n) == 1


def test_count_discrepancies_ignores_unmoved_turns():
    """An ascent on a forsaken turn is bookkeeping noise, never counted."""
    tr = _mk_trace([False, False],
                   c0=[10.0, 20.0], ct0=[1.0, 2.0])
    for fw in costs.FRAMEWORKS:
        assert int(count_discrepancies(tr, fw,
                                       initial_other=jnp.asarray(1.0))) == 0


def test_count_discrepancies_rel_tol_edges():
    """Ascents right at the threshold: counted iff delta > rel_tol*|prev|."""
    base = 1000.0
    just_below = base * (1 + 0.5e-4)       # 0.005% — below default 1e-4
    just_above = base * (1 + 5e-4)         # 0.05%  — above default 1e-4
    tr = _mk_trace([True, True],
                   c0=[just_below, just_above],
                   ct0=[1.0, 1.0])
    n_default = count_discrepancies(tr, costs.CT_FRAMEWORK,
                                    initial_other=jnp.asarray(base))
    assert int(n_default) == 1             # only the 0.05% ascent
    n_loose = count_discrepancies(tr, costs.CT_FRAMEWORK,
                                  initial_other=jnp.asarray(base),
                                  rel_tol=1e-5)
    assert int(n_loose) == 2               # both exceed 0.001%
    n_strict = count_discrepancies(tr, costs.CT_FRAMEWORK,
                                   initial_other=jnp.asarray(base),
                                   rel_tol=1e-2)
    assert int(n_strict) == 0              # neither exceeds 1%


def test_count_discrepancies_negative_potentials():
    """rel_tol scales by |prev| — correct sign handling for negative Ct_0
    values (the Ct load term can be negative at small mu)."""
    tr = _mk_trace([True], c0=[1.0], ct0=[-99.0])
    # prev = -100 -> threshold |prev|*1e-4 = 0.01; delta = +1.0 counts
    n = count_discrepancies(tr, costs.C_FRAMEWORK,
                            initial_other=jnp.asarray(-100.0))
    assert int(n) == 1
