"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(the 512-device override belongs exclusively to repro/launch/dryrun.py)."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

# ``hypothesis`` is a [test] extra, not a hard requirement: on a bare
# environment the property-based tests must degrade to skips instead of
# killing collection.  The stub installs a minimal fake into sys.modules
# before any test module runs its own ``from hypothesis import ...``.
try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    from _hypothesis_stub import install as _install_hypothesis_stub

    _install_hypothesis_stub()
    from hypothesis import HealthCheck, settings

# jit compilation makes individual examples slow; disable deadlines globally
settings.register_profile(
    "repro",
    deadline=None,
    max_examples=25,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


@pytest.fixture(scope="session")
def paper_problem():
    """The §5.1 setup: 230 nodes, degree 3..6, weights mean 5, K=5, mu=8."""
    from repro.core.problem import make_problem
    from repro.graphs.generators import random_degree_graph, random_weights

    adj = random_degree_graph(230, seed=0)
    b, c = random_weights(adj, seed=1, mean=5.0)
    prob = make_problem(c, b, [0.1, 0.2, 0.3, 0.3, 0.1], mu=8.0)
    return adj, prob


def small_problem(n=24, k=3, seed=0, mu=4.0):
    from repro.core.problem import make_problem
    from repro.graphs.generators import random_degree_graph, random_weights

    adj = random_degree_graph(n, seed=seed, dmin=2, dmax=4)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    speeds = np.random.default_rng(seed + 2).uniform(0.5, 2.0, size=k)
    return adj, make_problem(c, b, speeds, mu=mu)
