"""Dynamic rebalancing: the refinement game must see the real machines."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.des.engine import DESConfig, _refine_partition, make_initial_state


def test_refine_partition_uses_live_speeds():
    """REGRESSION (hardcoded speeds = 1/K): refinement must optimize the
    machines' actual speeds.  8 identical LPs on a 3x-vs-1x pair start
    balanced — the uniform-speed game is already at equilibrium there (the
    old code made zero moves), the true game shifts load 3:1."""
    n = 8
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=2 * n,
                    event_capacity=8, history_capacity=16, refine_freq=1,
                    refine_mu=1.0)
    # two seed events per LP, zero adjacency: a pure load game with b_i = 2
    src = np.repeat(np.arange(n, dtype=np.int32), 2)
    state = make_initial_state(cfg, jnp.asarray(np.arange(n) % 2, jnp.int32),
                               src, np.zeros(2 * n, np.float32),
                               np.zeros(2 * n, np.int32))
    adj = jnp.zeros((n, n), jnp.float32)
    speeds = jnp.asarray([3.0, 1.0], jnp.float32)
    out = _refine_partition(cfg, adj, state, speeds)
    loads = np.zeros(2)
    np.add.at(loads, np.asarray(out.machine),
              np.asarray(jnp.sum(state.ev.valid, axis=1), np.float64))
    assert loads[0] >= 2.0 * loads[1], \
        f"refinement ignored the live speeds: loads {loads}"
