"""Dynamic rebalancing under churn (DESIGN.md §11).

Three layers under test:

  * **hysteresis refinement** — per-node migration-price thresholds
    ``theta``: theta=0 reproduces the threshold-free move sequences
    BITWISE (single and distributed backends — the repo's core↔distributed
    contract), accepted moves descend the potential by at least the
    threshold margin (2*theta_i for C_0 via Thm. 3.1, theta_i for Ct_0 via
    Thm. 5.1), and larger thresholds never move more;
  * **heterogeneous machines** — busy-time scales inversely with the
    resident machine's speed, refinement optimizes the LIVE speeds
    (regression for the hardcoded-uniform bug), and speed schedules drive
    churn scenarios;
  * **migration cost in the DES** — state-sized transfer freezes, with the
    flood-closure oracle proving the Time Warp semantics survive the whole
    churn + hysteresis + freeze stack.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import costs
from repro.core.problem import make_problem
from repro.core.refine import refine, refine_simultaneous, refine_traced
from repro.des import scenarios
from repro.des.engine import (DESConfig, _refine_partition, des_tick,
                              make_initial_state, run_simulation)
from repro.des.workload import flooded_packet_workload
from repro.distributed import refine_distributed, refine_distributed_traced
from repro.graphs.generators import random_degree_graph, random_weights


def _problem(n=80, k=4, seed=0, mu=8.0):
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    speeds = np.asarray([0.1, 0.2, 0.3, 0.4][:k])
    prob = make_problem(c, b, speeds, mu=mu)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, r0


def _theta(n, seed, scale=10.0):
    return jnp.asarray(
        np.random.default_rng(seed).uniform(0, scale, n), jnp.float32)


# ---------------------------------------------------------------------------
# theta = 0 bitwise contracts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
@pytest.mark.parametrize("zero", [0.0, "vector"])
def test_theta_zero_bitwise_single(framework, zero):
    """theta=0 (scalar and (N,)) reproduces today's move sequence bitwise
    on the single controller — gains compared with assert_array_equal."""
    prob, r0 = _problem(seed=3)
    theta = jnp.zeros(prob.num_nodes) if zero == "vector" else zero
    ref_res, ref_tr = refine_traced(prob, r0, framework, max_turns=300)
    res, tr = refine_traced(prob, r0, framework, max_turns=300, theta=theta)
    for field in ("moved", "node", "source", "dest", "gain", "c0", "ct0"):
        np.testing.assert_array_equal(np.asarray(getattr(ref_tr, field)),
                                      np.asarray(getattr(tr, field)),
                                      err_msg=field)
    np.testing.assert_array_equal(np.asarray(ref_res.assignment),
                                  np.asarray(res.assignment))
    assert int(ref_res.num_moves) == int(res.num_moves)


@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
def test_theta_zero_bitwise_distributed(framework):
    """theta=0 through the sharded runtime == the threshold-free single
    controller, move for move (the core↔distributed contract holds with
    the hysteresis path threaded in)."""
    prob, r0 = _problem(seed=5)
    ref_res, ref_tr = refine_traced(prob, r0, framework, max_turns=300)
    res, tr = refine_distributed_traced(prob, r0, framework, num_shards=3,
                                        max_turns=300,
                                        theta=jnp.zeros(prob.num_nodes))
    for field in ("moved", "node", "source", "dest", "gain"):
        np.testing.assert_array_equal(np.asarray(getattr(ref_tr, field)),
                                      np.asarray(getattr(tr, field)),
                                      err_msg=field)
    np.testing.assert_array_equal(np.asarray(ref_res.assignment),
                                  np.asarray(res.assignment))


@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
def test_theta_nonzero_distributed_matches_single(framework):
    """Per-node thresholds are evaluated shard-locally yet the distributed
    move sequence stays bitwise-identical to the controller's."""
    prob, r0 = _problem(seed=7)
    theta = _theta(prob.num_nodes, seed=8, scale=20.0)
    ref_res, ref_tr = refine_traced(prob, r0, framework, max_turns=300,
                                    theta=theta)
    res, tr = refine_distributed_traced(prob, r0, framework, num_shards=5,
                                        max_turns=300, theta=theta)
    for field in ("moved", "node", "source", "dest", "gain"):
        np.testing.assert_array_equal(np.asarray(getattr(ref_tr, field)),
                                      np.asarray(getattr(tr, field)),
                                      err_msg=field)
    np.testing.assert_array_equal(np.asarray(ref_res.assignment),
                                  np.asarray(res.assignment))
    # while-loop production drivers land on the same fixed point
    w_ref = refine(prob, r0, framework, theta=theta)
    w_dist = refine_distributed(prob, r0, framework, num_shards=5,
                                theta=theta)
    np.testing.assert_array_equal(np.asarray(w_ref.assignment),
                                  np.asarray(w_dist.assignment))
    assert int(w_ref.num_moves) == int(w_dist.num_moves)


# ---------------------------------------------------------------------------
# descent + monotonicity properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
def test_hysteresis_descent_margin(framework):
    """Every accepted move decreases the framework's own potential by at
    least the threshold margin: 2*theta_i for C_0 (Thm. 3.1), theta_i for
    Ct_0 (Thm. 5.1) — the reason Thm. 4.1 convergence survives theta."""
    prob, r0 = _problem(seed=11)
    theta = _theta(prob.num_nodes, seed=12, scale=15.0)
    _, tr = refine_traced(prob, r0, framework, max_turns=300, theta=theta)
    own = np.asarray(tr.c0 if framework == costs.C_FRAMEWORK else tr.ct0,
                     np.float64)
    init = float(costs.global_cost(prob, r0, framework))
    prev = np.concatenate([[init], own[:-1]])
    moved = np.asarray(tr.moved)
    node = np.asarray(tr.node)
    margin = 2.0 if framework == costs.C_FRAMEWORK else 1.0
    th = np.asarray(theta, np.float64)
    assert moved.any(), "instance produced no moves — test is vacuous"
    for t in np.flatnonzero(moved):
        delta = own[t] - prev[t]
        bound = -margin * th[node[t]] + 1e-4 * abs(prev[t]) + 1e-3
        assert delta <= bound, \
            f"turn {t}: potential fell by {-delta:.4f} < " \
            f"{margin}*theta={margin * th[node[t]]:.4f}"


def test_theta_monotone_no_more_moves():
    """Raising a uniform threshold never increases the number of accepted
    moves, and a prohibitive threshold accepts none (instant convergence)."""
    prob, r0 = _problem(seed=13)
    moves = []
    for th in (0.0, 2.0, 10.0, 50.0, 1e9):
        res = refine(prob, r0, "c", theta=th)
        assert bool(res.converged)
        moves.append(int(res.num_moves))
    assert all(a >= b for a, b in zip(moves, moves[1:])), moves
    assert moves[0] > 0
    assert moves[-1] == 0


def test_theta_simultaneous_mode():
    """§4.5 sweep mode honors theta: zero thresholds reproduce the
    unthresholded sweeps bitwise; prohibitive thresholds freeze the game."""
    prob, r0 = _problem(seed=17)
    ref_res, (rc0, rct0, ract) = refine_simultaneous(prob, r0, "c")
    res, (c0, ct0, act) = refine_simultaneous(prob, r0, "c",
                                              theta=jnp.zeros(prob.num_nodes))
    np.testing.assert_array_equal(np.asarray(ref_res.assignment),
                                  np.asarray(res.assignment))
    np.testing.assert_array_equal(np.asarray(rc0), np.asarray(c0))
    assert int(ref_res.num_moves) == int(res.num_moves)
    frozen, _ = refine_simultaneous(prob, r0, "c", theta=1e9)
    assert int(frozen.num_moves) == 0
    np.testing.assert_array_equal(np.asarray(frozen.assignment),
                                  np.asarray(r0))


# ---------------------------------------------------------------------------
# heterogeneous machines in the DES engine
# ---------------------------------------------------------------------------

def _flat_workload(n, num_threads, scope=0):
    """num_threads threads spread round-robin over LPs, all at t=0."""
    src = np.arange(num_threads, dtype=np.int32) % n
    return (src, np.zeros(num_threads, np.float32),
            np.full(num_threads, scope, np.int32))


def test_busy_ticks_scale_with_machine_speed():
    """One tick: an LP starting an event on a 4x machine owes a quarter of
    the busy ticks of the same-density 1x machine."""
    n = 4
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=n,
                    event_capacity=8, history_capacity=16, proc_ticks=2,
                    machine_speeds=(1.0, 4.0))
    src, time, count = _flat_workload(n, n)
    state = make_initial_state(cfg, jnp.asarray([0, 0, 1, 1], jnp.int32),
                               src, time, count)
    adj = jnp.zeros((n, n), jnp.float32)
    out = des_tick(cfg, adj, state)
    # both machines host 2 LPs: base cost 2*2 = 4 ticks; machine 1 is 4x
    np.testing.assert_array_equal(np.asarray(out.busy_tick), [4, 4, 1, 1])
    assert bool(out.busy.all())


def test_machine_speeds_must_match_machine_count():
    cfg = DESConfig(num_lps=4, num_machines=2, num_threads=1,
                    machine_speeds=(1.0, 1.0, 1.0))
    src, time, count = _flat_workload(4, 1)
    state = make_initial_state(cfg, jnp.zeros(4, jnp.int32), src, time, count)
    with pytest.raises(ValueError, match="machine_speeds"):
        des_tick(cfg, jnp.zeros((4, 4), jnp.float32), state)


def test_fast_machines_drain_sooner():
    """The same workload finishes in fewer wall ticks when every machine
    is 4x, and with per-machine imbalance the slow machine's event lists
    run longer than the fast machine's."""
    n, t = 20, 6
    adj = random_degree_graph(n, seed=21, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 22, num_threads=t, scope=2,
                                   max_per_lp=3)
    ticks = {}
    for name, sp in (("slow", (1.0, 1.0)), ("fast", (4.0, 4.0))):
        cfg = DESConfig(num_lps=n, num_machines=2, num_threads=t,
                        event_capacity=32, history_capacity=64,
                        machine_speeds=sp, max_ticks=60_000)
        state = make_initial_state(cfg, jnp.arange(n, dtype=jnp.int32) % 2,
                                   spec.src, spec.time, spec.count)
        out = run_simulation(cfg, jnp.asarray(adj, jnp.float32), state)
        assert bool(out.done)
        ticks[name] = int(out.tick)
    assert ticks["fast"] < ticks["slow"], ticks

    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=t,
                    event_capacity=32, history_capacity=64,
                    machine_speeds=(0.25, 1.0), trace_stride=5,
                    max_ticks=60_000)
    state = make_initial_state(cfg, jnp.arange(n, dtype=jnp.int32) % 2,
                               spec.src, spec.time, spec.count)
    out = run_simulation(cfg, jnp.asarray(adj, jnp.float32), state)
    ptr = int(out.trace_ptr)
    tr = np.asarray(out.trace)[:ptr]
    assert tr.shape[0] > 0
    # slower machine (column 0) carries the longer queues on average
    assert tr[:, 0].mean() > tr[:, 1].mean()
    # speed-normalized backlog trace: wload = total queue / speed, so the
    # 4x-slower machine's drain-time disadvantage is even starker (each LP
    # hosts 10 LPs/machine: wload = mean_len * 10 / speed)
    wl = np.asarray(out.trace_wload)[:ptr]
    np.testing.assert_allclose(wl[:, 0], tr[:, 0] * 10 / 0.25, rtol=1e-5)
    np.testing.assert_allclose(wl[:, 1], tr[:, 1] * 10 / 1.0, rtol=1e-5)
    assert wl[:, 0].mean() > wl[:, 1].mean()


def test_refine_partition_uses_live_speeds():
    """REGRESSION (hardcoded speeds = 1/K): refinement must optimize the
    machines' actual speeds.  8 identical LPs on a 3x-vs-1x pair start
    balanced — the uniform-speed game is already at equilibrium there (the
    old code made zero moves), the true game shifts load 3:1."""
    n = 8
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=2 * n,
                    event_capacity=8, history_capacity=16, refine_freq=1,
                    refine_mu=1.0)
    # two seed events per LP, zero adjacency: a pure load game with b_i = 2
    src = np.repeat(np.arange(n, dtype=np.int32), 2)
    state = make_initial_state(cfg, jnp.asarray(np.arange(n) % 2, jnp.int32),
                               src, np.zeros(2 * n, np.float32),
                               np.zeros(2 * n, np.int32))
    adj = jnp.zeros((n, n), jnp.float32)
    speeds = jnp.asarray([3.0, 1.0], jnp.float32)
    out = _refine_partition(cfg, adj, state, speeds)
    loads = np.zeros(2)
    np.add.at(loads, np.asarray(out.machine),
              np.asarray(jnp.sum(state.ev.valid, axis=1), np.float64))
    assert loads[0] >= 2.0 * loads[1], \
        f"refinement ignored the live speeds: loads {loads}"


# ---------------------------------------------------------------------------
# speed schedules (churn scenarios)
# ---------------------------------------------------------------------------

def test_schedule_lookup_boundaries():
    sched = scenarios.make_schedule(
        [0, 10, 20], [[1.0, 1.0], [0.5, 1.0], [1.0, 0.25]])
    for tick, want in ((0, [1.0, 1.0]), (9, [1.0, 1.0]), (10, [0.5, 1.0]),
                       (19, [0.5, 1.0]), (20, [1.0, 0.25]),
                       (1000, [1.0, 0.25])):
        np.testing.assert_allclose(
            np.asarray(scenarios.speeds_at(sched, jnp.int32(tick))), want)


def test_schedule_validation():
    with pytest.raises(ValueError, match="start at tick 0"):
        scenarios.make_schedule([5], [[1.0]])
    with pytest.raises(ValueError, match="ascending"):
        scenarios.make_schedule([0, 10, 10], [[1.0]] * 3)
    with pytest.raises(ValueError, match="shape mismatch"):
        scenarios.make_schedule([0, 10], [[1.0]])
    # failed machines are floored, not stopped (busy-time divides by speed)
    sched = scenarios.make_schedule([0], [[0.0, 1.0]])
    assert float(sched.speeds[0, 0]) == pytest.approx(scenarios.MIN_SPEED)


def test_scenario_builders():
    sd = scenarios.slowdown(3, machine=1, at_tick=100, factor=0.25,
                            recover_tick=300)
    assert sd.speeds.shape == (3, 3)
    np.testing.assert_allclose(np.asarray(sd.speeds[:, 1]),
                               [1.0, 0.25, 1.0])
    np.testing.assert_allclose(np.asarray(sd.speeds[:, 0]), 1.0)
    fr = scenarios.failure_recovery(2, machine=0, fail_tick=50,
                                    recover_tick=200)
    assert float(fr.speeds[1, 0]) == pytest.approx(scenarios.MIN_SPEED)
    assert float(fr.speeds[2, 0]) == pytest.approx(1.0)
    ch = scenarios.random_churn(4, num_segments=6, segment_ticks=50, seed=3,
                                low=0.3, high=1.0)
    sp = np.asarray(ch.speeds)
    assert sp.shape == (6, 4) and (sp >= 0.3).all() and (sp <= 1.0).all()
    np.testing.assert_array_equal(np.asarray(ch.times),
                                  np.arange(6) * 50)
    with pytest.raises(ValueError):
        scenarios.random_churn(2, num_segments=0, segment_ticks=50, seed=0)


def test_constant_schedule_matches_static_speeds():
    """A constant all-ones schedule is the uniform no-schedule run."""
    n, t = 16, 4
    adj = random_degree_graph(n, seed=31, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 32, num_threads=t, scope=2,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=t,
                    event_capacity=32, history_capacity=64, max_ticks=40_000)
    m0 = jnp.arange(n, dtype=jnp.int32) % 2
    adjj = jnp.asarray(adj, jnp.float32)
    base = run_simulation(cfg, adjj, make_initial_state(
        cfg, m0, spec.src, spec.time, spec.count))
    sched = run_simulation(cfg, adjj, make_initial_state(
        cfg, m0, spec.src, spec.time, spec.count), scenarios.constant(2))
    assert int(base.tick) == int(sched.tick)
    assert int(base.processed) == int(sched.processed)
    np.testing.assert_array_equal(np.asarray(base.seen),
                                  np.asarray(sched.seen))


# ---------------------------------------------------------------------------
# workload fixes
# ---------------------------------------------------------------------------

def test_workload_per_thread_scope_rides_the_time_sort():
    """Per-thread scopes must stay associated with their thread after the
    injection-time sort.  Scopes are constant per window, and windows
    partition the time axis — so every returned thread's count must equal
    its window's scope (the un-permuted bug returns generation order)."""
    adj = random_degree_graph(30, seed=41, dmin=2, dmax=4)
    t, w, wt = 16, 4, 25.0
    scope = np.repeat(np.arange(1, w + 1, dtype=np.int32), t // w)
    spec = flooded_packet_workload(adj, 42, num_threads=t, num_windows=w,
                                   window_sim_time=wt, scope=scope)
    want = (np.asarray(spec.time) // wt).astype(np.int32) + 1
    np.testing.assert_array_equal(spec.count, want)
    # scalar scope is unchanged behavior
    spec_s = flooded_packet_workload(adj, 42, num_threads=t, num_windows=w,
                                     window_sim_time=wt, scope=3)
    np.testing.assert_array_equal(spec_s.count, 3)
    np.testing.assert_array_equal(spec_s.src, spec.src)


def test_workload_capacity_overflow_raises():
    """More threads than seed slots must fail loudly, not overflow the
    seeding scatter (silent OOB drops under jit)."""
    adj = np.ones((2, 2)) - np.eye(2)
    with pytest.raises(ValueError, match="max_per_lp"):
        flooded_packet_workload(adj, 1, num_threads=10, max_per_lp=2)


def test_trace_ptr_clamped_at_max_trace():
    n, t = 12, 3
    adj = random_degree_graph(n, seed=51, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 52, num_threads=t, scope=2,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=t,
                    event_capacity=32, history_capacity=64,
                    trace_stride=1, max_trace=4, max_ticks=40_000)
    state = make_initial_state(cfg, jnp.arange(n, dtype=jnp.int32) % 2,
                               spec.src, spec.time, spec.count)
    out = run_simulation(cfg, jnp.asarray(adj, jnp.float32), state)
    assert int(out.tick) > 4          # ran long past the trace capacity
    assert int(out.trace_ptr) == 4    # ... but the pointer stopped at max


# ---------------------------------------------------------------------------
# the whole stack: churn + hysteresis + freeze keep Time Warp semantics
# ---------------------------------------------------------------------------

from test_des import _hop_closure  # noqa: E402 — the one closure oracle


@pytest.mark.parametrize("backend", ["single", "distributed"])
def test_flood_closure_oracle_under_churn_stack(backend):
    """Heterogeneous speeds + failure/recovery churn + state-sized
    hysteresis + transfer freezes: the final seen-sets still equal the
    exact k-hop closures, and both refine backends drain."""
    n, t = 24, 6
    adj = random_degree_graph(n, seed=61, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 62, num_threads=t, scope=2,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=3, num_threads=t,
                    event_capacity=32, history_capacity=64,
                    refine_freq=100, max_ticks=60_000,
                    machine_speeds=(1.0, 0.5, 2.0),
                    refine_theta_scale=0.1, migration_freeze=0.25,
                    refine_backend=backend)
    sched = scenarios.failure_recovery(3, machine=2, fail_tick=150,
                                       recover_tick=400)
    state = make_initial_state(cfg, jnp.arange(n, dtype=jnp.int32) % 3,
                               spec.src, spec.time, spec.count)
    out = run_simulation(cfg, jnp.asarray(adj, jnp.float32), state, sched)
    assert bool(out.done), f"not drained after {int(out.tick)} ticks"
    assert int(out.refines) >= 1
    seen = np.asarray(out.seen)
    for j in range(t):
        want = _hop_closure(adj, int(spec.src[j]), int(spec.count[j]))
        np.testing.assert_array_equal(seen[:, j], want,
                                      err_msg=f"thread {j}")


def test_des_backends_agree_with_theta_and_churn():
    """single vs distributed refine backends stay move-for-move identical
    with live speeds + state-sized theta in play (the bitwise contract,
    end to end through the engine)."""
    n, t = 24, 6
    adj = random_degree_graph(n, seed=71, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 72, num_threads=t, scope=2,
                                   max_per_lp=3)
    outs = {}
    for backend in ("single", "distributed"):
        cfg = DESConfig(num_lps=n, num_machines=3, num_threads=t,
                        event_capacity=32, history_capacity=64,
                        refine_freq=120, max_ticks=60_000,
                        machine_speeds=(2.0, 1.0, 0.5),
                        refine_theta_scale=0.15, migration_freeze=0.2,
                        refine_backend=backend)
        state = make_initial_state(cfg, jnp.arange(n, dtype=jnp.int32) % 3,
                                   spec.src, spec.time, spec.count)
        outs[backend] = run_simulation(cfg, jnp.asarray(adj, jnp.float32),
                                       state)
    a, b = outs["single"], outs["distributed"]
    assert bool(a.done) and bool(b.done)
    assert int(a.refines) > 0
    np.testing.assert_array_equal(np.asarray(a.machine),
                                  np.asarray(b.machine))
    assert int(a.moves) == int(b.moves)
    assert int(a.tick) == int(b.tick)
