"""Sparse edge-list runtime (DESIGN.md §13): round trips, aggregate
invariants, refinement agreement with the dense path, batched sweeps and
the fused edge-block kernel."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import costs
from repro.core.aggregate import apply_move, init_aggregate_state
from repro.core.batch import problem_shape_key, stack_problems
from repro.core.problem import make_problem, make_state
from repro.core.refine import (refine, refine_simultaneous, refine_sweeps,
                               refine_traced)
from repro.core.sparse import (SparseProblem, dense_from_sparse,
                               make_sparse_problem, node_incident_edges,
                               sparse_from_dense)
from repro.graphs.generators import (random_degree_graph,
                                     random_degree_graph_edges,
                                     random_weights, random_weights_edges)
from repro import sweeps


def _instance(n=60, k=4, seed=0):
    adj = random_degree_graph(n, seed=seed, dmin=2, dmax=4)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    prob = make_problem(c, b, np.linspace(0.5, 2.0, k), mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, sparse_from_dense(prob), r0


# ---------------------------------------------------------------------------
# representation: round trips, layout invariants
# ---------------------------------------------------------------------------

def test_dense_sparse_dense_round_trip_exact():
    prob, sp, _ = _instance()
    back = dense_from_sparse(sp)
    np.testing.assert_array_equal(np.asarray(back.adjacency),
                                  np.asarray(prob.adjacency))
    np.testing.assert_array_equal(np.asarray(back.node_weights),
                                  np.asarray(prob.node_weights))
    np.testing.assert_array_equal(np.asarray(back.speeds),
                                  np.asarray(prob.speeds))


@given(n=st.integers(6, 40), seed=st.integers(0, 5_000))
@settings(max_examples=10)
def test_round_trip_property(n, seed):
    adj = random_degree_graph(n, seed=seed, dmin=1, dmax=3)
    b, c = random_weights(adj, seed=seed + 7, mean=5.0)
    prob = make_problem(c, b, np.ones(3) / 3, mu=4.0)
    back = dense_from_sparse(sparse_from_dense(prob))
    np.testing.assert_array_equal(np.asarray(back.adjacency),
                                  np.asarray(prob.adjacency))


def test_sparse_layout_invariants():
    _, sp, _ = _instance()
    sp.validate()
    s = np.asarray(sp.senders)
    r = np.asarray(sp.receivers)
    w = np.asarray(sp.edge_weights)
    rs = np.asarray(sp.row_start)
    assert np.all(np.diff(s) >= 0), "senders must be sorted"
    # directed edge count (before padding) is even: both orientations
    assert (w > 0).sum() % 2 == 0
    # row_start really is the CSR offset of each node's slab
    for node in range(sp.num_nodes):
        real = np.flatnonzero((s == node) & (w > 0))
        if real.size:
            assert real[0] == rs[node]
            assert np.all(np.diff(real) == 1)
            assert real.size <= sp.max_degree
    # padded slots are weight-0 and keep sortedness
    pad = np.flatnonzero(w == 0)
    assert np.all(s[pad] == sp.num_nodes - 1) or pad.size == 0


def test_make_sparse_problem_dedupes_and_drops_loops():
    sp = make_sparse_problem([0, 1, 0, 2], [1, 0, 0, 1],
                             [2.0, 3.0, 9.0, 1.0],
                             np.ones(3), np.ones(2), mu=1.0)
    dense = np.asarray(dense_from_sparse(sp).adjacency)
    assert dense[0, 1] == 5.0          # duplicate {0,1} weights summed
    assert dense[0, 0] == 0.0          # self loop dropped
    assert dense[1, 2] == 1.0


def test_node_incident_edges_window():
    prob, sp, _ = _instance()
    adj = np.asarray(prob.adjacency)
    for node in [0, 7, sp.num_nodes - 1]:
        nbrs, w = node_incident_edges(sp, jnp.asarray(node))
        got = np.zeros(sp.num_nodes, np.float32)
        np.add.at(got, np.asarray(nbrs), np.asarray(w))
        np.testing.assert_array_equal(got, adj[node])


# ---------------------------------------------------------------------------
# costs: aggregates, cut, potentials
# ---------------------------------------------------------------------------

def test_sparse_aggregate_matches_dense():
    prob, sp, r0 = _instance()
    a_dense = costs.adjacency_aggregate(prob.adjacency, r0,
                                        prob.num_machines)
    a_sparse = costs.adjacency_aggregate_sparse(sp, r0)
    np.testing.assert_allclose(np.asarray(a_sparse), np.asarray(a_dense),
                               rtol=1e-6, atol=1e-4)


def test_sparse_cut_and_potentials_match_dense():
    prob, sp, r0 = _instance()
    np.testing.assert_allclose(float(costs.total_cut_sparse(sp, r0)),
                               float(costs.total_cut(prob.adjacency, r0)),
                               rtol=1e-6)
    for fn in (costs.global_cost_c0, costs.global_cost_ct0):
        d, s = float(fn(prob, r0)), float(fn(sp, r0))
        assert abs(d - s) <= 1e-3 * abs(d), (fn.__name__, d, s)


def test_sparse_cost_matrix_matches_dense():
    prob, sp, r0 = _instance()
    st_ = make_state(prob, r0)
    for fw in costs.FRAMEWORKS:
        cd = np.asarray(costs.cost_matrix(prob, st_, fw), np.float64)
        cs = np.asarray(costs.cost_matrix(sp, st_, fw), np.float64)
        assert np.max(np.abs(cd - cs) / (np.abs(cd) + 1.0)) < 1e-5


# ---------------------------------------------------------------------------
# aggregate carry: I1-I4 over edge aggregates, O(deg) moves
# ---------------------------------------------------------------------------

def test_sparse_init_aggregate_invariants():
    prob, sp, r0 = _instance()
    agg = init_aggregate_state(sp, r0)
    # I1 vs the dense oracle
    np.testing.assert_allclose(
        np.asarray(agg.aggregate),
        np.asarray(costs.adjacency_aggregate(prob.adjacency, r0,
                                             prob.num_machines)),
        rtol=1e-6, atol=1e-4)
    # I2
    np.testing.assert_allclose(
        np.asarray(agg.loads),
        np.asarray(jnp.zeros(4).at[r0].add(prob.node_weights)), rtol=1e-6)
    # I3
    assert abs(float(agg.c0) - float(costs.global_cost_c0(sp, r0))) == 0.0
    assert abs(float(agg.ct0) - float(costs.global_cost_ct0(sp, r0))) == 0.0


def test_sparse_apply_move_matches_rebuild():
    _, sp, r0 = _instance()
    agg = init_aggregate_state(sp, r0)
    total_b = jnp.sum(sp.node_weights)
    node, source, dest = jnp.asarray(5), r0[5], jnp.asarray(
        (int(r0[5]) + 1) % 4)
    moved = apply_move(sp, agg, node, source, dest, jnp.asarray(True),
                       total_b)
    fresh = init_aggregate_state(sp, moved.assignment)
    np.testing.assert_allclose(np.asarray(moved.aggregate),
                               np.asarray(fresh.aggregate),
                               rtol=1e-6, atol=1e-4)
    assert abs(float(moved.c0) - float(fresh.c0)) \
        <= 1e-3 * abs(float(fresh.c0))
    # gated-off move is the identity
    frozen = apply_move(sp, agg, node, source, dest, jnp.asarray(False),
                        total_b)
    np.testing.assert_array_equal(np.asarray(frozen.assignment),
                                  np.asarray(agg.assignment))
    np.testing.assert_array_equal(np.asarray(frozen.aggregate),
                                  np.asarray(agg.aggregate))


def test_sparse_drift_small_after_refinement():
    # f32-noise-sized vs the O(1e5) carried potentials — the same bound
    # test_incremental.py pins for the dense carry
    _, sp, r0 = _instance(n=80, k=4, seed=3)
    res = refine(sp, r0, "c", verify_every=16)
    assert float(res.aggregate_drift) < 1.0


# ---------------------------------------------------------------------------
# refinement: sparse reproduces dense accepted-move sequences
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fw", costs.FRAMEWORKS)
@pytest.mark.parametrize("theta", [None, 0.5])
def test_sparse_traced_matches_dense(fw, theta):
    prob, sp, r0 = _instance(n=90, k=5, seed=2)
    res_d, tr_d = refine_traced(prob, r0, fw, max_turns=192, theta=theta)
    res_s, tr_s = refine_traced(sp, r0, fw, max_turns=192, theta=theta)
    for field in ("moved", "node", "source", "dest", "active"):
        np.testing.assert_array_equal(np.asarray(getattr(tr_s, field)),
                                      np.asarray(getattr(tr_d, field)),
                                      err_msg=field)
    np.testing.assert_array_equal(np.asarray(res_s.assignment),
                                  np.asarray(res_d.assignment))
    for pot in ("c0", "ct0"):
        a = np.asarray(getattr(tr_s, pot), np.float64)
        b = np.asarray(getattr(tr_d, pot), np.float64)
        assert np.max(np.abs(a - b) / np.maximum(np.abs(b), 1.0)) <= 1e-3


def test_sparse_refine_and_simultaneous_match_dense():
    prob, sp, r0 = _instance(n=72, k=4, seed=5)
    rd = refine(prob, r0, "ct")
    rs = refine(sp, r0, "ct")
    assert int(rd.num_moves) == int(rs.num_moves)
    np.testing.assert_array_equal(np.asarray(rs.assignment),
                                  np.asarray(rd.assignment))
    rd, (c0d, _, actd) = refine_simultaneous(prob, r0, "c", max_sweeps=48)
    rs, (c0s, _, acts) = refine_simultaneous(sp, r0, "c", max_sweeps=48)
    np.testing.assert_array_equal(np.asarray(rs.assignment),
                                  np.asarray(rd.assignment))
    np.testing.assert_array_equal(np.asarray(acts), np.asarray(actd))


def test_sparse_theta_zero_matches_none_bitwise():
    _, sp, r0 = _instance(n=64, k=4, seed=9)
    res0, tr0 = refine_traced(sp, r0, "c", max_turns=128, theta=None)
    resz, trz = refine_traced(sp, r0, "c", max_turns=128, theta=0.0)
    for field in ("moved", "node", "source", "dest", "gain"):
        np.testing.assert_array_equal(np.asarray(getattr(tr0, field)),
                                      np.asarray(getattr(trz, field)))


def test_pure_edge_list_pipeline_never_densifies():
    """End to end from generators: edges -> SparseProblem -> refinement,
    no (N, N) array anywhere; sanity-checked against the densified twin."""
    n, k = 120, 4
    s, r = random_degree_graph_edges(n, seed=11)
    b, w = random_weights_edges(n, s, seed=12, mean=5.0)
    sp = make_sparse_problem(s, r, w, b, np.ones(k) / k, mu=8.0)
    r0 = jnp.asarray(np.arange(n) % k, jnp.int32)
    res_s = refine(sp, r0, "c")
    res_d = refine(dense_from_sparse(sp), r0, "c")
    assert int(res_s.num_moves) == int(res_d.num_moves)
    np.testing.assert_array_equal(np.asarray(res_s.assignment),
                                  np.asarray(res_d.assignment))
    assert bool(res_s.converged)


# ---------------------------------------------------------------------------
# batching: stacking rules + vmapped sparse fleets
# ---------------------------------------------------------------------------

def test_problem_shape_key_and_stacking():
    prob, sp, _ = _instance(seed=0)
    _, sp2, _ = _instance(seed=1)
    assert problem_shape_key(sp) == problem_shape_key(sp2)
    assert problem_shape_key(sp) != problem_shape_key(prob)
    stacked = stack_problems([sp, sp2])
    assert isinstance(stacked, SparseProblem)
    assert stacked.senders.shape == (2, sp.num_edges)
    assert stacked.max_degree == sp.max_degree
    with pytest.raises(ValueError):
        stack_problems([prob, sp])


def test_sparse_sweep_matches_looped_bitwise():
    cases, looped = [], []
    for seed in range(3):
        _, sp, r0 = _instance(n=48, k=3, seed=seed)
        cases.append(sweeps.SweepCase(problem=sp, assignment=r0,
                                      framework="c", label=f"s{seed}"))
        looped.append(refine_traced(sp, r0, "c", max_turns=96))
    res = sweeps.run_sweep(sweeps.make_spec(cases, mode="traced",
                                            max_turns=96))
    for i, (lr, lt) in enumerate(looped):
        for field in ("moved", "node", "source", "dest", "gain"):
            np.testing.assert_array_equal(
                np.asarray(getattr(res.traces[i], field)),
                np.asarray(getattr(lt, field)), err_msg=f"case {i} {field}")
        np.testing.assert_array_equal(np.asarray(res.results[i].assignment),
                                      np.asarray(lr.assignment))


def test_sparse_and_dense_cases_group_separately():
    prob, sp, r0 = _instance(seed=4)
    res = sweeps.run_sweep(sweeps.make_spec(
        [sweeps.SweepCase(problem=prob, assignment=r0, framework="c"),
         sweeps.SweepCase(problem=sp, assignment=r0, framework="c")],
        mode="refine", max_turns=512))
    np.testing.assert_array_equal(np.asarray(res.results[0].assignment),
                                  np.asarray(res.results[1].assignment))


# ---------------------------------------------------------------------------
# edge-block kernel through the dissat_fn seam
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fw", costs.FRAMEWORKS)
@pytest.mark.parametrize("theta", [None, 0.3])
def test_edge_kernel_matches_jnp_reduction(fw, theta):
    from repro.kernels.edge_block import (build_edge_tile_layout,
                                          dissatisfaction_from_edges_pallas)
    _, sp, r0 = _instance(n=150, k=5, seed=6)
    agg = init_aggregate_state(sp, r0)
    total_b = jnp.sum(sp.node_weights)
    cost = costs.cost_matrix_from_aggregate(
        agg.aggregate, r0, sp.node_weights, agg.loads, sp.speeds, sp.mu,
        fw, total_weight=total_b)
    th = None if theta is None else jnp.full((sp.num_nodes,), theta)
    d_ref, b_ref = costs.dissatisfaction_from_cost(cost, r0, th)
    layout = build_edge_tile_layout(sp)
    d_k, b_k = dissatisfaction_from_edges_pallas(
        layout, r0, sp.node_weights, agg.loads, sp.speeds, sp.mu, fw,
        theta=theta, total_weight=total_b)
    np.testing.assert_array_equal(np.asarray(b_k), np.asarray(b_ref))
    # the Ct cost entries are O(1e5) in f32, so a reassociated assembly
    # differs by up to ~1e-3 relative on the dissat differences — the
    # pinned DESIGN.md §13.3 budget
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref),
                               rtol=1e-3, atol=1e-2)


def test_refine_via_edge_kernel_matches_jnp_path():
    from repro.kernels.ops import make_edge_dissat_fn
    _, sp, r0 = _instance(n=100, k=4, seed=8)
    res_j = refine(sp, r0, "c")
    res_k = refine(sp, r0, "c", dissat_fn=make_edge_dissat_fn(sp))
    assert int(res_j.num_moves) == int(res_k.num_moves)
    np.testing.assert_array_equal(np.asarray(res_k.assignment),
                                  np.asarray(res_j.assignment))


def test_edge_kernel_interpret_modes_agree():
    from repro.kernels.edge_block import (build_edge_tile_layout,
                                          dissatisfaction_from_edges_pallas)
    _, sp, r0 = _instance(n=70, k=3, seed=10)
    agg = init_aggregate_state(sp, r0)
    layout = build_edge_tile_layout(sp)
    args = (layout, r0, sp.node_weights, agg.loads, sp.speeds, sp.mu, "c")
    d_i, b_i = dissatisfaction_from_edges_pallas(*args, interpret=True)
    assert np.asarray(d_i).shape == (70,)
    assert np.asarray(b_i).dtype == np.int32
    assert int(np.asarray(b_i).max()) < 3


# ---------------------------------------------------------------------------
# multi-move probabilistic sweeps on SparseProblem (DESIGN.md §17)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fw", costs.FRAMEWORKS)
@pytest.mark.parametrize("theta", [None, 0.5])
def test_sparse_sweeps_degenerate_bitwise(fw, theta):
    """moves_per_machine=1, move_prob=1, epsilon=0 stages refine_simultaneous's
    op sequence on the sparse path too — the whole result must be bitwise."""
    _, sp, r0 = _instance(seed=4)
    res_s, (c0_s, ct0_s, act_s) = refine_simultaneous(
        sp, r0, fw, max_sweeps=64, theta=theta)
    res_w, (c0_w, ct0_w, act_w) = refine_sweeps(
        sp, r0, fw, max_sweeps=64, theta=theta)
    for a, b in zip(jax.tree.leaves(res_s), jax.tree.leaves(res_w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(act_s), np.asarray(act_w))
    np.testing.assert_array_equal(np.asarray(c0_s), np.asarray(c0_w))
    np.testing.assert_array_equal(np.asarray(ct0_s), np.asarray(ct0_w))


@given(seed=st.integers(0, 2_000))
@settings(max_examples=8)
def test_sparse_sweeps_degenerate_bitwise_property(seed):
    """Random sparse instances × frameworks × theta: the degenerate
    config stays bitwise (accepted sweeps and final assignment)."""
    _, sp, r0 = _instance(seed=seed % 11)
    fw = "ct" if seed % 2 else "c"
    theta = None if seed % 3 == 0 else 0.5
    res_s, (_, _, act_s) = refine_simultaneous(sp, r0, fw, max_sweeps=48,
                                               theta=theta)
    res_w, (_, _, act_w) = refine_sweeps(sp, r0, fw, max_sweeps=48,
                                         theta=theta)
    np.testing.assert_array_equal(np.asarray(res_w.assignment),
                                  np.asarray(res_s.assignment))
    np.testing.assert_array_equal(np.asarray(act_w), np.asarray(act_s))


@pytest.mark.parametrize("fw", costs.FRAMEWORKS)
@pytest.mark.parametrize("theta", [None, 0.5])
def test_sparse_dense_multimove_match(fw, theta):
    """Sparse == dense multi-move sweep sequences under a shared key:
    same accepted sweeps, same assignment, same mover count; potentials
    within the §13.3 reassociation budget."""
    prob, sp, r0 = _instance(seed=5)
    key = jax.random.PRNGKey(21)
    kwargs = dict(max_sweeps=128, theta=theta, moves_per_machine=2,
                  move_prob=0.5, epsilon=1e-3, key=key)
    res_d, (c0_d, ct0_d, act_d) = refine_sweeps(prob, r0, fw, **kwargs)
    res_s, (c0_s, ct0_s, act_s) = refine_sweeps(sp, r0, fw, **kwargs)
    np.testing.assert_array_equal(np.asarray(res_s.assignment),
                                  np.asarray(res_d.assignment))
    assert int(res_s.num_moves) == int(res_d.num_moves)
    np.testing.assert_array_equal(np.asarray(act_s), np.asarray(act_d))
    for name, a, b in (("c0", c0_d, c0_s), ("ct0", ct0_d, ct0_s)):
        aa = np.asarray(a, np.float64)
        bb = np.asarray(b, np.float64)
        rel = np.max(np.abs(aa - bb) / np.maximum(np.abs(aa), 1e-9))
        assert rel <= 1e-3, (name, rel)


@pytest.mark.parametrize("fw", costs.FRAMEWORKS)
def test_sparse_unbounded_sweeps_descend_and_converge(fw):
    """The unbounded mode with cs/0506098 adaptive acceptance descends to
    an equilibrium below the start (fixed-seed empirical check)."""
    _, sp, r0 = _instance(n=120, k=4, seed=9)
    res, (c0s, ct0s, active) = refine_sweeps(
        sp, r0, fw, max_sweeps=512, moves_per_machine=None,
        move_prob=0.5, epsilon=1e-3, key=jax.random.PRNGKey(3))
    assert bool(res.converged)
    pots = np.asarray(c0s if fw == "c" else ct0s, np.float64)
    n_active = int(np.asarray(active).sum())
    assert n_active >= 1
    assert pots[n_active - 1] < float(costs.global_cost(sp, r0, fw))


def test_refine_sweeps_validation():
    from repro.kernels.ops import make_edge_sweep_fn
    _, sp, r0 = _instance()
    with pytest.raises(ValueError, match="key"):
        refine_sweeps(sp, r0, "c", move_prob=0.5)
    fn = make_edge_sweep_fn(sp, interpret=True)
    with pytest.raises(ValueError, match="moves_per_machine"):
        refine_sweeps(sp, r0, "c", sweep_fn=fn, moves_per_machine=2)
    with pytest.raises(ValueError, match="not both"):
        refine_sweeps(sp, r0, "c", sweep_fn=fn, dissat_fn=fn)


# ---------------------------------------------------------------------------
# fused sweep-election kernel (DESIGN.md §17.4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fw", costs.FRAMEWORKS)
@pytest.mark.parametrize("theta", [None, 0.5])
def test_sweep_kernel_election_matches_jnp(fw, theta):
    """The kernel's per-machine election — gains, picks, destinations —
    against the jnp reference: picks/dests EXACTLY (same lowest-index
    tie-break as jnp.argmax), gains within the §13.3 budget."""
    from repro.kernels.edge_block import (build_edge_tile_layout,
                                          sweep_candidates_from_edges_pallas)
    _, sp, r0 = _instance(n=150, k=5, seed=6)
    agg = init_aggregate_state(sp, r0)
    total_b = jnp.sum(sp.node_weights)
    th = None if theta is None else jnp.full((sp.num_nodes,), theta)
    cost = costs.cost_matrix_from_aggregate(
        agg.aggregate, r0, sp.node_weights, agg.loads, sp.speeds, sp.mu,
        fw, total_weight=total_b)
    dissat, best = costs.dissatisfaction_from_cost(cost, r0, th)
    owned = jax.nn.one_hot(r0, sp.num_machines, dtype=dissat.dtype)
    masked = jnp.where(owned.T > 0, dissat[None, :], -jnp.inf)
    pick_ref = jnp.argmax(masked, axis=1).astype(jnp.int32)
    gain_ref = jnp.max(masked, axis=1)
    dest_ref = best[pick_ref]

    layout = build_edge_tile_layout(sp)
    gain_k, pick_k, dest_k = sweep_candidates_from_edges_pallas(
        layout, r0, sp.node_weights, agg.loads, sp.speeds, sp.mu, fw,
        theta=theta, total_weight=total_b)
    np.testing.assert_array_equal(np.asarray(pick_k), np.asarray(pick_ref))
    np.testing.assert_array_equal(np.asarray(dest_k), np.asarray(dest_ref))
    np.testing.assert_allclose(np.asarray(gain_k), np.asarray(gain_ref),
                               rtol=1e-3, atol=5e-2)


def test_refine_sweeps_via_sweep_fn_matches_jnp_path():
    """Full refinement through the fused election == the jnp path: same
    coins (shared key, same (K,) shape), so identical elections must give
    identical accepted sweeps, assignment and mover counts."""
    from repro.kernels.ops import make_edge_sweep_fn
    _, sp, r0 = _instance(n=100, k=4, seed=8)
    fn = make_edge_sweep_fn(sp)
    for fw in costs.FRAMEWORKS:
        kwargs = dict(max_sweeps=256, move_prob=0.5, epsilon=1e-3,
                      key=jax.random.PRNGKey(5))
        res_j, (_, _, act_j) = refine_sweeps(sp, r0, fw, **kwargs)
        res_k, (_, _, act_k) = refine_sweeps(sp, r0, fw, sweep_fn=fn,
                                             **kwargs)
        assert bool(res_j.converged) and bool(res_k.converged)
        assert int(res_j.num_moves) == int(res_k.num_moves)
        np.testing.assert_array_equal(np.asarray(res_k.assignment),
                                      np.asarray(res_j.assignment))
        np.testing.assert_array_equal(np.asarray(act_k), np.asarray(act_j))
