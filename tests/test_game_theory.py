"""The paper's theoretical backbone, verified numerically.

  * Theorem 3.1 — C_0 is an exact potential up to factor 2:
        C_0(r*) - C_0(r) = 2 (C_l(r*) - C_l(r))   for any unilateral move.
  * Theorem 5.1 — Ct_i is the exact move-differential of Ct_0 (Eq. 8):
        Ct_0(r*) - Ct_0(r) = Ct_l(r*) - Ct_l(r).
  * Theorem 4.1 — best-response refinement converges; every accepted move
    strictly descends the respective potential; the fixed point is a Nash
    equilibrium (Eq. 3: no node can unilaterally improve).

These identities are algebraic, so hypothesis drives them over random
graphs, weights, speeds, mu, assignments and moves.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import costs
from repro.core.annealing import simulated_annealing
from repro.core.cluster import cluster_move_pass
from repro.core.constrained import equalize_cardinality
from repro.core.problem import make_problem, make_state, machine_loads
from repro.core.refine import (count_discrepancies, refine,
                               refine_simultaneous, refine_traced)
from repro.graphs.generators import random_degree_graph, random_weights

from conftest import small_problem


# ---------------------------------------------------------------------------
# random problem instances for hypothesis
# ---------------------------------------------------------------------------

@st.composite
def problem_instances(draw):
    n = draw(st.integers(6, 40))
    k = draw(st.integers(2, 6))
    seed = draw(st.integers(0, 2**31 - 1))
    mu = draw(st.floats(0.0, 32.0))
    rng = np.random.default_rng(seed)
    # random symmetric adjacency with ~30% density and nonneg weights
    raw = rng.uniform(0.0, 10.0, size=(n, n)) * (rng.random((n, n)) < 0.3)
    b = rng.uniform(0.1, 10.0, size=n)
    speeds = rng.uniform(0.2, 2.0, size=k)
    prob = make_problem(raw, b, speeds, mu=mu)
    r = jnp.asarray(rng.integers(0, k, size=n), jnp.int32)
    node = draw(st.integers(0, n - 1))
    dest = draw(st.integers(0, k - 1))
    return prob, r, node, dest


def _node_cost(prob, r, i, framework):
    state = make_state(prob, r)
    cm = costs.cost_matrix(prob, state, framework)
    return cm[i, r[i]]


# ---------------------------------------------------------------------------
# Theorem 3.1 / 5.1 exact-potential identities
# ---------------------------------------------------------------------------

@given(problem_instances())
def test_theorem_3_1_identity(inst):
    """Delta C_0 == 2 * Delta C_l for any unilateral move of node l."""
    prob, r, node, dest = inst
    r_new = r.at[node].set(dest)
    dc0 = (costs.global_cost_c0(prob, r_new)
           - costs.global_cost_c0(prob, r))
    dcl = (_node_cost(prob, r_new, node, costs.C_FRAMEWORK)
           - _node_cost(prob, r, node, costs.C_FRAMEWORK))
    np.testing.assert_allclose(float(dc0), 2.0 * float(dcl),
                               rtol=1e-4, atol=1e-2)


@given(problem_instances())
def test_theorem_5_1_identity(inst):
    """Delta Ct_0 == Delta Ct_l (Eq. 8 with the unordered-cut convention)."""
    prob, r, node, dest = inst
    r_new = r.at[node].set(dest)
    dct0 = (costs.global_cost_ct0(prob, r_new)
            - costs.global_cost_ct0(prob, r))
    dctl = (_node_cost(prob, r_new, node, costs.CT_FRAMEWORK)
            - _node_cost(prob, r, node, costs.CT_FRAMEWORK))
    np.testing.assert_allclose(float(dct0), float(dctl),
                               rtol=1e-4, atol=5e-2)


@given(problem_instances())
def test_noop_move_changes_nothing(inst):
    prob, r, node, _ = inst
    r_same = r.at[node].set(r[node])
    assert float(costs.global_cost_c0(prob, r_same)
                 - costs.global_cost_c0(prob, r)) == 0.0


# ---------------------------------------------------------------------------
# cost-matrix internals
# ---------------------------------------------------------------------------

def test_cost_matrix_current_column_is_eq1():
    """Row i, column r_i reproduces Eq. 1 computed by brute force."""
    adj, prob = small_problem()
    rng = np.random.default_rng(3)
    r = jnp.asarray(rng.integers(0, prob.num_machines, prob.num_nodes),
                    jnp.int32)
    state = make_state(prob, r)
    cm = np.asarray(costs.cost_matrix(prob, state, costs.C_FRAMEWORK))
    A = np.asarray(prob.adjacency)
    b = np.asarray(prob.node_weights)
    w = np.asarray(prob.speeds)
    mu = float(prob.mu)
    rr = np.asarray(r)
    for i in range(prob.num_nodes):
        same = (rr == rr[i]) & (np.arange(prob.num_nodes) != i)
        expect = b[i] / w[rr[i]] * b[same].sum() \
            + 0.5 * mu * A[i, rr != rr[i]].sum()
        np.testing.assert_allclose(cm[i, rr[i]], expect, rtol=1e-4)


def test_cost_matrix_hypothetical_columns():
    """Column k of row i equals Eq. 1 evaluated on the moved assignment."""
    adj, prob = small_problem(n=16, k=3, seed=7)
    rng = np.random.default_rng(11)
    r = jnp.asarray(rng.integers(0, 3, 16), jnp.int32)
    state = make_state(prob, r)
    for fw in costs.FRAMEWORKS:
        cm = np.asarray(costs.cost_matrix(prob, state, fw))
        for i in range(16):
            for k in range(3):
                moved = r.at[i].set(k)
                np.testing.assert_allclose(
                    cm[i, k], float(_node_cost(prob, moved, i, fw)),
                    rtol=1e-4, atol=1e-2,
                    err_msg=f"framework={fw} node={i} dest={k}")


def test_dissatisfaction_nonnegative_and_argbest():
    adj, prob = small_problem(n=20, k=4, seed=5)
    r = jnp.asarray(np.random.default_rng(0).integers(0, 4, 20), jnp.int32)
    state = make_state(prob, r)
    for fw in costs.FRAMEWORKS:
        dis, best = costs.dissatisfaction(prob, state, fw)
        assert bool(jnp.all(dis >= -1e-5))
        cm = costs.cost_matrix(prob, state, fw)
        np.testing.assert_array_equal(np.asarray(best),
                                      np.argmin(np.asarray(cm), axis=1))


# ---------------------------------------------------------------------------
# Theorem 4.1 — convergence, descent, Nash fixed point
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
def test_refine_converges_to_nash(framework, paper_problem):
    adj, prob = paper_problem
    rng = np.random.default_rng(42)
    r0 = jnp.asarray(rng.integers(0, prob.num_machines, prob.num_nodes),
                     jnp.int32)
    res = refine(prob, r0, framework)
    assert bool(res.converged)
    # Nash: no node can unilaterally improve (Eq. 3)
    state = make_state(prob, res.assignment)
    dis, _ = costs.dissatisfaction(prob, state, framework)
    assert float(jnp.max(dis)) <= 1e-3
    # loads bookkeeping consistent with the assignment
    np.testing.assert_allclose(
        np.asarray(res.loads),
        np.asarray(machine_loads(prob.node_weights, res.assignment,
                                 prob.num_machines)), rtol=1e-5)


@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
def test_refine_traced_potential_descends(framework, paper_problem):
    """Every accepted move strictly decreases the OWN potential (Thm 4.1)."""
    adj, prob = paper_problem
    rng = np.random.default_rng(7)
    r0 = jnp.asarray(rng.integers(0, prob.num_machines, prob.num_nodes),
                     jnp.int32)
    res, trace = refine_traced(prob, r0, framework, max_turns=600)
    own = trace.c0 if framework == costs.C_FRAMEWORK else trace.ct0
    own = np.asarray(own)
    moved = np.asarray(trace.moved)
    init = float(costs.global_cost(prob, r0, framework))
    prev = np.concatenate([[init], own[:-1]])
    # descent at move turns, unchanged at idle turns
    assert np.all(own[moved] < prev[moved] + 1e-6 * np.abs(prev[moved]))
    idle = ~moved & np.asarray(trace.active)
    np.testing.assert_allclose(own[idle], prev[idle], rtol=1e-6)


def test_refine_fixed_point_is_stable(paper_problem):
    """Refining an equilibrium again makes zero moves."""
    adj, prob = paper_problem
    r0 = jnp.asarray(np.random.default_rng(1).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)
    res = refine(prob, r0, costs.C_FRAMEWORK)
    res2 = refine(prob, res.assignment, costs.C_FRAMEWORK)
    assert int(res2.num_moves) == 0
    np.testing.assert_array_equal(np.asarray(res.assignment),
                                  np.asarray(res2.assignment))


def test_refine_mu_zero_balances_load():
    """With mu=0 the game is pure load balancing (Eq. 2): the equilibrium
    max weighted load is close to the ideal B."""
    adj = random_degree_graph(60, seed=3)
    b, c = random_weights(adj, seed=4, mean=5.0)
    prob = make_problem(c, b, np.ones(4) / 4, mu=0.0)
    r0 = jnp.zeros(60, jnp.int32)                    # worst case: all on m0
    res = refine(prob, r0, costs.C_FRAMEWORK)
    loads = np.asarray(res.loads) / np.asarray(prob.speeds)
    total = float(np.sum(np.asarray(prob.node_weights)))
    # speeds are normalized (sum 1) so the PERFECT equilibrium has
    # L_k / w_k == total for every machine; allow 10% + one max node.
    assert loads.max() <= total * 1.10
    assert loads.max() - loads.min() <= \
        float(np.asarray(prob.node_weights).max()) * 4.0 + 1e-3
    assert bool(res.converged)


def test_refine_huge_mu_prefers_no_cut():
    """With mu huge, grouping everything on one machine is an equilibrium
    (the paper: 'partitioning among fewer than K machines might be
    optimal')."""
    adj = random_degree_graph(30, seed=9)
    b, c = random_weights(adj, seed=10, mean=5.0)
    prob = make_problem(c, b, np.ones(3) / 3, mu=1e7)
    r0 = jnp.zeros(30, jnp.int32)
    res = refine(prob, r0, costs.C_FRAMEWORK)
    assert int(res.num_moves) == 0                   # no one wants to leave


@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
def test_single_machine_game_trivial(framework):
    adj, prob0 = small_problem(n=12, k=3, seed=2)
    prob = make_problem(prob0.adjacency, prob0.node_weights, np.ones(1),
                        mu=4.0)
    res = refine(prob, jnp.zeros(12, jnp.int32), framework)
    assert int(res.num_moves) == 0 and bool(res.converged)


def test_simultaneous_mode_reaches_fixed_point(paper_problem):
    adj, prob = paper_problem
    r0 = jnp.asarray(np.random.default_rng(5).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)
    res, (c0s, ct0s, active) = refine_simultaneous(prob, r0,
                                                   costs.C_FRAMEWORK)
    state = make_state(prob, res.assignment)
    dis, _ = costs.dissatisfaction(prob, state, costs.C_FRAMEWORK)
    if bool(res.converged):
        assert float(jnp.max(dis)) <= 1e-3
    # §4.5: descent NOT guaranteed — but the final cost should still be
    # far below the initial one on this instance
    assert float(costs.global_cost_c0(prob, res.assignment)) < \
        float(costs.global_cost_c0(prob, r0))


def test_discrepancy_counter():
    """count_discrepancies flags ascents of the OTHER potential only."""
    from repro.core.refine import Trace
    moved = jnp.array([True, True, False, True])
    c0 = jnp.array([10.0, 12.0, 12.0, 11.0])     # ascent at turn 1
    ct0 = jnp.array([5.0, 4.0, 4.0, 3.0])
    tr = Trace(moved=moved, node=jnp.zeros(4, jnp.int32),
               source=jnp.zeros(4, jnp.int32), dest=jnp.zeros(4, jnp.int32),
               gain=jnp.zeros(4), c0=c0, ct0=ct0,
               active=jnp.ones(4, bool))
    # criterion ct -> count C_0 ascents: initial 11 -> 10 (desc), 10 -> 12 (asc)
    n = count_discrepancies(tr, costs.CT_FRAMEWORK,
                            initial_other=jnp.asarray(11.0))
    assert int(n) == 1


# ---------------------------------------------------------------------------
# meta-heuristics (§4.4, §7)
# ---------------------------------------------------------------------------

def test_annealing_never_regresses(paper_problem):
    adj, prob = paper_problem
    r0 = jnp.asarray(np.random.default_rng(8).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)
    base = refine(prob, r0, costs.C_FRAMEWORK)
    out = simulated_annealing(prob, base.assignment, jax.random.PRNGKey(0),
                              steps=512)
    assert float(out.cost) <= float(
        costs.global_cost_c0(prob, base.assignment)) + 1e-3
    np.testing.assert_allclose(
        float(out.cost), float(costs.global_cost_c0(prob, out.assignment)),
        rtol=1e-5)


def test_cluster_move_gain_is_exact(paper_problem):
    adj, prob = paper_problem
    r0 = jnp.asarray(np.random.default_rng(12).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)
    eq = refine(prob, r0, costs.C_FRAMEWORK).assignment
    out = cluster_move_pass(prob, eq, costs.C_FRAMEWORK, hops=1)
    before = float(costs.global_cost_c0(prob, eq))
    after = float(costs.global_cost_c0(prob, out.assignment))
    if bool(out.moved):
        np.testing.assert_allclose(before - after, float(out.gain),
                                   rtol=1e-4, atol=1e-2)
        assert after < before
    else:
        np.testing.assert_array_equal(np.asarray(out.assignment),
                                      np.asarray(eq))


def test_equalize_cardinality():
    adj, prob = small_problem(n=24, k=3, seed=6)
    r0 = jnp.zeros(24, jnp.int32)                    # maximally unequal
    out = equalize_cardinality(prob, r0)
    counts = np.bincount(np.asarray(out), minlength=3)
    np.testing.assert_array_equal(counts, [8, 8, 8])


# ---------------------------------------------------------------------------
# §5.1 comparison claim (statistical, small-scale in-test; full study in
# benchmarks/batch_study.py)
# ---------------------------------------------------------------------------

def test_c_framework_usually_wins_both_costs():
    """Table I / §5.1: refining with C_i typically lands at better values of
    BOTH global costs than refining with Ct_i (same init, same turn order).
    We require a majority over 6 instances, not the paper's 49/50 —
    small sample, different RNG."""
    wins = 0
    for seed in range(6):
        adj = random_degree_graph(120, seed=100 + seed)
        b, c = random_weights(adj, seed=200 + seed, mean=5.0)
        prob = make_problem(c, b, [0.1, 0.2, 0.3, 0.3, 0.1], mu=8.0)
        r0 = jnp.asarray(np.random.default_rng(300 + seed).integers(
            0, 5, 120), jnp.int32)
        ra = refine(prob, r0, costs.C_FRAMEWORK).assignment
        rb = refine(prob, r0, costs.CT_FRAMEWORK).assignment
        if float(costs.global_cost_c0(prob, ra)) <= \
           float(costs.global_cost_c0(prob, rb)) and \
           float(costs.global_cost_ct0(prob, ra)) <= \
           float(costs.global_cost_ct0(prob, rb)) * 1.05:
            wins += 1
    assert wins >= 4, f"C_i framework won only {wins}/6"


def test_vmapped_refine_matches_sequential():
    """The batch study vmaps refine_traced over stacked problems; each lane
    must equal the sequential run on the same instance."""
    from repro.core.problem import PartitionProblem
    probs = []
    inits = []
    for seed in range(3):
        adj = random_degree_graph(40, seed=seed, dmin=2, dmax=4)
        b, c = random_weights(adj, seed=seed + 50, mean=5.0)
        probs.append(make_problem(c, b, np.ones(4) / 4, mu=8.0))
        inits.append(np.random.default_rng(seed).integers(0, 4, 40))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *probs)
    r0 = jnp.asarray(np.stack(inits), jnp.int32)
    batched, _ = jax.vmap(
        lambda p, r: refine_traced(p, r, "c", max_turns=256))(stacked, r0)
    for i in range(3):
        single, _ = refine_traced(probs[i], r0[i], "c", max_turns=256)
        np.testing.assert_array_equal(np.asarray(batched.assignment[i]),
                                      np.asarray(single.assignment))
        assert int(batched.num_moves[i]) == int(single.num_moves)
