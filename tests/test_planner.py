"""PartitionPlanner: the paper's game as EP/PP load balancer (DESIGN.md §4),
plus elastic-rescale behaviour."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import costs
from repro.core.problem import make_problem
from repro.core.refine import refine
from repro.models import moe as M
from repro.sharding.planner import (PartitionPlanner, apply_expert_permutation,
                                    expert_placement, stage_assignment)
from repro.training.train_step import init_train_state, make_train_step, TrainHyper
from repro.training.data import SyntheticDataConfig, synthetic_batch


# ---------------------------------------------------------------------------
# expert placement
# ---------------------------------------------------------------------------

def test_expert_placement_balances_skewed_load():
    rng = np.random.default_rng(0)
    e, g = 16, 4
    load = np.ones(e, np.float32)
    load[:4] = 50.0                       # hot experts, initially all on g0
    coact = rng.uniform(0, 1, (e, e)).astype(np.float32)
    coact = np.triu(coact, 1); coact = coact + coact.T
    perm, assign, stats = expert_placement(jnp.asarray(load),
                                           jnp.asarray(coact), g)
    counts = np.bincount(np.asarray(assign), minlength=g)
    np.testing.assert_array_equal(counts, [4, 4, 4, 4])   # exact cardinality
    assert stats["imbalance_after"] <= stats["imbalance_before"] + 1e-6
    assert stats["imbalance_after"] < 2.0                  # hot experts spread
    # perm is a permutation
    assert sorted(np.asarray(perm).tolist()) == list(range(e))


def test_expert_permutation_preserves_moe_function():
    """Permuting expert weights AND router columns leaves the MoE block's
    input->output map unchanged (the planner's correctness condition)."""
    cfg = configs.get_smoke_config("granite-moe-1b-a400m")
    params = {"moe": M.init_moe(jax.random.PRNGKey(0), cfg)}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y0, _ = M.moe_block(params["moe"], cfg, x)

    perm = jnp.asarray(np.random.default_rng(3).permutation(cfg.num_experts),
                       jnp.int32)
    # stack a fake layer dim so the path regex (blocks/*/moe/...) applies
    stacked = {"blocks": {"moe": jax.tree.map(lambda p: p[None],
                                              params["moe"])}}
    permuted = apply_expert_permutation(stacked, perm)
    pl = jax.tree.map(lambda p: p[0], permuted["blocks"]["moe"])
    y1, _ = M.moe_block(pl, cfg, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)


def test_planner_replan_in_training_loop():
    cfg = configs.get_smoke_config("granite-moe-1b-a400m")
    hyper = TrainHyper(total_steps=10, warmup=1)
    step = jax.jit(make_train_step(cfg, hyper))
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    data = SyntheticDataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=4)
    planner = PartitionPlanner(num_groups=4, interval=3)
    losses = []
    for i in range(7):
        state, metrics = step(state, synthetic_batch(data, i))
        losses.append(float(metrics["loss"]))
        state, stats = planner.maybe_replan(i + 1, state)
    assert all(np.isfinite(losses))
    # loss keeps decreasing through replans (function preserved)
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# pipeline stages
# ---------------------------------------------------------------------------

def test_stage_assignment_contiguous_and_near_optimal():
    rng = np.random.default_rng(1)
    L, S = 24, 4
    layer_cost = rng.uniform(1.0, 3.0, L).astype(np.float32)
    assign, game_max, dp_max = stage_assignment(layer_cost, 128.0, S)
    a = np.asarray(assign)
    # contiguous: stage ids are sorted along the chain
    assert np.all(np.diff(a) >= 0)
    assert a.min() == 0 and a.max() == S - 1
    # within 25% of the interval-DP optimum
    assert game_max <= dp_max * 1.25 + 1e-6


def test_stage_assignment_heterogeneous_hybrid():
    """Zamba2-style: shared-attn layers cost ~3x a mamba layer; the game
    must not put all expensive layers in one stage."""
    L, S = 18, 3
    cost = np.ones(L, np.float32)
    cost[[5, 11, 17]] = 3.0
    assign, game_max, dp_max = stage_assignment(cost, 4.0, S)
    loads = np.zeros(S)
    np.add.at(loads, np.asarray(assign), cost)
    assert loads.max() <= dp_max * 1.3


# ---------------------------------------------------------------------------
# elastic rescale: machine join/leave re-runs the game from the surviving
# assignment (iterative improvement, not a refresh — §1's dynamic argument)
# ---------------------------------------------------------------------------

def _random_problem(n=60, k=4, seed=0, mu=4.0):
    from repro.graphs.generators import random_degree_graph, random_weights
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1)
    return make_problem(c, b, np.ones(k) / k, mu=mu)


def test_elastic_machine_join():
    prob4 = _random_problem(k=4, seed=2)
    r = refine(prob4, jnp.zeros(60, jnp.int32), "c").assignment
    # a 5th machine joins: same node weights, wider speed vector
    prob5 = make_problem(prob4.adjacency, prob4.node_weights,
                         np.ones(5) / 5, mu=4.0)
    res = refine(prob5, r, "c")
    assert bool(res.converged)
    # the new machine actually attracts load
    counts = np.bincount(np.asarray(res.assignment), minlength=5)
    assert counts[4] > 0
    # and global cost under the 5-machine game improved vs. not moving
    assert float(costs.global_cost_c0(prob5, res.assignment)) <= \
        float(costs.global_cost_c0(prob5, r))


def test_elastic_machine_leave():
    prob4 = _random_problem(k=4, seed=5)
    r = np.asarray(refine(prob4, jnp.zeros(60, jnp.int32), "c").assignment)
    # machine 3 dies: evacuate its nodes to machine 0, then re-refine on 3
    surviving = np.where(r == 3, 0, r).astype(np.int32)
    prob3 = make_problem(prob4.adjacency, prob4.node_weights,
                         np.ones(3) / 3, mu=4.0)
    res = refine(prob3, jnp.asarray(surviving), "c")
    assert bool(res.converged)
    a = np.asarray(res.assignment)
    assert a.max() <= 2
    loads = np.asarray(res.loads)
    total = float(np.sum(np.asarray(prob3.node_weights)))
    assert loads.max() / total < 0.55      # rebalanced, not all-on-one


def test_straggler_mitigation_via_speed_reestimate():
    """The paper's w_k is the mechanism for straggler mitigation: halving a
    machine's speed and re-refining sheds load from it."""
    prob = _random_problem(k=4, seed=7)
    r = refine(prob, jnp.zeros(60, jnp.int32), "c").assignment
    load_before = float(np.asarray(
        refine(prob, r, "c").loads)[2])
    slow = np.ones(4); slow[2] = 0.25       # machine 2 straggles
    prob_slow = make_problem(prob.adjacency, prob.node_weights, slow, mu=4.0)
    res = refine(prob_slow, r, "c")
    load_after = float(np.asarray(res.loads)[2])
    assert load_after < load_before * 0.7
