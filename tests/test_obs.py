"""Run telemetry layer contracts (DESIGN.md §14).

Three properties are load-bearing and pinned here:

  1. **Disabled mode is free** — every instrumented entry point called
     with ``recorder=None`` produces bitwise-identical results to the
     telemetry-enabled call, and its jaxpr contains ZERO host callbacks
     (§14.3's overhead contract).
  2. **The log is sufficient** — a run can be replayed from its event
     stream alone: the report module's replay reconstructs the final
     loads, move counts and potential descent that the live run
     produced, and round-trips through the JSONL sink + report CLI.
  3. **Measured wire == ledger** — distributed runs under a recorder
     carry a ``wire`` event whose measured bytes equal the §9.3 analytic
     prediction exactly (the deep per-driver grid lives in
     ``tests/test_distributed.py``; here the event-stream side is
     checked).
"""
from __future__ import annotations

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.problem import make_problem
from repro.core.refine import refine, refine_simultaneous, refine_traced
from repro.distributed import refine_distributed
from repro.graphs.generators import random_degree_graph, random_weights
from repro.obs import (EVENT_KINDS, JsonlSink, MemorySink, Recorder,
                       chrome_trace, make_event, read_jsonl, validate_event)
from repro.obs.report import check_run, main as report_main, replay_run, \
    split_runs

N, K = 48, 4


@pytest.fixture(scope="module")
def instance():
    adj = random_degree_graph(N, seed=3)
    b, c = random_weights(adj, seed=4, mean=5.0)
    prob = make_problem(c, b, np.ones(K) / K, mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(5).integers(0, K, N), jnp.int32)
    return prob, r0


def _tree_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# event schema
# ---------------------------------------------------------------------------

def test_event_schema_registry():
    assert {"run_start", "turn", "sweep", "tick", "des_refine", "wire",
            "drift", "phase", "element", "run_end"} <= set(EVENT_KINDS)
    event = make_event("turn", "r0000", t=0, moved=True, c0=1.0, ct0=2.0)
    validate_event(event)
    with pytest.raises(ValueError):
        make_event("turn", "r0000", t=0)          # missing required fields
    with pytest.raises(ValueError):
        validate_event({"kind": "nope", "run": "r0000"})


def test_events_are_json_serializable(instance):
    prob, r0 = instance
    rec = Recorder()
    refine_traced(prob, r0, "c", max_turns=64, recorder=rec)
    for event in rec.events:
        json.loads(json.dumps(event))


# ---------------------------------------------------------------------------
# disabled mode: bitwise identical, zero callbacks
# ---------------------------------------------------------------------------

def test_disabled_mode_results_bitwise(instance):
    prob, r0 = instance
    rec = Recorder()
    for fn, kwargs in ((refine, {"max_turns": 500}),
                       (refine_traced, {"max_turns": 64}),
                       (refine_simultaneous, {"max_sweeps": 16})):
        base = fn(prob, r0, "c", **kwargs)
        inst = fn(prob, r0, "c", **kwargs, recorder=rec)
        assert _tree_equal(base, inst), fn.__name__
    assert any(e["kind"] == "run_end" for e in rec.events)


def test_disabled_entry_points_have_no_callbacks():
    # registry-driven coverage (DESIGN.md §16.3): EVERY registered public
    # entry point — not just refine — stages zero host callbacks on its
    # telemetry-disabled path.  The per-path jaxprs are traced once per
    # process and shared with tests/test_contracts.py.
    from repro.analysis.entrypoints import (registered_entry_points,
                                            trace_entry_point)
    from repro.analysis.jaxpr_rules import callback_primitives

    eps = registered_entry_points()
    assert len(eps) >= 10
    for ep in eps:
        assert callback_primitives(trace_entry_point(ep.name)) == [], ep.name


# ---------------------------------------------------------------------------
# replay: the log alone reproduces the run
# ---------------------------------------------------------------------------

def test_refine_replay_matches_result(instance):
    prob, r0 = instance
    rec = Recorder()
    result = refine(prob, r0, "c", max_turns=500, recorder=rec)
    summary = replay_run(rec.events)
    assert check_run(summary) == []
    assert summary["accepted"] == int(result.num_moves)
    np.testing.assert_allclose(summary["loads"],
                               np.asarray(result.loads, np.float64),
                               rtol=1e-5, atol=1e-3)
    # carried C_0 descends monotonically for the sequential game
    pots = [c0 for _, c0, _ in summary["potentials"]]
    assert pots and pots[-1] <= pots[0]


def test_traced_replay_and_load_cv_trace(instance):
    prob, r0 = instance
    rec = Recorder()
    refine_traced(prob, r0, "c", max_turns=96, recorder=rec)
    summary = replay_run(rec.events)
    assert check_run(summary) == []
    cv = summary["cv_trace"]
    assert cv.size and cv[-1] < cv[0]     # §5: refinement balances loads


def test_distributed_wire_event_reconciles(instance):
    prob, r0 = instance
    rec = Recorder()
    base = refine_distributed(prob, r0, "c", num_shards=K, max_turns=500)
    inst = refine_distributed(prob, r0, "c", num_shards=K, max_turns=500,
                              recorder=rec)
    assert _tree_equal(base, inst)
    wires = [e for e in rec.events if e["kind"] == "wire"]
    assert len(wires) == 1 and wires[0]["ok"]
    assert wires[0]["measured_payload"] == wires[0]["predicted_payload"]
    assert wires[0]["measured_setup"] == wires[0]["predicted_setup"]
    assert check_run(replay_run(rec.events)) == []


def test_des_telemetry_bitwise_and_replay():
    from repro.des.engine import (DESConfig, make_initial_state,
                                  run_simulation)
    from repro.des.workload import flooded_packet_workload
    from repro.graphs.generators import preferential_attachment

    n, k, threads = 20, 3, 8
    adj = preferential_attachment(n, 5, m=2)
    spec = flooded_packet_workload(adj, 9, num_threads=threads,
                                   num_windows=2, scope=2,
                                   window_sim_time=40.0, max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=k, num_threads=threads,
                    event_capacity=48, history_capacity=96,
                    inter_delay=6, intra_delay=1, trace_stride=10,
                    max_ticks=20_000, machine_speeds=(1.0, 0.7, 0.5),
                    refine_freq=80, refine_theta_scale=5.0,
                    migration_freeze=0.25)
    m0 = jnp.asarray(np.arange(n) % k, jnp.int32)
    state0 = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
    adjj = jnp.asarray(adj, jnp.float32)

    base = run_simulation(cfg, adjj, state0)
    rec = Recorder()
    inst = run_simulation(cfg, adjj, state0, recorder=rec)
    assert _tree_equal(base, inst)

    summary = replay_run(rec.events)
    assert check_run(summary) == []
    assert summary["ticks"] > 0 and summary["des_refines"] > 0
    ticks = [e for e in rec.events if e["kind"] == "tick"]
    assert all(e["t"] % cfg.trace_stride == 0 for e in ticks)
    assert summary["end"]["converged"]


def test_sweep_telemetry_results_identical(instance):
    from repro import sweeps

    prob, r0 = instance
    cases = [sweeps.SweepCase(problem=prob, assignment=r0, framework=fw,
                              label=fw) for fw in ("c", "ct")]
    spec = sweeps.make_spec(cases, mode="traced", max_turns=64)
    base = sweeps.run_sweep(spec)
    rec = Recorder()
    inst = sweeps.run_sweep(spec, recorder=rec)
    for r_base, r_inst in zip(base.results, inst.results):
        assert _tree_equal(r_base, r_inst)

    elements = [e for e in rec.events if e["kind"] == "element"]
    assert [e["batch"] for e in elements] == [0, 1]
    turns = [e for e in rec.events if e["kind"] == "turn"]
    assert turns and {e["batch"] for e in turns} == {0, 1}
    assert check_run(replay_run(rec.events)) == []


# ---------------------------------------------------------------------------
# JSONL round-trip + report CLI
# ---------------------------------------------------------------------------

def test_jsonl_roundtrip_through_report_cli(instance, tmp_path, capsys):
    prob, r0 = instance
    log = tmp_path / "run.jsonl"
    rec = Recorder([JsonlSink(log)])
    refine(prob, r0, "c", max_turns=500, recorder=rec)
    refine_distributed(prob, r0, "ct", num_shards=K, max_turns=500,
                       recorder=rec)
    rec.close()
    events = read_jsonl(log)
    assert events == rec.events
    assert len(split_runs(events)) == 2

    assert report_main([str(log), "--check"]) == 0
    out = capsys.readouterr().out
    assert "[refine]" in out and "[distributed]" in out
    assert "wire [OK]" in out

    assert report_main([str(log), "--json"]) == 0
    for line in capsys.readouterr().out.strip().splitlines():
        json.loads(line)


def test_report_cli_namespaces_multiple_logs(instance, tmp_path, capsys):
    """Distinct logs reuse run ids (r0000, ...); reporting several at once
    must not merge unrelated runs."""
    prob, r0 = instance
    paths = []
    for name in ("a", "b"):
        path = tmp_path / f"{name}.jsonl"
        rec = Recorder([JsonlSink(path)])
        refine(prob, r0, "c", max_turns=500, recorder=rec)
        rec.close()
        paths.append(str(path))
    assert report_main([*paths, "--check"]) == 0
    out = capsys.readouterr().out
    assert "run a:r0000" in out and "run b:r0000" in out


def test_report_cli_check_fails_on_bad_log(tmp_path, capsys):
    log = tmp_path / "bad.jsonl"
    events = [
        make_event("run_start", "r0000", runtime="distributed",
                   n=8, k=2, framework="c"),
        make_event("wire", "r0000", rounds=3, measured_payload=100,
                   predicted_payload=96, measured_setup=12,
                   predicted_setup=12, ok=False),
        make_event("run_end", "r0000"),
    ]
    with JsonlSink(log) as sink:
        for event in events:
            sink.write(event)
    assert report_main([str(log), "--check"]) == 1
    assert "wire" in capsys.readouterr().err


def test_chrome_trace_export(instance, tmp_path):
    prob, r0 = instance
    log = tmp_path / "run.jsonl"
    rec = Recorder([JsonlSink(log)])
    refine(prob, r0, "c", max_turns=500, recorder=rec)
    rec.close()
    trace_path = tmp_path / "trace.json"
    assert report_main([str(log), "--trace", str(trace_path)]) == 0
    trace = json.loads(trace_path.read_text())
    slices = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert slices and all(e["dur"] >= 0 for e in slices)
    assert chrome_trace(rec.events)["traceEvents"]


# ---------------------------------------------------------------------------
# sinks + recorder mechanics
# ---------------------------------------------------------------------------

def test_memory_sink_fanout_and_phase():
    rec = Recorder([MemorySink(), MemorySink()])
    run = rec.new_run("refine", n=8, k=2, framework="c")
    with rec.phase("unit.test", run):
        pass
    rec.emit("run_end", run)
    for sink in rec.sinks:
        assert [e["kind"] for e in sink.events] == \
            ["run_start", "phase", "run_end"]
    assert rec.events == rec.sinks[0].events


def test_timed_dissat_fn_eager_vs_traced(instance):
    from repro.kernels.ops import make_timed_dissat_fn

    prob, r0 = instance
    rec = Recorder()
    agg = jnp.zeros((N, K), jnp.float32)
    loads = jnp.zeros(K, jnp.float32).at[r0].add(prob.node_weights)

    def plain_fn(aggregate, assignment, node_weights, loads, speeds, mu,
                 framework, total_weight, theta=None):
        del aggregate, framework, theta
        dissat = loads[assignment] / speeds[assignment]
        return dissat, jnp.broadcast_to(jnp.argmin(loads), dissat.shape)

    timed_fn = make_timed_dissat_fn(plain_fn, rec, name="unit.dissat")

    def call(fn, loads_arg):
        return fn(agg, r0, prob.node_weights, loads_arg, prob.speeds,
                  prob.mu, "c", jnp.sum(prob.node_weights))

    base = call(plain_fn, loads)
    eager = call(timed_fn, loads)
    assert _tree_equal(base, eager)
    assert [e["name"] for e in rec.events if e["kind"] == "phase"] \
        == ["unit.dissat"]

    # under tracing the wrapper passes straight through: same jaxpr, no
    # extra phase events
    before = len(rec.events)
    jaxpr_timed = str(jax.make_jaxpr(lambda l: call(timed_fn, l))(loads))
    jaxpr_plain = str(jax.make_jaxpr(lambda l: call(plain_fn, l))(loads))
    assert jaxpr_timed == jaxpr_plain
    assert len(rec.events) == before
