"""Minimal stand-in for ``hypothesis`` on bare environments.

The tier-1 suite must run on a machine with nothing but jax + pytest
installed (ISSUE: conftest previously died with ModuleNotFoundError at
collection).  When the real ``hypothesis`` package is absent, conftest
installs this shim into ``sys.modules`` *before* any test module imports
it.  Property-based tests then collect normally and individually skip at
call time; every example-based test in the same files keeps running.

Only the API surface the test suite actually uses is provided:
``given``, ``settings`` (decorator + register_profile/load_profile),
``assume``, ``HealthCheck``, and ``strategies`` (composite / integers /
floats / sampled_from / booleans / lists).
"""
from __future__ import annotations

import sys
import types

import pytest

SKIP_REASON = "hypothesis is not installed (property-based test skipped)"


class _Strategy:
    """Inert placeholder returned by every strategy constructor."""

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<stub strategy>"

    def map(self, fn):
        return self

    def filter(self, fn):
        return self

    def flatmap(self, fn):
        return self


def _strategy_factory(*_args, **_kwargs) -> _Strategy:
    return _Strategy()


def given(*_args, **_kwargs):
    def decorate(fn):
        def skipper(*a, **k):
            pytest.skip(SKIP_REASON)

        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        skipper.__module__ = fn.__module__
        skipper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return skipper

    return decorate


def assume(condition) -> bool:
    return bool(condition)


class settings:  # noqa: N801 - mirrors hypothesis' lowercase class name
    _profiles: dict[str, dict] = {}

    def __init__(self, *args, **kwargs):
        self.kwargs = kwargs

    def __call__(self, fn):
        return fn  # decorator form: passthrough (given() already skips)

    @classmethod
    def register_profile(cls, name: str, parent=None, **kwargs) -> None:
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls._profiles.setdefault(name, {})


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    large_base_example = "large_base_example"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much,
                cls.large_base_example]


def _composite(fn):
    def build(*args, **kwargs):
        return _Strategy()

    build.__name__ = fn.__name__
    return build


def install() -> None:
    """Register stub ``hypothesis`` + ``hypothesis.strategies`` modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    mod.__version__ = "0.0.0-stub"
    mod.__is_repro_stub__ = True

    st = types.ModuleType("hypothesis.strategies")
    st.composite = _composite
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "tuples", "just", "one_of", "text"):
        setattr(st, name, _strategy_factory)
    mod.strategies = st

    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
