"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.

The kernels execute in interpret mode (CPU container); on TPU the same
pallas_call compiles for real.  Tolerances reflect f32 accumulation against
the oracles' f32 math.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.dissatisfaction import (
    cost_matrix_pallas, dissatisfaction_from_aggregate_pallas)


def _problem_arrays(n, k, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    adj = rng.uniform(0, 10, (n, n)) * (rng.random((n, n)) < 0.4)
    adj = np.triu(adj, 1)
    adj = adj + adj.T
    b = rng.uniform(0.1, 10, n).astype(np.float32)
    r = rng.integers(0, k, n).astype(np.int32)
    speeds = rng.uniform(0.2, 2.0, k).astype(np.float32)
    speeds /= speeds.sum()
    loads = np.zeros(k, np.float32)
    np.add.at(loads, r, b)
    return (jnp.asarray(adj, dtype), jnp.asarray(r), jnp.asarray(b),
            jnp.asarray(loads), jnp.asarray(speeds))


# ---------------------------------------------------------------------------
# cost-matrix kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 64, 128, 130, 300])
@pytest.mark.parametrize("k", [2, 5, 16])
@pytest.mark.parametrize("framework", ["c", "ct"])
def test_cost_matrix_kernel_shapes(n, k, framework):
    adj, r, b, loads, speeds = _problem_arrays(n, k, seed=n * 31 + k)
    got = cost_matrix_pallas(adj, r, b, loads, speeds, 8.0, framework,
                             interpret=True)
    want = ref.cost_matrix_ref(adj, r, b, loads, speeds, 8.0, framework)
    assert got.shape == (n, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cost_matrix_kernel_dtypes(dtype):
    adj, r, b, loads, speeds = _problem_arrays(96, 4, seed=9, dtype=dtype)
    got = cost_matrix_pallas(adj, r, b, loads, speeds, 2.0, "c",
                             interpret=True)
    want = ref.cost_matrix_ref(adj, r, b, loads, speeds, 2.0, "c")
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=1.0 if dtype == jnp.bfloat16 else 1e-2)


@pytest.mark.parametrize("tiles", [(128, 128), (128, 256), (256, 128)])
def test_cost_matrix_kernel_tile_sweep(tiles):
    tn, tj = tiles
    adj, r, b, loads, speeds = _problem_arrays(260, 5, seed=17)
    got = cost_matrix_pallas(adj, r, b, loads, speeds, 8.0, "c",
                             tile_n=tn, tile_j=tj, interpret=True)
    want = ref.cost_matrix_ref(adj, r, b, loads, speeds, 8.0, "c")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-2)


@given(st.integers(2, 50), st.integers(2, 8), st.integers(0, 10_000),
       st.sampled_from(["c", "ct"]))
@settings(max_examples=15)
def test_cost_matrix_kernel_property(n, k, seed, framework):
    adj, r, b, loads, speeds = _problem_arrays(n, k, seed=seed)
    got = cost_matrix_pallas(adj, r, b, loads, speeds, 4.0, framework,
                             interpret=True)
    want = ref.cost_matrix_ref(adj, r, b, loads, speeds, 4.0, framework)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=5e-2)


def test_ops_wrapper_matches_core():
    """The kernel adapter plugs into refine() and matches the core path."""
    from repro.core import costs as core_costs
    from repro.core.problem import make_problem, make_state
    adj, r, b, loads, speeds = _problem_arrays(64, 4, seed=3)
    prob = make_problem(adj, b, speeds, mu=8.0, normalize_speeds=False)
    state = make_state(prob, r)
    fn = ops.make_core_cost_matrix_fn(interpret=True)
    got = fn(prob, state, "c")
    want = core_costs.cost_matrix(prob, state, "c")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-2)


def test_refine_with_pallas_kernel_matches_jnp():
    """Full refinement driven by the Pallas cost kernel lands on the same
    equilibrium as the jnp path (identical tie-breaking)."""
    from repro.core.problem import make_problem
    from repro.core.refine import refine
    adj, r, b, loads, speeds = _problem_arrays(48, 3, seed=21)
    prob = make_problem(adj, b, speeds, mu=8.0, normalize_speeds=False)
    res_jnp = refine(prob, r, "c", max_turns=300)
    res_pal = refine(prob, r, "c", max_turns=300,
                     cost_matrix_fn=ops.make_core_cost_matrix_fn(interpret=True))
    np.testing.assert_array_equal(np.asarray(res_jnp.assignment),
                                  np.asarray(res_pal.assignment))


# ---------------------------------------------------------------------------
# fused dissatisfaction-from-aggregate kernel (incremental path, §10)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 64, 128, 130, 300])
@pytest.mark.parametrize("k", [2, 5, 16])
@pytest.mark.parametrize("framework", ["c", "ct"])
def test_dissat_from_aggregate_kernel_shapes(n, k, framework):
    """(dissat, best) from the fused kernel == the jnp assembly + Eq. 4
    reduction, including the lowest-index argmin tie-breaking."""
    from repro.core import costs as core_costs
    adj, r, b, loads, speeds = _problem_arrays(n, k, seed=n * 13 + k)
    agg = core_costs.adjacency_aggregate(adj, r, k)
    cost = core_costs.cost_matrix_from_aggregate(
        agg, r, b, loads, speeds, 8.0, framework)
    want_d, want_b = core_costs.dissatisfaction_from_cost(cost, r)
    got_d, got_b = dissatisfaction_from_aggregate_pallas(
        agg, r, b, loads, speeds, 8.0, framework, interpret=True)
    assert got_d.shape == (n,) and got_b.shape == (n,)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=2e-4, atol=2e-2)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))


def test_dissat_from_aggregate_kernel_row_block():
    """Rectangular row blocks (the distributed per-shard case): the fused
    kernel on a block aggregate reproduces the matching rows of the full
    reduction (Ct framework needs the explicit global total_weight)."""
    from repro.core import costs as core_costs
    adj, r, b, loads, speeds = _problem_arrays(90, 5, seed=33)
    agg = core_costs.adjacency_aggregate(adj, r, 5)
    total_b = jnp.sum(b)
    for fw in ("c", "ct"):
        cost = core_costs.cost_matrix_from_aggregate(
            agg, r, b, loads, speeds, 4.0, fw, total_weight=total_b)
        want_d, want_b = core_costs.dissatisfaction_from_cost(cost, r)
        lo, hi = 30, 60
        got_d, got_b = dissatisfaction_from_aggregate_pallas(
            agg[lo:hi], r[lo:hi], b[lo:hi], loads, speeds, 4.0, fw,
            total_weight=total_b, interpret=True)
        np.testing.assert_allclose(np.asarray(got_d),
                                   np.asarray(want_d[lo:hi]),
                                   rtol=2e-4, atol=2e-2)
        np.testing.assert_array_equal(np.asarray(got_b),
                                      np.asarray(want_b[lo:hi]))


@pytest.mark.parametrize("framework", ["c", "ct"])
def test_dissat_from_aggregate_kernel_theta(framework):
    """The (N,) theta operand subtracts the migration price inside the
    fused reduction (DESIGN.md §11): net dissatisfaction == jnp net path,
    best machine unchanged, and theta=None == explicit zeros."""
    from repro.core import costs as core_costs
    adj, r, b, loads, speeds = _problem_arrays(70, 5, seed=51)
    agg = core_costs.adjacency_aggregate(adj, r, 5)
    theta = jnp.asarray(
        np.random.default_rng(52).uniform(0, 30, 70), jnp.float32)
    cost = core_costs.cost_matrix_from_aggregate(
        agg, r, b, loads, speeds, 8.0, framework)
    want_d, want_b = core_costs.dissatisfaction_from_cost(cost, r, theta)
    got_d, got_b = dissatisfaction_from_aggregate_pallas(
        agg, r, b, loads, speeds, 8.0, framework, theta=theta,
        interpret=True)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=2e-4, atol=2e-2)
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))
    none_d, none_b = dissatisfaction_from_aggregate_pallas(
        agg, r, b, loads, speeds, 8.0, framework, interpret=True)
    zero_d, zero_b = dissatisfaction_from_aggregate_pallas(
        agg, r, b, loads, speeds, 8.0, framework,
        theta=jnp.zeros(70), interpret=True)
    np.testing.assert_array_equal(np.asarray(none_d), np.asarray(zero_d))
    np.testing.assert_array_equal(np.asarray(none_b), np.asarray(zero_b))


def test_refine_with_aggregate_dissat_kernel():
    """Incremental refinement with the fused kernel as its per-turn
    reduction lands on the jnp incremental path's equilibrium."""
    from repro.core.problem import make_problem
    from repro.core.refine import refine
    adj, r, b, loads, speeds = _problem_arrays(48, 3, seed=21)
    prob = make_problem(adj, b, speeds, mu=8.0, normalize_speeds=False)
    res_jnp = refine(prob, r, "c", max_turns=300)
    res_pal = refine(prob, r, "c", max_turns=300,
                     dissat_fn=ops.make_aggregate_dissat_fn(interpret=True))
    np.testing.assert_array_equal(np.asarray(res_jnp.assignment),
                                  np.asarray(res_pal.assignment))
    assert int(res_jnp.num_moves) == int(res_pal.num_moves)


# ---------------------------------------------------------------------------
# batch-grid dissatisfaction kernel (DESIGN.md §12.3)
# ---------------------------------------------------------------------------

def _batched_problem_arrays(bsz, n, k, seed):
    rng = np.random.default_rng(seed)
    agg = rng.uniform(0, 50, (bsz, n, k)) * (rng.random((bsz, n, k)) < 0.7)
    r = rng.integers(0, k, (bsz, n)).astype(np.int32)
    b = rng.uniform(0.1, 10, (bsz, n)).astype(np.float32)
    loads = rng.uniform(1, 100, (bsz, k)).astype(np.float32)
    speeds = rng.uniform(0.2, 2.0, (bsz, k)).astype(np.float32)
    mu = rng.uniform(1, 10, bsz).astype(np.float32)
    return (jnp.asarray(agg, jnp.float32), jnp.asarray(r), jnp.asarray(b),
            jnp.asarray(loads), jnp.asarray(speeds), jnp.asarray(mu))


# ``interpret`` modes: True forces interpret; None resolves per backend
# (interpret on CPU, compiled on a real TPU) — the two modes the wrappers
# actually dispatch between (resolve_interpret).
@pytest.mark.parametrize("interpret", [True, None])
@pytest.mark.parametrize("framework", ["c", "ct"])
def test_dissat_batched_kernel_vs_unbatched_and_reference(framework,
                                                          interpret):
    """Batch-grid kernel == per-element unbatched kernel BITWISE, and ==
    the jnp reference reduction (tolerance + exact arg-best) per element,
    theta on and off."""
    from repro.core import costs as core_costs
    from repro.kernels.dissatisfaction import (
        dissatisfaction_from_aggregate_batched_pallas)
    bsz, n, k = 4, 70, 5
    agg, r, b, loads, speeds, mu = _batched_problem_arrays(
        bsz, n, k, seed=ord(framework[0]))
    total_b = jnp.sum(b, axis=-1)
    theta = jnp.asarray(
        np.random.default_rng(3).uniform(0, 10, (bsz, n)), jnp.float32)
    for th in (None, theta):
        got_d, got_b = dissatisfaction_from_aggregate_batched_pallas(
            agg, r, b, loads, speeds, mu, framework, theta=th,
            total_weight=total_b, interpret=interpret)
        assert got_d.shape == (bsz, n) and got_b.shape == (bsz, n)
        for i in range(bsz):
            one_d, one_b = dissatisfaction_from_aggregate_pallas(
                agg[i], r[i], b[i], loads[i], speeds[i], mu[i], framework,
                theta=None if th is None else th[i],
                total_weight=total_b[i], interpret=interpret)
            np.testing.assert_array_equal(np.asarray(got_d)[i],
                                          np.asarray(one_d))
            np.testing.assert_array_equal(np.asarray(got_b)[i],
                                          np.asarray(one_b))
            cost = core_costs.cost_matrix_from_aggregate(
                agg[i], r[i], b[i], loads[i], speeds[i], mu[i], framework,
                total_weight=total_b[i])
            want_d, want_b = core_costs.dissatisfaction_from_cost(
                cost, r[i], None if th is None else th[i])
            np.testing.assert_allclose(np.asarray(got_d)[i],
                                       np.asarray(want_d),
                                       rtol=2e-4, atol=2e-2)
            np.testing.assert_array_equal(np.asarray(got_b)[i],
                                          np.asarray(want_b))


@pytest.mark.parametrize("interpret", [True, None])
def test_vmap_of_ops_wrapper_hits_batch_grid_kernel(interpret):
    """jax.vmap of ops.dissatisfaction_from_aggregate must match the
    batch-grid kernel exactly (the custom_vmap dispatch of DESIGN.md
    §12.3) — fused, not an unrolled fallback."""
    from repro.kernels.dissatisfaction import (
        dissatisfaction_from_aggregate_batched_pallas)
    bsz, n, k = 3, 40, 4
    agg, r, b, loads, speeds, mu = _batched_problem_arrays(bsz, n, k, 11)
    total_b = jnp.sum(b, axis=-1)
    got_d, got_b = jax.vmap(
        lambda a, rr, w, l, s, m, t: ops.dissatisfaction_from_aggregate(
            a, rr, w, l, s, m, t, "c", interpret=interpret)
    )(agg, r, b, loads, speeds, mu, total_b)
    want_d, want_b = dissatisfaction_from_aggregate_batched_pallas(
        agg, r, b, loads, speeds, mu, "c", total_weight=total_b,
        interpret=interpret)
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(want_d))
    np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))


def test_vmapped_refine_with_kernel_matches_jnp_path():
    """The end-to-end §12.3 claim: vmapped incremental refinement with
    the fused kernel reduction reproduces the vmapped jnp path's moves
    and assignments."""
    from repro.core.batch import refine_batched, stack_problems
    from repro.core.problem import make_problem
    problems, r0s = [], []
    for s in range(3):
        adj, r, b, loads, speeds = _problem_arrays(48, 4, seed=60 + s)
        problems.append(make_problem(adj, b, speeds, mu=8.0,
                                     normalize_speeds=False))
        r0s.append(r)
    stacked = stack_problems(problems)
    r0 = jnp.stack(r0s)
    res_jnp = refine_batched(stacked, r0, "c", max_turns=300)
    res_pal = refine_batched(
        stacked, r0, "c", max_turns=300,
        dissat_fn=ops.make_aggregate_dissat_fn(interpret=True))
    np.testing.assert_array_equal(np.asarray(res_jnp.assignment),
                                  np.asarray(res_pal.assignment))
    np.testing.assert_array_equal(np.asarray(res_jnp.num_moves),
                                  np.asarray(res_pal.num_moves))


def test_interpret_auto_detection():
    """interpret=None auto-detects from the backend (satellite: no more
    hard-coded interpret=True default); explicit values win."""
    from repro.kernels.dissatisfaction import resolve_interpret
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    expected = jax.default_backend() != "tpu"
    assert resolve_interpret(None) is expected
    # the kernel entry points accept interpret=None (the new default)
    adj, r, b, loads, speeds = _problem_arrays(16, 3, seed=1)
    out = cost_matrix_pallas(adj, r, b, loads, speeds, 2.0, "c",
                             interpret=None)
    assert out.shape == (16, 3)


# ---------------------------------------------------------------------------
# decode-attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,d", [
    (1, 4, 4, 64), (2, 8, 2, 64), (3, 8, 1, 128), (2, 7, 7, 64),
])
@pytest.mark.parametrize("s", [100, 512, 1000])
def test_decode_attention_shapes(b, h, hkv, d, s):
    rng = np.random.default_rng(b * 131 + s)
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    length = jnp.asarray(rng.integers(1, s + 1, b), jnp.int32)
    got = decode_attention_pallas(q, k, v, length, interpret=True)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-4),
                                       (jnp.bfloat16, 3e-2)])
def test_decode_attention_dtypes(dtype, tol):
    rng = np.random.default_rng(0)
    b, h, hkv, d, s = 2, 8, 2, 64, 384
    q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    length = jnp.asarray([s, s // 3], jnp.int32)
    got = decode_attention_pallas(q, k, v, length, interpret=True)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_decode_attention_length_masking():
    """Tokens beyond ``length`` must not influence the output."""
    rng = np.random.default_rng(1)
    b, h, hkv, d, s = 1, 4, 2, 64, 256
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    length = jnp.asarray([100], jnp.int32)
    out1 = decode_attention_pallas(q, k, v, length, interpret=True)
    # poison the invalid region
    k2 = k.at[:, 100:].set(1e4)
    v2 = v.at[:, 100:].set(-1e4)
    out2 = decode_attention_pallas(q, k2, v2, length, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 3), st.sampled_from([(4, 4), (8, 2), (6, 3)]),
       st.integers(16, 300), st.integers(0, 10_000))
@settings(max_examples=10)
def test_decode_attention_property(b, heads, s, seed):
    h, hkv = heads
    d = 64
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    length = jnp.asarray(rng.integers(1, s + 1, b), jnp.int32)
    got = decode_attention_pallas(q, k, v, length, interpret=True)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_decode_attention_vs_model_attention_step():
    """Kernel output agrees with the model's jnp decode path (same math,
    independent implementations)."""
    from repro.models import attention as A
    from repro import configs
    cfg = configs.get_smoke_config("yi-34b")
    rng = np.random.default_rng(4)
    B, S = 2, 96
    params = A.init_attention(jax.random.PRNGKey(0), cfg)
    cache = A.init_kv_cache(cfg, B, S, jnp.float32)
    # warm the cache with real keys/values at positions < length
    length = 40
    kpre = jnp.asarray(rng.standard_normal(
        (B, S, cfg.num_kv_heads, cfg.head_dim)), jnp.float32)
    vpre = jnp.asarray(rng.standard_normal(
        (B, S, cfg.num_kv_heads, cfg.head_dim)), jnp.float32)
    q = jnp.asarray(rng.standard_normal(
        (B, cfg.num_heads, cfg.head_dim)), jnp.float32)
    got = ops.decode_attention(q, kpre, vpre,
                               jnp.full((B,), length, jnp.int32),
                               interpret=True)
    want = ref.decode_attention_ref(q, kpre, vpre,
                                    jnp.full((B,), length, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash-attention forward kernel (train/prefill hot spot)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,hkv,d", [
    (1, 128, 4, 2, 64), (2, 200, 8, 2, 64), (1, 384, 6, 1, 128),
    (1, 96, 7, 7, 64), (2, 64, 4, 4, 32),
])
def test_flash_attention_shapes(b, s, h, hkv, d):
    from repro.kernels.flash_attention import flash_attention_pallas
    rng = np.random.default_rng(b * 997 + s)
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    got = flash_attention_pallas(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-4),
                                       (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol):
    from repro.kernels.flash_attention import flash_attention_pallas
    rng = np.random.default_rng(7)
    b, s, h, hkv, d = 1, 192, 8, 4, 64
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    got = flash_attention_pallas(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("tiles", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_tile_sweep(tiles):
    from repro.kernels.flash_attention import flash_attention_pallas
    tq, tk = tiles
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    got = flash_attention_pallas(q, k, v, tile_q=tq, tile_k=tk,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_model_core():
    """Kernel agrees with the model's jnp _causal_core (independent path)."""
    from repro import configs
    from repro.models import attention as A
    from repro.kernels.flash_attention import flash_attention_pallas
    cfg = configs.get_smoke_config("yi-34b")
    rng = np.random.default_rng(11)
    B, S = 2, 64
    q = jnp.asarray(rng.standard_normal(
        (B, S, cfg.num_heads, cfg.head_dim)), jnp.float32)
    k = jnp.asarray(rng.standard_normal(
        (B, S, cfg.num_kv_heads, cfg.head_dim)), jnp.float32)
    v = jnp.asarray(rng.standard_normal(
        (B, S, cfg.num_kv_heads, cfg.head_dim)), jnp.float32)
    got = flash_attention_pallas(q, k, v, interpret=True)
    want = A._causal_core(q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(1, 2), st.sampled_from([(4, 2), (4, 4), (6, 3)]),
       st.integers(16, 200), st.integers(0, 10_000))
@settings(max_examples=8)
def test_flash_attention_property(b, heads, s, seed):
    from repro.kernels.flash_attention import flash_attention_pallas
    h, hkv = heads
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, s, h, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, 64)), jnp.float32)
    got = flash_attention_pallas(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# Mamba2 SSD scan kernel (SSM train/prefill hot spot)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,l,h,p,n,q", [
    (2, 64, 3, 8, 5, 16), (1, 100, 2, 16, 8, 32),
    (2, 128, 4, 64, 32, 128), (1, 48, 1, 4, 3, 64),
])
def test_ssd_scan_kernel_shapes(b, l, h, p, n, q):
    from repro.kernels.ssd_scan import ssd_scan_pallas
    rng = np.random.default_rng(b * 53 + l)
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, l, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 2.0, h), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    got_y, got_s = ssd_scan_pallas(x, dt, a, bm, cm, chunk=q,
                                   interpret=True)
    want_y, want_s = ref.ssd_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=3e-4, atol=3e-4)


def test_ssd_scan_kernel_matches_model_path():
    """Kernel output == the model's chunked-jnp path (what ssm_block runs),
    at a DIFFERENT chunking — both must equal the same recurrence."""
    from repro.kernels.ssd_scan import ssd_scan_pallas
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(5)
    b, l, h, p, n = 2, 96, 4, 32, 16
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, l, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 2.0, h), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    got_y, got_s = ssd_scan_pallas(x, dt, a, bm, cm, chunk=32,
                                   interpret=True)
    want_y, want_s = ssd_chunked(x, dt, a, bm, cm, chunk=48)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=3e-4, atol=3e-4)


@given(st.integers(1, 2), st.integers(1, 3), st.integers(8, 80),
       st.integers(0, 10_000))
@settings(max_examples=8)
def test_ssd_scan_kernel_property(b, h, l, seed):
    from repro.kernels.ssd_scan import ssd_scan_pallas
    rng = np.random.default_rng(seed)
    p, n = 8, 4
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, l, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 2.0, h), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, l, n)), jnp.float32)
    got_y, got_s = ssd_scan_pallas(x, dt, a, bm, cm, chunk=16,
                                   interpret=True)
    want_y, want_s = ref.ssd_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=5e-4, atol=5e-4)
