"""Tests for the complexity family (DESIGN.md §18): real-profile sanity
on the quick grid, every rule proven to fire on a seeded violation
(mirroring test_contracts.py), the expectation-table lifecycle, the CLI
exit-code matrix incl. --prune-stale, deterministic provenance-stamped
JSON, and the BENCH payload schema gate."""
from __future__ import annotations

import importlib.util
import json
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import complexity_rules as cx
from repro.analysis import entrypoints
from repro.analysis.registry import AnalysisContext
from repro.core.sparse import SPARSE_COMPLEXITY
from repro.distributed import protocol

REPO = pathlib.Path(__file__).resolve().parent.parent
QUICK = cx.GRIDS["quick"]


def _quick_profiles():
    return cx.all_profiles("quick")


# ---------------------------------------------------------------------------
# real-profile sanity: the repo's own paths obey their budgets
# ---------------------------------------------------------------------------

def test_every_entry_point_has_declared_budget():
    eps = entrypoints.registered_entry_points()
    assert len(eps) >= 21
    for ep in eps:
        budget = cx.declared_budget(ep)
        assert budget is not None, ep.name
        assert set(budget) == {"mem", "ops", "collectives"}


def test_sparse_paths_have_linear_memory():
    profs = _quick_profiles()
    for name in ("refine.sparse", "refine_traced.sparse",
                 "refine.sparse.edge_kernel",
                 "refine_sweeps.sparse.unbounded"):
        fits = profs[name]["fits"]
        assert fits["mem"]["n"] <= 1.0 + cx.EXPONENT_TOL, (name, fits)
        assert fits["mem"]["e"] <= 1.0 + cx.EXPONENT_TOL, (name, fits)


def test_dense_paths_sit_at_the_quadratic_floor():
    profs = _quick_profiles()
    assert abs(profs["refine"]["fits"]["mem"]["n"] - 2.0) < 0.1
    assert profs["refine"]["peak_shape"] == (256, 256)


def test_shard_map_collectives_match_ledger():
    coll = _quick_profiles()["distributed.shard_map"]["collectives"]
    assert coll["n_independent"]
    assert coll["recurring_bytes"] == protocol.CANDIDATE_BYTES
    assert coll["setup_bytes"] == 0
    # one CandidateMsg per round: 4 scalar all_gathers
    gathers = [c for c in coll["schedule"] if "all_gather" in c[0]]
    assert len(gathers) == 4
    assert all(ph == "recurring" for _, ph, _ in gathers)


def test_emulated_drivers_stage_zero_collectives():
    profs = _quick_profiles()
    for name in ("distributed.refine", "distributed.refine_traced",
                 "distributed.refine_simultaneous"):
        assert profs[name]["collectives"]["schedule"] == ()


def test_no_findings_on_the_real_tree():
    ctx = AnalysisContext(repo_root=REPO, complexity_grid="quick")
    from repro.analysis.registry import run_rules
    findings = run_rules(ctx, families=["complexity"])
    assert findings == [], [f.id for f in findings]
    report = ctx.reports["complexity"]
    assert report["grid"] == "quick"
    assert len(report["entry_points"]) >= 21


def test_fit_exponent_recovers_power_laws():
    ns = (32, 64, 128, 256)
    assert abs(cx.fit_exponent(ns, [n * n for n in ns]) - 2.0) < 1e-9
    assert abs(cx.fit_exponent(ns, [7 * n for n in ns]) - 1.0) < 1e-9
    assert abs(cx.fit_exponent(ns, [5, 5, 5, 5])) < 1e-9
    assert cx.fit_exponent((4,), (16,)) == 0.0


# ---------------------------------------------------------------------------
# seeded violations: every rule fires (ISSUE satellite — the fixture
# materializes senders[:, None] == receivers[None, :])
# ---------------------------------------------------------------------------

def _dense_mask_trace(n, k, degree):
    """A 'sparse' fixture that secretly materializes a dense (E, E)
    mask — the exact regression the mem rule exists to catch."""
    sp = entrypoints.canonical_sparse_degree(n, k, degree or 8)

    def fn(r):
        mask = sp.senders[:, None] == sp.receivers[None, :]
        return jnp.sum(jnp.where(mask, 1.0, 0.0)) + jnp.sum(r)

    return jax.make_jaxpr(fn)(entrypoints.canonical_assignment(n, k))


def test_seeded_dense_materialization_fails_mem_budget():
    prof = cx.profile_trace(_dense_mask_trace, QUICK, sparse=True)
    assert prof["fits"]["mem"]["n"] > 1.8           # quadratic in N
    findings = cx.exponent_findings("seeded.densemask", prof,
                                    SPARSE_COMPLEXITY | {"collectives": {}},
                                    "mem")
    keys = {f.key for f in findings}
    assert "seeded.densemask:n" in keys             # O(N^2) memory finding
    assert "seeded.densemask:e" in keys             # quadratic in E too
    assert all(f.rule == "complexity-mem-budget" for f in findings)
    n_msg = next(f.message for f in findings
                 if f.key == "seeded.densemask:n")
    assert "peak intermediate" in n_msg             # names the (E, E) aval
    # and the op count blows the budget as well
    ops = cx.exponent_findings("seeded.densemask", prof,
                               SPARSE_COMPLEXITY | {"collectives": {}},
                               "ops")
    assert any(f.key == "seeded.densemask:n" for f in ops)


def _psum_trace(n, k, degree):
    """An injected per-shard psum of an (N,) operand inside the round
    loop — the collective audit must reject it twice over: the schedule
    depends on N, and the recurring bytes are not the ledger constant."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("shards",))

    def spmd(x):
        def step(_, acc):
            return acc + jax.lax.psum(x, "shards")
        return jax.lax.fori_loop(0, 3, step, jnp.zeros_like(x))

    f = shard_map(spmd, mesh=mesh, in_specs=P(), out_specs=P(),
                  check_rep=False)
    return jax.make_jaxpr(f)(jnp.ones((n,), jnp.float32))


def test_seeded_wide_psum_fails_collective_audit():
    prof = cx.profile_trace(_psum_trace, QUICK)
    coll = prof["collectives"]
    assert not coll["n_independent"]
    assert coll["recurring_bytes"] == 4 * QUICK.n[-1]
    findings = cx.collective_findings(
        "seeded.psum", coll, {"recurring_bytes": 0, "setup_bytes": 0})
    keys = {f.key for f in findings}
    assert "seeded.psum:n-dependent" in keys
    assert "seeded.psum:recurring-bytes" in keys
    assert all(f.rule == "complexity-collectives" for f in findings)


def test_missing_budget_fires():
    eps = entrypoints.registered_entry_points()
    findings = cx.budget_findings(eps, lookup=lambda ep: None)
    assert len(findings) == len(eps)
    assert all(f.rule == "complexity-budget-declared" for f in findings)
    assert cx.budget_findings(eps) == []            # the real tree declares all


# ---------------------------------------------------------------------------
# expectation table lifecycle
# ---------------------------------------------------------------------------

def test_expectation_table_missing_grid_and_drift_and_stale(tmp_path):
    profiles = {"refine": cx.profile_entry_point("refine", "quick")}

    missing = cx.expectation_findings(profiles, {}, "quick")
    assert [f.key for f in missing] == ["table:quick"]

    table = {"grids": {"quick": {
        "refine": cx.build_table_entry(profiles["refine"]),
        "ghost.entry": cx.build_table_entry(profiles["refine"]),
    }}}
    findings = cx.expectation_findings(profiles, table, "quick")
    assert [f.key for f in findings] == ["stale:ghost.entry"]

    drifted = json.loads(json.dumps(table))
    drifted["grids"]["quick"]["refine"]["fits"]["mem"]["n"] += 0.5
    del drifted["grids"]["quick"]["ghost.entry"]
    findings = cx.expectation_findings(profiles, drifted, "quick")
    assert [f.key for f in findings] == ["refine:mem.n"]


def test_checked_in_table_agrees_with_quick_refit():
    table = cx.load_table()
    findings = cx.expectation_findings(_quick_profiles(), table, "quick")
    assert findings == [], [f.id for f in findings]


def test_update_table_roundtrip(tmp_path):
    path = tmp_path / "complexity.json"
    cx.update_table("quick", path)
    table = cx.load_table(path)
    assert set(table["grids"]) == {"quick"}
    assert len(table["grids"]["quick"]) >= 21
    # regenerating is idempotent (fits are exact shape arithmetic)
    before = path.read_text()
    cx.update_table("quick", path)
    assert path.read_text() == before


# ---------------------------------------------------------------------------
# CLI: complexity wiring, exit-code matrix, --prune-stale, JSON shape
# ---------------------------------------------------------------------------

def _main(argv):
    from repro.analysis.__main__ import main
    return main(argv)


def test_cli_complexity_family_check_passes(tmp_path):
    out = tmp_path / "findings.json"
    rc = _main(["--check", "--families", "complexity",
                "--complexity-grid", "quick", "--json", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["reports"]["complexity"]["grid"] == "quick"
    shard = report["reports"]["complexity"]["entry_points"][
        "distributed.shard_map"]
    assert shard["collectives"]["recurring_bytes"] == protocol.CANDIDATE_BYTES


def test_cli_update_complexity_writes_table(tmp_path, capsys):
    path = tmp_path / "table.json"
    rc = _main(["--update-complexity", "--complexity-grid", "quick",
                "--complexity-table", str(path)])
    assert rc == 0
    assert "21" in capsys.readouterr().out
    assert "quick" in json.loads(path.read_text())["grids"]


_KNOWN = {"rule": "dispatch-coverage", "key": "sparse-distributed"}


def _baseline_file(tmp_path, entries):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"findings": entries}, indent=2) + "\n")
    return p


def test_cli_exit_codes_known_new_stale(tmp_path):
    # known-only: exit 0
    b = _baseline_file(tmp_path, [_KNOWN])
    assert _main(["--check", "--families", "ast",
                  "--baseline", str(b)]) == 0
    # empty baseline: the known gap is NEW -> exit 2
    b = _baseline_file(tmp_path, [])
    assert _main(["--check", "--families", "ast",
                  "--baseline", str(b)]) == 2
    # stale extra entry: never fatal, file untouched without --prune-stale
    b = _baseline_file(tmp_path, [_KNOWN, {"rule": "ghost", "key": "x"}])
    before = b.read_text()
    assert _main(["--check", "--families", "ast",
                  "--baseline", str(b)]) == 0
    assert b.read_text() == before


def test_cli_prune_stale_rewrites_baseline(tmp_path):
    b = _baseline_file(tmp_path, [_KNOWN, {"rule": "ghost", "key": "x"}])
    assert _main(["--check", "--prune-stale", "--families", "ast",
                  "--baseline", str(b)]) == 0
    data = json.loads(b.read_text())
    assert data["findings"] == [_KNOWN]
    # stale AND new at once: prune still happens, check still fails
    b = _baseline_file(tmp_path, [{"rule": "ghost", "key": "x"}])
    assert _main(["--check", "--prune-stale", "--families", "ast",
                  "--baseline", str(b)]) == 2
    assert json.loads(b.read_text())["findings"] == []


def test_cli_update_baseline_prunes_and_dedupes(tmp_path):
    b = _baseline_file(tmp_path, [{"rule": "ghost", "key": "x"},
                                  _KNOWN, _KNOWN])
    assert _main(["--update-baseline", "--families", "ast",
                  "--baseline", str(b)]) == 0
    assert json.loads(b.read_text())["findings"] == [_KNOWN]


def test_cli_json_is_deterministic_and_stamped(tmp_path):
    out1, out2 = tmp_path / "a.json", tmp_path / "b.json"
    assert _main(["--families", "ast", "--json", str(out1)]) == 0
    assert _main(["--families", "ast", "--json", str(out2)]) == 0
    r1, r2 = json.loads(out1.read_text()), json.loads(out2.read_text())
    for r in (r1, r2):
        # same provenance block the benchmarks stamp (DESIGN.md §14.5)
        assert {"git_sha", "jax", "jaxlib", "backend",
                "device_kind"} <= set(r["provenance"])
        ids = [f["id"] for f in r["findings"]]
        assert ids == sorted(ids)
    for k in ("rules", "findings", "new", "baselined", "stale_baseline",
              "reports"):
        assert r1[k] == r2[k]


# ---------------------------------------------------------------------------
# benchmarks/common.py payload schema gate
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def bench_common():
    spec = importlib.util.spec_from_file_location(
        "bench_common", REPO / "benchmarks" / "common.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_validate_bench_payload(bench_common):
    good = {"provenance": bench_common.provenance(),
            "results": {"rows": [{"n": 64, "seconds": 0.5}]}}
    bench_common.validate_bench_payload(good)    # no raise

    with pytest.raises(bench_common.BenchPayloadError, match="provenance"):
        bench_common.validate_bench_payload({"results": {}})
    with pytest.raises(bench_common.BenchPayloadError, match="missing keys"):
        bench_common.validate_bench_payload({"provenance": {"jax": "x"}})
    bad = dict(good, results={"v": float("nan")})
    with pytest.raises(bench_common.BenchPayloadError, match="non-finite"):
        bench_common.validate_bench_payload(bad)
    bad = dict(good, results={"v": [1.0, float("inf")]})
    with pytest.raises(bench_common.BenchPayloadError, match="non-finite"):
        bench_common.validate_bench_payload(bad)
    bad = dict(good, results={"v": object()})
    with pytest.raises(bench_common.BenchPayloadError, match="non-JSON"):
        bench_common.validate_bench_payload(bad)


def test_write_bench_json_refuses_bad_payload(bench_common, tmp_path,
                                              monkeypatch):
    monkeypatch.setattr(bench_common, "REPO_ROOT", str(tmp_path))
    with pytest.raises(bench_common.BenchPayloadError):
        bench_common.write_bench_json("seeded", {"v": float("nan")})
    assert not (tmp_path / "BENCH_seeded.json").exists()

    path = bench_common.write_bench_json("seeded", {"v": 1.5})
    doc = json.loads(pathlib.Path(path).read_text())
    assert doc["results"] == {"v": 1.5}
    assert doc["provenance"]["jax"]
