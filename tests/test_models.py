"""Model-layer correctness: per-arch smoke tests + algebraic oracles.

Key oracles:
  * step-by-step decode == full teacher-forced forward (all four families);
  * chunked SSD == naive per-token recurrence;
  * scatter MoE == dense all-experts oracle (ample capacity);
  * GQA == explicit head-repetition attention;
  * analytic param_count == actual parameter-tree size (also validates the
    roofline's MODEL_FLOPS accounting, full configs via eval_shape).
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.transformer import (decode_step, forward_logits,
                                      forward_train, init_cache, init_params,
                                      prefill)

ALL_ARCHS = configs.all_archs()


def _batch(cfg, key, B=2, S=32):
    kt, ki = jax.random.split(key)
    targets = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    if cfg.input_kind == "embeddings":
        inputs = jax.random.normal(ki, (B, S, cfg.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(ki, (B, S), 0, cfg.vocab_size)
    return {"inputs": inputs, "targets": targets}


# ---------------------------------------------------------------------------
# per-arch smoke: one forward/train step on CPU, shapes + no NaNs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = configs.get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, _ = forward_logits(params, cfg, batch["inputs"])
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, metrics = forward_train(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: forward_train(p, cfg, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_param_count_analytic_matches_tree(arch):
    """Analytic count (used for roofline MODEL_FLOPS) == actual tree size.
    Checked for BOTH the smoke config and the full published config (the
    latter via eval_shape — no allocation)."""
    for cfg in (configs.get_smoke_config(arch), configs.get_config(arch)):
        tree = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
        expect = cfg.param_count() + cfg.shared_block_params()
        assert actual == expect, (cfg.name, actual, expect,
                                  actual - expect)


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "granite-moe-1b-a400m",
                                  "mamba2-1.3b", "zamba2-7b"])
def test_decode_matches_teacher_forced_forward(arch):
    """Feeding tokens one-by-one through decode_step reproduces the full
    causal forward's logits at every position (per family).

    MoE: ample capacity so the batched forward drops nothing (decode is
    dropless by design; equality requires the forward not to drop either).
    """
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0,
                                cfg.vocab_size)
    full_logits, _ = forward_logits(params, cfg, tokens)

    cache = init_cache(cfg, B, max_len=T + 4, dtype=jnp.float32)
    got = []
    for t in range(T):
        step_logits, cache = decode_step(params, cfg, tokens[:, t:t + 1],
                                         cache)
        got.append(step_logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "granite-moe-1b-a400m"])
def test_prefill_then_decode_continues_forward(arch):
    """prefill(prompt) + decode_step(next) == forward over prompt+next."""
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 10
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, T + 1), 0,
                                cfg.vocab_size)
    last_logits, cache = prefill(params, cfg, tokens[:, :T], max_len=T + 4)
    full_logits, _ = forward_logits(params, cfg, tokens)
    np.testing.assert_allclose(np.asarray(last_logits[:, 0]),
                               np.asarray(full_logits[:, T - 1]),
                               rtol=2e-2, atol=2e-2)
    step_logits, cache = decode_step(params, cfg, tokens[:, T:T + 1], cache)
    np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                               np.asarray(full_logits[:, T]),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# SSD: chunked algorithm vs naive recurrence
# ---------------------------------------------------------------------------

def _ssd_naive(x, dt, a, bm, cm, init_state=None):
    """Per-token linear recurrence: s_t = exp(dt_t a) s_{t-1} + dt_t B_t x_t;
    y_t = C_t . s_t."""
    B, L, H, P = x.shape
    N = bm.shape[-1]
    s = np.zeros((B, H, P, N)) if init_state is None else \
        np.asarray(init_state, np.float64).copy()
    ys = np.zeros((B, L, H, P))
    x = np.asarray(x, np.float64)
    dt = np.asarray(dt, np.float64)
    a = np.asarray(a, np.float64)
    bm = np.asarray(bm, np.float64)
    cm = np.asarray(cm, np.float64)
    for t in range(L):
        decay = np.exp(dt[:, t] * a[None, :])                     # (B, H)
        outer = np.einsum("bh,bn,bhp->bhpn", dt[:, t], bm[:, t], x[:, t])
        s = s * decay[:, :, None, None] + outer
        ys[:, t] = np.einsum("bn,bhpn->bhp", cm[:, t], s)
    return ys, s


@pytest.mark.parametrize("L,chunk", [(16, 4), (32, 8), (24, 24), (8, 16)])
def test_ssd_chunked_matches_recurrence(L, chunk):
    rng = np.random.default_rng(L * 7 + chunk)
    B, H, P, N = 2, 3, 8, 5
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, L, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 2.0, H), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    y, final = S.ssd_chunked(x, dt, a, bm, cm, chunk)
    y_ref, s_ref = _ssd_naive(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), s_ref, rtol=2e-4,
                               atol=2e-4)


def test_ssd_chunked_with_initial_state():
    rng = np.random.default_rng(0)
    B, L, H, P, N = 1, 12, 2, 4, 3
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, L, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 2.0, H), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((B, H, P, N)), jnp.float32)
    y, final = S.ssd_chunked(x, dt, a, bm, cm, chunk=4, init_state=s0)
    y_ref, s_ref = _ssd_naive(x, dt, a, bm, cm, init_state=s0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), s_ref, rtol=2e-4,
                               atol=2e-4)


@given(st.integers(1, 2), st.integers(1, 4), st.integers(0, 1000))
@settings(max_examples=10)
def test_ssd_chunk_invariance(B, H, seed):
    """Output must not depend on the chunk size (pure reformulation)."""
    rng = np.random.default_rng(seed)
    L, P, N = 16, 4, 3
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (B, L, H)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 2.0, H), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    y4, _ = S.ssd_chunked(x, dt, a, bm, cm, chunk=4)
    y16, _ = S.ssd_chunked(x, dt, a, bm, cm, chunk=16)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16),
                               rtol=2e-4, atol=2e-4)


def test_ssm_block_decode_matches_prefill_state():
    """ssm_block's final state equals the state after L decode steps."""
    cfg = configs.get_smoke_config("mamba2-1.3b")
    params = {k: v for k, v in init_params(
        cfg, jax.random.PRNGKey(0))["blocks"].items()}
    block = jax.tree.map(lambda x: x[0], params)   # first (only) layer slice
    B, L = 2, 8
    u = jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model),
                          jnp.float32)
    y_full, final = S.ssm_block(block["ssm"], cfg, u)
    cache = S.init_ssm_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(L):
        y, cache = S.ssm_decode_step(block["ssm"], cfg, u[:, t:t + 1], cache)
        ys.append(y[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cache.state), np.asarray(final),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# MoE: scatter dispatch vs dense oracle
# ---------------------------------------------------------------------------

def test_moe_scatter_matches_dense_oracle():
    import dataclasses
    cfg = configs.get_smoke_config("qwen3-moe-235b-a22b")
    # capacity ample enough that nothing is dropped
    cfg_scatter = dataclasses.replace(cfg, moe_impl="scatter",
                                      capacity_factor=8.0)
    cfg_dense = dataclasses.replace(cfg, moe_impl="dense")
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y_s, st_s = M.moe_block(params, cfg_scatter, x)
    y_d, st_d = M.moe_block(params, cfg_dense, x)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_d),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_s.expert_load),
                               np.asarray(st_d.expert_load), rtol=1e-6)


def test_moe_einsum_matches_dense_oracle():
    """The GShard-style einsum dispatch (the SPMD production path, §Perf
    hillclimb #3) is numerically identical to the dense oracle and the
    scatter path given ample capacity."""
    import dataclasses
    cfg = configs.get_smoke_config("qwen3-moe-235b-a22b")
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y_d, _ = M.moe_block(params, dataclasses.replace(cfg, moe_impl="dense"),
                         x)
    y_e, _ = M.moe_block(params, dataclasses.replace(
        cfg, moe_impl="einsum", capacity_factor=8.0), x)
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_d),
                               rtol=1e-4, atol=1e-4)
    # same capacity => identical drops as the scatter path
    y_e2, _ = M.moe_block(params, dataclasses.replace(
        cfg, moe_impl="einsum", capacity_factor=0.5), x)
    y_s2, _ = M.moe_block(params, dataclasses.replace(
        cfg, moe_impl="scatter", capacity_factor=0.5), x)
    np.testing.assert_allclose(np.asarray(y_e2), np.asarray(y_s2),
                               rtol=1e-4, atol=1e-4)


def test_attention_q_chunking_invariance():
    """Blocked attention (attn_q_chunks > 1) must be a pure reformulation."""
    import dataclasses
    cfg = configs.get_smoke_config("yi-34b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    l1, _ = forward_logits(params, cfg, toks)
    cfg8 = dataclasses.replace(cfg, attn_q_chunks=8)
    l8, _ = forward_logits(params, cfg8, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l8),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drop_reduces_output_only():
    """With capacity_factor tiny, overflow tokens are dropped (output is a
    partial combine) but stats and shapes remain sane."""
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke_config("qwen3-moe-235b-a22b"),
                              capacity_factor=0.25)
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y, stats = M.moe_block(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.isfinite(stats.aux_loss))


def test_moe_stats_for_planner():
    cfg = configs.get_smoke_config("granite-moe-1b-a400m")
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model),
                          jnp.float32)
    _, stats = M.moe_block(params, cfg, x)
    e, k = cfg.num_experts, cfg.top_k
    load = np.asarray(stats.expert_load)
    np.testing.assert_allclose(load.sum(), k, rtol=1e-4)   # top-k per token
    coact = np.asarray(stats.coactivation)
    np.testing.assert_array_equal(coact, coact.T)
    assert np.all(np.diag(coact) == 0)
    assert np.all(coact >= 0)


# ---------------------------------------------------------------------------
# attention: GQA vs explicit repeat, rope shift, bias path
# ---------------------------------------------------------------------------

def test_gqa_matches_repeated_heads():
    cfg = configs.get_smoke_config("yi-34b")       # kv < heads
    assert cfg.num_kv_heads < cfg.num_heads
    params = A.init_attention(jax.random.PRNGKey(0), cfg)
    B, Sq = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, Sq, cfg.d_model),
                          jnp.float32)
    got = A.causal_attention(params, cfg, x)

    # reference: repeat kv heads to full MHA and use plain softmax attention
    pos = jnp.arange(Sq)[None, :]
    q, k, v = A._project_qkv(params, cfg, x, pos)
    rep = cfg.num_heads // cfg.num_kv_heads
    k_full = jnp.repeat(k, rep, axis=2)
    v_full = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_full) / np.sqrt(cfg.head_dim)
    mask = jnp.tril(jnp.ones((Sq, Sq), bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v_full).reshape(B, Sq, -1)
    want = jnp.einsum("bse,ed->bsd", out, params["wo"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_qkv_bias_changes_output():
    cfg = configs.get_smoke_config("qwen1.5-4b")
    assert cfg.qkv_bias
    params = A.init_attention(jax.random.PRNGKey(0), cfg)
    assert "bq" in params
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y0 = A.causal_attention(params, cfg, x)
    params2 = dict(params, bq=params["bq"] + 1.0)
    y1 = A.causal_attention(params2, cfg, x)
    assert float(jnp.max(jnp.abs(y1 - y0))) > 1e-4


def test_rope_relative_position_property():
    """RoPE dot products depend only on relative positions."""
    from repro.models.layers import apply_rope
    rng = np.random.default_rng(0)
    d = 32
    q = jnp.asarray(rng.standard_normal((1, 1, 1, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, d)), jnp.float32)

    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]), 10_000.0)
        kr = apply_rope(k, jnp.array([[pk]]), 10_000.0)
        return float(jnp.sum(qr * kr))

    np.testing.assert_allclose(dot_at(3, 1), dot_at(13, 11), rtol=1e-4)
    np.testing.assert_allclose(dot_at(7, 0), dot_at(107, 100), rtol=1e-4)


def test_causal_mask_blocks_future():
    """Changing future tokens must not affect past logits."""
    cfg = configs.get_smoke_config("granite-34b")      # MQA kv=1
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                              cfg.vocab_size)
    l1, _ = forward_logits(params, cfg, toks)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    l2, _ = forward_logits(params, cfg, toks2)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                               np.asarray(l2[:, :-1]), rtol=1e-4, atol=1e-4)


def test_embeddings_input_stub():
    """[audio]/[vlm] archs consume precomputed frontend embeddings."""
    for arch in ("musicgen-medium", "chameleon-34b"):
        cfg = configs.get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        if cfg.input_kind == "embeddings":
            x = jax.random.normal(jax.random.PRNGKey(1),
                                  (2, 8, cfg.d_model), jnp.float32)
        else:
            x = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                   cfg.vocab_size)
        logits, _ = forward_logits(params, cfg, x)
        assert logits.shape == (2, 8, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_full_configs_match_assignment_table():
    """Pin the published numbers (drift guard for the 40-cell dry-run)."""
    table = {
        "mamba2-1.3b": dict(num_layers=48, d_model=2048, vocab_size=50280,
                            ssm_state=128),
        "qwen3-moe-235b-a22b": dict(num_layers=94, d_model=4096,
                                    num_heads=64, num_kv_heads=4, d_ff=1536,
                                    vocab_size=151936, num_experts=128,
                                    top_k=8),
        "granite-moe-1b-a400m": dict(num_layers=24, d_model=1024,
                                     num_heads=16, num_kv_heads=8, d_ff=512,
                                     vocab_size=49155, num_experts=32,
                                     top_k=8),
        "minicpm-2b": dict(num_layers=40, d_model=2304, num_heads=36,
                           num_kv_heads=36, d_ff=5760, vocab_size=122753),
        "yi-34b": dict(num_layers=60, d_model=7168, num_heads=56,
                       num_kv_heads=8, d_ff=20480, vocab_size=64000),
        "granite-34b": dict(num_layers=88, d_model=6144, num_heads=48,
                            num_kv_heads=1, d_ff=24576, vocab_size=49152),
        "qwen1.5-4b": dict(num_layers=40, d_model=2560, num_heads=20,
                           num_kv_heads=20, d_ff=6912, vocab_size=151936,
                           qkv_bias=True),
        "musicgen-medium": dict(num_layers=48, d_model=1536, num_heads=24,
                                num_kv_heads=24, d_ff=6144, vocab_size=2048),
        "chameleon-34b": dict(num_layers=48, d_model=8192, num_heads=64,
                              num_kv_heads=8, d_ff=22016, vocab_size=65536),
        "zamba2-7b": dict(num_layers=81, d_model=3584, num_heads=32,
                          num_kv_heads=32, d_ff=14336, vocab_size=32000,
                          ssm_state=64),
    }
    for arch, want in table.items():
        cfg = configs.get_config(arch)
        for field, value in want.items():
            assert getattr(cfg, field) == value, (arch, field)
