"""Fault-tolerance suite (DESIGN.md §15).

Pins the two contracts of the robustness layer:

  * the fault-free path is BITWISE identical — pushing an all-clear
    ``zero_fault_plan`` through the faulty drivers reproduces the plain
    drivers' results exactly (one documented exemption: the sweep
    driver's ``num_moves`` counter, §15.1), and ``repair_every=0`` in
    :func:`repro.core.refine.refine` stages the pre-repair program;
  * under ANY injected fault plan the run either recovers to within the
    repair budget of the recompute oracle or fails loudly with a typed
    :class:`~repro.distributed.faults.FaultToleranceError`.
"""
from __future__ import annotations

import dataclasses
import importlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings, strategies as st

from repro.core import checkpoint as ckpt_mod
from repro.core import costs
from repro.core.aggregate import drift, init_aggregate_state, repair_columns
from repro.core.problem import (PartitionProblem, ProblemValidationError,
                                make_problem, validate_assignment)
from repro.core.refine import refine
from repro.core.sparse import sparse_from_dense
from repro.distributed import (DeadShardError, DegradedMode,
                               FaultToleranceError, faults, ledger_for_run,
                               refine_distributed,
                               refine_distributed_shard_map,
                               refine_distributed_simultaneous,
                               refine_distributed_traced, zero_fault_plan)
from repro.distributed.accounting import reconcile
from repro.distributed.views import boundary_stats
from repro.graphs.generators import random_degree_graph, random_weights
from repro.obs import MemorySink, Recorder
from repro.obs.report import check_run, replay_run, split_runs

N, K, S = 64, 4, 4          # one shape for every driver: one compile each
PLAN_ROUNDS = 96


def _problem(n=N, k=K, seed=0, mu=8.0):
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    speeds = [0.1, 0.2, 0.3, 0.4][:k] if k <= 4 else np.ones(k) / k
    prob = make_problem(c, b, speeds, mu=mu)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, r0


def _mixed_plan(seed=0, rounds=PLAN_ROUNDS, **overrides):
    kwargs = dict(p_down=0.03, down_length=(2, 4), p_omit=0.05,
                  p_lost=0.2, p_dup=0.08, p_corrupt=0.04,
                  num_machines=K, num_nodes=N)
    kwargs.update(overrides)
    return faults.make_fault_plan(rounds, S, seed, **kwargs)


def _permanent_down_plan(rounds, shards, shard=0):
    """A plan no degraded mode can absorb: one shard down every round."""
    z = np.zeros((rounds, shards), bool)
    down = z.copy()
    down[:, shard] = True
    return faults._assemble(down, z, np.zeros((rounds, shards), np.int32),
                            z, z, np.zeros((rounds, shards), np.int32),
                            np.zeros((rounds, shards), np.float32),
                            faults.DEFAULT_DEGRADED, 0)


def _assert_result_bitwise(ref, res, *, check_moves=True):
    np.testing.assert_array_equal(np.asarray(ref.assignment),
                                  np.asarray(res.assignment))
    np.testing.assert_array_equal(np.asarray(ref.loads),
                                  np.asarray(res.loads))
    assert int(ref.num_turns) == int(res.num_turns)
    assert bool(ref.converged) == bool(res.converged)
    if check_moves:
        assert int(ref.num_moves) == int(res.num_moves)


# ---------------------------------------------------------------------------
# zero-fault bitwise identity (the "do no harm" half of the contract)
# ---------------------------------------------------------------------------

def test_zero_fault_plain_bitwise():
    prob, r0 = _problem()
    ref = refine_distributed(prob, r0, costs.C_FRAMEWORK, num_shards=S)
    res, report = refine_distributed(
        prob, r0, costs.C_FRAMEWORK, num_shards=S,
        fault_plan=zero_fault_plan(PLAN_ROUNDS, S))
    _assert_result_bitwise(ref, res)
    assert report.recovered and not report.dead
    assert report.retries == 0 and report.repairs == 0
    assert report.recovery_drift <= faults.DEFAULT_DEGRADED.repair_tol


def test_zero_fault_traced_bitwise():
    prob, r0 = _problem()
    ref, ref_tr = refine_distributed_traced(prob, r0, costs.C_FRAMEWORK,
                                            num_shards=S, max_turns=256)
    res, tr, report = refine_distributed_traced(
        prob, r0, costs.C_FRAMEWORK, num_shards=S, max_turns=256,
        fault_plan=zero_fault_plan(PLAN_ROUNDS, S))
    _assert_result_bitwise(ref, res)
    for a, b in zip(ref_tr, tr):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert report.recovered


def test_zero_fault_sweep_bitwise():
    """Sweep driver: assignment / loads / potential traces bitwise; the
    self-move counters are exempt (DESIGN.md §15.1: XLA fusion-order ULP
    noise can elect a zero-gain SELF-move under the baseline ``elect``,
    inflating num_moves / num_turns and pinning ``active`` without ever
    changing the assignment; the degraded election nets those out)."""
    prob, r0 = _problem()
    ref, (c0s, ct0s, _) = refine_distributed_simultaneous(
        prob, r0, costs.C_FRAMEWORK, num_shards=S, max_sweeps=96)
    res, (fc0s, fct0s, _), report = refine_distributed_simultaneous(
        prob, r0, costs.C_FRAMEWORK, num_shards=S, max_sweeps=96,
        fault_plan=zero_fault_plan(PLAN_ROUNDS, S))
    np.testing.assert_array_equal(np.asarray(ref.assignment),
                                  np.asarray(res.assignment))
    np.testing.assert_array_equal(np.asarray(ref.loads),
                                  np.asarray(res.loads))
    # every recorded potential — including the post-fixed-point tail the
    # baseline keeps sweeping through — is bitwise identical
    np.testing.assert_array_equal(np.asarray(c0s), np.asarray(fc0s))
    np.testing.assert_array_equal(np.asarray(ct0s), np.asarray(fct0s))
    assert int(res.num_turns) <= int(ref.num_turns)
    assert report.recovered


def test_zero_fault_shard_map_bitwise():
    prob, r0 = _problem()
    ref = refine_distributed_shard_map(prob, r0, costs.C_FRAMEWORK,
                                       num_shards=1)
    res, report = refine_distributed_shard_map(
        prob, r0, costs.C_FRAMEWORK, num_shards=1,
        fault_plan=zero_fault_plan(PLAN_ROUNDS, 1))
    _assert_result_bitwise(ref, res)
    assert report.recovered and not report.dead


# ---------------------------------------------------------------------------
# recover-or-raise under injected faults
# ---------------------------------------------------------------------------

def test_transient_faults_recover():
    """A mixed outage/loss/dup/corruption plan recovers within budget."""
    prob, r0 = _problem()
    plan = _mixed_plan(seed=7)
    res, report = refine_distributed(prob, r0, costs.C_FRAMEWORK,
                                     num_shards=S, fault_plan=plan)
    assert report.recovered and not report.dead
    assert report.recovery_drift <= faults.DEFAULT_DEGRADED.repair_tol
    assert report.down_rounds > 0 or report.retries > 0
    r = np.asarray(res.assignment)
    assert r.min() >= 0 and r.max() < K
    assert np.isfinite(np.asarray(res.loads)).all()


def test_nan_corruption_repaired():
    """Pure NaN bit-corruption of carried aggregates is healed in-run."""
    prob, r0 = _problem()
    plan = _mixed_plan(seed=3, p_down=0.0, p_omit=0.0, p_lost=0.0,
                       p_dup=0.0, p_corrupt=0.15, nan_frac=1.0)
    res, report = refine_distributed(prob, r0, costs.C_FRAMEWORK,
                                     num_shards=S, fault_plan=plan)
    assert report.recovered
    assert report.repairs > 0
    assert np.isfinite(np.asarray(res.loads)).all()
    # the worst pre-repair drift actually saw the NaN poison
    assert report.max_repair_drift > faults.DEFAULT_DEGRADED.repair_tol


def test_permanent_down_raises_dead_shard():
    """A shard still down when the run ends is unrecoverable: typed raise,
    with the report attached for post-mortems."""
    prob, r0 = _problem()
    plan = _permanent_down_plan(PLAN_ROUNDS, S, shard=1)
    with pytest.raises(DeadShardError) as exc_info:
        refine_distributed(prob, r0, costs.C_FRAMEWORK, num_shards=S,
                           fault_plan=plan, max_turns=PLAN_ROUNDS // 2)
    report = exc_info.value.report
    assert report is not None and report.dead and not report.recovered


def test_faulty_rejects_recompute_path():
    prob, r0 = _problem()
    with pytest.raises(ValueError, match="incremental"):
        refine_distributed(prob, r0, costs.C_FRAMEWORK, num_shards=S,
                           incremental=False,
                           fault_plan=zero_fault_plan(8, S))


# ---------------------------------------------------------------------------
# wire accounting: fault traffic is measured and byte-exactly reconciled
# ---------------------------------------------------------------------------

def test_fault_wire_reconciles_byte_exact():
    prob, r0 = _problem()
    plan = _mixed_plan(seed=11)
    stats = boundary_stats(prob, S)

    res, wire, report = refine_distributed(
        prob, r0, costs.C_FRAMEWORK, num_shards=S, fault_plan=plan,
        measure_wire=True)
    rounds = int(res.num_turns)
    extra = faults.plan_extra_bytes(plan, rounds, faults.message_bytes(
        traced=False, simultaneous=False, num_machines=K))
    assert extra > 0, "plan produced no retry/repair traffic"
    led = ledger_for_run(stats, K, rounds, fault_bytes=extra)
    check = reconcile(led, wire)
    assert check.ok, check
    assert int(wire.payload_bytes) == led.candidate_bytes \
        + led.trace_bytes + led.fault_bytes

    # per-round steady-state payload stays O(K): identical to a fault-free
    # ledger for the same run length — fault bytes ride on top, they do
    # not change the protocol's per-turn message size.
    clean = ledger_for_run(stats, K, rounds)
    assert led.per_round_bytes == clean.per_round_bytes


def test_fault_wire_reconciles_traced_and_sweep():
    prob, r0 = _problem()
    plan = _mixed_plan(seed=13, p_down=0.0, p_corrupt=0.0)
    stats = boundary_stats(prob, S)

    res, _, wire, _ = refine_distributed_traced(
        prob, r0, costs.C_FRAMEWORK, num_shards=S, max_turns=256,
        fault_plan=plan, measure_wire=True)
    extra = faults.plan_extra_bytes(
        plan, int(res.num_turns),
        faults.message_bytes(traced=True, simultaneous=False,
                             num_machines=K))
    assert reconcile(ledger_for_run(stats, K, int(res.num_turns),
                                    traced=True, fault_bytes=extra),
                     wire).ok

    res, _, wire, _ = refine_distributed_simultaneous(
        prob, r0, costs.C_FRAMEWORK, num_shards=S, max_sweeps=96,
        fault_plan=plan, measure_wire=True)
    extra = faults.plan_extra_bytes(
        plan, int(res.num_turns),
        faults.message_bytes(traced=False, simultaneous=True,
                             num_machines=K))
    assert reconcile(ledger_for_run(stats, K, int(res.num_turns),
                                    simultaneous=True, fault_bytes=extra),
                     wire).ok


# ---------------------------------------------------------------------------
# core: column repair + checkpoint heal
# ---------------------------------------------------------------------------

def test_repair_columns_clean_state_untouched():
    prob, r0 = _problem(n=32, k=3, seed=5)
    agg = init_aggregate_state(prob, r0)
    repaired, observed, cols = repair_columns(prob, agg, 1e-3)
    assert int(cols) == 0
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(repaired)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(observed) <= 1e-3


def test_repair_columns_patches_only_bad_column():
    prob, r0 = _problem(n=32, k=3, seed=5)
    agg = init_aggregate_state(prob, r0)
    bad = agg._replace(aggregate=agg.aggregate.at[:, 1].add(5.0))
    repaired, observed, cols = repair_columns(prob, bad, 1e-3)
    assert int(cols) == 1
    assert float(observed) == pytest.approx(5.0)
    np.testing.assert_array_equal(np.asarray(repaired.aggregate),
                                  np.asarray(agg.aggregate))
    # untouched columns come back bitwise from the corrupted carry, not
    # from the oracle rebuild
    np.testing.assert_array_equal(np.asarray(repaired.aggregate[:, 0]),
                                  np.asarray(bad.aggregate[:, 0]))


def test_checkpoint_heal_rolls_back_nan_poison():
    prob, r0 = _problem(n=32, k=3, seed=5)
    agg = init_aggregate_state(prob, r0)
    ckpt = ckpt_mod.take(agg, jnp.zeros((), jnp.int32))
    poisoned = agg._replace(aggregate=agg.aggregate.at[0, 0].set(jnp.nan))
    assert not bool(ckpt_mod.is_healthy(poisoned))
    healed, observed, cols, rolled = ckpt_mod.heal(prob, poisoned, ckpt)
    assert bool(rolled)
    assert float(observed) == np.inf       # NaN reports as inf drift
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(healed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a finite-but-drifted carry is column-repaired, not rolled back
    drifted = agg._replace(aggregate=agg.aggregate.at[:, 2].add(7.0))
    healed, observed, cols, rolled = ckpt_mod.heal(prob, drifted, ckpt)
    assert not bool(rolled) and int(cols) == 1
    assert float(drift(prob, healed)) <= ckpt_mod.DEFAULT_REPAIR_TOL


def test_refine_repair_every_bitwise_dense_and_sparse():
    """repair_every on a healthy run never rewrites clean state: the full
    result is bitwise identical to the repair-free program, dense and
    sparse alike."""
    prob, r0 = _problem(n=48, k=3, seed=9)
    for p in (prob, sparse_from_dense(prob)):
        ref = refine(p, r0, costs.C_FRAMEWORK)
        res = refine(p, r0, costs.C_FRAMEWORK, repair_every=8)
        _assert_result_bitwise(ref, res)
        # aggregate_drift is a diagnostic, not part of the bitwise
        # contract: repair runs REPORT the observed f32 carry drift
        # (like verify_every), the baseline reports 0.0
        assert np.isfinite(float(res.aggregate_drift))


# ---------------------------------------------------------------------------
# DES: speed 0 == machine down (satellite regression)
# ---------------------------------------------------------------------------

def _des_down_setup(n=16, t=2):
    from repro.des.engine import DESConfig, make_initial_state
    from repro.des.workload import flooded_packet_workload
    adj = random_degree_graph(n, seed=4, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 6, num_threads=t, scope=2,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=t,
                    event_capacity=32, history_capacity=64, max_ticks=400)
    # every LP on machine 0 — the machine we take down
    state = make_initial_state(cfg, jnp.zeros(n, jnp.int32),
                               spec.src, spec.time, spec.count)
    return cfg, adj, state


def test_des_speed_zero_freezes_machine():
    """speed=0 means DOWN: the machine processes NOTHING while failed.
    With every LP and every thread source on the failed machine, the
    engine commits zero events and GVT stays at 0."""
    from repro.des import scenarios
    from repro.des.engine import run_simulation
    cfg, adj, state = _des_down_setup()
    sched = scenarios.true_failure(2, machine=0, fail_tick=0)
    out = run_simulation(cfg, jnp.asarray(adj, jnp.float32), state,
                         speed_schedule=sched)
    assert int(out.processed) == 0
    assert float(out.gvt) == 0.0
    assert not bool(out.done)


def test_des_machine_recovers_and_drains():
    """Regression for the speed=0 busy-time bug (DESIGN.md §15.5): the
    old engine divided service time by speed and cast the resulting inf
    to int32 (saturating to INT32_MAX), wedging the 'failed' machine's
    LP in a busy state it could never complete — the simulation never
    drained even after the schedule restored the speed.  The fixed
    engine freezes the queue instead, so recovery drains normally."""
    from repro.des import scenarios
    from repro.des.engine import run_simulation
    cfg, adj, state = _des_down_setup()
    cfg = dataclasses.replace(cfg, max_ticks=20_000)
    sched = scenarios.true_failure(2, machine=0, fail_tick=0,
                                   recover_tick=60)
    out = run_simulation(cfg, jnp.asarray(adj, jnp.float32), state,
                         speed_schedule=sched)
    assert bool(out.done)
    assert int(out.processed) > 0
    assert int(out.dropped) == 0


def test_des_all_positive_schedule_bitwise():
    """A schedule that never hits zero leaves the engine bitwise on the
    pre-§15.5 path: all the down-gates are constant-false."""
    from repro.des import scenarios
    from repro.des.engine import run_simulation
    cfg, adj, state = _des_down_setup()
    cfg = dataclasses.replace(cfg, max_ticks=20_000)
    ref = run_simulation(cfg, jnp.asarray(adj, jnp.float32), state)
    out = run_simulation(cfg, jnp.asarray(adj, jnp.float32), state,
                         speed_schedule=scenarios.constant(2))
    assert int(ref.processed) == int(out.processed)
    assert float(ref.gvt) == float(out.gvt)
    np.testing.assert_array_equal(np.asarray(ref.seen), np.asarray(out.seen))


def test_scenarios_exchange_loss_is_fault_plan():
    from repro.des import scenarios
    plan = scenarios.refine_exchange_loss(32, S, seed=1, p_lost=0.3)
    assert isinstance(plan, faults.FaultPlan)
    assert plan.num_shards == S and plan.horizon == 32
    assert int(np.asarray(plan.lost).sum()) > 0


# ---------------------------------------------------------------------------
# input validation (satellite: typed errors instead of jit-deep failures)
# ---------------------------------------------------------------------------

def _raw_problem(adj, b=None, w=None, mu=8.0):
    n = adj.shape[0]
    return PartitionProblem(
        adjacency=jnp.asarray(adj, jnp.float32),
        node_weights=jnp.asarray(np.ones(n) if b is None else b, jnp.float32),
        speeds=jnp.asarray(np.ones(3) / 3 if w is None else w, jnp.float32),
        mu=jnp.float32(mu))


def test_validate_dense_typed_errors():
    good = np.triu(np.ones((6, 6)), 1)
    with pytest.raises(ProblemValidationError, match="symmetric"):
        _raw_problem(good).validate()
    sym = good + good.T
    _raw_problem(sym).validate()
    bad = sym.copy()
    bad[0, 1] = bad[1, 0] = np.nan
    with pytest.raises(ProblemValidationError, match="NaN"):
        _raw_problem(bad).validate()
    bad = sym.copy()
    bad[0, 1] = bad[1, 0] = -1.0
    with pytest.raises(ProblemValidationError, match="negative"):
        _raw_problem(bad).validate()
    with pytest.raises(ProblemValidationError, match="node_weights"):
        _raw_problem(sym, b=-np.ones(6)).validate()
    with pytest.raises(ProblemValidationError, match="speeds"):
        _raw_problem(sym, w=np.array([0.5, 0.5, 0.0])).validate()
    with pytest.raises(ProblemValidationError, match="square"):
        PartitionProblem(jnp.zeros((4, 5)), jnp.ones(4), jnp.ones(2),
                         jnp.float32(1.0)).validate()


def test_validate_assignment_typed_errors():
    validate_assignment(jnp.asarray([0, 1, 2, 0], jnp.int32), 3)
    with pytest.raises(ProblemValidationError, match="integer"):
        validate_assignment(jnp.zeros(4, jnp.float32), 3)
    with pytest.raises(ProblemValidationError, match=r"\[0, 3\)"):
        validate_assignment(jnp.asarray([0, 1, 3, 0], jnp.int32), 3)
    with pytest.raises(ProblemValidationError, match="entries"):
        validate_assignment(jnp.asarray([0, 1], jnp.int32), 3, num_nodes=4)
    with pytest.raises(ProblemValidationError, match="1-D"):
        validate_assignment(jnp.zeros((2, 2), jnp.int32), 3)


def test_validate_sparse_typed_errors():
    prob, _ = _problem(n=24, k=3, seed=2)
    sp = sparse_from_dense(prob)
    sp.validate()
    with pytest.raises(ProblemValidationError, match="NaN"):
        dataclasses.replace(
            sp, edge_weights=sp.edge_weights.at[0].set(jnp.nan)).validate()
    with pytest.raises(ProblemValidationError, match="negative"):
        dataclasses.replace(
            sp, edge_weights=sp.edge_weights.at[0].set(-2.0)).validate()
    with pytest.raises(ProblemValidationError, match="row_start"):
        dataclasses.replace(
            sp, row_start=sp.row_start[::-1]).validate()
    with pytest.raises(ProblemValidationError, match="sorted"):
        dataclasses.replace(
            sp, senders=sp.senders[::-1],
            receivers=sp.receivers[::-1],
            edge_weights=sp.edge_weights[::-1]).validate()


# ---------------------------------------------------------------------------
# obs: abort flush + recovery verdict in report --check
# ---------------------------------------------------------------------------

def test_recorder_abort_flushes_terminal_event():
    class Boom(RuntimeError):
        pass

    sink = MemorySink()
    rec = Recorder([sink])
    run = rec.new_run("refine")
    rec.begin_rows()
    rec._on_turn_row(np.int32(0), np.int32(0), np.int32(1), np.int32(3),
                     np.int32(0), np.int32(1), np.float32(0.5),
                     np.float32(1.0), np.float32(9.0), np.float32(4.0),
                     np.int32(0))
    with pytest.raises(Boom):
        with rec.phase("refine.loop", run):
            raise Boom("device OOM mid-run")
    kinds = [e["kind"] for e in sink.events]
    assert kinds[-1] == "run_aborted"
    assert kinds[-2] == "phase"            # the span still closed
    aborted = sink.events[-1]
    assert "Boom" in aborted["error"]
    assert aborted["pending_rows"] == 1
    # an aborted run fails --check loudly
    summary = replay_run(split_runs(sink.events)[run])
    assert any("aborted" in p for p in check_run(summary))


def test_report_check_requires_recovery_verdict():
    """A fault-injected run passes --check only if its run_end carries
    recovered=True within budget; a missing/false verdict is a failure."""
    prob, r0 = _problem()
    rec = Recorder([MemorySink()])
    refine_distributed(prob, r0, costs.C_FRAMEWORK, num_shards=S,
                       fault_plan=_mixed_plan(seed=17), recorder=rec)
    runs = split_runs(rec.events)
    assert len(runs) == 1
    events = next(iter(runs.values()))
    summary = replay_run(events)
    assert summary["faults"], "fault events were not recorded"
    assert not check_run(summary), check_run(summary)

    # strip the verdict: same events must now FAIL the check
    stripped = [dict(e) for e in events]
    for e in stripped:
        if e["kind"] == "run_end":
            e["recovered"] = False
    problems = check_run(replay_run(stripped))
    assert any("recover" in p for p in problems)


# ---------------------------------------------------------------------------
# adversarial property suite (hypothesis, stub-aware — see conftest)
# ---------------------------------------------------------------------------

_DRIVER = {
    "plain": lambda p, r0, plan: refine_distributed(
        p, r0, costs.C_FRAMEWORK, num_shards=S, fault_plan=plan),
    "traced": lambda p, r0, plan: refine_distributed_traced(
        p, r0, costs.C_FRAMEWORK, num_shards=S, max_turns=256,
        fault_plan=plan)[::2],
    "sweep": lambda p, r0, plan: refine_distributed_simultaneous(
        p, r0, costs.C_FRAMEWORK, num_shards=S, max_sweeps=96,
        fault_plan=plan)[::2],
}


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), mode=st.sampled_from(sorted(_DRIVER)))
def test_random_fault_plans_recover_or_raise(seed, mode):
    """ANY seeded fault plan either recovers within the repair budget or
    raises a typed FaultToleranceError — never a silent bad result."""
    prob, r0 = _problem()
    plan = _mixed_plan(seed=seed)
    try:
        res, report = _DRIVER[mode](prob, r0, plan)
    except FaultToleranceError as err:
        assert err.report is not None
        assert err.report.dead or not err.report.recovered
        return
    assert report.recovered
    assert report.recovery_drift <= faults.DEFAULT_DEGRADED.repair_tol
    r = np.asarray(res.assignment)
    assert r.min() >= 0 and r.max() < K
    assert np.isfinite(np.asarray(res.loads)).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16), use_sparse=st.booleans(),
       repair_every=st.sampled_from([4, 8, 16]))
def test_repair_every_never_perturbs_healthy_runs(seed, use_sparse,
                                                  repair_every):
    prob, r0 = _problem(n=48, k=3, seed=seed % 1000)
    p = sparse_from_dense(prob) if use_sparse else prob
    ref = refine(p, r0, costs.C_FRAMEWORK)
    res = refine(p, r0, costs.C_FRAMEWORK, repair_every=repair_every)
    _assert_result_bitwise(ref, res)
    assert np.isfinite(np.asarray(res.loads)).all()
