"""Docs-consistency gate — thin wrapper over the contract linter.

The actual scans (DESIGN-§ citations resolve, referenced doc files
exist) live in :mod:`repro.analysis.docs_rules` as registry rules
(DESIGN.md §16), shared by ``python -m repro.analysis --check`` and the
``lint-contracts`` CI job.  These tests keep the tier-1 behavior: any
docs finding fails the suite.
"""
from __future__ import annotations

import pathlib

from repro.analysis import AnalysisContext
from repro.analysis.docs_rules import (design_ref_findings,
                                       design_sections, doc_file_findings)

REPO = pathlib.Path(__file__).resolve().parent.parent


def _ctx() -> AnalysisContext:
    return AnalysisContext(repo_root=REPO)


def test_design_md_has_section_headers():
    sections = design_sections(_ctx())
    assert "1" in sections and "12" in sections, sorted(sections)


def test_src_design_references_resolve():
    findings = [f for f in design_ref_findings(_ctx())
                if f.key.startswith("src")]
    assert not findings, [f.message for f in findings]


def test_doc_file_references_exist():
    findings = doc_file_findings(_ctx())
    assert not findings, [f.message for f in findings]


def test_src_actually_cites_design():
    # the convention is load-bearing (new public APIs must cite their
    # section); the rule emits a dedicated finding if extraction matches
    # fewer than 10 citing files
    assert not any(f.key == "too-few-citing-files"
                   for f in design_ref_findings(_ctx()))
