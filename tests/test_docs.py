"""Docs-consistency gate: DESIGN.md section references must resolve.

Docstrings across ``src/`` cite design sections as ``DESIGN.md §N`` /
``DESIGN.md §N.M``; stale citations (a renumbered or removed section)
rot silently.  This test extracts every such reference and checks it
against the actual DESIGN.md headers, so CI fails the moment a docstring
points at a section that no longer exists.
"""
from __future__ import annotations

import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parent.parent
REF_RE = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)")
HEADER_RE = re.compile(r"^#{1,6}\s.*?§(\d+(?:\.\d+)?)", re.MULTILINE)


def _design_sections() -> set[str]:
    text = (REPO / "DESIGN.md").read_text()
    return set(HEADER_RE.findall(text))


def _source_references() -> dict[str, set[str]]:
    refs: dict[str, set[str]] = {}
    for path in sorted((REPO / "src").rglob("*.py")):
        found = set(REF_RE.findall(path.read_text()))
        if found:
            refs[str(path.relative_to(REPO))] = found
    return refs


def test_design_md_has_section_headers():
    sections = _design_sections()
    assert "1" in sections and "12" in sections, sorted(sections)


def test_src_design_references_resolve():
    sections = _design_sections()
    dangling = {
        path: sorted(found - sections)
        for path, found in _source_references().items()
        if found - sections
    }
    assert not dangling, (
        f"docstrings cite DESIGN.md sections that have no header: "
        f"{dangling}; valid sections: {sorted(sections)}")


def test_src_actually_cites_design():
    # the convention is load-bearing (new public APIs must cite their
    # section); guard against the reference extraction silently matching
    # nothing
    refs = _source_references()
    assert len(refs) >= 10, sorted(refs)
