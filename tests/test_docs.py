"""Docs-consistency gate: doc references from code must resolve.

Two failure modes are caught:

  * Docstrings across ``src/`` cite design sections as ``DESIGN.md §N``
    / ``DESIGN.md §N.M``; stale citations (a renumbered or removed
    section) rot silently.  Every such reference is checked against the
    actual DESIGN.md headers.
  * Docstrings citing a repo doc FILE that does not exist — e.g. the
    ``random_weights`` docstring long pointed at a nonexistent
    ``EXPERIMENTS.md`` (ISSUE 5).  Every ``SOMETHING.md`` mention in
    ``src``/``tests``/``benchmarks``/``examples`` must name a file that
    is actually in the repo root.
"""
from __future__ import annotations

import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parent.parent
REF_RE = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)")
HEADER_RE = re.compile(r"^#{1,6}\s.*?§(\d+(?:\.\d+)?)", re.MULTILINE)
DOCFILE_RE = re.compile(r"\b([A-Z][A-Z0-9_]*\.md)\b")
DOCFILE_SCAN_DIRS = ("src", "tests", "benchmarks", "examples")


def _design_sections() -> set[str]:
    text = (REPO / "DESIGN.md").read_text()
    return set(HEADER_RE.findall(text))


def _source_references() -> dict[str, set[str]]:
    refs: dict[str, set[str]] = {}
    for path in sorted((REPO / "src").rglob("*.py")):
        found = set(REF_RE.findall(path.read_text()))
        if found:
            refs[str(path.relative_to(REPO))] = found
    return refs


def test_design_md_has_section_headers():
    sections = _design_sections()
    assert "1" in sections and "12" in sections, sorted(sections)


def test_src_design_references_resolve():
    sections = _design_sections()
    dangling = {
        path: sorted(found - sections)
        for path, found in _source_references().items()
        if found - sections
    }
    assert not dangling, (
        f"docstrings cite DESIGN.md sections that have no header: "
        f"{dangling}; valid sections: {sorted(sections)}")


def test_doc_file_references_exist():
    """Every UPPERCASE.md mentioned anywhere in code must exist in the
    repo root (catches citations of removed/never-written docs)."""
    this_file = pathlib.Path(__file__).resolve()
    dangling: dict[str, set[str]] = {}
    for d in DOCFILE_SCAN_DIRS:
        for path in sorted((REPO / d).rglob("*.py")):
            if path.resolve() == this_file:
                continue   # this file names nonexistent docs as examples
            missing = {name for name in DOCFILE_RE.findall(path.read_text())
                       if not (REPO / name).is_file()}
            if missing:
                dangling[str(path.relative_to(REPO))] = missing
    assert not dangling, (
        f"code references repo doc files that do not exist: {dangling}")


def test_src_actually_cites_design():
    # the convention is load-bearing (new public APIs must cite their
    # section); guard against the reference extraction silently matching
    # nothing
    refs = _source_references()
    assert len(refs) >= 10, sorted(refs)
