"""Optimistic-DES archetype invariants (paper §6 + Appendix B).

The strongest oracle: a thread with hop budget c injected at src must
eventually be seen by EXACTLY the nodes within c hops of src — regardless
of machine placement, transfer delays, stragglers and rollbacks.  The
engine's whole Time Warp machinery exists to preserve that semantics while
executing optimistically.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.des.engine import (DESConfig, DESState, des_tick,
                              make_initial_state, run_simulation)
from repro.des.workload import flooded_packet_workload
from repro.graphs.generators import (preferential_attachment,
                                     random_degree_graph)


def _hop_closure(adj: np.ndarray, src: int, hops: int) -> np.ndarray:
    mask = np.zeros(adj.shape[0], bool)
    mask[src] = True
    nbr = adj > 0
    for _ in range(hops):
        mask = mask | (mask @ nbr)
    return mask


def _run(cfg, adj, spec, machine=None):
    n = cfg.num_lps
    m0 = jnp.arange(n, dtype=jnp.int32) % cfg.num_machines \
        if machine is None else jnp.asarray(machine, jnp.int32)
    state = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
    return run_simulation(cfg, jnp.asarray(adj, jnp.float32), state)


@pytest.mark.parametrize("num_machines,seed", [(1, 0), (3, 1), (5, 2)])
def test_flood_closure_oracle(num_machines, seed):
    """Final 'seen' sets == exact k-hop closures, for any machine count."""
    n, t = 24, 6
    adj = random_degree_graph(n, seed=seed, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, seed + 10, num_threads=t, scope=2,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=num_machines, num_threads=t,
                    event_capacity=32, history_capacity=64, max_ticks=40_000)
    out = _run(cfg, adj, spec)
    assert bool(out.done), f"not drained after {int(out.tick)} ticks"
    assert int(out.dropped) == 0 and int(out.hist_evict) == 0
    seen = np.asarray(out.seen)
    for j in range(t):
        want = _hop_closure(adj, int(spec.src[j]), int(spec.count[j]))
        np.testing.assert_array_equal(
            seen[:, j], want,
            err_msg=f"thread {j} src={spec.src[j]} scope={spec.count[j]}")


def test_processed_counts_match_closure():
    """Each flood event is processed exactly once per (node, thread) pair
    that the closure admits (no double-processing after rollbacks)."""
    n, t = 16, 4
    adj = random_degree_graph(n, seed=3, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 5, num_threads=t, scope=2,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=t,
                    event_capacity=32, history_capacity=64, max_ticks=40_000)
    out = _run(cfg, adj, spec)
    assert bool(out.done)
    expect = sum(int(_hop_closure(adj, int(spec.src[j]),
                                  int(spec.count[j])).sum())
                 for j in range(t))
    # processed counts include rollback re-executions; net completions must
    # be at least the closure size and exactly it when no rollbacks occurred
    assert int(out.processed) >= expect
    if int(out.rollbacks) == 0:
        assert int(out.processed) == expect


def test_gvt_monotone_nondecreasing():
    n, t = 16, 5
    adj = preferential_attachment(n, seed=1, m=2)
    spec = flooded_packet_workload(adj, 2, num_threads=t, scope=2,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=3, num_threads=t,
                    event_capacity=32, history_capacity=64, max_ticks=5_000)
    m0 = jnp.arange(n, dtype=jnp.int32) % 3
    state = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
    tick = jax.jit(partial(des_tick, cfg), static_argnums=())
    adjj = jnp.asarray(adj, jnp.float32)
    prev_gvt = -1.0
    for _ in range(400):
        state = tick(adjj, state)
        g = float(state.gvt)
        assert g >= prev_gvt - 1e-6, "GVT regressed"
        prev_gvt = g
        if bool(state.done):
            break
    assert bool(state.done)


def test_single_machine_never_needs_intermachine_delay():
    """On one machine every transfer uses intra_delay; a huge inter_delay
    must not change the outcome."""
    n, t = 12, 3
    adj = random_degree_graph(n, seed=7, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 8, num_threads=t, scope=2,
                                   max_per_lp=3)
    outs = []
    for inter in (2, 50):
        cfg = DESConfig(num_lps=n, num_machines=1, num_threads=t,
                        event_capacity=32, history_capacity=64,
                        inter_delay=inter, max_ticks=40_000)
        outs.append(_run(cfg, adj, spec, machine=np.zeros(n)))
    assert int(outs[0].tick) == int(outs[1].tick)
    np.testing.assert_array_equal(np.asarray(outs[0].seen),
                                  np.asarray(outs[1].seen))


def test_intermachine_delay_slows_simulation():
    """Cross-machine placement with large transfer delay costs wall-clock
    ticks vs an all-on-one-machine placement of the same workload — the
    rollback-risk mechanism the partition game's edge weights model."""
    n, t = 20, 5
    adj = random_degree_graph(n, seed=11, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 12, num_threads=t, scope=3,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=t,
                    event_capacity=48, history_capacity=96,
                    inter_delay=25, intra_delay=1, max_ticks=60_000)
    # adversarial placement: alternate machines along node index
    bad = _run(cfg, adj, spec, machine=np.arange(n) % 2)
    # everything on machine 0 (machine speed model penalizes density, but
    # avoids all transfer delay)
    good = _run(cfg, adj, spec, machine=np.zeros(n))
    assert bool(bad.done) and bool(good.done)
    assert int(bad.rollbacks) >= int(good.rollbacks)


def test_refinement_runs_and_migrates():
    n, t = 24, 8
    adj = preferential_attachment(n, seed=4, m=2)
    spec = flooded_packet_workload(adj, 6, num_threads=t, scope=2,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=3, num_threads=t,
                    event_capacity=32, history_capacity=64,
                    refine_freq=150, max_ticks=40_000)
    out = _run(cfg, adj, spec)
    assert bool(out.done)
    assert int(out.refines) >= 1
    # machine ids stay valid after migrations
    m = np.asarray(out.machine)
    assert m.min() >= 0 and m.max() < 3


def test_load_trace_recorded():
    n, t = 16, 4
    adj = random_degree_graph(n, seed=6, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 3, num_threads=t, scope=2,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=t,
                    event_capacity=32, history_capacity=64,
                    trace_stride=10, max_ticks=40_000)
    out = _run(cfg, adj, spec)
    assert int(out.trace_ptr) > 0
    tr = np.asarray(out.trace)[:int(out.trace_ptr)]
    assert np.all(tr >= 0)


def test_determinism():
    """Identical inputs -> identical simulation (pure function of state)."""
    n, t = 14, 4
    adj = random_degree_graph(n, seed=9, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 1, num_threads=t, scope=2,
                                   max_per_lp=3)
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=t,
                    event_capacity=32, history_capacity=64, max_ticks=40_000)
    a = _run(cfg, adj, spec)
    b = _run(cfg, adj, spec)
    assert int(a.tick) == int(b.tick)
    assert int(a.processed) == int(b.processed)
    np.testing.assert_array_equal(np.asarray(a.seen), np.asarray(b.seen))
