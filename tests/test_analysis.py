"""Roofline bookkeeping: the HLO collective parser and the jaxpr FLOP
counter that feed ``benchmarks.roofline``."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis, jaxpr_flops


# ---------------------------------------------------------------------------
# HLO shape/collective parsing on handcrafted text
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
HloModule jit_step

%loop_body.1 (arg.1: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %x = f32[64,128]{1,0} get-tuple-element(%p), index=1
  %ag = f32[128,128]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[64,128]{1,0} all-reduce(%x), to_apply=%add
  %done = s32[] constant(4)
}

%loop_cond.1 (arg.2: (s32[], f32[64,128])) -> pred[] {
  %pc = (s32[], f32[64,128]) parameter(0)
  %i = s32[] get-tuple-element(%pc), index=0
  %lim = s32[] constant(12)
  %cmp = pred[] compare(%i, %lim), direction=LT
}

ENTRY %main (a: f32[64,128]) -> f32[64,128] {
  %a = f32[64,128]{1,0} parameter(0)
  %w = (s32[], f32[64,128]) while((s32[], f32[64,128]) %init), condition=%loop_cond.1, body=%loop_body.1
  %rs = f32[32,128]{1,0} reduce-scatter(%a), dimensions={0}
  %cp = f32[64,128]{1,0} collective-permute(%a), source_target_pairs={{0,1}}
  %a2a = f32[64,128]{1,0} all-to-all(%a), dimensions={0}
}
"""


def test_shape_bytes():
    assert hlo_analysis._shape_bytes("f32[64,128]") == 64 * 128 * 4
    assert hlo_analysis._shape_bytes("bf16[2,3,4]") == 24 * 2
    assert hlo_analysis._shape_bytes("(f32[8], s32[4])") == 32 + 16
    assert hlo_analysis._shape_bytes("pred[]") == 1
    assert hlo_analysis._shape_bytes("token[]") == 0


def test_shape_bytes_wide_and_narrow_dtypes():
    # widths that used to silently contribute 0 bytes
    assert hlo_analysis._shape_bytes("c128[8]") == 8 * 16
    assert hlo_analysis._shape_bytes("c64[8]") == 8 * 8
    for f8 in ("f8e4m3b11fnuz", "f8e4m3fnuz", "f8e5m2fnuz"):
        assert hlo_analysis._shape_bytes(f"{f8}[16,4]") == 64
    # 4-bit ints pack two per byte, odd counts round up
    assert hlo_analysis._shape_bytes("s4[64]") == 32
    assert hlo_analysis._shape_bytes("u4[7]") == 4
    assert hlo_analysis._shape_bytes("(s4[3], f32[2])") == 2 + 8


def test_shape_bytes_unknown_dtype_raises():
    with pytest.raises(ValueError, match="unknown HLO dtype"):
        hlo_analysis._shape_bytes("f6e3m2[8]")
    # zero-size tokens stay accepted, not raised on
    assert hlo_analysis._shape_bytes("(token[], f32[2])") == 8


def test_collective_stats_with_loop_trip():
    stats = hlo_analysis.collective_stats(HLO_SAMPLE)
    f = 4  # bytes
    # inside while body (trip 12): all-gather 128*128*4, all-reduce 64*128*4
    ag = 128 * 128 * f * 12
    ar = 64 * 128 * f * 12
    rs = 32 * 128 * f
    cp = 64 * 128 * f
    a2a = 64 * 128 * f
    assert stats["by_kind"]["all-gather"] == ag
    assert stats["by_kind"]["all-reduce"] == ar
    assert stats["by_kind"]["reduce-scatter"] == rs
    assert stats["by_kind"]["collective-permute"] == cp
    assert stats["by_kind"]["all-to-all"] == a2a
    assert stats["total_bytes"] == ag + ar + rs + cp + a2a
    assert stats["count"]["all-gather"] == 1


def test_collective_stats_empty():
    stats = hlo_analysis.collective_stats("ENTRY %m () -> f32[] {\n}\n")
    assert stats["total_bytes"] == 0


# ---------------------------------------------------------------------------
# jaxpr FLOP counting
# ---------------------------------------------------------------------------

def test_dot_flops_exact():
    def f(a, b):
        return a @ b

    a = jnp.zeros((64, 32))
    b = jnp.zeros((32, 48))
    flops, _ = jaxpr_flops.count_fn(f, a, b)
    assert flops == 2 * 64 * 32 * 48


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jnp.zeros((4, 8, 16))
    b = jnp.zeros((4, 16, 32))
    flops, _ = jaxpr_flops.count_fn(f, a, b)
    assert flops == 2 * 4 * 8 * 16 * 32


def test_scan_multiplies_trip_count():
    w = jnp.zeros((16, 16))

    def f(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jnp.zeros((16, 16))
    flops_trip, _ = jaxpr_flops.count_fn(f, x)
    closed = jax.make_jaxpr(f)(x)
    flops_once, _ = jaxpr_flops.count_jaxpr(closed, multiply_trips=False)
    assert flops_trip == 7 * flops_once
    assert flops_once == 2 * 16 ** 3


def test_trip_factor_for_layered_model():
    """The scan-over-layers trip factor recovered by count_fn_with_factor is
    ~num_layers for a deep model (what corrects XLA's body-once count)."""
    from repro import configs
    from repro.models import init_params
    from repro.models.transformer import forward_logits

    cfg = configs.get_smoke_config("qwen1.5-4b")   # 2-layer smoke
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((1, 16), jnp.int32)

    def fwd(p, t):
        return forward_logits(p, cfg, t)[0]

    f1, b1, tf, tb = jaxpr_flops.count_fn_with_factor(fwd, params, toks)
    assert f1 > 0 and b1 > 0
    assert tf > 1.2            # the layer scan dominates => factor ~ L


def test_flops_and_bytes_from_compiled():
    def f(a, b):
        return jnp.sum(a @ b)

    a = jnp.ones((128, 128))
    b = jnp.ones((128, 128))
    compiled = jax.jit(f).lower(a, b).compile()
    flops, nbytes = hlo_analysis.flops_and_bytes(compiled)
    assert flops >= 2 * 128 ** 3 * 0.9
    assert nbytes > 0


def test_analytic_model_flops_sanity():
    """6*N*D per train token: the jaxpr count for a smoke model's forward
    is within 2x of 2*N_active*D (forward only, embeddings excluded)."""
    from repro import configs
    from repro.models import init_params
    from repro.models.transformer import forward_train

    cfg = configs.get_smoke_config("musicgen-medium")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    batch = {"inputs": jnp.zeros((B, S, cfg.d_model), jnp.float32),
             "targets": jnp.zeros((B, S), jnp.int32)}

    def fwd(p, bt):
        return forward_train(p, cfg, bt)[0]

    flops, _ = jaxpr_flops.count_fn(fwd, params, batch)
    approx = 2 * cfg.active_param_count() * B * S
    assert 0.4 * approx < flops < 3.0 * approx, (flops, approx)
