"""repro.distributed — the sharded O(K)-exchange refinement runtime.

The load-bearing claims:
  * sequential-turn distributed refinement reproduces the single
    controller's move sequence EXACTLY (same turn order, same nodes, same
    destinations, bitwise-equal gains) and lands on the identical final
    assignment — for any shard count and both cost frameworks;
  * each framework's own global potential is non-increasing across rounds;
  * the per-round inter-machine payload carries no O(N) term (flat as N
    grows at fixed K — the paper's central scalability claim);
  * the real shard_map/all_gather driver agrees with the emulated one
    (single-device in-process, multi-device via a subprocess that forces
    a 4-device host platform — the main test process must stay 1-device).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.problem import make_problem, make_state
from repro.core.refine import refine, refine_simultaneous, refine_traced
from repro.distributed import (boundary_stats, build_views, ledger_for_run,
                               refine_distributed,
                               refine_distributed_shard_map,
                               refine_distributed_simultaneous,
                               refine_distributed_traced)
from repro.distributed import accounting, protocol
from repro.graphs.generators import random_degree_graph, random_weights


def _problem(n=120, k=5, seed=0, mu=8.0):
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    speeds = [0.1, 0.2, 0.3, 0.3, 0.1][:k]
    prob = make_problem(c, b, speeds, mu=mu)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, r0


# ---------------------------------------------------------------------------
# views
# ---------------------------------------------------------------------------

def test_views_partition_and_padding():
    prob, _ = _problem(n=50, k=5)
    views = build_views(prob, 4)                     # 50 -> 4 shards of 13
    assert views.row_block.shape == (4, 13, 50)
    assert int(jnp.sum(views.valid)) == 50
    # row blocks reassemble to the adjacency (padding rows are zero)
    flat = views.row_block.reshape(52, 50)
    np.testing.assert_array_equal(np.asarray(flat[:50]),
                                  np.asarray(prob.adjacency))
    np.testing.assert_array_equal(np.asarray(flat[50:]), 0.0)
    # weights of padded rows are zero; valid ids cover 0..N-1 exactly once
    assert float(jnp.sum(views.weights)) == pytest.approx(
        float(jnp.sum(prob.node_weights)), rel=1e-6)
    ids = np.asarray(views.ids)[np.asarray(views.valid)]
    np.testing.assert_array_equal(np.sort(ids), np.arange(50))


def test_boundary_stats_two_cliques():
    """Two 4-cliques joined by one edge, split at the clique boundary:
    exactly one boundary node / one ghost / one cross edge per shard."""
    adj = np.zeros((8, 8))
    adj[:4, :4] = 1.0
    adj[4:, 4:] = 1.0
    np.fill_diagonal(adj, 0.0)
    adj[3, 4] = adj[4, 3] = 1.0
    prob = make_problem(adj, np.ones(8), np.ones(2), mu=1.0)
    stats = boundary_stats(prob, 2)
    np.testing.assert_array_equal(stats.boundary_nodes, [1, 1])
    np.testing.assert_array_equal(stats.ghost_nodes, [1, 1])
    np.testing.assert_array_equal(stats.cross_edges, [1, 1])
    assert stats.total_ghosts == 2


def test_shard_cost_rows_bitwise_equal_controller():
    """The shard-local cost rows ARE the controller's cost-matrix rows."""
    prob, r0 = _problem(n=60, k=5, seed=3)
    state = make_state(prob, r0)
    total_b = jnp.sum(prob.node_weights)
    views = build_views(prob, 3)
    for fw in costs.FRAMEWORKS:
        full = np.asarray(costs.cost_matrix(prob, state, fw))
        for s in range(3):
            valid = np.asarray(views.valid[s])
            block = protocol.shard_cost_matrix(
                views.row_block[s], r0[views.ids[s]], views.weights[s], r0,
                state.loads, prob.speeds, prob.mu, total_b, fw)
            ids = np.asarray(views.ids[s])[valid]
            np.testing.assert_array_equal(np.asarray(block)[valid], full[ids])


# ---------------------------------------------------------------------------
# acceptance: identical move sequence + non-increasing potentials
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
@pytest.mark.parametrize("num_shards", [1, 3, 5])
def test_sequential_move_sequence_identical(framework, num_shards,
                                            paper_problem):
    """Same problem/seed: the distributed sequential-turn runtime produces
    the identical move sequence and final assignment as refine_traced."""
    adj, prob = paper_problem
    r0 = jnp.asarray(np.random.default_rng(42).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)
    ref_res, ref_tr = refine_traced(prob, r0, framework, max_turns=600)
    res, tr = refine_distributed_traced(prob, r0, framework,
                                        num_shards=num_shards, max_turns=600)
    np.testing.assert_array_equal(np.asarray(ref_tr.moved),
                                  np.asarray(tr.moved))
    np.testing.assert_array_equal(np.asarray(ref_tr.node), np.asarray(tr.node))
    np.testing.assert_array_equal(np.asarray(ref_tr.source),
                                  np.asarray(tr.source))
    np.testing.assert_array_equal(np.asarray(ref_tr.dest), np.asarray(tr.dest))
    np.testing.assert_array_equal(np.asarray(ref_tr.gain), np.asarray(tr.gain))
    np.testing.assert_array_equal(np.asarray(ref_res.assignment),
                                  np.asarray(res.assignment))
    assert int(ref_res.num_moves) == int(res.num_moves)
    assert bool(res.converged)


@pytest.mark.parametrize("framework", costs.FRAMEWORKS)
def test_potentials_non_increasing(framework, paper_problem):
    """Both potentials are recorded; the framework's OWN potential never
    increases across rounds (Thm 4.1 descent, distributed)."""
    adj, prob = paper_problem
    r0 = jnp.asarray(np.random.default_rng(7).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)
    res, tr = refine_distributed_traced(prob, r0, framework, num_shards=5,
                                        max_turns=600)
    own = np.asarray(tr.c0 if framework == costs.C_FRAMEWORK else tr.ct0)
    active = np.asarray(tr.active)
    init = float(costs.global_cost(prob, r0, framework))
    prev = np.concatenate([[init], own[:-1]])
    ok = own[active] <= prev[active] + 1e-5 * np.abs(prev[active])
    assert ok.all(), f"potential ascended at turns {np.flatnonzero(~ok)}"
    # the potentials match the controller's definition at the fixed point;
    # the traced values are exact-potential-identity accumulations (f32),
    # so the bound is the incremental-path drift budget (<= 1e-3 relative
    # over a full trace, DESIGN.md §10) rather than reduction-order noise
    np.testing.assert_allclose(
        own[active][-1], float(costs.global_cost(prob, res.assignment,
                                                 framework)), rtol=1e-3)


@pytest.mark.parametrize("num_shards", [2, 5])
def test_while_loop_driver_matches_core_refine(num_shards, paper_problem):
    adj, prob = paper_problem
    r0 = jnp.asarray(np.random.default_rng(11).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)
    ref = refine(prob, r0, costs.C_FRAMEWORK)
    res = refine_distributed(prob, r0, costs.C_FRAMEWORK,
                             num_shards=num_shards)
    np.testing.assert_array_equal(np.asarray(ref.assignment),
                                  np.asarray(res.assignment))
    assert int(ref.num_moves) == int(res.num_moves)
    assert int(ref.num_turns) == int(res.num_turns)
    np.testing.assert_allclose(np.asarray(ref.loads), np.asarray(res.loads))


def test_refine_distributed_pallas_cost_path():
    """cost_fn="pallas" routes shard cost rows through the fused kernel;
    the equilibrium agrees with the jnp path (kernel is float-close, not
    bitwise, so we compare outcomes rather than move traces)."""
    prob, r0 = _problem(n=48, k=3, seed=9, mu=4.0)
    jnp_res = refine_distributed(prob, r0, "c", num_shards=3)
    pl_res = refine_distributed(prob, r0, "c", num_shards=3,
                                cost_fn="pallas")
    assert bool(pl_res.converged)
    np.testing.assert_allclose(
        float(costs.global_cost_c0(prob, pl_res.assignment)),
        float(costs.global_cost_c0(prob, jnp_res.assignment)), rtol=1e-3)


def test_simultaneous_pallas_and_bad_cost_fn(paper_problem):
    """The incremental sweep driver honors cost_fn: "pallas" routes the
    per-sweep reduction through the fused kernel (float-close outcome),
    and an unknown value raises instead of being silently ignored."""
    adj, prob = paper_problem
    r0 = jnp.asarray(np.random.default_rng(5).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)
    jnp_res, _ = refine_distributed_simultaneous(prob, r0, "c", num_shards=3,
                                                 max_sweeps=64)
    pl_res, _ = refine_distributed_simultaneous(prob, r0, "c", num_shards=3,
                                                max_sweeps=64,
                                                cost_fn="pallas")
    np.testing.assert_allclose(
        float(costs.global_cost_c0(prob, pl_res.assignment)),
        float(costs.global_cost_c0(prob, jnp_res.assignment)), rtol=1e-3)
    with pytest.raises(ValueError, match="cost_fn"):
        refine_distributed_simultaneous(prob, r0, "c", num_shards=3,
                                        cost_fn="typo")


def test_simultaneous_sweep_mode(paper_problem):
    """§4.5 distributed sweeps descend far below the initial cost and agree
    with the single-controller sweep mode (loads are reduced from shard
    partials, so agreement is float-close, not bitwise)."""
    adj, prob = paper_problem
    r0 = jnp.asarray(np.random.default_rng(5).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)
    ref, (rc0, _, ract) = refine_simultaneous(prob, r0, costs.C_FRAMEWORK)
    res, (c0s, ct0s, active) = refine_distributed_simultaneous(
        prob, r0, costs.C_FRAMEWORK, num_shards=3)
    assert float(costs.global_cost_c0(prob, res.assignment)) < \
        float(costs.global_cost_c0(prob, r0))
    np.testing.assert_allclose(float(c0s[-1]), float(rc0[-1]), rtol=1e-4)


# ---------------------------------------------------------------------------
# shard_map driver
# ---------------------------------------------------------------------------

def test_shard_map_single_device(paper_problem):
    """The collective code path on a 1-device mesh (all this process has)."""
    adj, prob = paper_problem
    r0 = jnp.asarray(np.random.default_rng(1).integers(
        0, prob.num_machines, prob.num_nodes), jnp.int32)
    ref = refine(prob, r0, costs.C_FRAMEWORK)
    res = refine_distributed_shard_map(prob, r0, costs.C_FRAMEWORK,
                                       num_shards=1)
    np.testing.assert_array_equal(np.asarray(ref.assignment),
                                  np.asarray(res.assignment))
    assert int(ref.num_moves) == int(res.num_moves)


def test_shard_map_requires_enough_devices():
    prob, r0 = _problem(n=24, k=3, seed=0)
    if len(jax.devices()) >= 3:
        pytest.skip("test requires a 1-device process")
    with pytest.raises(ValueError, match="need 3 devices"):
        refine_distributed_shard_map(prob, r0, num_shards=3)


def test_shard_map_multi_device_subprocess():
    """Real 4-device all_gather exchange == single controller.  Runs in a
    subprocess because the forced host-platform device count must be set
    before jax initializes (this process must stay 1-device)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import numpy as np, jax, jax.numpy as jnp
        assert len(jax.devices()) == 4
        from repro.core.problem import make_problem
        from repro.core.refine import refine
        from repro.graphs.generators import random_degree_graph, random_weights
        from repro.distributed import refine_distributed_shard_map
        adj = random_degree_graph(64, seed=0)
        b, c = random_weights(adj, seed=1, mean=5.0)
        prob = make_problem(c, b, [0.2, 0.3, 0.5], mu=4.0)
        r0 = jnp.asarray(np.random.default_rng(0).integers(0, 3, 64), jnp.int32)
        ref = refine(prob, r0, "c")
        res = refine_distributed_shard_map(prob, r0, "c", num_shards=4)
        assert bool(jnp.all(ref.assignment == res.assignment)), "assignment"
        assert int(ref.num_moves) == int(res.num_moves), "moves"
        assert bool(res.converged)
        print("SHARD_MAP_OK")
    """)
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARD_MAP_OK" in out.stdout


# ---------------------------------------------------------------------------
# accounting: the O(K + boundary) bound
# ---------------------------------------------------------------------------

def test_per_round_payload_independent_of_n():
    """Per-round bytes at fixed K/S are FLAT as N scales 4x (acceptance:
    within 2x; the protocol makes them exactly equal)."""
    per_round = []
    for n in (64, 256, 1024):
        adj = random_degree_graph(n, seed=1)
        b, c = random_weights(adj, seed=2, mean=5.0)
        prob = make_problem(c, b, np.ones(4) / 4, mu=8.0)
        r0 = jnp.asarray(np.random.default_rng(3).integers(0, 4, n),
                         jnp.int32)
        res = refine_distributed(prob, r0, "c", num_shards=4, max_turns=512)
        led = ledger_for_run(boundary_stats(prob, 4), 4,
                             rounds=int(res.num_turns))
        assert led.rounds > 0
        per_round.append(led.per_round_bytes)
    assert max(per_round) <= 2.0 * min(per_round), per_round
    # ... while the naive re-broadcast strawman grows linearly with N
    naive = [accounting.naive_broadcast_bytes(n, 4) for n in (64, 1024)]
    assert naive[1] == 16 * naive[0]


def test_ledger_formulas():
    s, k = 4, 5
    assert accounting.turn_payload_bytes(s, k) == s * 16
    # incremental traced turns ship the 8-byte exact-potential deltas on
    # each candidate (no per-turn partial reduction)
    assert accounting.turn_payload_bytes(s, k, traced=True) == s * (16 + 8)
    # recompute traced turns reduce C_0/cut partials + an O(K) load partial
    assert accounting.turn_payload_bytes(s, k, traced=True,
                                         incremental=False) \
        == s * (16 + 8 + 4 * k)
    # incremental sweeps reduce load + sq-load partials and an f32 cut
    # partial for the closed-form potentials; recompute sweeps ship one
    # load partial + the C_0/cut partial pair
    assert accounting.sweep_payload_bytes(s, k) \
        == s * (k * 16 + 2 * 4 * k + 4)
    assert accounting.sweep_payload_bytes(s, k, incremental=False) \
        == s * (k * 16 + 4 * k + 8)
    # traced setup reduces only the C_0/cut partial pair per shard — the
    # loads are already replicated by the 4K+4 setup allreduce (the
    # measured-wire cross-check below is what pins this down)
    assert accounting.init_potential_bytes(s, k) == s * 8
    prob, _ = _problem(n=40, k=5, seed=4)
    stats = boundary_stats(prob, s)
    led = ledger_for_run(stats, k, rounds=10, traced=True)
    assert led.candidate_bytes == 10 * s * 16
    assert led.trace_bytes == 10 * s * 8
    assert led.ghost_sync_bytes == 8 * stats.total_ghosts
    assert led.setup_bytes == (accounting.setup_bytes(k)
                               + accounting.init_potential_bytes(s, k))
    assert led.total_bytes == (led.candidate_bytes + led.trace_bytes
                               + led.ghost_sync_bytes + led.setup_bytes)
    assert "B/round" in led.summary()
    # recompute-protocol ledger: per-turn partials charged, no init reduction
    led_r = ledger_for_run(stats, k, rounds=10, traced=True,
                           incremental=False)
    assert led_r.trace_bytes == 10 * s * (8 + 4 * k)
    assert led_r.setup_bytes == accounting.setup_bytes(k)


def _reconciled(prob, stats, k, wire, **flags):
    led = ledger_for_run(stats, k, int(wire.rounds), **flags)
    return accounting.reconcile(led, wire)


def test_measured_wire_matches_ledger_incremental():
    """measure_wire=True counters equal the analytic ledger exactly for
    every incremental-protocol driver (payload AND setup)."""
    prob, r0 = _problem(n=96, k=5, seed=7)
    s, k = 6, 5
    stats = boundary_stats(prob, s)

    res, wire = refine_distributed(prob, r0, num_shards=s, measure_wire=True)
    assert int(wire.rounds) == int(res.num_turns)
    assert _reconciled(prob, stats, k, wire).ok

    res_t, _, wire_t = refine_distributed_traced(
        prob, r0, num_shards=s, max_turns=256, measure_wire=True)
    assert int(wire_t.rounds) == int(res_t.num_turns)
    assert _reconciled(prob, stats, k, wire_t, traced=True).ok

    res_s, _, wire_s = refine_distributed_simultaneous(
        prob, r0, num_shards=s, max_sweeps=64, measure_wire=True)
    assert int(wire_s.rounds) == int(res_s.num_turns)
    assert _reconciled(prob, stats, k, wire_s, simultaneous=True).ok

    # the measurement does not perturb the run itself
    res_plain = refine_distributed(prob, r0, num_shards=s)
    np.testing.assert_array_equal(np.asarray(res.assignment),
                                  np.asarray(res_plain.assignment))


def test_measured_wire_matches_ledger_recompute():
    """Same equality for the recompute protocol (per-turn partials on the
    wire instead of candidate-borne deltas)."""
    prob, r0 = _problem(n=96, k=5, seed=8)
    s, k = 6, 5
    stats = boundary_stats(prob, s)

    _, wire = refine_distributed(prob, r0, num_shards=s, incremental=False,
                                 measure_wire=True)
    assert _reconciled(prob, stats, k, wire, incremental=False).ok

    _, _, wire_t = refine_distributed_traced(
        prob, r0, num_shards=s, max_turns=256, incremental=False,
        measure_wire=True)
    assert _reconciled(prob, stats, k, wire_t, traced=True,
                       incremental=False).ok

    _, _, wire_s = refine_distributed_simultaneous(
        prob, r0, num_shards=s, max_sweeps=64, incremental=False,
        measure_wire=True)
    assert _reconciled(prob, stats, k, wire_s, simultaneous=True,
                       incremental=False).ok


def test_measured_wire_shard_map_and_round_mismatch():
    prob, r0 = _problem(n=60, k=5, seed=9)
    stats = boundary_stats(prob, 1)
    _, wire = refine_distributed_shard_map(prob, r0, num_shards=1,
                                           measure_wire=True)
    assert _reconciled(prob, stats, 5, wire).ok
    # a ledger built for the wrong round count is rejected loudly
    led = ledger_for_run(stats, 5, int(wire.rounds) + 1)
    with pytest.raises(ValueError, match="rounds"):
        accounting.reconcile(led, wire)


# ---------------------------------------------------------------------------
# DES engine integration
# ---------------------------------------------------------------------------

def test_des_engine_distributed_backend():
    """refine_backend="distributed" reproduces the single-controller DES
    run exactly (the sharded protocol is move-for-move identical)."""
    from repro.des.engine import (DESConfig, make_initial_state,
                                  run_simulation)
    from repro.des.workload import flooded_packet_workload

    n, t = 24, 6
    adj = random_degree_graph(n, seed=1, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 11, num_threads=t, scope=2,
                                   max_per_lp=3)
    m0 = jnp.arange(n, dtype=jnp.int32) % 3
    outs = {}
    for backend in ("single", "distributed"):
        cfg = DESConfig(num_lps=n, num_machines=3, num_threads=t,
                        event_capacity=32, history_capacity=64,
                        refine_freq=150, max_ticks=40_000,
                        refine_backend=backend)
        state = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
        outs[backend] = run_simulation(cfg, jnp.asarray(adj, jnp.float32),
                                       state)
    a, b_ = outs["single"], outs["distributed"]
    assert bool(a.done) and bool(b_.done)
    assert int(b_.refines) > 0
    np.testing.assert_array_equal(np.asarray(a.machine),
                                  np.asarray(b_.machine))
    assert int(a.moves) == int(b_.moves)
    assert int(a.tick) == int(b_.tick)


def test_des_engine_rejects_unknown_backend():
    from repro.des.engine import (DESConfig, make_initial_state,
                                  run_simulation)
    from repro.des.workload import flooded_packet_workload

    n, t = 12, 2
    adj = random_degree_graph(n, seed=2, dmin=2, dmax=3)
    spec = flooded_packet_workload(adj, 3, num_threads=t, scope=1,
                                   max_per_lp=2)
    cfg = DESConfig(num_lps=n, num_machines=2, num_threads=t,
                    refine_freq=50, refine_backend="nope")
    state = make_initial_state(cfg, jnp.zeros(n, jnp.int32), spec.src,
                               spec.time, spec.count)
    with pytest.raises(ValueError, match="refine_backend"):
        run_simulation(cfg, jnp.asarray(adj, jnp.float32), state)
