"""partitioners/baselines.py — the §2 comparison heuristics.

These are numpy reference implementations measured against the game; the
tests pin their contracts: valid assignments, the objective each one
claims to improve actually improves, and determinism where promised.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.generators import random_degree_graph, random_weights
from repro.partitioners.baselines import (greedy_load_partition,
                                          kernighan_lin_refine,
                                          nandy_loucks_refine,
                                          random_partition,
                                          spectral_bisection)


def _cut(adj: np.ndarray, r: np.ndarray) -> float:
    return 0.5 * float(np.sum(adj * (r[:, None] != r[None, :])))


def _setup(n=60, k=4, seed=0):
    adj = random_degree_graph(n, seed=seed, dmin=2, dmax=4)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    return np.asarray(c), np.asarray(b)


def test_random_partition_valid_and_deterministic():
    r1 = random_partition(100, 5, seed=7)
    r2 = random_partition(100, 5, seed=7)
    np.testing.assert_array_equal(r1, r2)
    assert r1.shape == (100,) and r1.dtype == np.int32
    assert r1.min() >= 0 and r1.max() < 5
    # every machine used with overwhelming probability at n=100, k=5
    assert len(np.unique(r1)) == 5


def test_greedy_load_partition_balances_weighted_load():
    _, b = _setup(n=80, k=4, seed=3)
    speeds = np.array([1.0, 1.0, 2.0, 4.0])
    r = greedy_load_partition(b, speeds)
    assert r.shape == b.shape and r.min() >= 0 and r.max() < 4
    loads = np.bincount(r, weights=b, minlength=4)
    # LPT guarantee: max normalized load within max-item of the mean
    norm = loads / speeds
    ideal = b.sum() / speeds.sum()
    assert norm.max() <= ideal + b.max()
    # the 4x machine must carry more than a 1x machine
    assert loads[3] > loads[0]


def test_greedy_load_beats_random_on_imbalance():
    _, b = _setup(n=100, k=5, seed=5)
    speeds = np.ones(5)
    greedy = np.bincount(greedy_load_partition(b, speeds), weights=b,
                         minlength=5)
    rand = np.bincount(random_partition(100, 5, seed=1), weights=b,
                       minlength=5)
    assert greedy.max() - greedy.min() <= rand.max() - rand.min()


def test_kernighan_lin_never_increases_cut():
    adj, b = _setup(n=50, k=3, seed=1)
    r0 = random_partition(50, 3, seed=2)
    r = kernighan_lin_refine(adj, r0)
    assert r.shape == r0.shape
    assert r.min() >= 0 and r.max() < 3
    assert _cut(adj, r) <= _cut(adj, r0) + 1e-6
    # pair swaps preserve part cardinalities exactly
    np.testing.assert_array_equal(np.bincount(r, minlength=3),
                                  np.bincount(r0, minlength=3))


def test_spectral_bisection_separates_disconnected_cliques():
    """Two disconnected 8-cliques: the Fiedler split must put each clique
    in its own part (cut 0)."""
    adj = np.zeros((16, 16))
    adj[:8, :8] = 1.0
    adj[8:, 8:] = 1.0
    np.fill_diagonal(adj, 0.0)
    r = spectral_bisection(adj, 2)
    assert set(np.unique(r)) == {0, 1}
    assert _cut(adj, r) == 0.0
    assert len(set(r[:8])) == 1 and len(set(r[8:])) == 1


def test_spectral_bisection_k4_covers_all_parts():
    adj, _ = _setup(n=64, k=4, seed=9)
    r = spectral_bisection(adj, 4)
    assert set(np.unique(r)) == {0, 1, 2, 3}
    counts = np.bincount(r, minlength=4)
    assert counts.min() >= 8          # median splits keep parts near-even


def test_nandy_loucks_never_increases_cut_and_terminates():
    adj, _ = _setup(n=40, k=3, seed=4)
    r0 = random_partition(40, 3, seed=5)
    r = nandy_loucks_refine(adj, r0)
    assert r.shape == r0.shape and r.min() >= 0 and r.max() < 3
    assert _cut(adj, r) <= _cut(adj, r0) + 1e-6
    # forced convergence: at most one migration per node
    assert int(np.sum(r != r0)) <= 40


def test_nandy_loucks_fixed_point_under_no_gain():
    """A zero-adjacency graph has no cut gain anywhere: nothing moves."""
    r0 = random_partition(20, 4, seed=8)
    r = nandy_loucks_refine(np.zeros((20, 20)), r0)
    np.testing.assert_array_equal(r, r0)
