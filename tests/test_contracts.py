"""The contract linter (repro.analysis, DESIGN.md §16).

Two halves:

  * the repo is CLEAN: every rule family runs over the real tree and
    reports nothing beyond the committed baseline (exactly the missing
    sparse×distributed dispatch cell);
  * every rule family FIRES: for each analyzer a deliberately seeded
    violation — a callback in a disabled path, an 8-arg dissat_fn, a
    second θ-subtraction site, an f64 leak, an N-dependent wire term, a
    removed dispatch arm — produces the expected finding.  Seeding uses
    ``AnalysisContext(source_overrides=...)`` (AST rules), injectable
    callables (wire rules) and hand-built jaxprs (jaxpr rules), so the
    tree on disk is never touched.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import (AnalysisContext, Finding, load_baseline,
                            registered_rules, run_rules, split_findings)
from repro.analysis import ast_rules, jaxpr_rules, wire_rules
from repro.analysis.entrypoints import (registered_entry_points,
                                        trace_entry_point)

REPO = pathlib.Path(__file__).resolve().parent.parent


def _ctx(**kwargs) -> AnalysisContext:
    return AnalysisContext(repo_root=REPO, **kwargs)


# ---------------------------------------------------------------------------
# registry basics
# ---------------------------------------------------------------------------

def test_registry_families_populated():
    rules = registered_rules()
    fams = {r.family for r in rules}
    assert fams == {"jaxpr", "ast", "wire", "docs", "complexity"}
    assert len(rules) >= 10


def test_finding_ids_and_baseline_split():
    f1 = Finding(rule="r", key="a", message="m")
    f2 = Finding(rule="r", key="b", message="m")
    new, known, stale = split_findings([f1, f2], {"r:a", "r:gone"})
    assert [f.id for f in new] == ["r:b"]
    assert [f.id for f in known] == ["r:a"]
    assert stale == {"r:gone"}


# ---------------------------------------------------------------------------
# entry-point registry + jaxpr analyzers over ALL of them
# ---------------------------------------------------------------------------

def test_entry_point_registry_covers_every_runtime():
    eps = registered_entry_points()
    assert len(eps) >= 10
    assert {ep.runtime for ep in eps} == \
        {"controller", "batched", "distributed", "des"}
    names = {ep.name for ep in eps}
    # the drivers the tentpole names explicitly
    for required in ("refine", "refine_traced", "refine_simultaneous",
                     "distributed.refine", "distributed.refine_traced",
                     "distributed.refine_simultaneous",
                     "distributed.shard_map", "des.tick", "batch.refine",
                     "refine.kernel"):
        assert required in names, required


def test_all_entry_points_zero_callbacks_and_f32_only():
    for ep in registered_entry_points():
        jaxpr = trace_entry_point(ep.name)
        assert jaxpr_rules.callback_primitives(jaxpr) == [], ep.name
        assert jaxpr_rules.dtype_drift(jaxpr) == [], ep.name


def test_seeded_callback_fires():
    def leaky(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2

    jaxpr = jax.make_jaxpr(leaky)(jnp.float32(1.0))
    prims = jaxpr_rules.callback_primitives(jaxpr)
    assert prims and all("callback" in p for p in prims)


def test_seeded_callback_inside_scan_body_fires():
    # the walker must recurse into sub-jaxprs, not just top-level eqns
    def leaky_scan(x):
        def body(c, _):
            jax.debug.callback(lambda v: None, c)
            return c + 1, c
        return jax.lax.scan(body, x, None, length=3)

    jaxpr = jax.make_jaxpr(leaky_scan)(jnp.float32(0.0))
    assert jaxpr_rules.callback_primitives(jaxpr)


def test_seeded_f64_leak_fires():
    from jax.experimental import enable_x64
    with enable_x64():
        jaxpr = jax.make_jaxpr(lambda x: jnp.cumsum(x * 2.0))(
            np.ones(4, np.float64))
    drift = jaxpr_rules.dtype_drift(jaxpr)
    assert any(dtype == "float64" for dtype, _ in drift)


def test_seeded_f16_truncation_fires():
    jaxpr = jax.make_jaxpr(lambda x: x.astype(jnp.float16) * 2)(
        jnp.ones(4, jnp.float32))
    assert any(dtype == "float16"
               for dtype, _ in jaxpr_rules.dtype_drift(jaxpr))


# ---------------------------------------------------------------------------
# compile-cache audit
# ---------------------------------------------------------------------------

def test_sweep_compile_audit_clean_on_canonical_grid():
    findings, report = jaxpr_rules.group_signature_findings(
        jaxpr_rules.canonical_sweep_cases())
    assert findings == []
    assert report["groups"] == 12 and report["cases"] == 16


def test_seeded_dtype_mismatch_breaks_group():
    from repro.core.problem import make_problem
    from repro.graphs.generators import random_degree_graph, random_weights
    from repro.sweeps.runtime import SweepCase

    adj = random_degree_graph(16, seed=3)
    b, c = random_weights(adj, seed=4, mean=5.0)
    p32 = make_problem(c, b, np.ones(3) / 3, mu=8.0)
    p16 = make_problem(c, b, np.ones(3) / 3, mu=8.0, dtype=jnp.float16)
    r0 = jnp.asarray(np.arange(16) % 3, jnp.int32)
    cases = [SweepCase(problem=p, assignment=r0, framework="c",
                       label=str(p.node_weights.dtype)) for p in (p32, p16)]
    findings, _ = jaxpr_rules.group_signature_findings(cases)
    assert findings and "distinct jit signatures" in findings[0].message


# ---------------------------------------------------------------------------
# AST rules: dissat signature
# ---------------------------------------------------------------------------

def test_repo_dissat_signatures_clean():
    assert ast_rules.dissat_signature_findings(_ctx()) == []


_BAD_FACTORY = textwrap.dedent("""\
    from repro.core.refine import DissatFn


    def make_bad_dissat_fn() -> DissatFn:
        def fn(aggregate, assignment, node_weights, loads, speeds, mu,
               framework, total_weight):
            return None, None
        return fn
    """)


def test_seeded_eight_arg_dissat_fn_fires():
    ctx = _ctx(source_overrides={
        "src/repro/kernels/_seeded.py": _BAD_FACTORY})
    findings = ast_rules.dissat_signature_findings(ctx)
    assert len(findings) == 1
    f = findings[0]
    assert f.key.startswith("def:src/repro/kernels/_seeded.py")
    assert "canonical convention" in f.message


def test_seeded_bad_call_site_fires():
    src = "def caller(dissat_fn, agg):\n    return dissat_fn(agg)\n"
    ctx = _ctx(source_overrides={"src/repro/core/_seeded.py": src})
    findings = ast_rules.dissat_signature_findings(ctx)
    assert len(findings) == 1 and findings[0].key.startswith("call:")


def test_varargs_wrappers_are_exempt():
    src = textwrap.dedent("""\
        from repro.core.refine import DissatFn


        def make_wrapper(inner) -> DissatFn:
            def fn(*args, **kwargs):
                return inner(*args, **kwargs)
            return fn
        """)
    ctx = _ctx(source_overrides={"src/repro/kernels/_seeded.py": src})
    assert ast_rules.dissat_signature_findings(ctx) == []


# ---------------------------------------------------------------------------
# AST rules: single theta-subtraction site
# ---------------------------------------------------------------------------

def test_repo_theta_single_site_clean():
    assert ast_rules.theta_site_findings(_ctx()) == []


def test_seeded_second_theta_subtraction_fires():
    src = ("def sneaky_netting(dissat, theta):\n"
           "    return dissat - theta\n")
    ctx = _ctx(source_overrides={"src/repro/core/_seeded.py": src})
    findings = ast_rules.theta_site_findings(ctx)
    assert len(findings) == 1
    assert findings[0].key == "src/repro/core/_seeded.py::sneaky_netting"
    assert "ONLY in costs.dissatisfaction_from_cost" in findings[0].message


def test_removing_canonical_theta_site_fires():
    costs_src = (REPO / "src/repro/core/costs.py").read_text()
    patched = costs_src.replace("dissat = dissat - theta",
                                "dissat = dissat")
    assert patched != costs_src
    ctx = _ctx(source_overrides={"src/repro/core/costs.py": patched})
    findings = ast_rules.theta_site_findings(ctx)
    assert any(f.key == "canonical-missing" for f in findings)


# ---------------------------------------------------------------------------
# AST rules: trace-unsafe patterns
# ---------------------------------------------------------------------------

def test_repo_trace_unsafe_clean():
    assert ast_rules.trace_unsafe_findings(_ctx()) == []


_TRACE_UNSAFE = textwrap.dedent("""\
    from functools import partial

    import numpy as np
    import jax


    @partial(jax.jit, static_argnames=("flag",))
    def bad(x, flag):
        noise = np.random.rand()
        if x > 0:
            return float(x) + noise
        if flag:
            return x
        return x - 1
    """)


def test_seeded_trace_unsafe_patterns_fire():
    ctx = _ctx(source_overrides={
        "src/repro/core/_seeded.py": _TRACE_UNSAFE})
    findings = ast_rules.trace_unsafe_findings(ctx)
    kinds = {f.key.split(":")[0] for f in findings}
    # np.random, the `if x > 0` tracer branch, and float(x); the
    # `if flag` static branch must NOT fire
    assert kinds == {"np-random", "if-tracer", "host-cast"}
    assert not any("if flag" in f.message for f in findings)


def test_is_none_tests_are_exempt():
    src = textwrap.dedent("""\
        import jax


        @jax.jit
        def fine(x, maybe):
            if maybe is None:
                return x
            return x + maybe
        """)
    ctx = _ctx(source_overrides={"src/repro/core/_seeded.py": src})
    assert ast_rules.trace_unsafe_findings(ctx) == []


# ---------------------------------------------------------------------------
# AST rules: dispatch-coverage matrix
# ---------------------------------------------------------------------------

def test_dispatch_matrix_missing_exactly_sparse_distributed():
    matrix = ast_rules.dispatch_matrix(_ctx())
    missing = [cell for cell, info in matrix.items() if not info["covered"]]
    assert missing == ["sparse-distributed"]


def test_repo_dispatch_findings_match_baseline_exactly():
    findings = ast_rules.dispatch_findings(_ctx())
    assert [f.id for f in findings] == \
        ["dispatch-coverage:sparse-distributed"]
    assert load_baseline() == {"dispatch-coverage:sparse-distributed"}


@pytest.mark.parametrize("arm", ["problem_aggregate", "problem_cut",
                                 "global_cost_c0"])
def test_removing_costs_isinstance_arm_uncovers_cells(arm):
    costs_src = (REPO / "src/repro/core/costs.py").read_text()
    # neutralize exactly the isinstance test inside the chosen function
    lines = costs_src.splitlines(keepends=True)
    out, in_fn, patched = [], False, False
    for line in lines:
        if line.startswith(f"def {arm}("):
            in_fn = True
        elif line.startswith("def "):
            in_fn = False
        if in_fn and not patched and \
                "isinstance(problem, SparseProblem)" in line:
            line = line.replace("isinstance(problem, SparseProblem)",
                                "False")
            patched = True
        out.append(line)
    assert patched, f"no isinstance arm found in {arm}"
    ctx = _ctx(source_overrides={"src/repro/core/costs.py": "".join(out)})
    findings = ast_rules.dispatch_findings(ctx)
    ids = {f.id for f in findings}
    assert "dispatch-coverage:sparse-controller" in ids
    assert "dispatch-coverage:sparse-batched" in ids
    # and these are NEW relative to the baseline -> --check would fail
    new, _, _ = split_findings(findings, load_baseline())
    assert any(f.key == "sparse-controller" for f in new)


def test_unregistered_dispatch_arm_fires():
    src = textwrap.dedent("""\
        from repro.core.sparse import SparseProblem


        def rogue_dispatch(problem):
            if isinstance(problem, SparseProblem):
                return 1
            return 0
        """)
    ctx = _ctx(source_overrides={"src/repro/core/_seeded.py": src})
    findings = ast_rules.dispatch_findings(ctx)
    assert any(f.key == "arm:src/repro/core/_seeded.py::rogue_dispatch"
               for f in findings)


def test_sparse_distributed_arm_would_close_the_gap():
    # adding ANY SparseProblem dispatch under distributed/ covers the cell
    src = ("from ..core.sparse import SparseProblem\n\n\n"
           "def dispatch(problem):\n"
           "    return isinstance(problem, SparseProblem)\n")
    ctx = _ctx(source_overrides={
        "src/repro/distributed/_seeded.py": src})
    matrix = ast_rules.dispatch_matrix(ctx)
    assert matrix["sparse-distributed"]["covered"]


# ---------------------------------------------------------------------------
# wire rules
# ---------------------------------------------------------------------------

def test_repo_wire_contracts_clean():
    assert wire_rules.candidate_findings() == []
    assert wire_rules.ledger_findings() == []


def test_symbolic_sizes_match_measured_constants():
    from repro.distributed import protocol
    for n in wire_rules.N_GRID:
        cand, _ = wire_rules.symbolic_candidate_bytes(n, 4)
        assert cand == protocol.CANDIDATE_BYTES == 16
        assert wire_rules.symbolic_delta_bytes(n, 4) == \
            protocol.TRACE_PARTIAL_BYTES == 8
    assert wire_rules.symbolic_load_partial_bytes(256, 7) == 4 * 7


def test_seeded_n_dependent_candidate_fires():
    from repro.distributed import protocol

    def fat_candidate(agg, b, ids, valid, r, loads, speeds, mu, total_b,
                      m, framework, with_deltas=False):
        # ships the whole per-row gain vector: O(Ns) on the wire
        cand = protocol.Candidate(gain=b, node=ids, dest=ids,
                                  weight=b)
        if with_deltas:
            return cand, b[0], b[0]
        return cand

    findings = wire_rules.candidate_findings(candidate_fn=fat_candidate)
    assert any(f.key.startswith("candidate-n-dep") for f in findings)
    assert any("O(K) wire contract" in f.message for f in findings)


def test_seeded_n_dependent_ledger_fires():
    from repro.distributed import accounting

    def bad_ledger(stats, k, rounds, **flags):
        led = accounting.ledger_for_run(stats, k, rounds, **flags)
        # a per-round term proportional to N — the classic broadcast bug
        return dataclasses.replace(
            led, candidate_bytes=led.candidate_bytes
            + rounds * 4 * stats.num_nodes)

    findings = wire_rules.ledger_findings(ledger_fn=bad_ledger)
    assert findings
    assert all("depend on N" in f.message for f in findings)


# ---------------------------------------------------------------------------
# full run + CLI
# ---------------------------------------------------------------------------

def test_full_run_has_only_baselined_findings():
    findings = run_rules(_ctx(complexity_grid="quick"))
    new, known, stale = split_findings(findings, load_baseline())
    assert new == [], [f.id for f in new]
    assert [f.id for f in known] == ["dispatch-coverage:sparse-distributed"]
    assert stale == set()


def test_cli_check_passes_and_writes_json(tmp_path, capsys):
    from repro.analysis.__main__ import main

    out = tmp_path / "findings.json"
    assert main(["--check", "--json", str(out),
                 "--complexity-grid", "quick"]) == 0
    report = json.loads(out.read_text())
    assert report["new"] == []
    assert report["baselined"] == ["dispatch-coverage:sparse-distributed"]
    cells = report["reports"]["dispatch-coverage"]["cells"]
    assert not cells["sparse-distributed"]["covered"]
    assert len(report["reports"]["jaxpr-zero-callback"]["entry_points"]) >= 10
    text = capsys.readouterr().out
    assert "sparse-distributed" in text and "MISSING" in text


def test_cli_check_fails_on_new_finding(tmp_path, capsys):
    from repro.analysis.__main__ import main

    empty = tmp_path / "empty_baseline.json"
    empty.write_text('{"findings": []}\n')
    # with an empty baseline the known sparse-distributed gap is NEW
    assert main(["--check", "--baseline", str(empty),
                 "--families", "ast"]) == 2
    assert "FAIL" in capsys.readouterr().out
