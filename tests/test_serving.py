"""Continuous-batching serving engine: correctness against the pure forward,
slot lifecycle, heterogeneous-length batching."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import init_params
from repro.models.transformer import forward_logits
from repro.serving import Request, ServeConfig, ServingEngine
from repro.serving.sampler import greedy, sample_logits


def _engine(arch="qwen1.5-4b", max_batch=3, max_len=64):
    cfg = configs.get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, ServingEngine(cfg, params,
                                      ServeConfig(max_batch=max_batch,
                                                  max_len=max_len,
                                                  cache_dtype="float32"))


def _reference_generate(cfg, params, prompt: np.ndarray, n: int) -> list[int]:
    """Greedy generation via repeated FULL forward passes (oracle)."""
    toks = list(prompt.tolist())
    for _ in range(n):
        logits, _ = forward_logits(params, cfg,
                                   jnp.asarray(toks, jnp.int32)[None, :])
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "mamba2-1.3b"])
def test_engine_matches_full_forward_oracle(arch):
    cfg, params, eng = _engine(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
               for s in (5, 9, 3)]
    n_new = 6
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=n_new))
    stats = eng.run()
    assert stats["requests"] == 3
    for req in eng.finished:
        want = _reference_generate(cfg, params, req.prompt, n_new)
        assert req.output == want, (req.uid, req.output, want)


def test_continuous_batching_admits_from_queue():
    cfg, params, eng = _engine(max_batch=2)
    rng = np.random.default_rng(1)
    for i in range(5):                       # more requests than slots
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                           max_new_tokens=4))
    stats = eng.run()
    assert stats["requests"] == 5
    assert stats["prefills"] == 5
    assert all(len(r.output) == 4 for r in eng.finished)


def test_heterogeneous_lengths_decode_together():
    """Requests of different prompt lengths share decode steps; outputs must
    still match the isolated oracle."""
    cfg, params, eng = _engine(max_batch=4)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (2, 11, 7, 4)]
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=5))
    eng.run()
    for req in sorted(eng.finished, key=lambda r: r.uid):
        want = _reference_generate(cfg, params, req.prompt, 5)
        assert req.output == want, req.uid


def test_eos_stops_early():
    cfg, params, eng = _engine()
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    ref = _reference_generate(cfg, params, prompt, 8)
    eos = ref[3]                              # force a stop at position 3
    eng.serve = ServeConfig(max_batch=3, max_len=64, eos_id=eos,
                            cache_dtype="float32")
    eng.submit(Request(0, prompt, max_new_tokens=8))
    eng.run()
    out = eng.finished[0].output
    assert out[-1] == eos
    assert len(out) <= 8


def test_capacity_guard():
    cfg, params, eng = _engine(max_len=16)
    with pytest.raises(AssertionError):
        eng.submit(Request(0, np.zeros(10, np.int32), max_new_tokens=10))


def test_samplers():
    logits = jnp.asarray([[0.1, 3.0, -1.0], [2.0, 0.0, 1.0]], jnp.float32)
    np.testing.assert_array_equal(np.asarray(greedy(logits)), [1, 0])
    # temperature 0 == greedy
    np.testing.assert_array_equal(
        np.asarray(sample_logits(jax.random.PRNGKey(0), logits,
                                 temperature=0.0)), [1, 0])
    # top-k=1 forces argmax regardless of temperature
    np.testing.assert_array_equal(
        np.asarray(sample_logits(jax.random.PRNGKey(0), logits,
                                 temperature=5.0, top_k=1)), [1, 0])
    # samples stay inside vocabulary
    s = sample_logits(jax.random.PRNGKey(1), logits, temperature=1.0)
    assert s.shape == (2,) and int(jnp.max(s)) < 3
