"""Sharding rules + a reduced in-test dry-run (8 fake devices, subprocess).

The full 16x16 / 2x16x16 dry-run lives in repro/launch/dryrun.py; here we
prove the same rules are coherent end-to-end on a small mesh inside the
test suite, and unit-test the spec logic against the production mesh
shapes via AbstractMesh (no devices needed)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro import configs
from repro.models import init_params
from repro.sharding import rules


def _abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: 0.4.37 takes ((name, size), ...);
    newer releases take (axis_sizes, axis_names)."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


def _abstract_production_mesh(multi_pod=False):
    if multi_pod:
        return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return _abstract_mesh((16, 16), ("data", "model"))


def _axis_size(mesh, axis):
    return rules._axis_size(mesh, axis)


@pytest.mark.parametrize("arch", configs.all_archs())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible_on_production_mesh(arch, multi_pod):
    """Every sharded dim divides its mesh-axis size, for every arch x mesh —
    the invariant that makes the 40-cell dry-run compile."""
    cfg = configs.get_config(arch)
    mesh = _abstract_production_mesh(multi_pod)
    tree = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = rules.param_specs(cfg, mesh, tree)

    flat_t = jax.tree_util.tree_leaves_with_path(tree)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_t) == len(flat_s)
    n_sharded = 0
    for (path, leaf), spec in zip(flat_t, flat_s):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, axis in zip(leaf.shape, tuple(spec)):
            size = _axis_size(mesh, axis)
            assert dim % size == 0, (arch, path, leaf.shape, spec)
            if size > 1:
                n_sharded += 1
    # the big tensors must actually shard (not everything replicated)
    assert n_sharded >= 4, f"{arch}: only {n_sharded} sharded dims"


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "yi-34b",
                                  "chameleon-34b"])
def test_param_bytes_fit_hbm(arch):
    """Params + Adam moments per chip must fit 16 GB on the 256-chip mesh
    (the FSDP story for the big archs)."""
    cfg = configs.get_config(arch)
    mesh = _abstract_production_mesh(False)
    tree = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = rules.param_specs(cfg, mesh, tree)
    flat_t = jax.tree_util.tree_leaves_with_path(tree)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    per_chip = 0
    for (path, leaf), spec in zip(flat_t, flat_s):
        shard = 1
        for axis in tuple(spec):
            shard *= _axis_size(mesh, axis)
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        # param + 2 f32 moments
        per_chip += (nbytes + 2 * int(np.prod(leaf.shape)) * 4) / shard
    assert per_chip < 16e9, f"{arch}: {per_chip/1e9:.1f} GB/chip"


def test_batch_spec_uses_pod_axis():
    mesh_multi = _abstract_production_mesh(True)
    spec = rules.batch_spec(mesh_multi)
    axes = spec[0]
    axes = axes if isinstance(axes, tuple) else (axes,)
    assert "pod" in axes and "data" in axes


# ---------------------------------------------------------------------------
# end-to-end reduced dry-run in a subprocess (8 fake devices)
# ---------------------------------------------------------------------------

_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.sharding import rules
    from repro.training.train_step import (TrainHyper, init_train_state,
                                           make_train_step)
    arch = sys.argv[1]
    cfg = configs.get_smoke_config(arch)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    step = make_train_step(cfg, TrainHyper(total_steps=10, warmup=1))
    state = jax.eval_shape(lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
    state_sh = rules.state_shardings(cfg, mesh, state)
    B, S = 8, 32
    if cfg.input_kind == "embeddings":
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)
    else:
        inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch = {"inputs": inputs,
             "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    batch_sh = rules.batch_shardings(cfg, mesh, batch)
    with mesh:
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                          donate_argnums=(0,)).lower(state, batch)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    print(json.dumps({"ok": True,
                      "temp": int(getattr(mem, "temp_size_in_bytes", 0))}))
""")


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "qwen3-moe-235b-a22b",
                                  "mamba2-1.3b", "zamba2-7b"])
def test_reduced_dryrun_subprocess(arch):
    """lower+compile a smoke config on an 8-device (4 data x 2 model) mesh —
    proves the rules + step function SPMD-partition cleanly, per family."""
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath("src"))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT, arch],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"]


def test_rules_fall_back_to_replication_when_indivisible():
    """A dim not divisible by its axis must silently replicate, never fail."""
    cfg = configs.get_smoke_config("qwen1.5-4b")   # tiny dims vs 16-wide axes
    mesh = _abstract_production_mesh(False)
    tree = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = rules.param_specs(cfg, mesh, tree)     # must not raise
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert isinstance(spec, P)
