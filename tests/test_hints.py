"""Sharding-hint helper: guards, fallbacks, and end-to-end effect."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.sharding.hints import DP, dp_axes, hint, mesh_axis_sizes


def test_no_mesh_is_noop():
    x = jnp.ones((8, 16))
    y = hint(x, "data", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_noop_inside_jit_without_mesh():
    @jax.jit
    def f(x):
        return hint(x, DP, "model") * 2.0

    out = f(jnp.ones((4, 8)))
    np.testing.assert_allclose(np.asarray(out), 2.0)


def test_dp_axes_selection():
    assert dp_axes({"pod": 2, "data": 16, "model": 16}) == ("pod", "data")
    assert dp_axes({"data": 16, "model": 16}) == ("data",)
    assert dp_axes({"model": 16}) == ()
    assert dp_axes({"pod": 1, "data": 4}) == ("data",)   # size-1 axes drop


_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, json
    from repro.sharding.hints import DP, hint

    mesh = jax.make_mesh((4, 2), ("data", "model"))

    def f(x):
        # 12 not divisible by model=2? it is; 7 is not -> must fall back
        a = hint(x, DP, "model")            # (8, 12): both shard
        b = hint(jnp.ones((7, 12)), "model", None)   # 7 % 2 != 0 -> replicate
        return a.sum() + b.sum()

    with mesh:
        compiled = jax.jit(f).lower(jnp.ones((8, 12))).compile()
    txt = compiled.as_text()
    print(json.dumps({"ok": True, "sharded": "sharding=" in txt}))
""")


def test_hint_applies_under_mesh_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.abspath("src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    import json
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["ok"] and payload["sharded"]


def test_no_hints_env_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_NO_HINTS", "1")
    x = jnp.ones((8, 16))
    y = hint(x, "data", "model")
    assert y is x          # exact object: nothing applied
