"""§7 cluster moves (DESIGN.md §17.3): h-hop masks (dense O(N^2) walk ==
sparse O(E) CSR frontier), joint-move atomicity, strict potential descent
on both representations, and the ``apply_cluster_move`` aggregate window
against the rebuild oracle."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import costs
from repro.core.aggregate import (apply_cluster_move, init_aggregate_state,
                                  rebuild_state)
from repro.core.cluster import _h_hop_mask, cluster_move_pass, h_hop_mask
from repro.core.problem import make_problem
from repro.core.sparse import frontier_expand, sparse_from_dense
from repro.graphs.generators import random_degree_graph, random_weights


def _instance(n=60, k=4, seed=0):
    adj = random_degree_graph(n, seed=seed, dmin=2, dmax=4)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    prob = make_problem(c, b, np.linspace(0.5, 2.0, k), mu=8.0)
    r0 = jnp.asarray(np.random.default_rng(seed + 2).integers(0, k, n),
                     jnp.int32)
    return prob, sparse_from_dense(prob), r0


# ---------------------------------------------------------------------------
# h-hop masks: dense walk == sparse CSR frontier
# ---------------------------------------------------------------------------

def test_frontier_expand_matches_dense_one_hop():
    prob, sp, _ = _instance(seed=3)
    nbr = np.asarray(prob.adjacency) > 0
    rng = np.random.default_rng(0)
    for _ in range(5):
        mask = jnp.asarray(rng.random(prob.num_nodes) < 0.2)
        want = np.asarray(mask) | (np.asarray(mask) @ nbr)
        got = np.asarray(frontier_expand(sp, mask))
        np.testing.assert_array_equal(got, want)


@given(seed=st.integers(0, 1_000), node=st.integers(0, 59),
       hops=st.integers(1, 3))
@settings(max_examples=15)
def test_h_hop_mask_dense_equals_sparse(seed, node, hops):
    prob, sp, _ = _instance(seed=seed % 7)
    seed_node = jnp.asarray(node, jnp.int32)
    dense_mask = h_hop_mask(prob, seed_node, hops)
    sparse_mask = h_hop_mask(sp, seed_node, hops)
    np.testing.assert_array_equal(np.asarray(sparse_mask),
                                  np.asarray(dense_mask))
    np.testing.assert_array_equal(
        np.asarray(dense_mask),
        np.asarray(_h_hop_mask(prob.adjacency, seed_node, hops)))
    assert bool(dense_mask[node])   # seed always included


# ---------------------------------------------------------------------------
# cluster_move_pass: atomicity + strict descent, both representations
# ---------------------------------------------------------------------------

def _candidate_clusters(problem, assignment, framework, hops):
    """The per-machine candidate sets the pass evaluates: each machine's
    most dissatisfied node's h-hop OWNED neighborhood (replicates the
    pass's election on public pieces)."""
    from repro.core.problem import make_state
    k = problem.num_machines
    state = make_state(problem, assignment)
    dissat, _ = costs.dissatisfaction(problem, state, framework)
    out = []
    a = np.asarray(assignment)
    d = np.asarray(dissat)
    for m in range(k):
        owned = a == m
        masked = np.where(owned, d, -np.inf)
        seed = int(np.argmax(masked))
        cluster = np.asarray(h_hop_mask(problem, jnp.asarray(seed), hops))
        out.append(cluster & (a == a[seed]))
    return out


@pytest.mark.parametrize("fw", costs.FRAMEWORKS)
@pytest.mark.parametrize("rep", ["dense", "sparse"])
def test_cluster_move_strictly_descends(fw, rep):
    moved_any = False
    for seed in range(6):
        prob, sp, r0 = _instance(seed=seed)
        problem = sp if rep == "sparse" else prob
        before = float(costs.global_cost(problem, r0, fw))
        res = cluster_move_pass(problem, r0, fw, hops=1)
        after = float(costs.global_cost(problem, res.assignment, fw))
        if bool(res.moved):
            moved_any = True
            assert after < before
            assert float(res.gain) > 0
            np.testing.assert_allclose(before - after, float(res.gain),
                                       rtol=1e-4, atol=1e-3)
        else:
            np.testing.assert_array_equal(np.asarray(res.assignment),
                                          np.asarray(r0))
    assert moved_any   # the property must actually be exercised


@pytest.mark.parametrize("rep", ["dense", "sparse"])
def test_cluster_move_never_splits_h_hop_component(rep):
    """An accepted move transfers a seed's whole owned h-hop component
    atomically: the changed set IS one of the K candidate clusters, all
    to one destination."""
    checked = 0
    for seed in range(8):
        prob, sp, r0 = _instance(seed=seed)
        problem = sp if rep == "sparse" else prob
        res = cluster_move_pass(problem, r0, "c", hops=1)
        if not bool(res.moved):
            continue
        old, new = np.asarray(r0), np.asarray(res.assignment)
        changed = old != new
        assert changed.any()
        # all moved nodes share one source and one destination
        assert len(set(old[changed])) == 1
        assert len(set(new[changed])) == 1
        # and the moved set is exactly one candidate cluster — no subset
        clusters = _candidate_clusters(problem, r0, "c", hops=1)
        assert any(np.array_equal(changed, c) for c in clusters)
        checked += 1
    assert checked >= 2


def test_cluster_pass_dense_equals_sparse():
    for seed in range(4):
        prob, sp, r0 = _instance(seed=seed)
        res_d = cluster_move_pass(prob, r0, "ct", hops=2)
        res_s = cluster_move_pass(sp, r0, "ct", hops=2)
        assert bool(res_d.moved) == bool(res_s.moved)
        np.testing.assert_array_equal(np.asarray(res_s.assignment),
                                      np.asarray(res_d.assignment))


# ---------------------------------------------------------------------------
# apply_cluster_move: aggregate window vs rebuild oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rep", ["dense", "sparse"])
def test_apply_cluster_move_matches_rebuild(rep):
    prob, sp, r0 = _instance(seed=1)
    problem = sp if rep == "sparse" else prob
    total_b = jnp.sum(problem.node_weights)
    agg = init_aggregate_state(problem, r0)
    seed_node = 7
    source = r0[seed_node]
    dest = (source + 1) % problem.num_machines
    mask = h_hop_mask(problem, jnp.asarray(seed_node, jnp.int32), 1)
    mask = mask & (r0 == source)

    out = apply_cluster_move(problem, agg, mask, source, dest,
                             jnp.asarray(True), total_b)
    want_assignment = jnp.where(mask, dest, r0).astype(jnp.int32)
    oracle = rebuild_state(problem, want_assignment, total_b)
    np.testing.assert_array_equal(np.asarray(out.assignment),
                                  np.asarray(oracle.assignment))
    np.testing.assert_allclose(np.asarray(out.aggregate),
                               np.asarray(oracle.aggregate),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out.loads),
                               np.asarray(oracle.loads), rtol=1e-5)
    for field in ("c0", "ct0"):
        np.testing.assert_allclose(float(getattr(out, field)),
                                   float(getattr(oracle, field)),
                                   rtol=1e-4)

    # do_move=False is a bitwise no-op on every carried leaf
    kept = apply_cluster_move(problem, agg, mask, source, dest,
                              jnp.asarray(False), total_b)
    for got, old in zip(jax.tree.leaves(kept), jax.tree.leaves(agg)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(old))
