"""Training substrate: optimizer, schedules, checkpoint/restart fault
tolerance, gradient compression, data pipeline, microbatching."""
from __future__ import annotations

import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.training import checkpoint
from repro.training.compression import (compress_int8, compressed_psum,
                                        decompress_int8, init_errors)
from repro.training.data import SyntheticDataConfig, synthetic_batch
from repro.training.optimizer import (adamw_init, adamw_update,
                                      cosine_schedule, wsd_schedule)
from repro.training.train_step import (TrainHyper, init_train_state,
                                       make_train_step)


def _tiny_setup(arch="qwen1.5-4b", **hyper_kw):
    cfg = configs.get_smoke_config(arch)
    hyper = TrainHyper(total_steps=20, warmup=2, **hyper_kw)
    step = make_train_step(cfg, hyper)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    data = SyntheticDataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                               global_batch=4, input_kind=cfg.input_kind,
                               d_model=cfg.d_model)
    return cfg, step, state, data


# ---------------------------------------------------------------------------
# training loop behaviour
# ---------------------------------------------------------------------------

def test_loss_decreases_on_learnable_data():
    cfg, step, state, data = _tiny_setup()
    jstep = jax.jit(step)
    first = last = None
    for i in range(15):
        state, metrics = jstep(state, synthetic_batch(data, i))
        if first is None:
            first = float(metrics["ce"])
        last = float(metrics["ce"])
    assert last < first - 0.1, (first, last)


def test_microbatching_matches_full_batch():
    """Gradient accumulation over 4 microbatches == one full-batch step."""
    cfg, _, state, data = _tiny_setup()
    batch = synthetic_batch(data, 0)
    hyper1 = TrainHyper(total_steps=20, warmup=2, microbatches=1)
    hyper4 = TrainHyper(total_steps=20, warmup=2, microbatches=4)
    s1, m1 = jax.jit(make_train_step(cfg, hyper1))(state, batch)
    s4, m4 = jax.jit(make_train_step(cfg, hyper4))(state, batch)
    # microbatch mean-of-means == full mean when slices are equal-sized;
    # grads/params agree to accumulation roundoff
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-4)


def test_gradient_clipping_engages():
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params)
    huge = {"w": jnp.full((4, 4), 1e6)}
    _, _, gnorm = adamw_update(huge, opt, params, lr=1e-3, clip_norm=1.0)
    assert float(gnorm) > 1.0           # reported norm is pre-clip
    # post-clip step must be bounded by lr * (1 + wd)-ish
    new_p, _, _ = adamw_update(huge, opt, params, lr=1e-3, clip_norm=1.0)


def test_router_stats_accumulate_for_moe():
    cfg, step, state, data = _tiny_setup("granite-moe-1b-a400m")
    jstep = jax.jit(step)
    for i in range(3):
        state, _ = jstep(state, synthetic_batch(data, i))
    assert float(jnp.sum(state.expert_load)) > 0
    assert state.coactivation.shape == (cfg.num_experts, cfg.num_experts)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def test_cosine_schedule_shape():
    steps = jnp.arange(0, 1000)
    lr = jax.vmap(lambda s: cosine_schedule(s, peak_lr=1e-3, warmup=100,
                                            total=1000))(steps)
    lr = np.asarray(lr)
    assert lr[0] == 0.0
    np.testing.assert_allclose(lr[100], 1e-3, rtol=1e-5)
    assert np.all(np.diff(lr[:100]) > 0)          # warmup rises
    assert np.all(np.diff(lr[100:]) <= 1e-12)     # cosine decays
    assert lr[-1] >= 1e-4 * 0.99                  # min_ratio floor


def test_wsd_schedule_shape():
    """MiniCPM's Warmup-Stable-Decay: flat stable phase, then fast decay."""
    lr = np.asarray(jax.vmap(
        lambda s: wsd_schedule(s, peak_lr=1e-3, warmup=50, stable=700,
                               decay=100))(jnp.arange(0, 900)))
    np.testing.assert_allclose(lr[50:750], 1e-3, rtol=1e-5)   # stable
    assert lr[0] == 0.0
    assert lr[-1] < 2e-4                                        # decayed
    assert np.all(np.diff(lr[750:850]) < 0)


def test_minicpm_uses_wsd():
    # the assignment's MiniCPM row is the WSD paper; the driver defaults
    # its schedule accordingly
    from repro.launch.train import train  # noqa: F401 — import side-checks
    cfg = configs.get_config("minicpm-2b")
    assert cfg.name == "minicpm-2b"


# ---------------------------------------------------------------------------
# checkpoint / restart (fault tolerance)
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg, step, state, data = _tiny_setup()
    path = checkpoint.save(str(tmp_path), 3, state)
    assert os.path.exists(os.path.join(path, "manifest.json"))
    restored, at = checkpoint.restore(str(tmp_path), state)
    assert at == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_is_bitwise_identical(tmp_path):
    """Kill-anywhere/restart fault tolerance: train 4 steps straight vs
    train 2, checkpoint, restore, train 2 more — identical parameters."""
    cfg, step, state0, data = _tiny_setup()
    jstep = jax.jit(step)

    state = state0
    for i in range(4):
        state, _ = jstep(state, synthetic_batch(data, i))
    straight = state

    state = state0
    for i in range(2):
        state, _ = jstep(state, synthetic_batch(data, i))
    checkpoint.save(str(tmp_path), 2, state)
    restored, at = checkpoint.restore(str(tmp_path), state0)
    assert at == 2
    state = restored
    for i in range(2, 4):
        state, _ = jstep(state, synthetic_batch(data, i))

    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_atomicity(tmp_path):
    cfg, step, state, data = _tiny_setup()
    assert checkpoint.latest_step(str(tmp_path)) is None
    checkpoint.save(str(tmp_path), 1, state)
    checkpoint.save(str(tmp_path), 5, state)
    # a stale tmp dir from a crashed save must be ignored
    os.makedirs(os.path.join(str(tmp_path), ".tmp_save_crashed"))
    # an incomplete step dir (no manifest) must be ignored
    os.makedirs(os.path.join(str(tmp_path), "step_00000009"))
    assert checkpoint.latest_step(str(tmp_path)) == 5
    _, at = checkpoint.restore(str(tmp_path), state)
    assert at == 5


def test_restore_rejects_structure_mismatch(tmp_path):
    cfg, step, state, data = _tiny_setup()
    checkpoint.save(str(tmp_path), 1, state)
    with pytest.raises(AssertionError):
        checkpoint.restore(str(tmp_path), {"different": jnp.zeros(3)})


# ---------------------------------------------------------------------------
# gradient compression (int8 + error feedback)
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, scale = compress_int8(x)
    back = decompress_int8(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-7


def test_compressed_psum_error_feedback():
    """Under a vmapped axis (stand-in for the DP mesh axis), compressed
    psum approximates the true mean and error feedback kills the bias over
    repeated rounds."""
    rng = np.random.default_rng(1)
    W = 4                                     # simulated data-parallel width
    grads = jnp.asarray(rng.standard_normal((W, 256)), jnp.float32)

    def one_round(g, e):
        return compressed_psum({"g": g}, "dp", {"g": e})

    out, new_e = jax.vmap(one_round, axis_name="dp")(
        grads, jnp.zeros((W, 256), jnp.float32))
    true_mean = jnp.mean(grads, axis=0)
    got = np.asarray(out["g"][0])
    np.testing.assert_allclose(got, np.asarray(true_mean), atol=2e-2)

    # error feedback: accumulated compensation means the *sum* of applied
    # updates over T rounds converges to the sum of true means
    T = 20
    e = jnp.zeros((W, 256), jnp.float32)
    applied = jnp.zeros(256, jnp.float32)
    for t in range(T):
        out, e_tree = jax.vmap(one_round, axis_name="dp")(grads, e)
        e = e_tree["g"]
        applied = applied + out["g"][0]
    np.testing.assert_allclose(np.asarray(applied / T),
                               np.asarray(true_mean), atol=2e-3)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_batch_deterministic_and_seekable():
    data = SyntheticDataConfig(vocab_size=128, seq_len=16, global_batch=4)
    a = synthetic_batch(data, 7)
    b = synthetic_batch(data, 7)
    c = synthetic_batch(data, 8)
    np.testing.assert_array_equal(np.asarray(a["inputs"]),
                                  np.asarray(b["inputs"]))
    assert not np.array_equal(np.asarray(a["inputs"]),
                              np.asarray(c["inputs"]))
    assert a["inputs"].shape == (4, 16)
    assert int(jnp.max(a["inputs"])) < 128


def test_synthetic_batch_embeddings_kind():
    data = SyntheticDataConfig(vocab_size=64, seq_len=8, global_batch=2,
                               input_kind="embeddings", d_model=32)
    b = synthetic_batch(data, 0)
    assert b["inputs"].shape == (2, 8, 32)
    assert b["targets"].shape == (2, 8)


def test_synthetic_data_is_learnable():
    """The Markov structure must be exploitable: repeated-token positions
    are predictable, so a bigram statistic beats uniform entropy."""
    data = SyntheticDataConfig(vocab_size=64, seq_len=64, global_batch=8,
                               markov_period=4)
    b = synthetic_batch(data, 0)
    toks = np.asarray(b["inputs"])
    idx = np.arange(64)
    rep = (idx % 4) == 3
    frac_equal = (toks[:, 1:][:, rep[1:]] == toks[:, :-1][:, rep[1:]]).mean()
    assert frac_equal > 0.95
