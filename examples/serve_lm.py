"""Serve a small model with batched requests through the continuous-
batching engine (deliverable-(b) serving scenario).

  PYTHONPATH=src python examples/serve_lm.py --requests 16
"""
import argparse

import numpy as np

import jax

from repro import configs
from repro.models import init_params
from repro.serving import Request, ServeConfig, ServingEngine
from repro.serving.sampler import sample_logits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    sampler = (lambda logits: sample_logits(jax.random.PRNGKey(1), logits,
                                            temperature=args.temperature)) \
        if args.temperature > 0 else None

    eng = ServingEngine(cfg, params,
                        ServeConfig(max_batch=args.slots, max_len=128,
                                    cache_dtype="float32"),
                        **({"sampler": sampler} if sampler else {}))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(4, 24))).astype(np.int32)
        eng.submit(Request(i, prompt, max_new_tokens=args.max_new))

    stats = eng.run()
    print(f"[serve] {stats['requests']} requests | "
          f"{stats['generated_tokens']} tokens | "
          f"{stats['decode_steps']} batched decode steps | "
          f"{stats['tok_per_s']:.1f} tok/s (CPU smoke config)")
    for r in eng.finished[:3]:
        print(f"  req {r.uid}: prompt[{r.prompt.size}] -> {r.output[:8]}...")


if __name__ == "__main__":
    main()
