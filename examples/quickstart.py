"""Quickstart: the paper's game-theoretic partitioner in 40 lines.

Builds the §5.1 setup (230 LPs, 5 machines of unequal speed, mu=8), runs
Appendix-A initial partitioning followed by iterative best-response
refinement, and prints the potential descent + the equilibrium check.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.initial import initial_partition
from repro.core.problem import make_problem, make_state
from repro.core.refine import refine
from repro.graphs.generators import random_degree_graph, random_weights


def main():
    # 1. the network model under simulation: a random graph of LPs
    adj = random_degree_graph(230, seed=0, dmin=3, dmax=6)
    node_w, edge_w = random_weights(adj, seed=1, mean=5.0)

    # 2. the partition game: 5 machines with speeds (0.1..0.3), mu = 8
    problem = make_problem(edge_w, node_w,
                           speeds=[0.1, 0.2, 0.3, 0.3, 0.1], mu=8.0)

    # 3. Appendix-A initial partition: focal nodes + hop-by-hop expansion
    r0 = initial_partition(jnp.asarray(adj), 5, jax.random.PRNGKey(0))
    print(f"initial  C_0 = {costs.global_cost_c0(problem, r0):12.0f}   "
          f"Ct_0 = {costs.global_cost_ct0(problem, r0):10.0f}")

    # 4. iterative refinement: machines take turns moving their most
    #    dissatisfied node to its best-response machine (Thm 4.1 descent)
    result = refine(problem, r0, framework="c")
    r = result.assignment
    print(f"refined  C_0 = {costs.global_cost_c0(problem, r):12.0f}   "
          f"Ct_0 = {costs.global_cost_ct0(problem, r):10.0f}   "
          f"({int(result.num_moves)} node transfers, "
          f"converged={bool(result.converged)})")

    # 5. Nash check: at the equilibrium no LP can improve unilaterally
    dis, _ = costs.dissatisfaction(problem, make_state(problem, r), "c")
    print(f"max dissatisfaction at equilibrium: {float(jnp.max(dis)):.2e} "
          f"(Eq. 3 holds)")

    loads = jnp.zeros(5).at[r].add(problem.node_weights) / problem.speeds
    print("weighted machine loads:", [f"{float(x):.0f}" for x in loads])

    # Next step: examples/sweep_study.py runs a whole scenario fleet
    # (graph families x frameworks x hysteresis levels) through the
    # batched sweep runtime (repro.sweeps, DESIGN.md §12) — same game,
    # one compiled batch per case group instead of a Python loop.
    print("\nnext: PYTHONPATH=src python examples/sweep_study.py "
          "(batched scenario fleets)")


if __name__ == "__main__":
    main()
