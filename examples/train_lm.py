"""End-to-end driver: train a ~100M-parameter MoE LM for a few hundred
steps with checkpoint/restart fault tolerance and the game-theoretic expert
planner rebalancing experts from live router statistics.

This is the deliverable-(b) end-to-end example.  It uses a ~100M-param
granite-MoE-style config (not the reduced smoke config), runs on however
many devices are available (CPU here; the same code path jit-shards on a
pod), checkpoints periodically, and — to demonstrate restart — kills and
resumes itself halfway through when --demo-restart is set.

  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --steps 40 --demo-restart
"""
import argparse
import dataclasses
import os
import shutil

import jax

from repro.configs import get_config
from repro.launch.train import train
from repro.models.config import ModelConfig, MOE


def midi_config() -> ModelConfig:
    """~100M-active-param MoE (granite-moe family, scaled between smoke and
    the published 1b-a400m config)."""
    base = get_config("granite-moe-1b-a400m")
    return dataclasses.replace(
        base, name="granite-moe-100m",
        num_layers=8, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=16384, num_experts=16, top_k=4,
        moe_group_size=256, param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--demo-restart", action="store_true")
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()

    if args.fresh and os.path.isdir(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)

    import repro.configs as configs
    cfg = midi_config()
    n_params = cfg.param_count()
    print(f"[example] {cfg.name}: {n_params / 1e6:.1f}M params "
          f"({cfg.active_param_count() / 1e6:.1f}M active), "
          f"{len(jax.devices())} device(s)")

    # register the custom config so the driver can resolve --arch by name
    configs.register_config(cfg)

    if args.demo_restart:
        half = args.steps // 2
        print(f"[example] phase 1: train to step ~{half}, then simulate a "
              f"crash and restart")
        train(cfg.name, smoke=False, steps=half, global_batch=args.batch,
              seq_len=args.seq, ckpt_dir=args.ckpt_dir,
              ckpt_every=max(half // 2, 1), replan=10)
        print("[example] --- simulated crash; relaunching ---")

    train(cfg.name, smoke=False, steps=args.steps, global_batch=args.batch,
          seq_len=args.seq, ckpt_dir=args.ckpt_dir,
          ckpt_every=max(args.steps // 5, 1), replan=25)


if __name__ == "__main__":
    main()
