"""The paper's technique as a production feature: dynamic MoE expert
placement from live router statistics (DESIGN.md §4).

Trains a small MoE whose data distribution SHIFTS mid-run (token
distribution change => router load shifts => expert hot spots move —
exactly the paper's 'moving hot spot' scenario, §6.1).  The partition
planner replans the expert→device-group assignment every N steps and we
print the weighted load imbalance before/after each replan.

  PYTHONPATH=src python examples/moe_expert_rebalance.py
"""
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.sharding.planner import PartitionPlanner
from repro.training.data import SyntheticDataConfig, synthetic_batch
from repro.training.train_step import (TrainHyper, init_train_state,
                                       make_train_step)


def main():
    cfg = configs.get_smoke_config("qwen3-moe-235b-a22b")   # 8 experts top-2
    steps = 60
    hyper = TrainHyper(total_steps=steps, warmup=5)
    step = jax.jit(make_train_step(cfg, hyper))
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    planner = PartitionPlanner(num_groups=4, interval=15, mu=0.5)

    data_a = SyntheticDataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                 global_batch=8, zipf_a=1.4, seed=0)
    data_b = dataclasses.replace(data_a, zipf_a=0.6, seed=7)  # the shift

    for i in range(steps):
        data = data_a if i < steps // 2 else data_b
        state, metrics = step(state, synthetic_batch(data, i))
        if i == steps // 2:
            print(f"--- step {i}: data distribution shift (hot spot moves)")
        state, stats = planner.maybe_replan(i + 1, state)
        if stats:
            print(f"step {i + 1:3d}  loss={float(metrics['loss']):.3f}  "
                  f"expert imbalance {stats['imbalance_before']:.2f} -> "
                  f"{stats['imbalance_after']:.2f}  "
                  f"({stats['moves']} game moves)")
    load = np.asarray(state.expert_load)
    print(f"\nfinal EMA expert load (top 4): "
          f"{np.sort(load)[::-1][:4].round(3)}")


if __name__ == "__main__":
    main()
