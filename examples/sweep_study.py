"""Sweep study: a graph-family x framework x theta fleet in compiled batches.

Where ``quickstart.py`` refines ONE instance, this runs a whole scenario
fleet — three graph families x both cost frameworks x three hysteresis
levels — through the batched sweep runtime (``repro.sweeps``, DESIGN.md
§12).  Cases sharing a compile-time key (framework, N, K, theta on/off)
execute as ONE ``jax.vmap``-compiled batch, so the 18-cell grid below
costs four compiled programs instead of eighteen sequential runs, and
prints the per-cell load-CV / potential / migration table the paper's
statistical claims are about.

  PYTHONPATH=src python examples/sweep_study.py
"""
import numpy as np

from repro import sweeps
from repro.core.problem import make_problem
from repro.graphs.generators import (preferential_attachment,
                                     random_degree_graph, random_weights,
                                     specialized_geometric)

N, K, MU = 96, 4, 8.0
SPEEDS = (0.4, 0.3, 0.2, 0.1)
FAMILIES = {
    "random-degree": lambda seed: random_degree_graph(N, seed),
    "pref-attach": lambda seed: preferential_attachment(N, seed, m=2),
    "geometric": lambda seed: specialized_geometric(N, seed),
}
THETAS = {"theta=0": None, "theta=5": 5.0, "theta=20": 20.0}


def build_cases():
    cases = []
    for fi, (fname, gen) in enumerate(FAMILIES.items()):
        adj = gen(fi)
        node_w, edge_w = random_weights(adj, seed=100 + fi, mean=5.0)
        problem = make_problem(edge_w, node_w, SPEEDS, mu=MU)
        r0 = np.random.default_rng(fi).integers(0, K, N)
        for fw in ("c", "ct"):
            for tname, theta in THETAS.items():
                cases.append(sweeps.SweepCase(
                    problem=problem, assignment=r0, framework=fw,
                    theta=theta, label=f"{fname}/{fw}/{tname}"))
    return cases


def main():
    cases = build_cases()
    spec = sweeps.make_spec(cases, mode="traced", max_turns=384)
    groups = {(c.framework, c.theta is None) for c in cases}
    print(f"{len(cases)} cells -> {len(groups)} compiled batches "
          f"(grouped by framework x theta-presence)\n")
    result = sweeps.run_sweep(spec)

    header = ["cell", "moves", "load CV", "C_0", "Ct_0"]
    rows = [[s["label"], s["moves"], f"{s['load_cv']:.3f}",
             f"{s['c0']:.0f}", f"{s['ct0']:.0f}"]
            for s in result.summary()]
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(header)]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    print(fmt.format(*header))
    print(fmt.format(*["-" * w for w in widths]))
    for r in rows:
        print(fmt.format(*r))

    # the statistical read-off: hysteresis trades balance for stability,
    # uniformly across families and frameworks
    cv = result.load_cv()
    moves = result.moves
    for tname in THETAS:
        sel = [i for i, c in enumerate(cases) if c.label.endswith(tname)]
        print(f"\n{tname:>8}: mean load CV {cv[sel].mean():.3f}, "
              f"mean moves {moves[sel].mean():.1f}")


if __name__ == "__main__":
    main()
