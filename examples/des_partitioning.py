"""Paper §6 end-to-end: optimistic parallel DES with dynamic repartitioning.

Runs the limited-scope flooded packet-flow workload (moving hot spots) on
the Time-Warp archetype twice — once with the initial partition only, once
with periodic game-theoretic refinement — and reports simulation execution
time, rollbacks and per-machine load balance (Figs. 7/9/10 in miniature).

  PYTHONPATH=src python examples/des_partitioning.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core.initial import initial_partition
from repro.des.engine import DESConfig, make_initial_state, run_simulation
from repro.des.workload import flooded_packet_workload
from repro.graphs.generators import preferential_attachment


def simulate(adj, refine_freq: int):
    n = adj.shape[0]
    spec = flooded_packet_workload(adj, seed=3, num_threads=16,
                                   num_windows=4, scope=2,
                                   window_sim_time=60.0, max_per_lp=3)
    deg = int((adj > 0).sum(1).max())
    cfg = DESConfig(num_lps=n, num_machines=4, num_threads=16,
                    event_capacity=max(48, 2 * deg + 8),
                    history_capacity=max(96, 4 * deg + 16),
                    inter_delay=8, intra_delay=1,
                    refine_freq=refine_freq, trace_stride=25,
                    max_ticks=100_000)
    m0 = initial_partition(jnp.asarray(adj), 4, jax.random.PRNGKey(1))
    state = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
    return run_simulation(cfg, jnp.asarray(adj, jnp.float32), state)


def main():
    adj = preferential_attachment(64, seed=2, m=2)
    static = simulate(adj, refine_freq=0)
    dynamic = simulate(adj, refine_freq=400)
    for name, out in (("static partition ", static),
                      ("refine @400 ticks", dynamic)):
        tr = np.asarray(out.trace)[:int(out.trace_ptr)]
        active = tr.mean(1) > 1e-6
        cv = float(np.mean(tr[active].std(1)
                           / np.maximum(tr[active].mean(1), 1e-6))) \
            if active.any() else 0.0
        print(f"{name}: sim time = {int(out.tick):6d} ticks   "
              f"rollbacks = {int(out.rollbacks):5d}   "
              f"refines = {int(out.refines):2d}   "
              f"migrations = {int(out.moves):3d}   load CV = {cv:.3f}")
    speedup = (int(static.tick) - int(dynamic.tick)) / int(static.tick)
    print(f"\ndynamic repartitioning changed simulation time by "
          f"{100 * speedup:+.1f}% (paper Figs. 7/8: faster with frequent "
          f"refinement)")


if __name__ == "__main__":
    main()
