from .generators import (  # noqa: F401
    erdos_renyi,
    erdos_renyi_edges,
    preferential_attachment,
    preferential_attachment_edges,
    random_degree_graph,
    random_degree_graph_edges,
    random_weights,
    random_weights_edges,
    specialized_geometric,
    specialized_geometric_edges,
)
