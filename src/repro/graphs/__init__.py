from .generators import (  # noqa: F401
    erdos_renyi,
    preferential_attachment,
    random_degree_graph,
    specialized_geometric,
    random_weights,
)
