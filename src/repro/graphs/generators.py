"""Random graph models used in the paper's experiments (§5.1, §6.1).

Host-side (numpy) generation — graphs are *data* fed to the JAX programs, so
this lives in the data-pipeline layer, mirroring how token pipelines sit
outside jit.  All generators return a dense symmetric float32 adjacency
matrix with zero diagonal (1.0 marks an edge; weights applied separately).

  * ``random_degree_graph``      — §5.1 study: per-node degree drawn from
                                   [dmin, dmax], random distinct targets.
  * ``preferential_attachment``  — §6 Fig. 7: Barabási–Albert style model
                                   (Bu–Towsley's Internet-like generator).
  * ``specialized_geometric``    — §6 Fig. 8: nodes get 2-D coordinates and
                                   link to nodes chosen among their 15
                                   nearest neighbors.
  * ``erdos_renyi``              — Appendix A / Thm A.1 property tests.
"""
from __future__ import annotations

import numpy as np


def _rng(seed) -> np.random.Generator:
    if isinstance(seed, np.random.Generator):
        return seed
    return np.random.default_rng(seed)


def _empty(n: int) -> np.ndarray:
    return np.zeros((n, n), np.float32)


def _ensure_connected(adj: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Stitch components together with zero-cost... no — unit edges.

    The paper (§3) notes a disconnected graph can be connected by adding
    zero-weight edges; for topology generation we instead add a unit edge
    from each stranded component to the giant component, which keeps BFS
    utilities simple.  Components are found with a simple label propagation.
    """
    n = adj.shape[0]
    labels = np.arange(n)
    nbr = adj > 0
    changed = True
    while changed:
        changed = False
        for i in range(n):
            m = labels[nbr[i]].min(initial=labels[i])
            if m < labels[i]:
                labels[i] = m
                changed = True
    roots = np.unique(labels)
    if roots.size > 1:
        counts = np.array([(labels == r).sum() for r in roots])
        giant = roots[np.argmax(counts)]
        for r in roots:
            if r == giant:
                continue
            a = rng.choice(np.flatnonzero(labels == r))
            b = rng.choice(np.flatnonzero(labels == giant))
            adj[a, b] = adj[b, a] = 1.0
            labels[labels == r] = giant
    return adj


def random_degree_graph(n: int, seed, dmin: int = 3, dmax: int = 6) -> np.ndarray:
    """Each node connects to d ~ U{dmin..dmax} random distinct others (§5.1)."""
    rng = _rng(seed)
    adj = _empty(n)
    for i in range(n):
        d = rng.integers(dmin, dmax + 1)
        targets = rng.choice(n - 1, size=d, replace=False)
        targets = targets + (targets >= i)  # skip self
        adj[i, targets] = 1.0
        adj[targets, i] = 1.0
    return _ensure_connected(adj, rng)


def preferential_attachment(n: int, seed, m: int = 2) -> np.ndarray:
    """Barabási–Albert: each new node attaches m edges ∝ current degree."""
    rng = _rng(seed)
    adj = _empty(n)
    seed_size = m + 1
    for i in range(seed_size):
        for j in range(i + 1, seed_size):
            adj[i, j] = adj[j, i] = 1.0
    degree = adj.sum(axis=1)
    for i in range(seed_size, n):
        probs = degree[:i] / degree[:i].sum()
        targets = rng.choice(i, size=min(m, i), replace=False, p=probs)
        adj[i, targets] = 1.0
        adj[targets, i] = 1.0
        degree[targets] += 1.0
        degree[i] = len(targets)
    return adj


def specialized_geometric(n: int, seed, links_per_node: int = 3,
                          neighborhood: int = 15) -> np.ndarray:
    """§6 geometric model: nodes in the unit square; each node randomly links
    to ``links_per_node`` nodes from its ``neighborhood`` nearest (L2)."""
    rng = _rng(seed)
    coords = rng.random((n, 2)).astype(np.float32)
    d2 = ((coords[:, None, :] - coords[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    adj = _empty(n)
    for i in range(n):
        near = np.argsort(d2[i])[:neighborhood]
        chosen = rng.choice(near, size=min(links_per_node, near.size),
                            replace=False)
        adj[i, chosen] = 1.0
        adj[chosen, i] = 1.0
    return _ensure_connected(adj, rng)


def erdos_renyi(n: int, p: float, seed) -> np.ndarray:
    rng = _rng(seed)
    upper = rng.random((n, n)) < p
    adj = np.triu(upper, k=1).astype(np.float32)
    return adj + adj.T


def random_weights(adj: np.ndarray, seed, mean: float = 5.0):
    """Node and edge weights with the §5.1 distribution (mean ``mean``).

    The paper says only "randomly generated ... with mean 5"; we use
    U(0, 2*mean), documented in EXPERIMENTS.md.
    Returns (node_weights (N,), weighted_adjacency (N, N)).
    """
    rng = _rng(seed)
    n = adj.shape[0]
    node_w = rng.uniform(0.0, 2.0 * mean, size=n).astype(np.float32)
    edge_w = rng.uniform(0.0, 2.0 * mean, size=(n, n)).astype(np.float32)
    edge_w = np.triu(edge_w, 1)
    edge_w = edge_w + edge_w.T
    return node_w, (edge_w * (adj > 0)).astype(np.float32)
