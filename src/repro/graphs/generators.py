"""Random graph models used in the paper's experiments (§5.1, §6.1).

Host-side (numpy) generation — graphs are *data* fed to the JAX programs, so
this lives in the data-pipeline layer, mirroring how token pipelines sit
outside jit.

Two output forms per model:

  * the original **dense** generators return a symmetric float32 (N, N)
    adjacency with zero diagonal (1.0 marks an edge; weights applied
    separately) — convenient up to a few thousand nodes;
  * the ``*_edges`` variants emit the **undirected edge list**
    ``(senders, receivers)`` directly (each edge once, ``s < r``), never
    touching an O(N^2) array, for the sparse runtime of DESIGN.md §13 —
    feed them to :func:`repro.core.sparse.make_sparse_problem` via
    :func:`random_weights_edges`.  The edge variants draw from the same
    model family (same per-node distributions, bounds included) but are
    *separate RNG streams* from their dense twins — fixed seeds give
    different graphs across the two forms.

Models:

  * ``random_degree_graph``      — §5.1 study: per-node degree drawn from
                                   [dmin, dmax], random distinct targets.
  * ``preferential_attachment``  — §6 Fig. 7: Barabási–Albert style model
                                   (Bu–Towsley's Internet-like generator).
  * ``specialized_geometric``    — §6 Fig. 8: nodes get 2-D coordinates and
                                   link to nodes chosen among their 15
                                   nearest neighbors.
  * ``erdos_renyi``              — Appendix A / Thm A.1 property tests.

All generators (both forms) guarantee CONNECTED output — the paper's §3
assumptions exclude disconnected graphs — by stitching stray components
into the giant component with unit edges (:func:`_ensure_connected`,
union-find over edges).
"""
from __future__ import annotations

import numpy as np


def _rng(seed) -> np.random.Generator:
    if isinstance(seed, np.random.Generator):
        return seed
    return np.random.default_rng(seed)


def _empty(n: int) -> np.ndarray:
    return np.zeros((n, n), np.float32)


def _component_labels(n: int, senders: np.ndarray,
                      receivers: np.ndarray) -> np.ndarray:
    """Connected-component labels, each component labeled by its MINIMUM
    node id — union-find via vectorized min-hooking + pointer jumping,
    O(E · log N) total instead of the old per-node label-propagation
    loop's O(N^2 · iters).

    The min-id labeling is exactly what the previous label-propagation
    implementation converged to, so everything downstream (component
    enumeration order, stitch RNG consumption) is unchanged bitwise —
    pinned by ``tests/test_graphs.py`` against a reference copy of the
    old algorithm.
    """
    labels = np.arange(n)
    if senders.size == 0:
        return labels
    while True:
        prev = labels
        m = np.minimum(labels[senders], labels[receivers])
        nxt = labels.copy()
        np.minimum.at(nxt, senders, m)
        np.minimum.at(nxt, receivers, m)
        nxt = nxt[nxt]          # pointer-jump: follow the label's label
        nxt = nxt[nxt]
        if np.array_equal(nxt, prev):
            return nxt
        labels = nxt


def _stitch_components(labels: np.ndarray, rng: np.random.Generator):
    """Unit edges joining every stray component to the (growing) giant.

    Component roots are visited in ascending min-node-id order; for each,
    one random member links to one random member of the giant — the same
    rule (and the same RNG consumption sequence) as the original dense
    implementation, pinned bitwise by ``tests/test_graphs.py``.  Returns
    the (a, b) endpoint lists.  O(N) per stray component (the growing
    giant's member list is rescanned each step) — fine for the dense
    generators, whose representation is O(N^2) anyway; the edge-list
    path uses the vectorized :func:`_stitch_components_star` instead.
    """
    roots = np.unique(labels)
    extra_a, extra_b = [], []
    if roots.size > 1:
        counts = np.bincount(labels, minlength=labels.size)[roots]
        giant = roots[np.argmax(counts)]
        for r in roots:
            if r == giant:
                continue
            a = rng.choice(np.flatnonzero(labels == r))
            b = rng.choice(np.flatnonzero(labels == giant))
            extra_a.append(int(a))
            extra_b.append(int(b))
            labels[labels == r] = giant
    return extra_a, extra_b


def _stitch_components_star(labels: np.ndarray, rng: np.random.Generator):
    """Vectorized stitch for the edge-list path: every stray component
    links one uniform-random member to one uniform-random member of the
    INITIAL giant (a star onto the giant rather than the dense path's
    sequentially growing giant) — O(N log N) total however many
    components there are, where the faithful sequential rule is O(N) per
    stray.  Same connectivity guarantee; different (but documented) RNG
    stream, which is fine because the ``*_edges`` generators never
    promise draw-for-draw parity with their dense twins.
    """
    n = labels.size
    roots = np.unique(labels)
    if roots.size <= 1:
        return np.empty(0, np.int64), np.empty(0, np.int64)
    counts = np.bincount(labels, minlength=n)[roots]
    giant = roots[np.argmax(counts)]
    # nodes grouped by component, node-id ascending inside each group
    order = np.argsort(labels, kind="stable")
    sorted_labels = labels[order]
    starts = np.searchsorted(sorted_labels, roots, side="left")
    sizes = np.concatenate([np.diff(starts), [n - starts[-1]]])
    stray = roots != giant
    gi = int(np.flatnonzero(~stray)[0])
    # one uniform member per stray + one uniform giant member per stray
    a = order[starts[stray]
              + rng.integers(0, sizes[stray], size=int(stray.sum()))]
    b = order[starts[gi]
              + rng.integers(0, sizes[gi], size=int(stray.sum()))]
    return a.astype(np.int64), b.astype(np.int64)


def _ensure_connected(adj: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Stitch components together with zero-cost... no — unit edges.

    The paper (§3) notes a disconnected graph can be connected by adding
    zero-weight edges; for topology generation we instead add a unit edge
    from each stranded component to the giant component, which keeps BFS
    utilities simple.  Components come from union-find over the edge
    list (:func:`_component_labels`) — O(E) instead of the previous
    O(N^2·iters) label propagation, with identical stitched output on
    fixed seeds.
    """
    n = adj.shape[0]
    s, r = np.nonzero(adj)
    labels = _component_labels(n, s, r)
    extra_a, extra_b = _stitch_components(labels, rng)
    for a, b in zip(extra_a, extra_b):
        adj[a, b] = adj[b, a] = 1.0
    return adj


def _ensure_connected_edges(n: int, senders: np.ndarray,
                            receivers: np.ndarray,
                            rng: np.random.Generator):
    """Edge-list twin of :func:`_ensure_connected`: returns the input
    undirected pairs plus one stitch edge per stray component (the
    vectorized star stitch — see :func:`_stitch_components_star`)."""
    labels = _component_labels(n, senders, receivers)
    ea, eb = _stitch_components_star(labels, rng)
    if ea.size == 0:
        return senders, receivers
    return (np.concatenate([senders.astype(np.int64),
                            np.minimum(ea, eb)]),
            np.concatenate([receivers.astype(np.int64),
                            np.maximum(ea, eb)]))


def _dedupe_pairs(senders: np.ndarray, targets: np.ndarray):
    """Canonicalize to unique undirected pairs (s < r), dropping loops."""
    keep = senders != targets
    a = np.minimum(senders[keep], targets[keep]).astype(np.int64)
    b = np.maximum(senders[keep], targets[keep]).astype(np.int64)
    pairs = np.unique(np.stack([a, b], axis=1), axis=0)
    return pairs[:, 0], pairs[:, 1]


def random_degree_graph(n: int, seed, dmin: int = 3, dmax: int = 6) -> np.ndarray:
    """Each node connects to d ~ U{dmin..dmax} random distinct others (§5.1)."""
    rng = _rng(seed)
    adj = _empty(n)
    for i in range(n):
        d = rng.integers(dmin, dmax + 1)
        targets = rng.choice(n - 1, size=d, replace=False)
        targets = targets + (targets >= i)  # skip self
        adj[i, targets] = 1.0
        adj[targets, i] = 1.0
    return _ensure_connected(adj, rng)


def _distinct_targets(rng: np.random.Generator, senders: np.ndarray,
                      n: int) -> np.ndarray:
    """One distinct non-self target per (sender, slot) row, vectorized:
    draw all rows at once, then redraw only within-sender duplicates
    until none remain (rejection sampling — exactly the uniform
    distinct-subset distribution of ``rng.choice(replace=False)``,
    without the per-node Python loop).  Terminates a.s. for per-sender
    slot counts < n; expected a couple of rounds at d ≪ n."""
    t = rng.integers(0, n - 1, size=senders.size)
    t += t >= senders                           # skip self
    for _ in range(10_000):
        order = np.lexsort((t, senders))
        s_s, t_s = senders[order], t[order]
        dup = (s_s[1:] == s_s[:-1]) & (t_s[1:] == t_s[:-1])
        dup_idx = order[1:][dup]
        if dup_idx.size == 0:
            return t
        fresh = rng.integers(0, n - 1, size=dup_idx.size)
        t[dup_idx] = fresh + (fresh >= senders[dup_idx])
    raise RuntimeError("duplicate-target rejection failed to converge "
                       "(per-node degree too close to n?)")


def random_degree_graph_edges(n: int, seed, dmin: int = 3, dmax: int = 6):
    """Edge-list §5.1 model: vectorized over all nodes (no Python-per-node
    loop, no (N, N) array), viable at N=10^5–10^6.

    Each node draws d ~ U{dmin..dmax} DISTINCT uniform targets (same
    per-node distribution as the dense twin's ``replace=False`` draws,
    realized by vectorized rejection of within-node duplicates), so the
    dense generator's degree >= dmin guarantee holds here too.  Returns
    undirected pairs ``(senders, receivers)`` with s < r, connected
    (stitched like every other generator).
    """
    rng = _rng(seed)
    d = np.minimum(rng.integers(dmin, dmax + 1, size=n), n - 1)
    senders = np.repeat(np.arange(n, dtype=np.int64), d)
    targets = _distinct_targets(rng, senders, n)
    s, r = _dedupe_pairs(senders, targets)
    return _ensure_connected_edges(n, s, r, rng)


def preferential_attachment(n: int, seed, m: int = 2) -> np.ndarray:
    """Barabási–Albert: each new node attaches m edges ∝ current degree."""
    rng = _rng(seed)
    adj = _empty(n)
    seed_size = m + 1
    for i in range(seed_size):
        for j in range(i + 1, seed_size):
            adj[i, j] = adj[j, i] = 1.0
    degree = adj.sum(axis=1)
    for i in range(seed_size, n):
        probs = degree[:i] / degree[:i].sum()
        targets = rng.choice(i, size=min(m, i), replace=False, p=probs)
        adj[i, targets] = 1.0
        adj[targets, i] = 1.0
        degree[targets] += 1.0
        degree[i] = len(targets)
    return adj


def preferential_attachment_edges(n: int, seed, m: int = 2):
    """Edge-list Barabási–Albert via the repeated-endpoints trick: sampling
    an entry of the edge-endpoint multiset IS degree-proportional
    sampling, so attachment is O(1) per edge with no O(i) probability
    renormalization per node (the dense generator's bottleneck).
    Connected by construction.  Returns undirected (senders, receivers).
    """
    rng = _rng(seed)
    seed_size = m + 1
    s0, r0 = np.triu_indices(seed_size, k=1)
    num_edges = s0.size + (n - seed_size) * m
    sends = np.empty(num_edges, np.int64)
    recvs = np.empty(num_edges, np.int64)
    sends[:s0.size], recvs[:s0.size] = s0, r0
    # endpoint multiset: each edge contributes both endpoints
    endpoints = np.empty(2 * num_edges, np.int64)
    endpoints[:2 * s0.size:2] = s0
    endpoints[1:2 * s0.size:2] = r0
    ecount = 2 * s0.size
    ne = s0.size
    for i in range(seed_size, n):
        take = min(m, i)
        # degree-proportional distinct targets: redraw until distinct
        cand = endpoints[rng.integers(0, ecount, size=take)]
        while np.unique(cand).size < take:
            cand = endpoints[rng.integers(0, ecount, size=take)]
        sends[ne:ne + take] = i
        recvs[ne:ne + take] = cand
        endpoints[ecount:ecount + 2 * take:2] = i
        endpoints[ecount + 1:ecount + 2 * take:2] = cand
        ecount += 2 * take
        ne += take
    return np.minimum(sends[:ne], recvs[:ne]), \
        np.maximum(sends[:ne], recvs[:ne])


def specialized_geometric(n: int, seed, links_per_node: int = 3,
                          neighborhood: int = 15) -> np.ndarray:
    """§6 geometric model: nodes in the unit square; each node randomly links
    to ``links_per_node`` nodes from its ``neighborhood`` nearest (L2)."""
    rng = _rng(seed)
    coords = rng.random((n, 2)).astype(np.float32)
    d2 = ((coords[:, None, :] - coords[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    adj = _empty(n)
    for i in range(n):
        near = np.argsort(d2[i])[:neighborhood]
        chosen = rng.choice(near, size=min(links_per_node, near.size),
                            replace=False)
        adj[i, chosen] = 1.0
        adj[chosen, i] = 1.0
    return _ensure_connected(adj, rng)


def specialized_geometric_edges(n: int, seed, links_per_node: int = 3,
                                neighborhood: int = 15):
    """Edge-list §6 geometric model: k-nearest neighbors via a KD-tree
    (O(N log N)) instead of the dense generator's O(N^2) distance matrix;
    each node links to ``links_per_node`` uniform distinct picks among its
    ``neighborhood`` nearest.  Returns undirected (senders, receivers),
    connected.
    """
    from scipy.spatial import cKDTree   # scipy ships with jax

    rng = _rng(seed)
    n_eff = min(neighborhood, n - 1)
    links = min(links_per_node, n_eff)
    coords = rng.random((n, 2)).astype(np.float32)
    _, near = cKDTree(coords).query(coords, k=n_eff + 1)
    near = near[:, 1:]                               # drop self
    # uniform distinct subset per row: argpartition of random keys
    keys = rng.random((n, n_eff))
    pick = np.argpartition(keys, links - 1, axis=1)[:, :links]
    targets = np.take_along_axis(near, pick, axis=1).ravel()
    senders = np.repeat(np.arange(n, dtype=np.int64), links)
    s, r = _dedupe_pairs(senders, targets)
    return _ensure_connected_edges(n, s, r, rng)


def erdos_renyi(n: int, p: float, seed) -> np.ndarray:
    """G(n, p).  Routed through :func:`_ensure_connected` like every other
    generator: small-p draws are disconnected with high probability, and
    the paper's §3 assumptions (BFS initial partitioning, Thm A.1 growth)
    exclude disconnected graphs — previously this was the ONE generator
    that skipped stitching and silently handed the game stranded
    components."""
    rng = _rng(seed)
    upper = rng.random((n, n)) < p
    adj = np.triu(upper, k=1).astype(np.float32)
    return _ensure_connected(adj + adj.T, rng)


def erdos_renyi_edges(n: int, p: float, seed):
    """Edge-list G(n, p): draw Binomial(C(n,2), p) for the edge count, then
    that many uniform distinct pairs — the standard G(n, M)-style
    construction of G(n, p), O(E) memory.  Connected (stitched).
    Returns undirected (senders, receivers)."""
    rng = _rng(seed)
    total = n * (n - 1) // 2
    m = int(rng.binomial(total, p)) if total else 0
    s = np.empty(0, np.int64)
    r = np.empty(0, np.int64)
    while s.size < m:
        draw = max(2 * (m - s.size), 16)
        cs = rng.integers(0, n, size=draw)
        cr = rng.integers(0, n, size=draw)
        s, r = _dedupe_pairs(np.concatenate([s, cs]), np.concatenate([r, cr]))
    if s.size > m:
        keep = rng.choice(s.size, size=m, replace=False)
        keep.sort()
        s, r = s[keep], r[keep]
    return _ensure_connected_edges(n, s, r, rng)


def random_weights(adj: np.ndarray, seed, mean: float = 5.0):
    """Node and edge weights with the §5.1 distribution (mean ``mean``).

    The paper says only "randomly generated ... with mean 5"; we use
    U(0, 2*mean), a deviation documented in DESIGN.md §8.
    Returns (node_weights (N,), weighted_adjacency (N, N)).
    """
    rng = _rng(seed)
    n = adj.shape[0]
    node_w = rng.uniform(0.0, 2.0 * mean, size=n).astype(np.float32)
    edge_w = rng.uniform(0.0, 2.0 * mean, size=(n, n)).astype(np.float32)
    edge_w = np.triu(edge_w, 1)
    edge_w = edge_w + edge_w.T
    return node_w, (edge_w * (adj > 0)).astype(np.float32)


def random_weights_edges(n: int, senders: np.ndarray, seed,
                         mean: float = 5.0):
    """Edge-list twin of :func:`random_weights`: per-node and per-edge
    U(0, 2*mean) weights (DESIGN.md §8) without the (N, N) draw.
    Returns (node_weights (N,), edge_weights (E,)) aligned with the
    undirected pair list."""
    rng = _rng(seed)
    node_w = rng.uniform(0.0, 2.0 * mean, size=n).astype(np.float32)
    edge_w = rng.uniform(0.0, 2.0 * mean,
                         size=np.asarray(senders).shape[0]).astype(np.float32)
    return node_w, edge_w
