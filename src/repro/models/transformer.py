"""Decoder-LM assembly for all four families.

Layers are *stacked* (leading L dim) and executed with ``jax.lax.scan`` so a
94-layer model compiles as one block body — essential for dry-run compile
times across 40 (arch x shape) cells.  The hybrid family scans Mamba2 layers
and applies a single *shared* attention+MLP block every ``attn_period``
layers (Zamba2-style weight sharing) via ``lax.cond`` inside the scan.

Public entry points:
  * init_params(cfg, key)
  * forward_train(params, cfg, batch)      -> loss, metrics
  * forward_logits(params, cfg, tokens)    -> logits  (prefill path)
  * decode_step(params, cfg, token, cache) -> logits, cache
  * init_cache(cfg, batch, max_len)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..sharding.hints import DP, hint
from . import attention, moe, ssm
from .config import DENSE, HYBRID, MOE, SSM, ModelConfig
from .layers import init_mlp, normal_init, rms_norm, swiglu

Array = jax.Array


class DecodeCache(NamedTuple):
    kv_k: Optional[Array]    # (L_attn, B, S_max, Hkv, D) or None
    kv_v: Optional[Array]    # (L_attn, B, S_max, Hkv, D) or None
    ssm_state: Optional[Array]  # (L, B, H, P, N) f32 or None
    ssm_conv: Optional[Array]   # (L, B, W-1, conv_dim) or None
    position: Array          # () int32 — tokens already in the cache


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dtype = cfg.pdtype()
    if cfg.family in (DENSE, MOE):
        block = {
            "attn_norm": jnp.ones((cfg.d_model,), dtype),
            "attn": attention.init_attention(k1, cfg),
            "ffn_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if cfg.family == MOE:
            block["moe"] = moe.init_moe(k2, cfg)
        else:
            block["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
        return block
    # ssm / hybrid per-layer block
    return {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "ssm": ssm.init_ssm(k3, cfg),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, cfg.num_layers + 4)
    dtype = cfg.pdtype()
    blocks = [_init_block(keys[i], cfg) for i in range(cfg.num_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    params: dict[str, Any] = {
        "embed": normal_init(keys[-1], (cfg.vocab_size, cfg.d_model),
                             0.02, dtype),
        "blocks": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(
            keys[-2], (cfg.d_model, cfg.vocab_size), cfg.d_model ** -0.5,
            dtype)
    if cfg.family == HYBRID and cfg.attn_period > 0:
        params["shared"] = {
            "attn_norm": jnp.ones((cfg.d_model,), dtype),
            "attn": attention.init_attention(keys[-3], cfg),
            "ffn_norm": jnp.ones((cfg.d_model,), dtype),
            "mlp": init_mlp(keys[-4], cfg.d_model, cfg.d_ff, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _dense_block_fwd(block, cfg: ModelConfig, x: Array):
    h = attention.causal_attention(
        block["attn"], cfg, rms_norm(x, block["attn_norm"], cfg.rms_eps))
    x = x + cfg.residual_multiplier * h
    if cfg.family == MOE:
        h, stats = moe.moe_block(
            block["moe"], cfg, rms_norm(x, block["ffn_norm"], cfg.rms_eps))
        aux = stats.aux_loss
        load = stats.expert_load
        coact = stats.coactivation
    else:
        m = block["mlp"]
        h = swiglu(rms_norm(x, block["ffn_norm"], cfg.rms_eps),
                   m["gate"], m["up"], m["down"])
        aux = jnp.zeros((), jnp.float32)
        load = jnp.zeros((max(cfg.num_experts, 1),), jnp.float32)
        coact = jnp.zeros((max(cfg.num_experts, 1),) * 2, jnp.float32)
    x = x + cfg.residual_multiplier * h
    return x, (aux, load, coact)


def _ssm_block_fwd(block, cfg: ModelConfig, x: Array):
    h, _ = ssm.ssm_block(block["ssm"], cfg,
                         rms_norm(x, block["norm"], cfg.rms_eps))
    return x + cfg.residual_multiplier * h


def _shared_block_fwd(shared, cfg: ModelConfig, x: Array):
    h = attention.causal_attention(
        shared["attn"], cfg, rms_norm(x, shared["attn_norm"], cfg.rms_eps))
    x = x + cfg.residual_multiplier * h
    m = shared["mlp"]
    h = swiglu(rms_norm(x, shared["ffn_norm"], cfg.rms_eps),
               m["gate"], m["up"], m["down"])
    return x + cfg.residual_multiplier * h


def backbone(params: dict, cfg: ModelConfig, x: Array):
    """Scan the stacked blocks.  x: (B, S, d) -> (B, S, d), moe aux stats."""
    shared = params.get("shared")

    def body(carry, inp):
        # residual stream stays sequence-sharded over 'model' between
        # layers: 16x less saved-activation HBM under remat and no
        # gather/scatter at layer boundaries (§Perf hillclimb #1)
        x = hint(carry, DP, "model", None)
        layer_idx, block = inp
        if cfg.family in (DENSE, MOE):
            x, aux = _dense_block_fwd(block, cfg, x)
        else:
            x = _ssm_block_fwd(block, cfg, x)
            if cfg.family == HYBRID and cfg.attn_period > 0:
                x = jax.lax.cond(
                    (layer_idx + 1) % cfg.attn_period == 0,
                    lambda v: _shared_block_fwd(shared, cfg, v),
                    lambda v: v, x)
            aux = (jnp.zeros((), jnp.float32),
                   jnp.zeros((max(cfg.num_experts, 1),), jnp.float32),
                   jnp.zeros((max(cfg.num_experts, 1),) * 2, jnp.float32))
        return x, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    layer_ids = jnp.arange(cfg.num_layers, dtype=jnp.int32)
    x, aux = jax.lax.scan(body, x, (layer_ids, params["blocks"]))
    aux_loss = jnp.sum(aux[0])
    expert_load = jnp.mean(aux[1], axis=0)
    coactivation = jnp.sum(aux[2], axis=0)
    return x, (aux_loss, expert_load, coactivation)


def embed_inputs(params: dict, cfg: ModelConfig, inputs: Array) -> Array:
    if cfg.input_kind == "embeddings":
        # modality-frontend stub: inputs ARE (B, S, d) frame/patch embeddings
        return inputs.astype(cfg.cdtype()) * cfg.emb_multiplier
    x = jnp.take(params["embed"], inputs, axis=0).astype(cfg.cdtype())
    return x * cfg.emb_multiplier


def unembed(params: dict, cfg: ModelConfig, x: Array) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return logits.astype(jnp.float32) / cfg.logit_divisor


def forward_logits(params: dict, cfg: ModelConfig, inputs: Array):
    x = embed_inputs(params, cfg, inputs)
    x, aux = backbone(params, cfg, x)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return unembed(params, cfg, x), aux


def forward_train(params: dict, cfg: ModelConfig, batch: dict):
    """batch: {"inputs": ids or embeddings, "targets": (B,S) int32}.
    Returns (loss, metrics dict)."""
    logits, (aux_loss, expert_load, coact) = forward_logits(
        params, cfg, batch["inputs"])
    targets = batch["targets"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + cfg.router_aux_coef * aux_loss
    return loss, {"ce": ce, "aux_loss": aux_loss,
                  "expert_load": expert_load, "coactivation": coact}


def prefill(params: dict, cfg: ModelConfig, inputs: Array, max_len: int):
    """Prefill forward: consumes the prompt, returns (last-token logits,
    DecodeCache ready for decode_step).  Realistic serving never
    materializes full-sequence logits (B x S x V would dwarf the model).
    """
    x = embed_inputs(params, cfg, inputs)
    B, S = x.shape[0], x.shape[1]
    pad = max_len - S

    if cfg.family in (DENSE, MOE):
        def body(x, block):
            xn = rms_norm(x, block["attn_norm"], cfg.rms_eps)
            positions = jnp.arange(S)[None, :]
            q, k, v = attention._project_qkv(block["attn"], cfg, xn,
                                             positions)
            h_attn = attention._causal_core(q, k, v, cfg)
            h_attn = jnp.einsum("bse,ed->bsd",
                                h_attn.reshape(B, S, -1),
                                block["attn"]["wo"].astype(x.dtype))
            x = x + cfg.residual_multiplier * h_attn
            if cfg.family == MOE:
                h, _ = moe.moe_block(
                    block["moe"], cfg,
                    rms_norm(x, block["ffn_norm"], cfg.rms_eps))
            else:
                m = block["mlp"]
                h = swiglu(rms_norm(x, block["ffn_norm"], cfg.rms_eps),
                           m["gate"], m["up"], m["down"])
            x = x + cfg.residual_multiplier * h
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return x, (kc.astype(cfg.cdtype()), vc.astype(cfg.cdtype()))

        if cfg.remat:
            body = jax.checkpoint(body)
        x, (kv_k, kv_v) = jax.lax.scan(body, x, params["blocks"])
        cache = DecodeCache(kv_k=kv_k, kv_v=kv_v, ssm_state=None,
                            ssm_conv=None,
                            position=jnp.asarray(S, jnp.int32))
    else:
        shared = params.get("shared")
        attn_ids = _attention_layer_index(cfg)
        n_attn = max(cfg.attention_layers, 1)
        kv_shape = (n_attn, B, max_len, cfg.num_kv_heads, cfg.head_dim)
        kv_k0 = jnp.zeros(kv_shape, cfg.cdtype()) \
            if cfg.attention_layers else None
        kv_v0 = jnp.zeros(kv_shape, cfg.cdtype()) \
            if cfg.attention_layers else None

        def body(carry, inp):
            x, kv_k, kv_v = carry
            layer_idx, block = inp
            h, final_state, conv_tail = ssm.ssm_block(
                block["ssm"], cfg, rms_norm(x, block["norm"], cfg.rms_eps),
                return_conv_tail=True)
            x = x + cfg.residual_multiplier * h
            if cfg.family == HYBRID and cfg.attn_period > 0:
                a_idx = attn_ids[layer_idx]

                def apply_shared(operand):
                    x, kv_k, kv_v = operand
                    xn = rms_norm(x, shared["attn_norm"], cfg.rms_eps)
                    positions = jnp.arange(S)[None, :]
                    q, k, v = attention._project_qkv(shared["attn"], cfg,
                                                     xn, positions)
                    h = attention._causal_core(q, k, v, cfg)
                    h = jnp.einsum("bse,ed->bsd", h.reshape(B, S, -1),
                                   shared["attn"]["wo"].astype(x.dtype))
                    x2 = x + cfg.residual_multiplier * h
                    m = shared["mlp"]
                    h = swiglu(rms_norm(x2, shared["ffn_norm"], cfg.rms_eps),
                               m["gate"], m["up"], m["down"])
                    x2 = x2 + cfg.residual_multiplier * h
                    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    return (x2, kv_k.at[a_idx].set(kc.astype(cfg.cdtype())),
                            kv_v.at[a_idx].set(vc.astype(cfg.cdtype())))

                x, kv_k, kv_v = jax.lax.cond(
                    (layer_idx + 1) % cfg.attn_period == 0,
                    apply_shared, lambda o: o, (x, kv_k, kv_v))
            return (x, kv_k, kv_v), (final_state,
                                     conv_tail.astype(cfg.cdtype()))

        if cfg.remat:
            body = jax.checkpoint(body)
        layer_ids = jnp.arange(cfg.num_layers, dtype=jnp.int32)
        (x, kv_k, kv_v), (states, conv_tails) = jax.lax.scan(
            body, (x, kv_k0, kv_v0), (layer_ids, params["blocks"]))
        cache = DecodeCache(kv_k=kv_k, kv_v=kv_v, ssm_state=states,
                            ssm_conv=conv_tails,
                            position=jnp.asarray(S, jnp.int32))

    x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.rms_eps)
    return unembed(params, cfg, x), cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> DecodeCache:
    kv_k = kv_v = ssm_state = ssm_conv = None
    if cfg.attention_layers > 0:
        shape = (cfg.attention_layers, batch, max_len, cfg.num_kv_heads,
                 cfg.head_dim)
        kv_k = jnp.zeros(shape, dtype)
        kv_v = jnp.zeros(shape, dtype)
    if cfg.family in (SSM, HYBRID):
        ssm_state = jnp.zeros((cfg.num_layers, batch, cfg.ssm_heads,
                               cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        ssm_conv = jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1,
                              ssm.conv_dim(cfg)), dtype)
    return DecodeCache(kv_k=kv_k, kv_v=kv_v, ssm_state=ssm_state,
                       ssm_conv=ssm_conv, position=jnp.zeros((), jnp.int32))


def decode_step(params: dict, cfg: ModelConfig, inputs: Array,
                cache: DecodeCache):
    """One decode step.  inputs: (B, 1) ids or (B, 1, d) embeddings."""
    x = embed_inputs(params, cfg, inputs)
    pos = cache.position

    if cfg.family in (DENSE, MOE):
        def body(x, inp):
            block, k_l, v_l = inp
            kv_l = attention.KVCache(k=k_l, v=v_l, length=pos)
            h, kv_l = attention.decode_attention_step(
                block["attn"], cfg,
                rms_norm(x, block["attn_norm"], cfg.rms_eps), kv_l)
            x = x + cfg.residual_multiplier * h
            if cfg.family == MOE:
                # decode is DROPLESS: dropping tokens corrupts generation
                h, _ = moe.moe_block(
                    block["moe"], cfg,
                    rms_norm(x, block["ffn_norm"], cfg.rms_eps),
                    dropless=True)
            else:
                m = block["mlp"]
                h = swiglu(rms_norm(x, block["ffn_norm"], cfg.rms_eps),
                           m["gate"], m["up"], m["down"])
            x = x + cfg.residual_multiplier * h
            return x, (kv_l.k, kv_l.v)

        x, (new_k, new_v) = jax.lax.scan(
            body, x, (params["blocks"], cache.kv_k, cache.kv_v))
        new_cache = cache._replace(kv_k=new_k, kv_v=new_v, position=pos + 1)
    else:
        shared = params.get("shared")
        attn_ids = _attention_layer_index(cfg)

        def body(carry, inp):
            x, kv_k, kv_v = carry
            layer_idx, block, state_l, conv_l = inp
            ssm_l = ssm.SSMCache(state=state_l, conv=conv_l)
            h, ssm_l = ssm.ssm_decode_step(
                block["ssm"], cfg, rms_norm(x, block["norm"], cfg.rms_eps),
                ssm_l)
            x = x + cfg.residual_multiplier * h
            if cfg.family == HYBRID and cfg.attn_period > 0:
                a_idx = attn_ids[layer_idx]

                def apply_shared(operand):
                    x, kv_k, kv_v = operand
                    kv_l = attention.KVCache(k=kv_k[a_idx], v=kv_v[a_idx],
                                             length=pos)
                    h, kv_l = attention.decode_attention_step(
                        shared["attn"], cfg,
                        rms_norm(x, shared["attn_norm"], cfg.rms_eps), kv_l)
                    x2 = x + cfg.residual_multiplier * h
                    m = shared["mlp"]
                    h = swiglu(rms_norm(x2, shared["ffn_norm"], cfg.rms_eps),
                               m["gate"], m["up"], m["down"])
                    x2 = x2 + cfg.residual_multiplier * h
                    return (x2, kv_k.at[a_idx].set(kv_l.k),
                            kv_v.at[a_idx].set(kv_l.v))

                x, kv_k, kv_v = jax.lax.cond(
                    (layer_idx + 1) % cfg.attn_period == 0,
                    apply_shared, lambda o: o, (x, kv_k, kv_v))
            return (x, kv_k, kv_v), (ssm_l.state, ssm_l.conv)

        layer_ids = jnp.arange(cfg.num_layers, dtype=jnp.int32)
        (x, kv_k, kv_v), (new_state, new_conv) = jax.lax.scan(
            body, (x, cache.kv_k, cache.kv_v),
            (layer_ids, params["blocks"], cache.ssm_state, cache.ssm_conv))
        new_cache = cache._replace(kv_k=kv_k, kv_v=kv_v,
                                   ssm_state=new_state, ssm_conv=new_conv,
                                   position=pos + 1)

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    return unembed(params, cfg, x), new_cache


def _attention_layer_index(cfg: ModelConfig) -> Array:
    """Map layer index -> index into the stacked shared-attn KV cache."""
    ids = jnp.full((cfg.num_layers,), 0, jnp.int32)
    count = 0
    vals = []
    for l in range(cfg.num_layers):
        if cfg.attn_period > 0 and (l + 1) % cfg.attn_period == 0:
            vals.append(count)
            count += 1
        else:
            vals.append(0)
    return jnp.asarray(vals, jnp.int32)
