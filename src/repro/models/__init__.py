from .config import DENSE, HYBRID, MOE, SSM, ModelConfig  # noqa: F401
from .transformer import (  # noqa: F401
    DecodeCache,
    decode_step,
    forward_logits,
    forward_train,
    init_cache,
    init_params,
    prefill,
)
