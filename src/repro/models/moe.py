"""Mixture-of-Experts block with scatter-based grouped dispatch.

Design (DESIGN.md §4): tokens are split into groups of ``moe_group_size``;
within each group every token's top-k experts get the token scattered into a
per-(group, expert) capacity buffer.  Dispatch/combine are gathers/scatters
(zero matmul FLOPs — the einsum-dispatch formulation would add ~2*S_g/(3*d_ff)
of the expert FLOPs as pure overhead), and the expert einsum runs on
capacity-shaped buffers that shard cleanly: groups on the data axis, experts
on the model axis (expert parallelism).

The router also exposes per-expert load statistics consumed by the
game-theoretic PartitionPlanner (repro/sharding/planner.py) for dynamic
expert placement — the paper's dynamic load-balancing applied to MoE.

``moe_impl="dense"`` computes every expert for every token (top-k combine
only); it is the correctness oracle used by tests.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..sharding.hints import DP, hint
from .config import ModelConfig
from .layers import normal_init

Array = jax.Array


class MoEStats(NamedTuple):
    aux_loss: Array       # load-balancing auxiliary loss (scalar)
    expert_load: Array    # (E,) fraction of tokens routed to each expert
    coactivation: Array   # (E, E) co-routing counts (edge weights for the
                          # partition game's expert graph)


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    scale_in = d ** -0.5
    return {
        "router": normal_init(ks[0], (d, e), scale_in, cfg.pdtype()),
        "gate": normal_init(ks[1], (e, d, f), scale_in, cfg.pdtype()),
        "up": normal_init(ks[2], (e, d, f), scale_in, cfg.pdtype()),
        "down": normal_init(ks[3], (e, f, d), f ** -0.5, cfg.pdtype()),
    }


def _route(params: dict, cfg: ModelConfig, x_flat: Array):
    """Top-k routing.  x_flat: (T, d) -> weights/ids (T, k), stats."""
    e, k = cfg.num_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, k)                  # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # Switch-style aux loss: E * sum_e f_e * p_e
    assign = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)
    frac = jnp.mean(assign, axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_p)

    # expert load + co-activation graph for the partition planner
    full_assign = jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=1)
    load = jnp.mean(full_assign, axis=0)
    coact = jnp.einsum("te,tf->ef", full_assign, full_assign) \
        * (1.0 - jnp.eye(e))
    return weights, ids, MoEStats(aux_loss=aux, expert_load=load,
                                  coactivation=coact)


def _experts(params: dict, buf: Array, dtype) -> Array:
    """SwiGLU over capacity buffers.  buf: (G, E, C, d) -> (G, E, C, d)."""
    gate = jnp.einsum("gecd,edf->gecf", buf, params["gate"].astype(dtype))
    up = jnp.einsum("gecd,edf->gecf", buf, params["up"].astype(dtype))
    return jnp.einsum("gecf,efd->gecd", jax.nn.silu(gate) * up,
                      params["down"].astype(dtype))


def moe_block(params: dict, cfg: ModelConfig, x: Array, *,
              dropless: bool = False):
    """x: (B, S, d) -> (B, S, d), MoEStats.

    ``dropless=True`` sizes expert capacity to the worst case (all tokens to
    one expert) — used by the decode path, where dropping tokens would
    corrupt generation; cheap there because T = batch is small."""
    B, S, d = x.shape
    dtype = x.dtype
    T = B * S
    x_flat = x.reshape(T, d)
    weights, ids, stats = _route(params, cfg, x_flat)
    e, k = cfg.num_experts, cfg.top_k

    if cfg.moe_impl == "dense":
        # oracle: every expert on every token
        gate = jnp.einsum("td,edf->tef", x_flat, params["gate"].astype(dtype))
        up = jnp.einsum("td,edf->tef", x_flat, params["up"].astype(dtype))
        y_all = jnp.einsum("tef,efd->ted", jax.nn.silu(gate) * up,
                           params["down"].astype(dtype))
        combine = jnp.zeros((T, e), jnp.float32).at[
            jnp.arange(T)[:, None], ids].add(weights)
        y = jnp.einsum("te,ted->td", combine.astype(dtype), y_all)
        return y.reshape(B, S, d), stats

    # ---- scatter dispatch ------------------------------------------------
    # pad the token stream to a multiple of the dispatch-group size (decode
    # and ragged serving batches have arbitrary T); padded slots are masked
    # out of the capacity cumsum so they never consume expert capacity.
    sg = min(cfg.moe_group_size, T)
    T_pad = -(-T // sg) * sg
    if T_pad != T:
        x_flat = jnp.pad(x_flat, ((0, T_pad - T), (0, 0)))
        ids = jnp.pad(ids, ((0, T_pad - T), (0, 0)))
        weights = jnp.pad(weights, ((0, T_pad - T), (0, 0)))
    G = T_pad // sg
    cap = sg if dropless else max(1, int(cfg.capacity_factor * sg * k / e))
    xg = x_flat.reshape(G, sg, d)
    idg = ids.reshape(G, sg, k)
    wg = weights.reshape(G, sg, k)

    g_idx = jnp.broadcast_to(jnp.arange(G)[:, None], (G, sg * k))
    tok_idx = jnp.broadcast_to(
        (jnp.arange(sg * k) // k)[None, :], (G, sg * k))
    real = (g_idx * sg + tok_idx) < T                           # not padding

    # position of each (token, slot) within its expert's capacity buffer:
    # cumulative count of earlier slots in the group routed to that expert.
    slot_expert = idg.reshape(G, sg * k)                        # (G, S*k)
    onehot = jax.nn.one_hot(slot_expert, e, dtype=jnp.int32) \
        * real[..., None].astype(jnp.int32)                     # (G, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot                   # exclusive
    slot_pos = jnp.take_along_axis(
        pos, slot_expert[..., None], axis=-1)[..., 0]           # (G, S*k)
    keep = (slot_pos < cap) & real                              # overflow drop

    safe_pos = jnp.where(keep, slot_pos, cap - 1)

    if cfg.moe_impl == "einsum":
        # GShard-style einsum dispatch — the layout GSPMD partitions
        # natively (§Perf hillclimb #3): groups shard over the data axes,
        # experts over 'model'.  dispatch/combine one-hot einsums become
        # local block-einsums + one combine all-reduce; the scatter path
        # below (CPU-efficient) forces GSPMD into replicated scatter-adds.
        disp = (jax.nn.one_hot(idg, e, dtype=dtype)[..., :, None]
                * jax.nn.one_hot(slot_pos.reshape(G, sg, k), cap,
                                 dtype=dtype)[..., None, :]
                * keep.reshape(G, sg, k, 1, 1).astype(dtype))   # (G,sg,k,e,c)
        dispatch = jnp.sum(disp, axis=2)                         # (G,sg,e,c)
        combine = jnp.sum(disp * wg[..., None, None].astype(dtype), axis=2)
        xg = hint(xg, DP, None, None)
        dispatch = hint(dispatch, DP, None, "model", None)
        buf = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
        buf = hint(buf, DP, "model", None, None)
        out_buf = _experts(params, buf, dtype)                   # (G,E,C,d)
        out_buf = hint(out_buf, DP, "model", None, None)
        y = jnp.einsum("gsec,gecd->gsd", combine, out_buf)
        y = hint(y, DP, None, None)
        return y.reshape(T_pad, d)[:T].reshape(B, S, d), stats

    buf = jnp.zeros((G, e, cap, d), dtype)
    src = xg[g_idx, tok_idx]                                    # (G, S*k, d)
    buf = buf.at[g_idx, slot_expert, safe_pos].add(
        jnp.where(keep[..., None], src, 0).astype(dtype))

    out_buf = _experts(params, buf, dtype)                      # (G, E, C, d)

    gathered = out_buf[g_idx, slot_expert, safe_pos]            # (G, S*k, d)
    wslot = wg.reshape(G, sg * k)
    contrib = gathered * (wslot * keep)[..., None].astype(dtype)
    y = jnp.sum(contrib.reshape(G, sg, k, d), axis=2)           # (G, S_pad, d)
    return y.reshape(T_pad, d)[:T].reshape(B, S, d), stats
