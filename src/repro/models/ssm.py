"""Mamba2 (state-space duality) block: chunked SSD for train/prefill and a
constant-memory recurrent step for decode.

Implements the SSD algorithm of [arXiv:2405.21060]: within-chunk attention-
like diagonal blocks + inter-chunk state recurrence.  All decay exponents
are non-positive (dt >= 0, A < 0), so every exp() here is bounded by 1 —
numerically safe in bf16 activations with f32 accumulation.

Tensor conventions:
  x   (B, L, H, P)  — H ssm heads of head_dim P (d_inner = H*P)
  dt  (B, L, H)     — softplus-positive step sizes
  A   (H,)          — negative per-head decay rates
  Bm/Cm (B, L, N)   — single-group input/output projections (n_groups = 1)
State: (B, H, P, N).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import causal_conv1d, normal_init, rms_norm

Array = jax.Array


class SSMCache(NamedTuple):
    state: Array   # (B, H, P, N) f32
    conv: Array    # (B, W-1, conv_dim) — rolling conv window


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_ssm(key, cfg: ModelConfig) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 6)
    proj_out = 2 * di + 2 * n + h     # z, x, B, C, dt
    dtype = cfg.pdtype()
    dt = jnp.exp(jax.random.uniform(ks[3], (h,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "in_proj": normal_init(ks[0], (d, proj_out), d ** -0.5, dtype),
        "conv_w": normal_init(ks[1], (conv_dim(cfg), cfg.ssm_conv),
                              cfg.ssm_conv ** -0.5, dtype),
        "A_log": jnp.log(jax.random.uniform(ks[2], (h,), jnp.float32, 1.0, 16.0)),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": normal_init(ks[4], (di, d), di ** -0.5, dtype),
    }


def _split_proj(params: dict, cfg: ModelConfig, u: Array):
    """in_proj + causal conv.  u: (B, L, d) -> (z, x, Bm, Cm, dt)."""
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj = jnp.einsum("bld,de->ble", u, params["in_proj"].astype(u.dtype))
    z = proj[..., :di]
    xbc_pre = proj[..., di:di + di + 2 * n]
    dt_raw = proj[..., di + di + 2 * n:]
    xbc = causal_conv1d(xbc_pre, params["conv_w"])
    x = xbc[..., :di]
    bm = xbc[..., di:di + n]
    cm = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    # xbc_pre's last (W-1) rows are exactly the rolling conv window that
    # ssm_decode_step keeps in SSMCache.conv — prefill hands decode a warm
    # window through it.
    return z, x, bm, cm, dt, xbc_pre


def ssd_chunked(x: Array, dt: Array, a: Array, bm: Array, cm: Array,
                chunk: int, init_state: Array | None = None):
    """Chunked SSD scan.  Returns (y (B,L,H,P) f32, final_state (B,H,P,N)).

    Arbitrary L is supported: the sequence is zero-padded to a chunk
    multiple (dt = 0 on padding => decay 1, state increment 0, so the final
    state and the real outputs are unaffected)."""
    B, L, H, P = x.shape
    N = bm.shape[-1]
    Q = min(chunk, L)
    L_pad = -(-L // Q) * Q
    if L_pad != L:
        pad = ((0, 0), (0, L_pad - L))
        x = jnp.pad(x, pad + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, pad + ((0, 0),))
        bm = jnp.pad(bm, pad + ((0, 0),))
        cm = jnp.pad(cm, pad + ((0, 0),))
        L_real, L = L, L_pad
    else:
        L_real = L
    nc = L // Q

    xf = x.astype(jnp.float32).reshape(B, nc, Q, H, P)
    dtc = dt.reshape(B, nc, Q, H)
    bmc = bm.astype(jnp.float32).reshape(B, nc, Q, N)
    cmc = cm.astype(jnp.float32).reshape(B, nc, Q, N)

    da = dtc * a[None, None, None, :]                      # (B,nc,Q,H) <= 0
    cum = jnp.cumsum(da, axis=2)                           # inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (B,nc,Qi,Qj,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    cb = jnp.einsum("bcin,bcjn->bcij", cmc, bmc)           # (B,nc,Q,Q)
    scores = cb[..., None] * decay * dtc[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", scores, xf)

    # per-chunk end states:  sum_j exp(cum_Q - cum_j) * dt_j * B_j ⊗ x_j
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,nc,Q,H)
    wts = decay_end * dtc                                  # (B,nc,Q,H)
    chunk_states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", wts, bmc, xf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (B,nc,H)

    def scan_fn(state, inp):
        cs, cd = inp                                       # (B,H,P,N), (B,H)
        new = state * cd[:, :, None, None] + cs
        return new, state                                  # emit state *before*

    s0 = jnp.zeros((B, H, P, N), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(chunk_states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (B,nc,H,P,N)

    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cmc, prev_states,
                       jnp.exp(cum))
    y = (y_diag + y_off).reshape(B, L, H, P)
    return y[:, :L_real], final


def ssm_block(params: dict, cfg: ModelConfig, u: Array,
              init_state: Array | None = None, *,
              return_conv_tail: bool = False):
    """Full Mamba2 block (train/prefill).  u: (B, L, d) -> (B, L, d).

    With ``return_conv_tail``, also returns the (B, W-1, conv_dim) rolling
    conv window so decode continues exactly where prefill stopped."""
    B, L, _ = u.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, x, bm, cm, dt, xbc_pre = _split_proj(params, cfg, u)
    a = -jnp.exp(params["A_log"])
    y, final = ssd_chunked(x.reshape(B, L, h, p), dt, a, bm, cm,
                           cfg.ssm_chunk, init_state)
    y = y + params["D"][None, None, :, None] \
        * x.reshape(B, L, h, p).astype(jnp.float32)
    y = y.reshape(B, L, cfg.d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.rms_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"].astype(u.dtype))
    if return_conv_tail:
        w = cfg.ssm_conv
        tail = jnp.pad(xbc_pre, ((0, 0), (w - 1, 0), (0, 0)))[:, -(w - 1):]
        return out, final, tail
    return out, final


def ssm_decode_step(params: dict, cfg: ModelConfig, u: Array,
                    cache: SSMCache):
    """One-token recurrent step.  u: (B, 1, d) -> (B, 1, d), new cache."""
    B = u.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bld,de->ble", u, params["in_proj"].astype(u.dtype))
    z = proj[..., :di]
    xbc_new = proj[..., di:di + di + 2 * n]
    dt_raw = proj[..., di + di + 2 * n:]

    # rolling causal conv window
    window = jnp.concatenate([cache.conv, xbc_new], axis=1)   # (B, W, conv)
    w = params["conv_w"]
    conv_out = jnp.einsum("bwc,cw->bc", window.astype(jnp.float32),
                          w.astype(jnp.float32))
    xbc = jax.nn.silu(conv_out)[:, None, :].astype(u.dtype)   # (B,1,conv)
    new_conv = window[:, 1:, :]

    x = xbc[..., :di].reshape(B, h, p).astype(jnp.float32)
    bm = xbc[..., di:di + n].reshape(B, n).astype(jnp.float32)
    cm = xbc[..., di + n:].reshape(B, n).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"][None, :])        # (B, h)
    a = -jnp.exp(params["A_log"])

    decay = jnp.exp(dt * a[None, :])                          # (B, h)
    state = cache.state * decay[:, :, None, None] \
        + jnp.einsum("bh,bn,bhp->bhpn", dt, bm, x)
    y = jnp.einsum("bn,bhpn->bhp", cm, state)
    y = y + params["D"][None, :, None] * x
    y = y.reshape(B, 1, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.rms_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"].astype(u.dtype))
    return out, SSMCache(state=state, conv=new_conv)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> SSMCache:
    return SSMCache(
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
    )
