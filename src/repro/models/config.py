"""Model configuration for the assigned architecture pool.

One dataclass covers all four families (dense / moe / ssm / hybrid); each
``src/repro/configs/<arch>.py`` instantiates it with the exact published
numbers and a reduced ``smoke()`` variant for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid
    num_layers: int
    d_model: int
    vocab_size: int
    # attention (unused by pure-SSM archs)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    d_ff: int = 0
    rope_theta: float = 10_000.0
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_group_size: int = 256        # dispatch-group length (tokens)
    capacity_factor: float = 1.25
    moe_impl: str = "scatter"        # scatter | dense (dense = oracle)
    router_aux_coef: float = 0.01
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_expand: int = 2
    # hybrid (Zamba2-style shared attention)
    attn_period: int = 0             # insert shared attn block every N layers
    # attention blocking: >1 = process q in chunks via lax.map so the SxS
    # logits never materialize as one HBM buffer (§Perf hillclimb #2)
    attn_q_chunks: int = 1
    # normalization / scaling
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    emb_multiplier: float = 1.0      # MiniCPM mu-P style scaling
    residual_multiplier: float = 1.0
    logit_divisor: float = 1.0
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # modality frontend: "tokens" (ids) or "embeddings" (stub frontend
    # supplies precomputed frame/patch embeddings)
    input_kind: str = "tokens"
    remat: bool = True

    # ----- derived -----------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def attention_layers(self) -> int:
        if self.family == SSM:
            return 0
        if self.family == HYBRID:
            return 0 if self.attn_period == 0 else \
                len(range(self.attn_period - 1, self.num_layers,
                          self.attn_period))
        return self.num_layers

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode (SSM/hybrid state)."""
        return self.family in (SSM, HYBRID)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    # ----- parameter / FLOP accounting (roofline §Roofline) -------------
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d                   # lm head
        n += self.num_layers * self._block_params()
        n += d                                          # final norm
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        hd = self.head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        bias = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + bias + d                   # + input norm

    def _mlp_params(self, d_ff: int) -> int:
        return 3 * self.d_model * d_ff + self.d_model  # SwiGLU + norm

    def _moe_params(self) -> int:
        return (self.num_experts * 3 * self.d_model * self.d_ff
                + self.d_model * self.num_experts      # router
                + self.d_model)                        # norm

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        g = 1                                          # single B/C group
        conv_dim = di + 2 * g * self.ssm_state
        n = d * (2 * di + 2 * g * self.ssm_state + self.ssm_heads)  # in_proj
        n += conv_dim * self.ssm_conv                  # depthwise conv
        n += self.ssm_heads * 2                        # A_log, D
        n += self.ssm_heads                            # dt_bias
        n += di                                        # gate norm
        n += di * d                                    # out_proj
        n += d                                         # input norm
        return n

    def _block_params(self) -> int:
        if self.family == DENSE:
            return self._attn_params() + self._mlp_params(self.d_ff)
        if self.family == MOE:
            return self._attn_params() + self._moe_params()
        if self.family == SSM:
            return self._ssm_params()
        if self.family == HYBRID:
            # per-layer mamba params; the shared attn+mlp block is counted
            # once (amortized here as a separate term in param_count via
            # shared_block_params()).
            return self._ssm_params()
        raise ValueError(self.family)

    def shared_block_params(self) -> int:
        if self.family != HYBRID or self.attn_period == 0:
            return 0
        return self._attn_params() + self._mlp_params(self.d_ff)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts experts)."""
        if self.family != MOE:
            return self.param_count() + self.shared_block_params()
        dense_part = self.param_count() - self.num_layers * (
            self.num_experts * 3 * self.d_model * self.d_ff)
        active_experts = self.num_layers * self.top_k * 3 * self.d_model * self.d_ff
        return dense_part + active_experts
