"""GQA attention: causal full-sequence (train/prefill) and cached decode.

The decode path can route through the flash-decoding Pallas kernel
(repro/kernels/decode_attention.py); the jnp path is the default because the
dry-run compiles for the XLA backend (kernels run interpret-only on CPU).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..sharding.hints import DP, hint
from .config import ModelConfig
from .layers import apply_rope, normal_init

Array = jax.Array


class KVCache(NamedTuple):
    k: Array       # (B, S_max, Hkv, D)
    v: Array       # (B, S_max, Hkv, D)
    length: Array  # () or (B,) int32 — tokens currently cached


def init_attention(key, cfg: ModelConfig) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    params = {
        "wq": normal_init(ks[0], (d, h * hd), scale, cfg.pdtype()),
        "wk": normal_init(ks[1], (d, hkv * hd), scale, cfg.pdtype()),
        "wv": normal_init(ks[2], (d, hkv * hd), scale, cfg.pdtype()),
        "wo": normal_init(ks[3], (h * hd, d), (h * hd) ** -0.5, cfg.pdtype()),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((h * hd,), cfg.pdtype())
        params["bk"] = jnp.zeros((hkv * hd,), cfg.pdtype())
        params["bv"] = jnp.zeros((hkv * hd,), cfg.pdtype())
    return params


def _project_qkv(params: dict, cfg: ModelConfig, x: Array, positions: Array):
    B, S, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dtype = x.dtype
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,de->bse", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,de->bse", x, params["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, hkv, hd)
    v = v.reshape(B, S, hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _causal_core(q: Array, k: Array, v: Array, cfg: ModelConfig,
                 q_chunks: int | None = None) -> Array:
    """Causal softmax attention.  q: (B,S,H,D), k/v: (B,S,Hkv,D) -> (B,S,H,D).

    SEQUENCE-PARALLEL layout (rationale in ``repro/sharding/hints.py``): q is
    sharded over 'model' on its SEQUENCE dim — always divisible, unlike
    head counts (yi-34b: 56 heads vs a 16-wide axis) — and k/v replicate
    over 'model'.  Both einsum contractions are then over unsharded dims,
    so no S x S partial sums are ever all-reduced; logits shard on the
    q-sequence dim instead.

    ``q_chunks > 1`` (hillclimb #2) processes the query sequence in blocks
    inside lax.map, so the S x S logits never exist as one HBM buffer —
    flash-attention-style blocking at the XLA level (the Pallas kernel does
    the same within VMEM on real hardware for decode).
    """
    B, S = q.shape[0], q.shape[1]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    group = h // hkv
    if q_chunks is None:
        q_chunks = cfg.attn_q_chunks
    q = hint(q, DP, "model", None, None)
    k = hint(k, DP, None, None, None)
    v = hint(v, DP, None, None, None)
    kf = k.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    def block(q_blk, pos_blk):
        """q_blk: (B, Sq, Hkv, G, D) at absolute positions pos_blk (Sq,)."""
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk.astype(jnp.float32),
                            kf) * scale
        logits = hint(logits, DP, None, None, "model", None)
        mask = pos_blk[:, None] >= jnp.arange(S)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
        return hint(out, DP, "model", None, None, None)

    qg = q.reshape(B, S, hkv, group, hd)
    if q_chunks <= 1 or S % q_chunks != 0 \
            or (S // q_chunks) % max(q_chunks, 1) == -1:
        out = block(qg, jnp.arange(S))
    else:
        blk = S // q_chunks
        qb = jnp.moveaxis(qg.reshape(B, q_chunks, blk, hkv, group, hd), 1, 0)
        # reshard: the split puts the sequence sharding on the chunk dim
        # (major); move it to each block's sequence dim so every chip works
        # on every chunk (otherwise lax.map serializes over shards)
        qb = hint(qb, None, DP, "model", None, None, None)
        pos = jnp.arange(S).reshape(q_chunks, blk)
        out = jax.lax.map(lambda args: block(*args), (qb, pos))
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, hkv, group, hd)
        out = hint(out, DP, "model", None, None, None)
    return out.reshape(B, S, h, hd)


def causal_attention(params: dict, cfg: ModelConfig, x: Array) -> Array:
    """Full causal self-attention for train/prefill.  x: (B, S, d)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = _causal_core(q, k, v, cfg).reshape(B, S, -1)
    return jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))


def decode_attention_step(params: dict, cfg: ModelConfig, x: Array,
                          cache: KVCache) -> tuple[Array, KVCache]:
    """One-token decode.  x: (B, 1, d); returns (B, 1, d) and updated cache."""
    B = x.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    positions = jnp.broadcast_to(cache.length, (B,))[:, None]
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)

    idx = jnp.broadcast_to(cache.length, (B,))
    k = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
        c, n, (i, 0, 0)))(cache.k, k_new, idx)
    v = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
        c, n, (i, 0, 0)))(cache.v, v_new, idx)
    new_len = cache.length + 1

    S = k.shape[1]
    group = h // hkv
    qg = q.reshape(B, hkv, group, hd)                     # (B, Hkv, G, D)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    valid = jnp.arange(S)[None, None, None, :] < \
        jnp.broadcast_to(new_len, (B,))[:, None, None, None]
    logits = jnp.where(valid, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v)
    out = out.reshape(B, 1, h * hd)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return out, KVCache(k=k, v=v, length=new_len)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )
