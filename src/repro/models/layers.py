"""Shared neural building blocks (pure-JAX functional style).

Parameters are nested dicts of jnp arrays; every function takes the params
subtree it owns.  Keeping the tree paths stable matters: the sharding rules
in repro/sharding/rules.py pattern-match on them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def normal_init(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def rms_norm(x: Array, weight: Array, eps: float) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    dtype = x.dtype
    gate = jnp.einsum("...d,df->...f", x, w_gate.astype(dtype))
    up = jnp.einsum("...d,df->...f", x, w_up.astype(dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(gate) * up,
                      w_down.astype(dtype))


def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model ** -0.5
    scale_out = d_ff ** -0.5
    return {
        "gate": normal_init(k1, (d_model, d_ff), scale_in, dtype),
        "up": normal_init(k2, (d_model, d_ff), scale_in, dtype),
        "down": normal_init(k3, (d_ff, d_model), scale_out, dtype),
    }


def causal_conv1d(x: Array, weight: Array) -> Array:
    """Depthwise causal conv over time.  x: (B, L, C); weight: (C, W)."""
    w = weight.shape[-1]
    pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros(x.shape, jnp.float32)
    for i in range(w):
        out = out + pad[:, i:i + x.shape[1], :].astype(jnp.float32) \
            * weight[:, i][None, None, :].astype(jnp.float32)
    return jax.nn.silu(out).astype(x.dtype)
