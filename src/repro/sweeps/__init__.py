"""Batched sweep runtime: scenario fleets as compiled batches (DESIGN.md §12).

``SweepSpec → run_sweep → SweepResult`` for the partition game, plus the
stacking/reduction helpers the batched DES entry point
(:func:`repro.des.engine.run_simulation_batch`) shares.
"""
from ..core.batch import (  # noqa: F401
    batch_size,
    refine_batched,
    refine_simultaneous_batched,
    refine_traced_batched,
    shard_across_devices,
    stack_problems,
    stack_pytrees,
    unstack_pytree,
)
from ..des.engine import run_simulation_batch  # noqa: F401
from ..des.scenarios import pad_segments, stack_schedules  # noqa: F401
from .metrics import load_cv, load_cv_trace, time_averaged_cv  # noqa: F401
from .runtime import (  # noqa: F401
    SweepCase,
    SweepResult,
    SweepSpec,
    make_spec,
    run_sweep,
)
