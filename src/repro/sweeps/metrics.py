"""Reduction helpers for sweep results (DESIGN.md §12.5).

Host-side (numpy) post-processing of batched runs: the sweep runtime
returns per-element device arrays; these helpers reduce them to the
statistics the paper's claims are about (cross-machine load CV, CV
descent traces, time-averaged DES backlog CV).
"""
from __future__ import annotations

import numpy as np


def load_cv(loads, speeds) -> np.ndarray:
    """Cross-machine coefficient of variation of the weighted loads
    ``L_k / w_k`` (the Eq.-8 balance quantity) over the last axis.

    Accepts ``(K,)`` or ``(..., K)``; returns a scalar / ``(...,)`` array.
    0 means perfectly balanced for the machines' speeds.
    """
    weighted = np.asarray(loads, np.float64) / np.asarray(speeds, np.float64)
    mean = weighted.mean(axis=-1)
    std = weighted.std(axis=-1)
    return std / np.maximum(mean, 1e-12)


def load_cv_trace(node_weights, speeds, assignment0, trace) -> np.ndarray:
    """(T,) weighted-load CV after every turn of a ``Trace``.

    Replays the move sequence on host: starting from ``assignment0``'s
    machine loads, each ``moved`` turn shifts ``b[node]`` from ``source``
    to ``dest``.  O(T + N) numpy — no device work, usable on any number
    of sweep elements.  Turns after convergence repeat the final value
    (the trace's no-op turns).
    """
    b = np.asarray(node_weights, np.float64)
    w = np.asarray(speeds, np.float64)
    r0 = np.asarray(assignment0)
    k = w.shape[0]
    loads = np.zeros(k)
    np.add.at(loads, r0, b)
    moved = np.asarray(trace.moved)
    node = np.asarray(trace.node)
    src = np.asarray(trace.source)
    dst = np.asarray(trace.dest)
    out = np.empty(moved.shape[0])
    for t in range(moved.shape[0]):
        if moved[t]:
            loads[src[t]] -= b[node[t]]
            loads[dst[t]] += b[node[t]]
        out[t] = load_cv(loads, w)
    return out


def time_averaged_cv(trace: np.ndarray) -> float:
    """Time-averaged cross-machine CV of a ``(T, K)`` DES load trace
    (e.g. ``DESState.trace_wload`` rows up to ``trace_ptr``), counting
    only active ticks (rows with nonzero mean) — the summary statistic
    of ``benchmarks/dynamics_bench.py``.
    """
    trace = np.asarray(trace, np.float64)
    if trace.size == 0:
        return 0.0
    mean = trace.mean(axis=1)
    active = mean > 1e-6
    if not active.any():
        return 0.0
    std = trace[active].std(axis=1)
    return float(np.mean(std / np.maximum(mean[active], 1e-6)))
