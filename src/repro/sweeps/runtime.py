"""SweepSpec → SweepResult: scenario fleets as compiled batches (DESIGN.md §12).

A *sweep* is a flat list of :class:`SweepCase` cells — (problem, initial
assignment, framework, theta) plus a free-form label — executed by
:func:`run_sweep` as a handful of ``jax.vmap``-compiled programs instead
of a Python loop.  Cases are grouped by their compile-time key (mode,
framework, N, K, theta present or not); each group stacks into one
batched pytree and runs through the corresponding
:mod:`repro.core.batch` entry point, so B same-shaped cells cost one
compile + one device program however many there are.  Per-element
results are the looped results bitwise (moves/assignments/loads/gains;
carried potentials to the usual ≤1e-3 relative budget — DESIGN.md
§12.2), which is what lets ``benchmarks/`` adopt the batched path
without renegotiating any of their gates.

Batched DES scenario fleets are the same idea one level up — see
:func:`repro.des.engine.run_simulation_batch` and
:func:`repro.des.scenarios.stack_schedules` (DESIGN.md §12.4).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from ..core import costs
from ..core.batch import (problem_shape_key, refine_batched,
                          refine_simultaneous_batched, refine_sweeps_batched,
                          refine_traced_batched, stack_problems,
                          unstack_pytree)
from ..core.problem import PartitionProblem
from ..core.refine import DEFAULT_TOL, DissatFn, RefineResult
from . import metrics

Array = jax.Array

MODES = ("refine", "traced", "simultaneous", "multimove")


@dataclasses.dataclass(frozen=True)
class SweepCase:
    """One scenario cell: a problem instance and how to refine it.

    ``theta`` is the per-node hysteresis threshold (DESIGN.md §11):
    ``None``, a scalar, or an (N,) array.  ``label`` is free-form
    metadata carried through to :meth:`SweepResult.summary`."""
    problem: PartitionProblem
    assignment: Any                   # (N,) int
    framework: str = costs.C_FRAMEWORK
    theta: Any = None
    label: str = ""


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A sweep: cases plus the (static) execution knobs shared by all.

    ``mode`` selects the refinement entry point: ``"refine"``
    (while-loop to convergence), ``"traced"`` (fixed-length scan with
    per-turn move/potential traces), ``"simultaneous"`` (§4.5 sweep
    mode) or ``"multimove"`` (the probabilistic multi-move sweeps of
    DESIGN.md §17 — :func:`repro.core.batch.refine_sweeps_batched`).
    ``use_kernel`` routes the per-turn reduction through the fused
    Pallas batch-grid kernel (DESIGN.md §12.3; ``"refine"`` mode only —
    the traced loop has no ``dissat_fn`` seam).

    The three multimove knobs — ``moves_per_machine`` (``None`` =
    unbounded), ``move_prob`` and ``epsilon`` — plus ``seed`` (each
    case's acceptance-coin key derives as
    ``fold_in(PRNGKey(seed), case_index)``, so fleet results are
    reproducible and independent of grouping) apply to
    ``mode="multimove"`` only; other modes reject non-default values."""
    cases: tuple[SweepCase, ...]
    mode: str = "traced"
    max_turns: int = 512
    tol: float = DEFAULT_TOL
    use_kernel: bool = False
    moves_per_machine: int | None = 1
    move_prob: float = 1.0
    epsilon: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown sweep mode {self.mode!r}; "
                             f"expected one of {MODES}")
        if self.use_kernel and self.mode != "refine":
            raise ValueError("use_kernel applies to mode='refine' only "
                             "(the traced/simultaneous loops have no "
                             "dissat_fn seam)")
        if self.mode != "multimove" and (
                self.moves_per_machine != 1 or self.move_prob != 1.0
                or self.epsilon != 0.0 or self.seed != 0):
            raise ValueError("moves_per_machine/move_prob/epsilon/seed "
                             "apply to mode='multimove' only")


def make_spec(cases: Sequence[SweepCase], **kwargs) -> SweepSpec:
    """Convenience constructor accepting any iterable of cases."""
    return SweepSpec(cases=tuple(cases), **kwargs)


@lru_cache(maxsize=None)
def _kernel_dissat_fn() -> DissatFn:
    """One shared fused-kernel adapter so every sweep reuses the same jit
    cache entry (``dissat_fn`` is a static argument of ``refine``)."""
    from ..kernels.ops import make_aggregate_dissat_fn
    return make_aggregate_dissat_fn()


def _group_key(case: SweepCase):
    """Compile-time key: cases sharing it stack into one vmap program.

    ``problem_shape_key`` covers representation + static dims — for
    sparse problems that adds the padded edge count and ``max_degree``
    (DESIGN.md §13.4), so sparse fleets stack and vmap exactly like
    dense ones as long as their padded edge shapes line up."""
    return (case.framework, case.theta is None,
            problem_shape_key(case.problem))


def _stack_group(cases: list[SweepCase]):
    problems = stack_problems([c.problem for c in cases])
    n = cases[0].problem.num_nodes
    r0 = jnp.stack([jnp.broadcast_to(jnp.asarray(c.assignment, jnp.int32),
                                     (n,)) for c in cases])
    if cases[0].theta is None:
        theta = None
    else:
        theta = jnp.stack([
            jnp.broadcast_to(jnp.asarray(c.theta, jnp.float32), (n,))
            for c in cases])
    return problems, r0, theta


def run_sweep(spec: SweepSpec, recorder=None) -> "SweepResult":
    """Execute a sweep: one compiled batched program per case group.

    Groups are keyed on (framework, theta-present, problem shape key) —
    the shape key being (representation, N, K) plus, for sparse
    problems, (padded E, max_degree); everything else — adjacency or
    edge list, weights, speeds, mu, theta values, initial assignments —
    varies freely inside a group's single ``vmap``.
    Returns a :class:`SweepResult` with per-case results and traces in
    the order of ``spec.cases``.

    ``recorder`` (a :class:`repro.obs.Recorder`, DESIGN.md §14) opts
    into telemetry: each group's compile+execute is a timed ``phase``
    span, every case closes with one ``element`` event (its headline
    summary stats), traced-mode cases additionally stream their
    per-turn events tagged with the case index, and the run ends with
    fleet totals.  ``recorder=None`` runs the identical programs.
    """
    ncases = len(spec.cases)
    groups: dict[tuple, list[int]] = {}
    for i, case in enumerate(spec.cases):
        groups.setdefault(_group_key(case), []).append(i)

    run = None
    if recorder is not None:
        run = recorder.new_run("sweep", mode=spec.mode, cases=ncases,
                               groups=len(groups),
                               use_kernel=spec.use_kernel)

    results: list = [None] * ncases
    traces: list = [None] * ncases
    for key, idxs in groups.items():
        cases = [spec.cases[i] for i in idxs]
        problems, r0, theta = _stack_group(cases)
        framework = key[0]

        def _run_group():
            if spec.mode == "refine":
                dissat_fn = _kernel_dissat_fn() if spec.use_kernel else None
                out = refine_batched(problems, r0, framework,
                                     max_turns=spec.max_turns, tol=spec.tol,
                                     dissat_fn=dissat_fn, theta=theta)
                return out, None
            if spec.mode == "traced":
                return refine_traced_batched(problems, r0, framework,
                                             max_turns=spec.max_turns,
                                             tol=spec.tol, theta=theta)
            if spec.mode == "multimove":
                keys = None
                if spec.move_prob < 1.0:
                    # per-CASE keys from the global case index, so a
                    # case's coins do not depend on how the fleet groups
                    base = jax.random.PRNGKey(spec.seed)
                    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(
                        jnp.asarray(idxs, jnp.int32))
                return refine_sweeps_batched(
                    problems, r0, framework, max_sweeps=spec.max_turns,
                    tol=spec.tol, theta=theta,
                    moves_per_machine=spec.moves_per_machine,
                    move_prob=spec.move_prob, epsilon=spec.epsilon,
                    keys=keys)
            return refine_simultaneous_batched(problems, r0, framework,
                                               max_sweeps=spec.max_turns,
                                               tol=spec.tol, theta=theta)

        if recorder is None:
            out, tr = _run_group()
        else:
            n, k = cases[0].problem.num_nodes, cases[0].problem.num_machines
            label = f"sweep.{spec.mode}[{framework} n={n} k={k} B={len(idxs)}]"
            with recorder.phase(label, run):
                out, tr = _run_group()
                jax.block_until_ready(out)
        for j, i in enumerate(idxs):
            results[i] = unstack_pytree(out, j)
            traces[i] = None if tr is None else unstack_pytree(tr, j)
    result = SweepResult(spec=spec, results=results, traces=traces)
    if recorder is not None:
        if spec.mode == "traced":
            for i, (case, tr) in enumerate(zip(spec.cases, traces)):
                recorder.record_trace(run, tr, case.problem.node_weights,
                                      case.problem.num_machines, batch=i)
        for i, row in enumerate(result.summary()):
            recorder.emit("element", run, batch=i, **row)
        recorder.emit("run_end", run,
                      num_moves=int(result.moves.sum()),
                      num_turns=int(result.turns.max()) if ncases else 0,
                      converged=bool(result.converged.all()))
    return result


@dataclasses.dataclass
class SweepResult:
    """Per-case outcomes of a sweep, ordered like ``spec.cases``.

    ``results[i]`` is case i's :class:`~repro.core.refine.RefineResult`;
    ``traces[i]`` is its ``Trace`` (traced mode), its
    ``(c0s, ct0s, active)`` per-sweep potentials (simultaneous and
    multimove modes) or ``None`` (refine mode).  The methods below reduce across the fleet
    (DESIGN.md §12.5)."""
    spec: SweepSpec
    results: list[RefineResult]
    traces: list

    def __len__(self) -> int:
        return len(self.results)

    @property
    def moves(self) -> np.ndarray:
        return np.asarray([int(r.num_moves) for r in self.results])

    @property
    def turns(self) -> np.ndarray:
        return np.asarray([int(r.num_turns) for r in self.results])

    @property
    def converged(self) -> np.ndarray:
        return np.asarray([bool(r.converged) for r in self.results])

    @property
    def assignments(self) -> np.ndarray:
        """(B, N) final assignments (cases must share N to stack)."""
        return np.stack([np.asarray(r.assignment) for r in self.results])

    def load_cv(self) -> np.ndarray:
        """(B,) final cross-machine CV of L_k/w_k per case."""
        return np.asarray([
            float(metrics.load_cv(np.asarray(r.loads),
                                  np.asarray(c.problem.speeds)))
            for r, c in zip(self.results, self.spec.cases)])

    def load_cv_traces(self) -> list[np.ndarray]:
        """Per-case (T,) CV-descent traces (traced mode only)."""
        if self.spec.mode != "traced":
            raise ValueError("CV traces need mode='traced'")
        return [
            metrics.load_cv_trace(c.problem.node_weights, c.problem.speeds,
                                  c.assignment, tr)
            for c, tr in zip(self.spec.cases, self.traces)]

    def final_potentials(self) -> tuple[np.ndarray, np.ndarray]:
        """(B,) final (C_0, Ct_0) per case.

        Traced/simultaneous modes read the carried per-turn potentials'
        last entry; refine mode evaluates the closed forms from the
        final assignments (one vectorized pass)."""
        if self.spec.mode == "traced":
            return (np.asarray([float(np.asarray(t.c0)[-1])
                                for t in self.traces]),
                    np.asarray([float(np.asarray(t.ct0)[-1])
                                for t in self.traces]))
        if self.spec.mode in ("simultaneous", "multimove"):
            return (np.asarray([float(np.asarray(t[0])[-1])
                                for t in self.traces]),
                    np.asarray([float(np.asarray(t[1])[-1])
                                for t in self.traces]))
        c0 = [float(costs.global_cost_c0(c.problem, r.assignment))
              for c, r in zip(self.spec.cases, self.results)]
        ct0 = [float(costs.global_cost_ct0(c.problem, r.assignment))
               for c, r in zip(self.spec.cases, self.results)]
        return np.asarray(c0), np.asarray(ct0)

    def summary(self) -> list[dict]:
        """One dict per case: label/framework plus the headline stats."""
        cv = self.load_cv()
        c0, ct0 = self.final_potentials()
        return [{
            "label": c.label,
            "framework": c.framework,
            "moves": int(m),
            "converged": bool(cvg),
            "load_cv": float(v),
            "c0": float(a),
            "ct0": float(b),
        } for c, m, cvg, v, a, b in zip(
            self.spec.cases, self.moves, self.converged, cv, c0, ct0)]
