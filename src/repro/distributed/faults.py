"""Deterministic fault injection for the distributed refinement stack
(DESIGN.md §15).

A :class:`FaultPlan` is a seeded, host-precomputed schedule of shard
failures, exchange losses/duplications, and aggregate corruption, traced
alongside the candidate-exchange protocol as plain device arrays.  Every
degraded-mode decision the drivers make — which shards are quarantined,
when self-repair fires, how many wire bytes the retries cost — is a pure
function of the plan, derived once in :func:`make_fault_plan`.  That
determinism is what makes the two hard contracts checkable:

* **Bitwise fault-free**: ``fault_plan=None`` dispatches to the
  unmodified drivers (same jit cache entry); a :func:`zero_fault_plan`
  pushed through the faulty drivers is *also* bitwise identical, because
  degraded election with zero staleness is decision-equivalent to
  :func:`repro.distributed.protocol.elect` and every repair is guarded.
* **Recover or raise**: after the run, the carried aggregate state is
  audited against a from-scratch rebuild of the final assignment.  Alive
  shards self-heal to within ``DegradedMode.repair_tol``; a shard still
  down at the end raises :class:`DeadShardError`, and any residual drift
  above the budget raises :class:`RecoveryFailedError` — never a silent
  divergence.

Fault semantics per round ``r`` and shard ``s``:

``down[r, s]``
    The shard is dead this round: it contributes no candidate and misses
    the winner broadcast (its carried block aggregate goes stale).
``omit[r, s]``
    The shard misses this round's winner broadcast only (stale
    aggregate, but its own candidate still competes).
``lost[r, s]``
    Number of failed attempts to deliver the shard's candidate.  Up to
    ``DegradedMode.max_retries`` retries re-send it; beyond that the
    round proceeds without the candidate (bounded timeout, no deadlock).
``dup[r, s]``
    The candidate is delivered twice; the duplicate is dropped by the
    controller but still costs wire bytes.
``corrupt[r, s]`` / ``corrupt_col`` / ``corrupt_val``
    Column ``corrupt_col`` of the shard's carried block aggregate is
    overwritten with ``corrupt_val`` (possibly NaN) at round start.

Staleness follows Adolphs & Berenbrink (arXiv:1109.6925): selfish load
balancing still converges when players act on information up to a
bounded number of rounds old, provided moves clear a threshold that
grows with the staleness.  ``lag[r, s]`` counts missed winner broadcasts
since the last repair; a shard may keep proposing moves while ``lag <=
DegradedMode.max_staleness`` (its acceptance threshold rises by
``stale_penalty`` per stale round), and is quarantined beyond that until
the repair path resynchronizes it.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from . import protocol

#: bytes per replayed winner record when a repair catches a shard up from
#: bounded staleness: (node i32, dest i32, weight f32) — enough to replay
#: the missed rank-1 aggregate updates against the shard's own row block.
REPLAY_ENTRY_BYTES = 12

#: fixed header charged per full-resync repair (round id) on top of the
#: fresh assignment broadcast (4 bytes per node).
RESYNC_HEADER_BYTES = 4


class FaultToleranceError(RuntimeError):
    """Base class for loud fault-layer failures; carries the report."""

    def __init__(self, message: str, report: "FaultReport | None" = None):
        super().__init__(message)
        self.report = report


class DeadShardError(FaultToleranceError):
    """The run ended while a shard was still down — its block aggregate
    could not be repaired, so the final carried state is untrusted."""


class RecoveryFailedError(FaultToleranceError):
    """Post-run audit found carried state further than the drift budget
    from the recompute oracle even after repair — a fault-layer bug."""


@dataclasses.dataclass(frozen=True)
class DegradedMode:
    """Static degraded-mode protocol parameters (hashable: jit-static).

    ``max_retries``
        Bounded retry budget per candidate exchange; a message lost more
        times than this is simply absent from the round (timeout).
    ``max_staleness``
        Bounded-staleness window S: a shard whose aggregate is up to S
        winner broadcasts old keeps participating; beyond S it is
        quarantined until repaired (1109.6925 licenses the window, not
        unbounded staleness).
    ``stale_penalty``
        Acceptance-threshold increment per stale round: a shard with lag
        L only proposes moves with gain > tol + L*stale_penalty, the
        S-dependent threshold from the bounded-staleness analysis.
    ``repair_every``
        Periodic repair cadence (rounds); repair also fires immediately
        when a shard's lag exceeds ``max_staleness`` and at the horizon.
    ``repair_tol``
        Per-column absolute deviation above which a repair replaces the
        carried column with the recompute oracle's (NaN always fails).
    """

    max_retries: int = 2
    max_staleness: int = 4
    stale_penalty: float = 0.05
    repair_every: int = 16
    repair_tol: float = 1e-3


DEFAULT_DEGRADED = DegradedMode()


class FaultPlan(NamedTuple):
    """Seeded fault schedule + host-derived degraded-mode consequences.

    All per-shard arrays have shape ``(R + 1, num_shards)`` where ``R``
    is the requested horizon; the final row is all-clear so drivers can
    index ``min(round, R)`` and runs that outlive the fault horizon see
    a healthy cluster.  ``clear`` has shape ``(R + 1,)``.

    Raw schedule: ``down``, ``omit``, ``lost``, ``dup``, ``corrupt``,
    ``corrupt_col``, ``corrupt_val``.  Derived (pure functions of the
    raw schedule + :class:`DegradedMode`, precomputed so the wire ledger
    and the traced drivers agree byte-exactly): ``delivered`` (candidate
    arrives within the retry budget), ``retries`` (paid re-sends),
    ``lag`` (staleness at round start), ``quarantined`` (lag exceeded
    the bounded-staleness window), ``repair`` (self-repair fires at this
    round's end), ``repair_bytes`` (wire cost of that repair), and
    ``clear`` (no fault effect is active anywhere — idle/convergence
    counting is only allowed on clear rounds).
    """

    down: jax.Array        # (R+1, S) bool
    omit: jax.Array        # (R+1, S) bool
    lost: jax.Array        # (R+1, S) int32
    dup: jax.Array         # (R+1, S) bool
    corrupt: jax.Array     # (R+1, S) bool
    corrupt_col: jax.Array  # (R+1, S) int32
    corrupt_val: jax.Array  # (R+1, S) float32
    delivered: jax.Array   # (R+1, S) bool
    retries: jax.Array     # (R+1, S) int32
    lag: jax.Array         # (R+1, S) int32
    quarantined: jax.Array  # (R+1, S) bool
    repair: jax.Array      # (R+1, S) bool
    repair_bytes: jax.Array  # (R+1, S) int32
    clear: jax.Array       # (R+1,) bool

    @property
    def horizon(self) -> int:
        """Last (all-clear) row index == the requested num_rounds."""
        return self.down.shape[0] - 1

    @property
    def num_shards(self) -> int:
        return self.down.shape[1]


class FaultOutcome(NamedTuple):
    """Device-side scalars the faulty drivers return for the audit."""

    final_drift: jax.Array     # f32: pre-repair max |carried - oracle|
    post_drift: jax.Array      # f32: residual after the final repair
    dead: jax.Array            # bool: some shard down at the last round
    repairs: jax.Array         # int32: in-loop repair rounds executed
    repaired_cols: jax.Array   # int32: columns replaced (in-loop + final)
    max_repair_drift: jax.Array  # f32: worst pre-repair drift seen


class FaultReport(NamedTuple):
    """Host-side recovery verdict built by :func:`build_report`."""

    recovered: bool
    dead: bool                 # some shard was still down at run end
    recovery_drift: float      # residual carried-vs-oracle drift
    pre_repair_drift: float    # worst drift before the final repair
    max_repair_drift: float    # worst drift any in-loop repair healed
    repairs: int
    repaired_cols: int
    retries: int
    dups: int
    down_rounds: int
    stale_rounds: int
    quarantined_rounds: int
    recovery_round: int | None  # first clear round after the last fault
    rounds: int


def _derive(down: np.ndarray, omit: np.ndarray, lost: np.ndarray,
            dup: np.ndarray, corrupt: np.ndarray, degraded: DegradedMode,
            num_nodes: int) -> dict[str, np.ndarray]:
    """Roll the degraded-mode state machine forward on the host.

    The drivers never decide *when* staleness accrues or repair fires —
    they read it from these arrays — so lag must not depend on anything
    data-dependent (like whether a round's winner actually moved).  A
    missed broadcast counts as one stale round regardless; that makes
    the schedule, and therefore the retry/repair wire ledger, exact.
    """
    rounds, shards = down.shape
    delivered = ~down & (lost <= degraded.max_retries)
    retries = np.minimum(lost, degraded.max_retries).astype(np.int32)
    lag = np.zeros((rounds, shards), np.int32)
    quarantined = np.zeros((rounds, shards), bool)
    repair = np.zeros((rounds, shards), bool)
    repair_bytes = np.zeros((rounds, shards), np.int32)
    tainted = np.zeros((rounds, shards), bool)
    pending_corrupt = np.zeros(shards, bool)
    cur_lag = np.zeros(shards, np.int32)
    for r in range(rounds):
        lag[r] = cur_lag
        quarantined[r] = cur_lag > degraded.max_staleness
        tainted[r] = pending_corrupt | corrupt[r]
        lag_end = cur_lag + (down[r] | omit[r]).astype(np.int32)
        pend = pending_corrupt | corrupt[r]
        want = (lag_end > 0) | pend
        boundary = (((r + 1) % degraded.repair_every == 0)
                    | (lag_end > degraded.max_staleness)
                    | (r == rounds - 1))
        fires = want & boundary & ~down[r]
        repair[r] = fires
        full = lag_end > degraded.max_staleness
        repair_bytes[r] = np.where(
            fires,
            np.where(full, 4 * num_nodes + RESYNC_HEADER_BYTES,
                     REPLAY_ENTRY_BYTES * lag_end),
            0).astype(np.int32)
        cur_lag = np.where(fires, 0, lag_end).astype(np.int32)
        pending_corrupt = pend & ~fires
    clear = (delivered & ~down & ~quarantined & (lag == 0)
             & ~tainted).all(axis=1)
    return dict(delivered=delivered, retries=retries, lag=lag,
                quarantined=quarantined, repair=repair,
                repair_bytes=repair_bytes, clear=clear)


def _assemble(down, omit, lost, dup, corrupt, corrupt_col, corrupt_val,
              degraded: DegradedMode, num_nodes: int) -> FaultPlan:
    """Derive consequences, append the all-clear horizon row, to device."""
    derived = _derive(down, omit, lost, dup, corrupt, degraded, num_nodes)
    shards = down.shape[1]

    def pad(a, fill):
        tail = np.full((1,) + a.shape[1:], fill, a.dtype)
        return np.concatenate([a, tail], axis=0)

    return FaultPlan(
        down=jnp.asarray(pad(down, False)),
        omit=jnp.asarray(pad(omit, False)),
        lost=jnp.asarray(pad(lost.astype(np.int32), 0)),
        dup=jnp.asarray(pad(dup, False)),
        corrupt=jnp.asarray(pad(corrupt, False)),
        corrupt_col=jnp.asarray(pad(corrupt_col.astype(np.int32), 0)),
        corrupt_val=jnp.asarray(pad(corrupt_val.astype(np.float32), 0.0)),
        delivered=jnp.asarray(pad(derived["delivered"], True)),
        retries=jnp.asarray(pad(derived["retries"], 0)),
        lag=jnp.asarray(pad(derived["lag"], 0)),
        quarantined=jnp.asarray(pad(derived["quarantined"], False)),
        repair=jnp.asarray(pad(derived["repair"], False)),
        repair_bytes=jnp.asarray(pad(derived["repair_bytes"], 0)),
        clear=jnp.asarray(np.concatenate(
            [derived["clear"], np.ones(1, bool)])),
        )


def make_fault_plan(num_rounds: int, num_shards: int, seed: int = 0, *,
                    degraded: DegradedMode | None = None,
                    p_down: float = 0.0,
                    down_length: tuple[int, int] = (2, 5),
                    p_omit: float = 0.0,
                    p_lost: float = 0.0, max_lost: int = 3,
                    p_dup: float = 0.0,
                    p_corrupt: float = 0.0, corrupt_scale: float = 100.0,
                    nan_frac: float = 0.25,
                    num_machines: int = 1,
                    num_nodes: int = 0) -> FaultPlan:
    """Draw a seeded fault schedule and derive its degraded-mode arrays.

    ``p_down`` starts a contiguous outage of ``down_length`` rounds per
    eligible round; the other probabilities are per (round, shard).
    ``num_machines`` bounds ``corrupt_col``; ``num_nodes`` prices the
    full-resync repair of a long outage in the wire ledger.
    """
    dm = degraded or DEFAULT_DEGRADED
    rng = np.random.default_rng(seed)
    rounds, shards = int(num_rounds), int(num_shards)
    down = np.zeros((rounds, shards), bool)
    for s in range(shards):
        r = 0
        while r < rounds:
            if rng.random() < p_down:
                length = int(rng.integers(down_length[0],
                                          down_length[1] + 1))
                down[r:r + length, s] = True
                r += length
            else:
                r += 1
    omit = (rng.random((rounds, shards)) < p_omit) & ~down
    lost = np.where(rng.random((rounds, shards)) < p_lost,
                    rng.integers(1, max_lost + 1, (rounds, shards)),
                    0).astype(np.int32)
    lost = np.where(down, 0, lost)  # a down shard sends nothing at all
    dup = (rng.random((rounds, shards)) < p_dup) & ~down
    corrupt = rng.random((rounds, shards)) < p_corrupt
    corrupt_col = rng.integers(0, max(1, num_machines), (rounds, shards))
    corrupt_val = rng.uniform(-corrupt_scale, corrupt_scale,
                              (rounds, shards)).astype(np.float32)
    corrupt_val = np.where(rng.random((rounds, shards)) < nan_frac,
                           np.float32(np.nan), corrupt_val)
    return _assemble(down, omit, lost, dup, corrupt, corrupt_col,
                     corrupt_val, dm, num_nodes)


def zero_fault_plan(num_rounds: int, num_shards: int,
                    degraded: DegradedMode | None = None) -> FaultPlan:
    """An all-clear plan: pushing it through the faulty drivers must be
    bitwise identical to ``fault_plan=None`` (pinned by tests)."""
    return make_fault_plan(num_rounds, num_shards, seed=0,
                           degraded=degraded)


def plan_row(plan: FaultPlan, t) -> FaultPlan:
    """Index round ``t`` (clamped to the all-clear horizon row)."""
    idx = jnp.minimum(t, plan.horizon)
    return jax.tree.map(lambda a: a[idx], plan)


def message_bytes(*, traced: bool, simultaneous: bool,
                  num_machines: int) -> int:
    """Size of one shard's candidate message for retry/dup accounting.

    Sequential exchanges carry one Candidate (plus the 8-byte potential
    deltas on the traced path — faulty drivers are incremental-only);
    sweep exchanges carry the shard's K-candidate block.  Retries only
    re-send the candidate payload, not the per-round partial reductions.
    """
    if simultaneous:
        return num_machines * protocol.CANDIDATE_BYTES
    return protocol.CANDIDATE_BYTES + (
        protocol.TRACE_PARTIAL_BYTES if traced else 0)


def round_extra_bytes(row: FaultPlan, per_message_bytes: int) -> jax.Array:
    """Device-side extra wire for one round: re-sends + repair traffic.

    The drivers accumulate this under ``measure_wire`` so the measured
    payload includes fault traffic; :func:`plan_extra_bytes` computes the
    identical sum host-side for the ledger, and ``accounting.reconcile``
    demands they agree byte-exactly.
    """
    resend = (row.retries + row.dup.astype(jnp.int32)) * per_message_bytes
    return jnp.sum(resend + row.repair_bytes).astype(jnp.int32)


def plan_extra_bytes(plan: FaultPlan, rounds: int,
                     per_message_bytes: int) -> int:
    """Host-side total fault wire bytes over the executed rounds."""
    idx = np.minimum(np.arange(int(rounds)), plan.horizon)
    retries = np.asarray(plan.retries)[idx]
    dups = np.asarray(plan.dup)[idx].astype(np.int64)
    repair = np.asarray(plan.repair_bytes)[idx]
    return int(((retries + dups) * per_message_bytes + repair).sum())


def build_report(plan: FaultPlan, outcome: FaultOutcome, rounds: int, *,
                 budget: float = 1e-3,
                 raise_on_failure: bool = True) -> FaultReport:
    """Turn the device audit + plan into the recovery verdict.

    Raises :class:`DeadShardError` if the run ended inside an outage and
    :class:`RecoveryFailedError` if residual drift exceeds the budget —
    the "fails loudly, never silently diverges" half of the contract.
    """
    rounds = int(rounds)
    idx = np.minimum(np.arange(rounds), plan.horizon)
    down = np.asarray(plan.down)[idx]
    lag = np.asarray(plan.lag)[idx]
    quarantined = np.asarray(plan.quarantined)[idx]
    clear = np.asarray(plan.clear)[idx]
    unclear = np.nonzero(~clear)[0]
    recovery_round = None
    if unclear.size:
        last = int(unclear[-1])
        recovery_round = last + 1 if last + 1 < rounds else None
    dead = bool(outcome.dead)
    post = float(outcome.post_drift)
    report = FaultReport(
        recovered=not dead and post <= budget,
        dead=dead,
        recovery_drift=post,
        pre_repair_drift=float(outcome.final_drift),
        max_repair_drift=float(outcome.max_repair_drift),
        repairs=int(outcome.repairs),
        repaired_cols=int(outcome.repaired_cols),
        retries=int(np.asarray(plan.retries)[idx].sum()),
        dups=int(np.asarray(plan.dup)[idx].sum()),
        down_rounds=int(down.any(axis=1).sum()),
        stale_rounds=int((lag > 0).any(axis=1).sum()),
        quarantined_rounds=int(quarantined.any(axis=1).sum()),
        recovery_round=recovery_round,
        rounds=rounds,
        )
    if raise_on_failure:
        raise_if_failed(report, budget=budget)
    return report


def raise_if_failed(report: FaultReport, *,
                    budget: float = 1e-3) -> FaultReport:
    """The loud half of the recover-or-raise contract."""
    if report.dead:
        raise DeadShardError(
            f"run ended after {report.rounds} rounds with a shard still "
            f"down; carried drift {report.pre_repair_drift:g} cannot be "
            "repaired", report)
    if not report.recovered:
        raise RecoveryFailedError(
            f"residual carried-state drift {report.recovery_drift:g} "
            f"exceeds the {budget:g} recovery budget after repair", report)
    return report


def emit_fault_events(recorder, run: str, plan: FaultPlan, rounds: int,
                      repair_drift=None, repaired_cols=None,
                      repaired=None) -> None:
    """Replay the plan's executed rounds into fault telemetry events.

    ``repair_drift``/``repaired_cols``/``repaired`` are the per-round
    side outputs of the traced faulty driver when available; without
    them repair events carry the plan's schedule only.
    """
    rounds = int(rounds)
    idx = np.minimum(np.arange(rounds), plan.horizon)
    down = np.asarray(plan.down)[idx]
    omit = np.asarray(plan.omit)[idx]
    lost = np.asarray(plan.lost)[idx]
    dup = np.asarray(plan.dup)[idx]
    corrupt = np.asarray(plan.corrupt)[idx]
    delivered = np.asarray(plan.delivered)[idx]
    retries = np.asarray(plan.retries)[idx]
    lag = np.asarray(plan.lag)[idx]
    quarantined = np.asarray(plan.quarantined)[idx]
    repair = np.asarray(plan.repair)[idx]
    drift = (np.asarray(repair_drift)
             if repair_drift is not None else None)
    cols = (np.asarray(repaired_cols)
            if repaired_cols is not None else None)
    did = np.asarray(repaired) if repaired is not None else None
    for t in range(rounds):
        for s in range(plan.num_shards):
            for name, hit in (("down", down[t, s]),
                              ("omit", omit[t, s]),
                              ("dup", dup[t, s]),
                              ("corrupt", corrupt[t, s])):
                if hit:
                    recorder.emit("fault_injected", run, t=t, shard=s,
                                  fault=name)
            if lost[t, s]:
                recorder.emit("exchange_retry", run, t=t, shard=s,
                              attempts=int(retries[t, s]),
                              delivered=bool(delivered[t, s]))
            if lag[t, s] or quarantined[t, s]:
                recorder.emit("staleness", run, t=t, shard=s,
                              lag=int(lag[t, s]),
                              quarantined=bool(quarantined[t, s]))
        if repair[t].any() and (did is None or did[t]):
            recorder.emit(
                "repair", run, t=t, action="column",
                drift=float(drift[t]) if drift is not None else None,
                cols=int(cols[t]) if cols is not None else None)
