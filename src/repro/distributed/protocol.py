"""The O(K) aggregate-exchange protocol (DESIGN.md §9.2).

Per sequential turn (acting machine m), each shard ships exactly one
:class:`Candidate` — 16 bytes: its most dissatisfied m-owned node, that
node's best-response machine, the dissatisfaction gain, and the node's
weight.  The all-gather of these S candidates *is* the entire inter-machine
exchange of the turn; every machine then runs the same deterministic
:func:`elect` on the gathered array and applies the same
:func:`apply_move` delta to its replicated assignment mirror and O(K) load
vector.  No O(N) state ever crosses the wire after the one-time
O(boundary) ghost sync (see :mod:`~repro.distributed.views`).

Shard-local compute is **incremental** (DESIGN.md §10): each shard carries
its (Ns, K) row-block aggregate in the loop and applies the elected move
as a rank-1 column update (:func:`update_block_aggregate`) — the candidate
costs come from :func:`shard_cost_from_aggregate` in O(Ns*K) per turn, and
the one-time block-aggregate matmul is the only O(Ns*N) work of a run.

Traced runs additionally exchange, per candidate, the two
exact-potential-identity deltas (ΔC_0, ΔCt_0 — Thm. 3.1/5.1, computed by
the proposing shard from its aggregate row in O(K)); the winner's deltas
update every machine's replicated potentials.  8 extra bytes per
candidate, still independent of N; the initial potentials are reduced
once from per-shard partials.

Hysteresis (DESIGN.md §11): the per-node migration-price threshold
``theta`` is a *shard-local* input — each shard subtracts its own slice
before picking its candidate, so candidates carry gains net of the
migration price and the wire payload is unchanged (still 16 B/candidate,
O(K) per turn, independent of N).

Numerical contract: :func:`shard_cost_matrix` (recompute) and
:func:`shard_cost_from_aggregate` (incremental) reproduce the rows of the
controller's cost matrix *bitwise* — both delegate to
:func:`repro.core.costs.cost_matrix_from_aggregate`, and the row-block
aggregate matmul / rank-1 updates mirror the controller's operations
exactly.  :func:`elect` reproduces the global ``argmax`` tie-breaking
(first/lowest node index wins among equal gains).  Together these make
the distributed runtime's move sequence identical to the single
controller's — asserted by tests/test_distributed.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import aggregate as agg_mod
from ..core import costs
from ..core.refine import DissatFn

Array = jax.Array

I32_MAX = jnp.int32(2**31 - 1)

# Wire sizes (bytes) of the protocol messages, for the accounting ledgers.
CANDIDATE_BYTES = 16          # gain f32 + node i32 + dest i32 + weight f32
TRACE_PARTIAL_BYTES = 8       # ΔC_0 f32 + ΔCt_0 f32 per traced candidate


def load_partial_bytes(num_machines: int) -> int:
    """Fresh O(K) load partial exchanged per shard per §4.5 sweep."""
    return 4 * num_machines


class Candidate(NamedTuple):
    """One shard's proposal for the acting machine's move (16 bytes)."""
    gain: Array     # f32 — dissatisfaction of the proposed node (-inf if
                    #       the shard holds no movable node for machine m)
    node: Array     # i32 — global node id
    dest: Array     # i32 — the node's best-response machine
    weight: Array   # f32 — b_node (lets every peer update loads locally)


class Winner(NamedTuple):
    """Deterministic election result, identical on every machine."""
    moved: Array    # bool — gain > tol
    node: Array     # i32
    dest: Array     # i32
    gain: Array     # f32
    weight: Array   # f32
    shard: Array    # i32 — index of the winning candidate's shard (lets
                    #       traced callers pick that shard's potential
                    #       deltas out of the gathered arrays)


# ---------------------------------------------------------------------------
# Shard-local compute (no communication)
# ---------------------------------------------------------------------------

def block_aggregate(row_block: Array, assignment: Array,
                    num_machines: int) -> Array:
    """One-time (Ns, K) row-block aggregate: A_s = rows @ one_hot(r).

    The contraction dimension stays exactly N, so the rows are bitwise
    equal to the controller's full-aggregate rows (DESIGN.md §9.1).
    """
    onehot = jax.nn.one_hot(assignment, num_machines, dtype=row_block.dtype)
    return row_block @ onehot


def update_block_aggregate(aggregate: Array, row_block: Array, node: Array,
                           source: Array, dest: Array,
                           moved: Array) -> Array:
    """Apply the elected move's rank-1 column update to the shard's block:
    the same ``A[:, s] -= c[:, l]; A[:, d] += c[:, l]`` the controller
    applies, restricted to the shard's rows — O(Ns), no communication
    (every shard holds column l of its own row block)."""
    col = row_block[:, node]
    new = aggregate.at[:, source].add(-col).at[:, dest].add(col)
    return jnp.where(moved, new, aggregate)


def update_block_aggregate_sweep(aggregate: Array, row_block: Array,
                                 picks: Array, dests: Array,
                                 moved: Array) -> Array:
    """§4.5 rank-K block update: machine m's move of node picks[m] (owned
    by m, so source column = m) to dests[m], for all moving machines at
    once — mirrors :func:`repro.core.aggregate.apply_sweep` restricted to
    the shard's rows.  Idle machines' columns are masked to exact zero."""
    mask = moved.astype(row_block.dtype)                     # (K,)
    cols = row_block[:, picks] * mask[None, :]               # (Ns, K)
    new = aggregate - cols
    return new.at[:, dests].add(cols)                        # dups summed


def shard_cost_from_aggregate(aggregate: Array, r_local: Array,
                              b_local: Array, loads: Array, speeds: Array,
                              mu: Array, total_b: Array,
                              framework: str) -> Array:
    """(Ns, K) cost rows from the shard's carried block aggregate — O(Ns*K)
    per turn, bitwise equal to the controller's incremental-path rows
    (shared assembly: :func:`repro.core.costs.cost_matrix_from_aggregate`)."""
    return costs.cost_matrix_from_aggregate(
        aggregate, r_local, b_local, loads, speeds, mu, framework,
        total_weight=total_b)


def shard_cost_matrix(row_block: Array, r_local: Array, b_local: Array,
                      assignment: Array, loads: Array, speeds: Array,
                      mu: Array, total_b: Array, framework: str) -> Array:
    """(Ns, K) cost rows rebuilt from scratch (the recompute path) —
    bitwise equal to the same rows of :func:`repro.core.costs.cost_matrix`.

    ``assignment`` is the shard's O(N) *mirror* (maintained by move
    broadcasts, never re-shipped); ``loads`` the replicated O(K) vector;
    ``total_b`` the global weight total B (a one-time O(1) allreduce —
    node weights are constants of the game).
    """
    k = speeds.shape[0]
    aggregate = block_aggregate(row_block, assignment, k)   # (Ns, K)
    return shard_cost_from_aggregate(aggregate, r_local, b_local, loads,
                                     speeds, mu, total_b, framework)


def _shard_dissatisfaction(row_block, b_local, ids, valid, assignment,
                           loads, speeds, mu, total_b, framework,
                           cost_matrix_fn=None, theta_local=None):
    """Per-node dissatisfaction + best machine for the shard's rows.

    ``theta_local`` is the shard's slice of the per-node hysteresis
    threshold (DESIGN.md §11) — evaluated locally, so the wire payload
    stays the same O(K) candidates; the subtraction delegates to
    :func:`repro.core.costs.dissatisfaction_from_cost` so the net values
    are bitwise identical to the controller's.
    """
    if cost_matrix_fn is None:
        cost_matrix_fn = shard_cost_matrix
    r_local = assignment[ids]
    cost = cost_matrix_fn(row_block, r_local, b_local, assignment,
                          loads, speeds, mu, total_b, framework)
    dissat, best_machine = costs.dissatisfaction_from_cost(cost, r_local,
                                                           theta_local)
    return r_local, dissat, best_machine


def local_candidate(row_block: Array, b_local: Array, ids: Array,
                    valid: Array, assignment: Array, loads: Array,
                    speeds: Array, mu: Array, total_b: Array,
                    machine: Array, framework: str,
                    cost_matrix_fn=None, theta_local=None) -> Candidate:
    """The shard's most dissatisfied node owned by ``machine`` (Eq. 4)."""
    r_local, dissat, best_machine = _shard_dissatisfaction(
        row_block, b_local, ids, valid, assignment, loads, speeds, mu,
        total_b, framework, cost_matrix_fn, theta_local)
    owned = (r_local == machine) & valid
    masked = jnp.where(owned, dissat, -jnp.inf)
    loc = jnp.argmax(masked).astype(jnp.int32)
    return Candidate(gain=masked[loc], node=ids[loc],
                     dest=best_machine[loc], weight=b_local[loc])


def local_candidate_from_aggregate(aggregate: Array, b_local: Array,
                                   ids: Array, valid: Array,
                                   assignment: Array, loads: Array,
                                   speeds: Array, mu: Array, total_b: Array,
                                   machine: Array, framework: str,
                                   with_deltas: bool = False,
                                   dissat_fn: DissatFn | None = None,
                                   theta_local=None):
    """Incremental-path candidate: costs from the shard's carried block
    aggregate, O(Ns*K) — no matmul, no read of any off-shard adjacency.

    With ``with_deltas=True`` additionally returns (ΔC_0, ΔCt_0) for the
    PROPOSED move via the exact-potential identities (Thm. 3.1/5.1),
    computed from the node's aggregate row in O(K) — the 8 traced bytes
    each shard attaches to its candidate.  ``dissat_fn`` substitutes a
    fused kernel for the jnp (dissat, best) reduction; it follows the
    canonical 9-argument convention of :mod:`repro.core.refine` ("The
    ``dissat_fn`` convention"), so
    ``repro.kernels.ops.make_aggregate_dissat_fn()`` plugs into both.
    ``theta_local`` is the shard's slice of the per-node
    hysteresis threshold (DESIGN.md §11) — subtracted shard-locally, so
    candidates carry net gains and the wire stays O(K).
    """
    r_local = assignment[ids]
    if dissat_fn is None:
        cost = shard_cost_from_aggregate(aggregate, r_local, b_local, loads,
                                         speeds, mu, total_b, framework)
        dissat, best_machine = costs.dissatisfaction_from_cost(cost, r_local,
                                                               theta_local)
    else:
        dissat, best_machine = dissat_fn(aggregate, r_local, b_local, loads,
                                         speeds, mu, framework, total_b,
                                         theta_local)
    owned = (r_local == machine) & valid
    masked = jnp.where(owned, dissat, -jnp.inf)
    loc = jnp.argmax(masked).astype(jnp.int32)
    cand = Candidate(gain=masked[loc], node=ids[loc],
                     dest=best_machine[loc], weight=b_local[loc])
    if not with_deltas:
        return cand
    dc0, dct0 = agg_mod.potential_deltas(
        aggregate[loc], b_local[loc], machine, best_machine[loc], loads,
        speeds, mu, total_b)
    return cand, dc0, dct0


def local_candidates_all_machines_from_aggregate(
        aggregate: Array, b_local: Array, ids: Array, valid: Array,
        assignment: Array, loads: Array, speeds: Array, mu: Array,
        total_b: Array, framework: str, dissat_fn=None,
        theta_local=None) -> Candidate:
    """§4.5 sweep candidates (one per machine) from the carried block
    aggregate — Candidate of (K,) arrays, O(Ns*K) per sweep.
    ``dissat_fn`` / ``theta_local`` as in
    :func:`local_candidate_from_aggregate`."""
    k = speeds.shape[0]
    r_local = assignment[ids]
    if dissat_fn is None:
        cost = shard_cost_from_aggregate(aggregate, r_local, b_local, loads,
                                         speeds, mu, total_b, framework)
        dissat, best_machine = costs.dissatisfaction_from_cost(cost, r_local,
                                                               theta_local)
    else:
        dissat, best_machine = dissat_fn(aggregate, r_local, b_local, loads,
                                         speeds, mu, framework, total_b,
                                         theta_local)
    owned = valid[None, :] & (r_local[None, :]
                              == jnp.arange(k, dtype=jnp.int32)[:, None])
    masked = jnp.where(owned, dissat[None, :], -jnp.inf)     # (K, Ns)
    loc = jnp.argmax(masked, axis=1).astype(jnp.int32)       # (K,)
    return Candidate(gain=jnp.take_along_axis(masked, loc[:, None], 1)[:, 0],
                     node=ids[loc], dest=best_machine[loc],
                     weight=b_local[loc])


def local_candidates_all_machines(row_block: Array, b_local: Array,
                                  ids: Array, valid: Array, assignment: Array,
                                  loads: Array, speeds: Array, mu: Array,
                                  total_b: Array, framework: str,
                                  cost_matrix_fn=None,
                                  theta_local=None) -> Candidate:
    """§4.5 sweep mode: one candidate per machine — Candidate of (K,) arrays."""
    k = speeds.shape[0]
    r_local, dissat, best_machine = _shard_dissatisfaction(
        row_block, b_local, ids, valid, assignment, loads, speeds, mu,
        total_b, framework, cost_matrix_fn, theta_local)
    owned = valid[None, :] & (r_local[None, :]
                              == jnp.arange(k, dtype=jnp.int32)[:, None])
    masked = jnp.where(owned, dissat[None, :], -jnp.inf)     # (K, Ns)
    loc = jnp.argmax(masked, axis=1).astype(jnp.int32)       # (K,)
    return Candidate(gain=jnp.take_along_axis(masked, loc[:, None], 1)[:, 0],
                     node=ids[loc], dest=best_machine[loc],
                     weight=b_local[loc])


# ---------------------------------------------------------------------------
# Exchange + replicated apply (the O(K) part)
# ---------------------------------------------------------------------------

def elect(cands: Candidate, tol) -> Winner:
    """Pick the winning candidate from the gathered (S,) Candidate arrays.

    Max gain wins; exact-gain ties break toward the lowest global node id —
    precisely the semantics of the single controller's ``jnp.argmax`` over
    the full masked dissatisfaction vector, because each shard's local
    argmax already picked its lowest-id maximizer and shard blocks are
    contiguous ascending id ranges.
    """
    best_gain = jnp.max(cands.gain)
    tie = cands.gain == best_gain
    shard = jnp.argmin(jnp.where(tie, cands.node, I32_MAX)).astype(jnp.int32)
    return Winner(moved=best_gain > tol,
                  node=cands.node[shard],
                  dest=cands.dest[shard],
                  gain=best_gain,
                  weight=cands.weight[shard],
                  shard=shard)


def elect_degraded(cands: Candidate, tol, lag: Array,
                   stale_penalty) -> Winner:
    """Degraded-mode election under bounded staleness (DESIGN.md §15.2).

    A shard whose carried aggregate is ``lag`` winner broadcasts old only
    wins with gain above ``tol + lag * stale_penalty`` — the S-dependent
    acceptance threshold from the Adolphs–Berenbrink bounded-staleness
    analysis (arXiv:1109.6925): stale gains are optimistic by at most the
    drift a bounded number of missed moves can cause, so demanding a
    proportionally larger improvement keeps the potential descending.
    Callers mask unavailable shards (down / quarantined / undelivered)
    to ``-inf`` gain before electing.

    With ``lag == 0`` everywhere and no masks this is decision-equivalent
    to :func:`elect`: the winner, tie-break, and every ``moved``-gated
    field match bitwise, which is what keeps a zero-fault plan through
    the faulty drivers identical to the fault-free path.
    """
    thresh = tol + stale_penalty * lag.astype(jnp.float32)   # (S,)
    eligible = cands.gain > thresh
    eff = jnp.where(eligible, cands.gain, -jnp.inf)
    best = jnp.max(eff)
    tie = eff == best
    shard = jnp.argmin(jnp.where(tie, cands.node, I32_MAX)).astype(jnp.int32)
    return Winner(moved=best > -jnp.inf,
                  node=cands.node[shard],
                  dest=cands.dest[shard],
                  gain=best,
                  weight=cands.weight[shard],
                  shard=shard)


def apply_move(assignment: Array, loads: Array, winner: Winner,
               machine: Array) -> tuple[Array, Array]:
    """Apply the elected move to the replicated mirror + O(K) loads.

    Mirrors ``repro.core.refine._turn`` operation-for-operation (same
    incremental ``.at[].add`` update order) so the replicated state stays
    bitwise identical to the single controller's.
    """
    new_assignment = jnp.where(
        winner.moved, assignment.at[winner.node].set(winner.dest), assignment)
    new_loads = jnp.where(
        winner.moved,
        loads.at[machine].add(-winner.weight).at[winner.dest].add(winner.weight),
        loads)
    return new_assignment, new_loads


# ---------------------------------------------------------------------------
# Traced-mode potential partials (pure reductions — O(1)/O(K) per shard)
# ---------------------------------------------------------------------------

def shard_load_partial(b_local: Array, ids: Array, valid: Array,
                       assignment: Array, num_machines: int) -> Array:
    """(K,) fresh load partial: sum of owned b over the shard's nodes."""
    bv = jnp.where(valid, b_local, jnp.zeros_like(b_local))
    return jnp.zeros((num_machines,), b_local.dtype).at[assignment[ids]].add(bv)


def shard_c0_partial(row_block: Array, b_local: Array, ids: Array,
                     valid: Array, assignment: Array, fresh_loads: Array,
                     speeds: Array, mu: Array, total_b: Array) -> Array:
    """Shard's contribution to C_0 = sum_i C_i (Thm. 3.1 potential)."""
    r_local = assignment[ids]
    cost = shard_cost_matrix(row_block, r_local, b_local, assignment,
                             fresh_loads, speeds, mu, total_b,
                             costs.C_FRAMEWORK)
    current = jnp.take_along_axis(cost, r_local[:, None], axis=1)[:, 0]
    return jnp.sum(jnp.where(valid, current, 0.0))


def shard_cut_partial(row_block: Array, ids: Array, valid: Array,
                      assignment: Array) -> Array:
    """Shard's (unhalved) cut contribution: sum_{i local} sum_j c_ij [r_i != r_j]."""
    r_local = assignment[ids]
    diff = r_local[:, None] != assignment[None, :]
    rows = jnp.where(valid[:, None], row_block, jnp.zeros_like(row_block))
    return jnp.sum(rows * diff)


def shard_cut_partial_from_aggregate(aggregate: Array, ids: Array,
                                     valid: Array,
                                     assignment: Array) -> Array:
    """Shard's (unhalved) cut contribution from its carried block aggregate
    — O(Ns*K) instead of the O(Ns*N) row sweep: per owned node,
    degree_i - A[i, r_i] (invariant I4 of DESIGN.md §10)."""
    r_local = assignment[ids]
    degree = jnp.sum(aggregate, axis=-1)
    internal = jnp.take_along_axis(aggregate, r_local[:, None], axis=1)[:, 0]
    return jnp.sum(jnp.where(valid, degree - internal, 0.0))


def global_potentials(c0_partials: Array, cut_partials: Array,
                      fresh_loads: Array, speeds: Array, mu: Array,
                      total_b: Array) -> tuple[Array, Array]:
    """Reduce gathered partials to (C_0, Ct_0) — replicated compute."""
    c0 = jnp.sum(c0_partials)
    cut = 0.5 * jnp.sum(cut_partials)
    variance = jnp.sum((fresh_loads / speeds - total_b) ** 2)
    ct0 = variance + 0.5 * mu * cut
    return c0, ct0
