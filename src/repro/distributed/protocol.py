"""The O(K) aggregate-exchange protocol (DESIGN.md §9.2).

Per sequential turn (acting machine m), each shard ships exactly one
:class:`Candidate` — 16 bytes: its most dissatisfied m-owned node, that
node's best-response machine, the dissatisfaction gain, and the node's
weight.  The all-gather of these S candidates *is* the entire inter-machine
exchange of the turn; every machine then runs the same deterministic
:func:`elect` on the gathered array and applies the same
:func:`apply_move` delta to its replicated assignment mirror and O(K) load
vector.  No O(N) state ever crosses the wire after the one-time
O(boundary) ghost sync (see :mod:`~repro.distributed.views`).

Traced runs additionally exchange per-shard potential partials (two f32
scalars plus a fresh O(K) load partial) so the global potentials C_0 /
Ct_0 can be reconstructed by pure reduction — still independent of N.

Numerical contract: :func:`shard_cost_matrix` reproduces the rows of
:func:`repro.core.costs.cost_matrix` *bitwise* (same formulas in the same
operation order; the row-block aggregate matmul keeps the contraction
dimension at exactly N), and :func:`elect` reproduces the global
``argmax`` tie-breaking (first/lowest node index wins among equal gains).
Together these make the distributed sequential runtime's move sequence
identical to the single controller's — asserted by
tests/test_distributed.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import costs

Array = jax.Array

I32_MAX = jnp.int32(2**31 - 1)

# Wire sizes (bytes) of the protocol messages, for the accounting ledgers.
CANDIDATE_BYTES = 16          # gain f32 + node i32 + dest i32 + weight f32
TRACE_PARTIAL_BYTES = 8       # c0 partial f32 + cut partial f32


def load_partial_bytes(num_machines: int) -> int:
    """Fresh O(K) load partial exchanged per shard on traced turns."""
    return 4 * num_machines


class Candidate(NamedTuple):
    """One shard's proposal for the acting machine's move (16 bytes)."""
    gain: Array     # f32 — dissatisfaction of the proposed node (-inf if
                    #       the shard holds no movable node for machine m)
    node: Array     # i32 — global node id
    dest: Array     # i32 — the node's best-response machine
    weight: Array   # f32 — b_node (lets every peer update loads locally)


class Winner(NamedTuple):
    """Deterministic election result, identical on every machine."""
    moved: Array    # bool — gain > tol
    node: Array     # i32
    dest: Array     # i32
    gain: Array     # f32
    weight: Array   # f32


# ---------------------------------------------------------------------------
# Shard-local compute (no communication)
# ---------------------------------------------------------------------------

def shard_cost_matrix(row_block: Array, r_local: Array, b_local: Array,
                      assignment: Array, loads: Array, speeds: Array,
                      mu: Array, total_b: Array, framework: str) -> Array:
    """(Ns, K) cost rows for the shard's nodes — bitwise equal to the same
    rows of :func:`repro.core.costs.cost_matrix`.

    ``assignment`` is the shard's O(N) *mirror* (maintained by move
    broadcasts, never re-shipped); ``loads`` the replicated O(K) vector;
    ``total_b`` the global weight total B (a one-time O(1) allreduce —
    node weights are constants of the game).
    """
    k = speeds.shape[0]
    onehot = jax.nn.one_hot(assignment, k, dtype=row_block.dtype)
    aggregate = row_block @ onehot                          # (Ns, K)
    degree = jnp.sum(aggregate, axis=-1, keepdims=True)
    cut_term = 0.5 * mu * (degree - aggregate)
    own = jax.nn.one_hot(r_local, k, dtype=b_local.dtype)
    others = loads[None, :] - b_local[:, None] * own
    if framework == costs.C_FRAMEWORK:
        load_term = (b_local[:, None] / speeds[None, :]) * others
        return load_term + cut_term
    elif framework == costs.CT_FRAMEWORK:
        inv_w = 1.0 / speeds[None, :]
        load_term = (b_local[:, None] ** 2) * inv_w**2 \
            + 2.0 * b_local[:, None] * inv_w**2 * others \
            - 2.0 * b_local[:, None] * inv_w * total_b
        return load_term + cut_term
    raise ValueError(f"unknown framework {framework!r}")


def _shard_dissatisfaction(row_block, b_local, ids, valid, assignment,
                           loads, speeds, mu, total_b, framework,
                           cost_matrix_fn=None):
    """Per-node dissatisfaction + best machine for the shard's rows."""
    if cost_matrix_fn is None:
        cost_matrix_fn = shard_cost_matrix
    r_local = assignment[ids]
    cost = cost_matrix_fn(row_block, r_local, b_local, assignment,
                          loads, speeds, mu, total_b, framework)
    current = jnp.take_along_axis(cost, r_local[:, None], axis=1)[:, 0]
    best_machine = jnp.argmin(cost, axis=1).astype(jnp.int32)
    dissat = current - jnp.min(cost, axis=1)
    return r_local, dissat, best_machine


def local_candidate(row_block: Array, b_local: Array, ids: Array,
                    valid: Array, assignment: Array, loads: Array,
                    speeds: Array, mu: Array, total_b: Array,
                    machine: Array, framework: str,
                    cost_matrix_fn=None) -> Candidate:
    """The shard's most dissatisfied node owned by ``machine`` (Eq. 4)."""
    r_local, dissat, best_machine = _shard_dissatisfaction(
        row_block, b_local, ids, valid, assignment, loads, speeds, mu,
        total_b, framework, cost_matrix_fn)
    owned = (r_local == machine) & valid
    masked = jnp.where(owned, dissat, -jnp.inf)
    loc = jnp.argmax(masked).astype(jnp.int32)
    return Candidate(gain=masked[loc], node=ids[loc],
                     dest=best_machine[loc], weight=b_local[loc])


def local_candidates_all_machines(row_block: Array, b_local: Array,
                                  ids: Array, valid: Array, assignment: Array,
                                  loads: Array, speeds: Array, mu: Array,
                                  total_b: Array, framework: str,
                                  cost_matrix_fn=None) -> Candidate:
    """§4.5 sweep mode: one candidate per machine — Candidate of (K,) arrays."""
    k = speeds.shape[0]
    r_local, dissat, best_machine = _shard_dissatisfaction(
        row_block, b_local, ids, valid, assignment, loads, speeds, mu,
        total_b, framework, cost_matrix_fn)
    owned = valid[None, :] & (r_local[None, :]
                              == jnp.arange(k, dtype=jnp.int32)[:, None])
    masked = jnp.where(owned, dissat[None, :], -jnp.inf)     # (K, Ns)
    loc = jnp.argmax(masked, axis=1).astype(jnp.int32)       # (K,)
    return Candidate(gain=jnp.take_along_axis(masked, loc[:, None], 1)[:, 0],
                     node=ids[loc], dest=best_machine[loc],
                     weight=b_local[loc])


# ---------------------------------------------------------------------------
# Exchange + replicated apply (the O(K) part)
# ---------------------------------------------------------------------------

def elect(cands: Candidate, tol) -> Winner:
    """Pick the winning candidate from the gathered (S,) Candidate arrays.

    Max gain wins; exact-gain ties break toward the lowest global node id —
    precisely the semantics of the single controller's ``jnp.argmax`` over
    the full masked dissatisfaction vector, because each shard's local
    argmax already picked its lowest-id maximizer and shard blocks are
    contiguous ascending id ranges.
    """
    best_gain = jnp.max(cands.gain)
    tie = cands.gain == best_gain
    shard = jnp.argmin(jnp.where(tie, cands.node, I32_MAX)).astype(jnp.int32)
    return Winner(moved=best_gain > tol,
                  node=cands.node[shard],
                  dest=cands.dest[shard],
                  gain=best_gain,
                  weight=cands.weight[shard])


def apply_move(assignment: Array, loads: Array, winner: Winner,
               machine: Array) -> tuple[Array, Array]:
    """Apply the elected move to the replicated mirror + O(K) loads.

    Mirrors ``repro.core.refine._turn`` operation-for-operation (same
    incremental ``.at[].add`` update order) so the replicated state stays
    bitwise identical to the single controller's.
    """
    new_assignment = jnp.where(
        winner.moved, assignment.at[winner.node].set(winner.dest), assignment)
    new_loads = jnp.where(
        winner.moved,
        loads.at[machine].add(-winner.weight).at[winner.dest].add(winner.weight),
        loads)
    return new_assignment, new_loads


# ---------------------------------------------------------------------------
# Traced-mode potential partials (pure reductions — O(1)/O(K) per shard)
# ---------------------------------------------------------------------------

def shard_load_partial(b_local: Array, ids: Array, valid: Array,
                       assignment: Array, num_machines: int) -> Array:
    """(K,) fresh load partial: sum of owned b over the shard's nodes."""
    bv = jnp.where(valid, b_local, jnp.zeros_like(b_local))
    return jnp.zeros((num_machines,), b_local.dtype).at[assignment[ids]].add(bv)


def shard_c0_partial(row_block: Array, b_local: Array, ids: Array,
                     valid: Array, assignment: Array, fresh_loads: Array,
                     speeds: Array, mu: Array, total_b: Array) -> Array:
    """Shard's contribution to C_0 = sum_i C_i (Thm. 3.1 potential)."""
    r_local = assignment[ids]
    cost = shard_cost_matrix(row_block, r_local, b_local, assignment,
                             fresh_loads, speeds, mu, total_b,
                             costs.C_FRAMEWORK)
    current = jnp.take_along_axis(cost, r_local[:, None], axis=1)[:, 0]
    return jnp.sum(jnp.where(valid, current, 0.0))


def shard_cut_partial(row_block: Array, ids: Array, valid: Array,
                      assignment: Array) -> Array:
    """Shard's (unhalved) cut contribution: sum_{i local} sum_j c_ij [r_i != r_j]."""
    r_local = assignment[ids]
    diff = r_local[:, None] != assignment[None, :]
    rows = jnp.where(valid[:, None], row_block, jnp.zeros_like(row_block))
    return jnp.sum(rows * diff)


def global_potentials(c0_partials: Array, cut_partials: Array,
                      fresh_loads: Array, speeds: Array, mu: Array,
                      total_b: Array) -> tuple[Array, Array]:
    """Reduce gathered partials to (C_0, Ct_0) — replicated compute."""
    c0 = jnp.sum(c0_partials)
    cut = 0.5 * jnp.sum(cut_partials)
    variance = jnp.sum((fresh_loads / speeds - total_b) ** 2)
    ct0 = variance + 0.5 * mu * cut
    return c0, ct0
