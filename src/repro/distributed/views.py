"""Per-machine local views of the partition game (DESIGN.md §9.1).

The distributed runtime statically shards the *node arrays* into S
contiguous blocks: shard s owns rows ``[s*Ns, (s+1)*Ns)`` of the adjacency
matrix, the matching slice of node weights, and nothing else.  Everything a
shard needs beyond its block is either

  * replicated O(K) state (the machine-load vector, machine speeds, mu,
    the global weight total B) kept fresh by the O(K) per-turn deltas of
    :mod:`~repro.distributed.protocol`, or
  * the assignment mirror, initialized once (O(boundary) ghost sync — a
    shard only ever *reads* the assignment of nodes adjacent to its own,
    see :func:`boundary_stats`) and thereafter maintained by the O(1)
    per-turn move broadcasts.

Static sharding by node id — rather than re-homing node data to whichever
machine currently owns the node in the *game* sense — keeps every array
shape fixed (JAX-friendly, no dynamic migration of adjacency rows) while
preserving the paper's protocol: the per-turn exchange stays O(K),
independent of N.  The game-owner of a node is a *value* (the assignment
vector), not a storage location.

Padding: only the row dimension is padded (to ``ceil(N/S)`` rows per
shard).  The contraction dimension of the per-shard aggregate matmul stays
exactly N so shard-local cost rows are bitwise identical to the rows the
single-controller :func:`repro.core.costs.cost_matrix` computes — the
property the move-sequence equivalence test relies on.  Padded rows carry
zero adjacency and zero weight, and are masked out of candidate selection
via ``valid``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.problem import PartitionProblem

Array = jax.Array


class ShardViews(NamedTuple):
    """Stacked per-shard local state; leading axis = shard index.

    In the emulated driver the stack lives on one device and shards are
    mapped with ``vmap``; in the ``shard_map`` driver the leading axis is
    sharded across the device mesh so each device holds exactly its block.
    """
    row_block: Array   # (S, Ns, N) float — adjacency rows owned by shard
    weights: Array     # (S, Ns) float — b_i of owned rows (0 for padding)
    ids: Array         # (S, Ns) int32 — global node ids (clamped to N-1
                       #                 for padding; see ``valid``)
    valid: Array       # (S, Ns) bool — False for padded rows

    @property
    def num_shards(self) -> int:
        return self.row_block.shape[0]

    @property
    def shard_size(self) -> int:
        return self.row_block.shape[1]

    @property
    def num_nodes(self) -> int:
        return self.row_block.shape[2]


def build_views(problem: PartitionProblem, num_shards: int) -> ShardViews:
    """Slice ``problem`` into S contiguous row-block shards (row-padded)."""
    n = problem.num_nodes
    if not 1 <= num_shards <= n:
        raise ValueError(f"num_shards={num_shards} must be in [1, {n}]")
    ns = -(-n // num_shards)                    # rows per shard (ceil)
    npad = ns * num_shards
    rows = jnp.zeros((npad, n), problem.adjacency.dtype)
    rows = rows.at[:n].set(problem.adjacency)
    weights = jnp.zeros((npad,), problem.node_weights.dtype)
    weights = weights.at[:n].set(problem.node_weights)
    ids = jnp.minimum(jnp.arange(npad, dtype=jnp.int32), n - 1)
    valid = jnp.arange(npad) < n
    return ShardViews(
        row_block=rows.reshape(num_shards, ns, n),
        weights=weights.reshape(num_shards, ns),
        ids=ids.reshape(num_shards, ns),
        valid=valid.reshape(num_shards, ns),
    )


def shard_node_values(values: Array, num_shards: int, fill=0.0) -> Array:
    """Pad + reshape an (N,) per-node array into (S, Ns) shard blocks with
    the same row layout as :func:`build_views` (padding rows get ``fill``).

    Used for per-node side inputs that must be read shard-locally — e.g.
    the hysteresis threshold ``theta`` (DESIGN.md §11), which never
    crosses the wire: each shard only ever evaluates its own block.
    """
    values = jnp.asarray(values)
    n = values.shape[0]
    if not 1 <= num_shards <= n:
        raise ValueError(f"num_shards={num_shards} must be in [1, {n}]")
    ns = -(-n // num_shards)
    out = jnp.full((ns * num_shards,), fill, values.dtype).at[:n].set(values)
    return out.reshape(num_shards, ns)


@dataclasses.dataclass(frozen=True)
class BoundaryStats:
    """Host-side ghost/boundary summary per shard (powers accounting).

    ``boundary_nodes[s]`` — owned nodes with at least one edge leaving the
    shard; ``ghost_nodes[s]`` — off-shard nodes adjacent to the shard (the
    assignment entries shard s actually has to mirror); ``cross_edges[s]``
    — edges from shard s to any other shard.
    """
    num_shards: int
    num_nodes: int
    boundary_nodes: np.ndarray   # (S,) int64
    ghost_nodes: np.ndarray      # (S,) int64
    cross_edges: np.ndarray      # (S,) int64

    @property
    def total_ghosts(self) -> int:
        return int(self.ghost_nodes.sum())

    @property
    def total_boundary(self) -> int:
        return int(self.boundary_nodes.sum())


def boundary_stats(problem: PartitionProblem, num_shards: int) -> BoundaryStats:
    """Compute the ghost/boundary structure of a static contiguous sharding."""
    adj = np.asarray(problem.adjacency) > 0
    n = adj.shape[0]
    ns = -(-n // num_shards)
    shard_of = np.minimum(np.arange(n) // ns, num_shards - 1)
    boundary = np.zeros(num_shards, np.int64)
    ghosts = np.zeros(num_shards, np.int64)
    cross = np.zeros(num_shards, np.int64)
    for s in range(num_shards):
        mine = shard_of == s
        out_edges = adj[mine][:, ~mine]
        boundary[s] = int(np.sum(out_edges.any(axis=1)))
        ghosts[s] = int(np.sum(adj[mine].any(axis=0) & ~mine))
        cross[s] = int(out_edges.sum())
    return BoundaryStats(num_shards=num_shards, num_nodes=n,
                         boundary_nodes=boundary, ghost_nodes=ghosts,
                         cross_edges=cross)
