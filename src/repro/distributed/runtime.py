"""Sharded refinement drivers (DESIGN.md §9).

Three execution modes over the same shard-local kernel + O(K) protocol:

  * :func:`refine_distributed`          — sequential round-robin turns,
    ``lax.while_loop`` to convergence (the production entry point; this is
    what ``repro.des.engine`` calls when ``refine_backend="distributed"``).
  * :func:`refine_distributed_traced`   — fixed-length scan recording the
    per-turn move sequence and both global potentials; move-for-move
    identical to :func:`repro.core.refine.refine_traced` (the equivalence
    the paper's Thm 4.1 convergence argument needs and
    tests/test_distributed.py asserts).
  * :func:`refine_distributed_simultaneous` — the §4.5 sweep mode: every
    machine moves its most dissatisfied node in the same round (descent
    not guaranteed, K× fewer exchange rounds).

Shard-local compute is **incremental by default** (DESIGN.md §10): each
shard carries its (Ns, K) row-block aggregate through the loop — built by
one O(Ns·N·K) matmul at round 0 — and thereafter

  * assembles its candidate costs from the carried block in O(Ns·K)/turn,
  * applies the elected move as the same rank-1 column update the
    controller applies (`A_s[:, s] -= c_s[:, l]; A_s[:, d] += c_s[:, l]`),
    O(Ns), using only its own rows — wire traffic stays the O(K)
    candidate exchange,
  * (traced) attaches the exact-potential-identity deltas (ΔC_0, ΔCt_0,
    Thm. 3.1/5.1) to its candidate — 8 B — so every machine updates its
    replicated potentials without any O(N) pass.

``incremental=False`` restores the recompute path (block aggregate matmul
every turn), which is also what ``cost_fn="pallas"`` drives through the
fused Pallas cost kernel when recomputing; on the incremental path
``cost_fn="pallas"`` routes the per-turn reduction through the fused
aggregate→(dissat, best) kernel instead.

Two drivers realize the SPMD program:

  * the **emulated** driver maps the shard axis with ``vmap`` and performs
    the candidate all-gather as a plain stacked reduction — it runs on a
    single device, is fully jit/cond-compatible (the DES engine embeds
    it), and is bit-identical in protocol terms to the mesh driver;
  * :func:`refine_distributed_shard_map` places each shard's row block on
    its own device of a ``jax.sharding.Mesh`` and exchanges candidates
    with ``lax.all_gather`` — the real-collective path, exercised by
    ``benchmarks/distributed_bench.py`` under a forced multi-device host
    platform.
"""
from __future__ import annotations

import contextlib
import time
from functools import partial
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core import aggregate as agg_mod
from ..core import costs
from ..core.problem import PartitionProblem, make_state
from ..core.refine import (DEFAULT_TOL, DissatFn, RefineResult, Trace,
                           _open_run)
from . import accounting, faults, protocol
from .views import ShardViews, boundary_stats, build_views, shard_node_values

Array = jax.Array

# Declared asymptotic budgets for the distributed drivers, consumed by
# the complexity analyzers (DESIGN.md §18).  The drivers shard the dense
# representation, so per-driver memory/work carry the dense budget; the
# paper's feasibility claim (§5 of arXiv 1111.0875) lives in the
# collective schedule instead — see DISTRIBUTED_COLLECTIVES below.
DISTRIBUTED_COMPLEXITY = {
    "mem": {"n": 2.0, "k": 1.0},
    "ops": {"n": 2.0, "k": 1.0},
}

# Per-driver collective budget: total per-shard operand bytes entering
# psum/all_gather-family primitives, split into the per-round
# ("recurring", inside the refinement while-loop) and one-off ("setup")
# phases.  The emulated drivers exchange through staged buffers audited
# by wire_rules (§9.2), so they must stage ZERO collectives; the mesh
# driver gathers exactly one CandidateMsg per round — 4 scalar
# all_gathers whose per-shard operands sum to protocol.CANDIDATE_BYTES
# (§14.5), independent of N.
DISTRIBUTED_COLLECTIVES = {
    "distributed.refine": {"recurring_bytes": 0, "setup_bytes": 0},
    "distributed.refine_traced": {"recurring_bytes": 0, "setup_bytes": 0},
    "distributed.refine_simultaneous": {"recurring_bytes": 0,
                                        "setup_bytes": 0},
    "distributed.shard_map": {"recurring_bytes": protocol.CANDIDATE_BYTES,
                              "setup_bytes": 0},
}


class WireMeasurement(NamedTuple):
    """Measured exchange bytes of one distributed run (DESIGN.md §14.5).

    Produced by the drivers under ``measure_wire=True``: ``payload_bytes``
    is the byte size of the pytrees that actually crossed the emulated
    (or real) exchange each round — measured from the staged buffers via
    :func:`_nbytes`, not from the analytic formulas — times the rounds
    the run executed; ``setup_bytes`` covers the one-time replicated
    state (O(K) loads + total-B scalar, plus the initial-potential
    partials on the incremental traced path).  ``rounds`` follows the
    same convention as ``RefineResult.num_turns`` (active turns/sweeps),
    which is what :func:`repro.distributed.accounting.ledger_for_run`
    is built from — so ``accounting.reconcile`` compares like with like.
    """
    rounds: Array          # int32 — active turns/sweeps (== num_turns)
    payload_bytes: Array   # int32 — per-round exchange, whole run
    setup_bytes: Array     # int32 — one-time replicated state


def _nbytes(tree) -> int:
    """Total byte size of a pytree's array leaves, at trace time.

    Shapes and dtypes are static under tracing, so this is a Python int
    even inside jit — the measured size of the buffers being exchanged.
    """
    return int(sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(tree)))


def _vmap_shards(fn, theta_blocks: Array | None, *axes):
    """Map ``fn(*per_shard_args, theta_local)`` over the shard axis with
    the optional (S, Ns) theta operand.  THE one place the optional-theta
    dispatch lives: ``theta_blocks=None`` passes a literal ``None``
    threshold through (the bitwise no-subtraction path of DESIGN.md §11)
    instead of mapping a zero block."""
    if theta_blocks is None:
        return jax.vmap(lambda *a: fn(*a, None))(*axes)
    return jax.vmap(fn)(*axes, theta_blocks)


def _shard_theta(theta, problem: PartitionProblem,
                 num_shards: int) -> Array | None:
    """(S, Ns) shard blocks of the per-node hysteresis threshold, or None.

    theta never crosses the wire: each shard reads only its own block
    (DESIGN.md §11), mirroring the single controller's (N,) broadcast.
    """
    if theta is None:
        return None
    theta = jnp.broadcast_to(jnp.asarray(theta, jnp.float32),
                             (problem.num_nodes,))
    return shard_node_values(theta, num_shards)


def shard_problem(problem: PartitionProblem, num_shards: int) -> ShardViews:
    """Build the static per-shard views for ``problem`` (see views.py)."""
    return build_views(problem, num_shards)


def _resolve_shards(problem: PartitionProblem, num_shards: int | None) -> int:
    if num_shards is None:
        num_shards = problem.num_machines
    return max(1, min(num_shards, problem.num_nodes))


def _shard_cost_fn(cost_fn: str):
    """Shard-local (Ns, K) cost-row builder for the RECOMPUTE path: "jnp"
    (exact, default) or "pallas" (fused kernel per row block, §3.2)."""
    if cost_fn == "jnp":
        return protocol.shard_cost_matrix
    if cost_fn == "pallas":
        from ..kernels.dissatisfaction import cost_matrix_pallas

        def pallas_rows(row_block, r_local, b_local, assignment, loads,
                        speeds, mu, total_b, framework):
            return cost_matrix_pallas(
                row_block, assignment, b_local, loads, speeds, mu,
                framework, row_assignment=r_local, total_weight=total_b)

        return pallas_rows
    raise ValueError(f"unknown cost_fn {cost_fn!r}")


def _shard_dissat_fn(cost_fn: str) -> DissatFn | None:
    """Shard-local (dissat, best) from the carried block aggregate, for the
    INCREMENTAL path: "jnp" (shared O(Ns·K) assembly, bitwise equal to the
    controller) or "pallas" (fused aggregate→(dissat, best) kernel).  Both
    follow the canonical 9-argument ``dissat_fn`` convention — see "The
    ``dissat_fn`` convention" in :mod:`repro.core.refine` — so the same
    ``ops.make_aggregate_dissat_fn`` adapter plugs in everywhere."""
    if cost_fn == "jnp":
        return None
    if cost_fn == "pallas":
        from ..kernels.ops import make_aggregate_dissat_fn
        return make_aggregate_dissat_fn()
    raise ValueError(f"unknown cost_fn {cost_fn!r}")


def _init_block_aggregates(views: ShardViews, assignment: Array,
                           num_machines: int) -> Array:
    """(S, Ns, K) carried block aggregates — the one-time matmuls."""
    return jax.vmap(
        lambda rb: protocol.block_aggregate(rb, assignment, num_machines)
    )(views.row_block)


def _vmap_candidates(views: ShardViews, assignment: Array, loads: Array,
                     speeds: Array, mu: Array, total_b: Array,
                     machine: Array, framework: str, cost_fn: str,
                     theta_blocks: Array | None = None) -> protocol.Candidate:
    """Recompute-path emulated exchange: all S candidates, stacked."""
    shard_cost = _shard_cost_fn(cost_fn)

    def one(rb, b, ids, valid, th):
        with jax.named_scope("shard_candidate"):
            return protocol.local_candidate(
                rb, b, ids, valid, assignment, loads, speeds, mu, total_b,
                machine, framework, cost_matrix_fn=shard_cost,
                theta_local=th)

    return _vmap_shards(one, theta_blocks, views.row_block, views.weights,
                        views.ids, views.valid)


def _vmap_candidates_incremental(views: ShardViews, block_aggs: Array,
                                 assignment: Array, loads: Array,
                                 speeds: Array, mu: Array, total_b: Array,
                                 machine: Array, framework: str,
                                 cost_fn: str, with_deltas: bool = False,
                                 theta_blocks: Array | None = None):
    """Incremental-path emulated exchange from the carried block aggregates."""
    dissat_fn = _shard_dissat_fn(cost_fn)

    def one(agg, b, ids, valid, th):
        with jax.named_scope("shard_candidate_incremental"):
            return protocol.local_candidate_from_aggregate(
                agg, b, ids, valid, assignment, loads, speeds, mu, total_b,
                machine, framework, with_deltas=with_deltas,
                dissat_fn=dissat_fn, theta_local=th)

    return _vmap_shards(one, theta_blocks, block_aggs, views.weights,
                        views.ids, views.valid)


def _update_block_aggregates(views: ShardViews, block_aggs: Array,
                             winner: protocol.Winner,
                             machine: Array) -> Array:
    """Every shard applies the elected rank-1 update to its own block."""
    return jax.vmap(
        lambda agg, rb: protocol.update_block_aggregate(
            agg, rb, winner.node, machine, winner.dest, winner.moved)
    )(block_aggs, views.row_block)


def _vmap_potentials(views: ShardViews, assignment: Array, speeds: Array,
                     mu: Array, total_b: Array, num_machines: int,
                     fresh_loads: Array | None = None):
    """Emulated reduction of the per-shard potential partials (used once to
    initialize the traced potentials, and by the recompute traced path).

    Pass ``fresh_loads`` when the caller already reduced the shard load
    partials for ``assignment`` (the sweep driver does) to skip the
    redundant second reduction.

    Returns ``(c0, ct0, partial_bytes)`` — the third element is the
    measured byte size of the partial arrays this reduction exchanged
    (a trace-time Python int, consumed by the ``measure_wire`` counters
    of DESIGN.md §14.5 and free to ignore otherwise).
    """
    partial_bytes = 0
    if fresh_loads is None:
        load_partials = jax.vmap(
            lambda b, ids, v: protocol.shard_load_partial(
                b, ids, v, assignment, num_machines)
        )(views.weights, views.ids, views.valid)
        fresh_loads = jnp.sum(load_partials, axis=0)
        partial_bytes += _nbytes(load_partials)
    c0_partials = jax.vmap(
        lambda rb, b, ids, v: protocol.shard_c0_partial(
            rb, b, ids, v, assignment, fresh_loads, speeds, mu, total_b)
    )(views.row_block, views.weights, views.ids, views.valid)
    cut_partials = jax.vmap(
        lambda rb, ids, v: protocol.shard_cut_partial(rb, ids, v, assignment)
    )(views.row_block, views.ids, views.valid)
    partial_bytes += _nbytes((c0_partials, cut_partials))
    c0, ct0 = protocol.global_potentials(c0_partials, cut_partials,
                                         fresh_loads, speeds, mu, total_b)
    return c0, ct0, partial_bytes


# ---------------------------------------------------------------------------
# Sequential round-robin turns (paper §4.2 protocol, distributed)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("framework", "num_shards", "max_turns",
                                   "cost_fn", "incremental", "measure_wire"))
def _refine_distributed(problem: PartitionProblem, assignment: Array,
                        framework: str = costs.C_FRAMEWORK,
                        num_shards: int | None = None,
                        max_turns: int = 10_000, tol: float = DEFAULT_TOL,
                        cost_fn: str = "jnp",
                        incremental: bool = True,
                        theta=None, measure_wire: bool = False):
    """Distributed round-robin refinement to convergence (K idle turns).

    Protocol per turn: each shard computes one Candidate from local state
    (16 bytes on the wire), the candidates are all-gathered, every machine
    elects the same winner and applies the same O(1) delta to its
    replicated assignment mirror + O(K) load vector — and, on the default
    incremental path, the same rank-1 update to its carried (Ns, K) block
    aggregate, so no shard ever rebuilds its aggregate matmul after turn 0.

    ``theta`` (scalar or (N,)) is the migration-price hysteresis threshold
    (DESIGN.md §11), evaluated shard-locally — the wire stays O(K) and
    ``theta=None``/``0`` reproduces the threshold-free move sequence
    bitwise (the core↔distributed contract).

    ``measure_wire=True`` (static) additionally returns a
    :class:`WireMeasurement` counting the bytes of the actual per-turn
    candidate exchange — ``(result, wire)`` instead of ``result`` — for
    reconciliation against ``accounting.ledger_for_run`` (DESIGN.md
    §14.5).  The default jaxpr is unchanged.
    """
    k = problem.num_machines
    s = _resolve_shards(problem, num_shards)
    views = build_views(problem, s)
    state0 = make_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)
    theta_blocks = _shard_theta(theta, problem, s)
    measured: dict = {}

    if incremental:
        aggs0 = _init_block_aggregates(views, state0.assignment, k)

        def cond(carry):
            _, _, _, _, idle, turns, _ = carry
            return (idle < k) & (turns < max_turns)

        def body(carry):
            r, loads, aggs, machine, idle, turns, moves = carry
            cands = _vmap_candidates_incremental(
                views, aggs, r, loads, problem.speeds, problem.mu, total_b,
                machine, framework, cost_fn, theta_blocks=theta_blocks)
            measured["turn"] = _nbytes(cands)
            winner = protocol.elect(cands, tol)
            aggs = _update_block_aggregates(views, aggs, winner, machine)
            r, loads = protocol.apply_move(r, loads, winner, machine)
            idle = jnp.where(winner.moved, 0, idle + 1)
            return (r, loads, aggs, (machine + 1) % k, idle, turns + 1,
                    moves + winner.moved.astype(jnp.int32))

        init = (state0.assignment, state0.loads, aggs0,
                jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        r, loads, _, _, idle, turns, moves = jax.lax.while_loop(
            cond, body, init)
        result = RefineResult(assignment=r, loads=loads, num_moves=moves,
                              num_turns=turns, converged=idle >= k)
        if not measure_wire:
            return result
        return result, WireMeasurement(
            rounds=turns, payload_bytes=turns * measured["turn"],
            setup_bytes=jnp.int32(_nbytes((state0.loads, total_b))))

    def cond(carry):
        _, _, _, idle, turns, _ = carry
        return (idle < k) & (turns < max_turns)

    def body(carry):
        r, loads, machine, idle, turns, moves = carry
        cands = _vmap_candidates(views, r, loads, problem.speeds, problem.mu,
                                 total_b, machine, framework, cost_fn,
                                 theta_blocks=theta_blocks)
        measured["turn"] = _nbytes(cands)
        winner = protocol.elect(cands, tol)
        r, loads = protocol.apply_move(r, loads, winner, machine)
        idle = jnp.where(winner.moved, 0, idle + 1)
        return (r, loads, (machine + 1) % k, idle, turns + 1,
                moves + winner.moved.astype(jnp.int32))

    init = (state0.assignment, state0.loads, jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32))
    r, loads, _, idle, turns, moves = jax.lax.while_loop(cond, body, init)
    result = RefineResult(assignment=r, loads=loads, num_moves=moves,
                          num_turns=turns, converged=idle >= k)
    if not measure_wire:
        return result
    return result, WireMeasurement(
        rounds=turns, payload_bytes=turns * measured["turn"],
        setup_bytes=jnp.int32(_nbytes((state0.loads, total_b))))


@partial(jax.jit, static_argnames=("framework", "num_shards", "max_turns",
                                   "cost_fn", "incremental", "measure_wire"))
def _refine_distributed_traced(problem: PartitionProblem, assignment: Array,
                               framework: str = costs.C_FRAMEWORK,
                               num_shards: int | None = None,
                               max_turns: int = 512,
                               tol: float = DEFAULT_TOL,
                               cost_fn: str = "jnp",
                               incremental: bool = True,
                               theta=None, measure_wire: bool = False):
    """Fixed-length traced variant; returns ``(RefineResult, Trace)`` with
    the exact semantics (and, in sequential mode, the exact move sequence)
    of :func:`repro.core.refine.refine_traced`.

    On the incremental path the potentials are initialized once from
    per-shard partials and thereafter updated by the winner's 8-byte
    exact-potential deltas (Thm. 3.1/5.1) — O(1) wire + O(K) compute per
    turn, no O(N) pass of any kind.  ``incremental=False`` restores the
    per-turn partial-reduction recompute.  ``theta`` as in
    :func:`refine_distributed`.

    ``measure_wire=True`` (static) returns ``(result, trace, wire)``
    with a :class:`WireMeasurement` counting the actual per-turn
    exchange (candidates + potential deltas, or + the recompute
    partials) and the one-time setup including the initial-potential
    partials (DESIGN.md §14.5).  The default jaxpr is unchanged.
    """
    k = problem.num_machines
    s = _resolve_shards(problem, num_shards)
    views = build_views(problem, s)
    state0 = make_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)
    theta_blocks = _shard_theta(theta, problem, s)
    measured: dict = {}
    setup_base = _nbytes((state0.loads, total_b))

    if incremental:
        aggs0 = _init_block_aggregates(views, state0.assignment, k)
        c0_init, ct0_init, init_pot_bytes = _vmap_potentials(
            views, state0.assignment, problem.speeds, problem.mu,
            total_b, k, fresh_loads=state0.loads)

        def step(carry, _):
            r, loads, aggs, c0, ct0, machine, idle = carry
            active = idle < k
            cands, dc0s, dct0s = _vmap_candidates_incremental(
                views, aggs, r, loads, problem.speeds, problem.mu, total_b,
                machine, framework, cost_fn, with_deltas=True,
                theta_blocks=theta_blocks)
            measured["turn"] = _nbytes((cands, dc0s, dct0s))
            winner = protocol.elect(cands, tol)
            moved = winner.moved & active
            gated = winner._replace(moved=moved)
            new_aggs = _update_block_aggregates(views, aggs, gated, machine)
            new_r, new_loads = protocol.apply_move(r, loads, gated, machine)
            new_c0 = jnp.where(moved, c0 + dc0s[winner.shard], c0)
            new_ct0 = jnp.where(moved, ct0 + dct0s[winner.shard], ct0)
            idle = jnp.where(moved, 0, idle + 1)
            out = Trace(
                moved=moved,
                node=jnp.where(winner.moved, winner.node, -1),
                source=jnp.where(winner.moved, machine, -1),
                dest=jnp.where(winner.moved, winner.dest, -1),
                gain=jnp.where(winner.moved, winner.gain, 0.0),
                c0=new_c0, ct0=new_ct0, active=active)
            return (new_r, new_loads, new_aggs, new_c0, new_ct0,
                    (machine + 1) % k, idle), out

        init = (state0.assignment, state0.loads, aggs0, c0_init, ct0_init,
                jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        (r, loads, _, _, _, _, idle), trace = jax.lax.scan(
            step, init, None, length=max_turns)
        moves = jnp.sum(trace.moved.astype(jnp.int32))
        turns = jnp.sum(trace.active.astype(jnp.int32))
        result = RefineResult(assignment=r, loads=loads, num_moves=moves,
                              num_turns=turns, converged=idle >= k)
        if not measure_wire:
            return result, trace
        return result, trace, WireMeasurement(
            rounds=turns, payload_bytes=turns * measured["turn"],
            setup_bytes=jnp.int32(setup_base + init_pot_bytes))

    def step(carry, _):
        r, loads, machine, idle = carry
        active = idle < k
        cands = _vmap_candidates(views, r, loads, problem.speeds, problem.mu,
                                 total_b, machine, framework, cost_fn,
                                 theta_blocks=theta_blocks)
        winner = protocol.elect(cands, tol)
        new_r, new_loads = protocol.apply_move(r, loads, winner, machine)
        new_r = jnp.where(active, new_r, r)
        new_loads = jnp.where(active, new_loads, loads)
        moved = winner.moved & active
        idle = jnp.where(moved, 0, idle + 1)
        c0, ct0, pot_bytes = _vmap_potentials(views, new_r, problem.speeds,
                                              problem.mu, total_b, k)
        measured["turn"] = _nbytes(cands) + pot_bytes
        out = Trace(
            moved=moved,
            node=jnp.where(winner.moved, winner.node, -1),
            source=jnp.where(winner.moved, machine, -1),
            dest=jnp.where(winner.moved, winner.dest, -1),
            gain=jnp.where(winner.moved, winner.gain, 0.0),
            c0=c0, ct0=ct0, active=active)
        return (new_r, new_loads, (machine + 1) % k, idle), out

    init = (state0.assignment, state0.loads, jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32))
    (r, loads, _, idle), trace = jax.lax.scan(step, init, None,
                                              length=max_turns)
    moves = jnp.sum(trace.moved.astype(jnp.int32))
    turns = jnp.sum(trace.active.astype(jnp.int32))
    result = RefineResult(assignment=r, loads=loads, num_moves=moves,
                          num_turns=turns, converged=idle >= k)
    if not measure_wire:
        return result, trace
    return result, trace, WireMeasurement(
        rounds=turns, payload_bytes=turns * measured["turn"],
        setup_bytes=jnp.int32(setup_base))


# ---------------------------------------------------------------------------
# §4.5 simultaneous sweeps, distributed
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("framework", "num_shards", "max_sweeps",
                                   "cost_fn", "incremental", "measure_wire"))
def _refine_distributed_simultaneous(problem: PartitionProblem,
                                     assignment: Array,
                                     framework: str = costs.C_FRAMEWORK,
                                     num_shards: int | None = None,
                                     max_sweeps: int = 256,
                                     tol: float = DEFAULT_TOL,
                                     cost_fn: str = "jnp",
                                     incremental: bool = True,
                                     theta=None, measure_wire: bool = False):
    """Distributed §4.5 sweeps: each shard ships K candidates per sweep
    (one per machine), elections run per machine, all K disjoint moves
    apply at once as a rank-K block-aggregate update.  Exchange per sweep:
    S*K candidates + S load/sq-load/cut partials — still independent of N.

    ``num_moves`` counts actual transfers (sum of per-sweep movers), not
    the K*sweeps upper bound.  ``theta`` as in :func:`refine_distributed`.

    ``measure_wire=True`` (static) returns ``(result, traces, wire)``
    with a :class:`WireMeasurement` of the actual per-sweep exchange
    (K candidates per shard + the partial reductions); ``rounds`` counts
    active sweeps, matching ``num_turns`` and the ledger convention
    (DESIGN.md §14.5).  The default jaxpr is unchanged.
    """
    k = problem.num_machines
    s = _resolve_shards(problem, num_shards)
    views = build_views(problem, s)
    state0 = make_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)
    sq_weights = views.weights * views.weights
    theta_blocks = _shard_theta(theta, problem, s)
    measured: dict = {}

    def _sweep_cands_incremental(aggs, r, loads, dissat_fn):
        def one(agg, b, ids, v, th):
            return protocol.local_candidates_all_machines_from_aggregate(
                agg, b, ids, v, r, loads, problem.speeds, problem.mu,
                total_b, framework, dissat_fn=dissat_fn, theta_local=th)

        return _vmap_shards(one, theta_blocks, aggs, views.weights,
                            views.ids, views.valid)              # (S, K)

    if incremental:
        aggs0 = _init_block_aggregates(views, state0.assignment, k)
        dissat_fn = _shard_dissat_fn(cost_fn)

        def sweep(carry, _):
            r, loads, aggs, done, moves = carry
            cands = _sweep_cands_incremental(aggs, r, loads, dissat_fn)
            winners = jax.vmap(protocol.elect, in_axes=(1, None),
                               out_axes=0)(cands, tol)            # (K,)
            any_move = jnp.any(winners.moved) & ~done
            # Idle machines elect a fallback candidate (all gains -inf)
            # whose node id may collide with a real move — mask their
            # columns / drop their writes instead of racing the update.
            safe_picks = jnp.where(winners.moved, winners.node,
                                   jnp.int32(problem.num_nodes))
            new_r = r.at[safe_picks].set(winners.dest, mode="drop")
            new_r = jnp.where(any_move, new_r, r)
            new_aggs = jax.vmap(
                lambda agg, rb: protocol.update_block_aggregate_sweep(
                    agg, rb, winners.node, winners.dest, winners.moved)
            )(aggs, views.row_block)
            new_aggs = jnp.where(any_move, new_aggs, aggs)
            load_partials = jax.vmap(
                lambda b, ids, v: protocol.shard_load_partial(
                    b, ids, v, new_r, k)
            )(views.weights, views.ids, views.valid)
            new_loads = jnp.sum(load_partials, axis=0)
            sq_partials = jax.vmap(
                lambda b2, ids, v: protocol.shard_load_partial(
                    b2, ids, v, new_r, k)
            )(sq_weights, views.ids, views.valid)
            sq_loads = jnp.sum(sq_partials, axis=0)
            cut_partials = jax.vmap(
                lambda agg, ids, v: protocol.shard_cut_partial_from_aggregate(
                    agg, ids, v, new_r)
            )(new_aggs, views.ids, views.valid)
            measured["sweep"] = _nbytes(
                (cands, load_partials, sq_partials, cut_partials))
            cut = 0.5 * jnp.sum(cut_partials)
            c0, ct0 = agg_mod.potentials_closed_form(
                new_loads, sq_loads, cut, problem.speeds, problem.mu,
                total_b)
            moves = moves + jnp.where(
                any_move, jnp.sum(winners.moved.astype(jnp.int32)), 0)
            return ((new_r, new_loads, new_aggs, done | ~any_move, moves),
                    (c0, ct0, any_move))

        (r, loads, _, done, moves), (c0s, ct0s, active) = jax.lax.scan(
            sweep, (state0.assignment, state0.loads, aggs0,
                    jnp.zeros((), bool), jnp.zeros((), jnp.int32)),
            None, length=max_sweeps)
        sweeps = jnp.sum(active.astype(jnp.int32))
        result = RefineResult(
            assignment=r, loads=loads, num_moves=moves,
            num_turns=sweeps, converged=done)
        if not measure_wire:
            return result, (c0s, ct0s, active)
        return result, (c0s, ct0s, active), WireMeasurement(
            rounds=sweeps, payload_bytes=sweeps * measured["sweep"],
            setup_bytes=jnp.int32(_nbytes((state0.loads, total_b))))

    shard_cost = _shard_cost_fn(cost_fn)

    def sweep(carry, _):
        r, loads, done, moves = carry

        def one(rb, b, ids, v, th):
            return protocol.local_candidates_all_machines(
                rb, b, ids, v, r, loads, problem.speeds, problem.mu,
                total_b, framework, cost_matrix_fn=shard_cost,
                theta_local=th)

        cands = _vmap_shards(one, theta_blocks, views.row_block,
                             views.weights, views.ids, views.valid)  # (S, K)
        winners = jax.vmap(protocol.elect, in_axes=(1, None),
                           out_axes=0)(cands, tol)                 # (K,)
        any_move = jnp.any(winners.moved) & ~done
        safe_picks = jnp.where(winners.moved, winners.node,
                               jnp.int32(problem.num_nodes))
        new_r = r.at[safe_picks].set(winners.dest, mode="drop")
        new_r = jnp.where(any_move, new_r, r)
        load_partials = jax.vmap(
            lambda b, ids, v: protocol.shard_load_partial(b, ids, v, new_r, k)
        )(views.weights, views.ids, views.valid)
        new_loads = jnp.sum(load_partials, axis=0)
        c0, ct0, pot_bytes = _vmap_potentials(views, new_r, problem.speeds,
                                              problem.mu, total_b, k,
                                              fresh_loads=new_loads)
        measured["sweep"] = _nbytes((cands, load_partials)) + pot_bytes
        moves = moves + jnp.where(
            any_move, jnp.sum(winners.moved.astype(jnp.int32)), 0)
        return ((new_r, new_loads, done | ~any_move, moves),
                (c0, ct0, any_move))

    (r, loads, done, moves), (c0s, ct0s, active) = jax.lax.scan(
        sweep, (state0.assignment, state0.loads, jnp.zeros((), bool),
                jnp.zeros((), jnp.int32)),
        None, length=max_sweeps)
    sweeps = jnp.sum(active.astype(jnp.int32))
    result = RefineResult(
        assignment=r, loads=loads, num_moves=moves,
        num_turns=sweeps, converged=done)
    if not measure_wire:
        return result, (c0s, ct0s, active)
    return result, (c0s, ct0s, active), WireMeasurement(
        rounds=sweeps, payload_bytes=sweeps * measured["sweep"],
        setup_bytes=jnp.int32(_nbytes((state0.loads, total_b))))


# ---------------------------------------------------------------------------
# Fault-injected drivers (DESIGN.md §15)
# ---------------------------------------------------------------------------
#
# The faulty drivers re-run the incremental protocol with a FaultPlan row
# consulted every round: candidates of down / quarantined / undelivered
# shards are masked out of the election, the election itself prices
# staleness (``protocol.elect_degraded`` — the 1109.6925 bounded-staleness
# rule), omitted broadcasts leave a shard's carried aggregate stale, the
# plan's corruption entries overwrite aggregate columns, and its repair
# schedule rebuilds + column-patches flagged shards inside the loop via
# ``lax.cond`` (the rebuild matmul stays off the per-round hot path).  A
# zero-fault plan reproduces the fault-free drivers bitwise: every
# degraded branch is gated by a predicate that is constant-false on a
# clear plan, and ``elect_degraded`` is decision-equivalent to ``elect``
# at lag 0 (the Winner fields that can differ are all downstream-gated on
# ``moved``).  Each driver ends with an oracle audit
# (``_fault_final_audit``): worst carried-vs-recomputed deviation before
# and after a final guarded patch of the still-alive shards — the public
# wrappers turn that FaultOutcome into the recover-or-raise contract.

class FaultTrace(NamedTuple):
    """Per-round repair side channel of the faulty scan drivers."""
    repaired: Array       # (T,) bool  — in-loop repair fired this round
    repair_drift: Array   # (T,) f32   — worst pre-repair column deviation
    repaired_cols: Array  # (T,) i32   — aggregate columns replaced


def _inf_dev(x: Array) -> Array:
    """Deviation → finite-or-inf: NaN counts as infinite drift, so a
    ``<= budget`` recovery check can never be satisfied by NaN soup."""
    return jnp.nan_to_num(x, nan=jnp.inf, posinf=jnp.inf)


def _shard_load_partials(views: ShardViews, weights: Array,
                         assignment: Array, num_machines: int) -> Array:
    """(S, K) per-shard load partials for the given per-shard weights."""
    return jax.vmap(
        lambda b, ids, v: protocol.shard_load_partial(
            b, ids, v, assignment, num_machines)
    )(weights, views.ids, views.valid)


def _fault_inject(aggs: Array, row, gate, num_machines: int) -> Array:
    """Overwrite column ``corrupt_col`` of flagged shards with
    ``corrupt_val`` (set semantics — a NaN payload lands as NaN)."""
    colmask = (jnp.arange(num_machines, dtype=jnp.int32)[None, :]
               == row.corrupt_col[:, None])                     # (S, K)
    zap = (row.corrupt & gate)[:, None] & colmask
    return jnp.where(zap[:, None, :], row.corrupt_val[:, None, None], aggs)


def _fault_repair_cols(views: ShardViews, aggs: Array, assignment: Array,
                       repair_mask: Array, rtol: float, num_machines: int):
    """Rebuild the oracle aggregates and patch — for flagged shards only —
    the columns whose carried values deviate beyond ``rtol``.  Healthy
    columns are left bit-identical (the guard predicate is NaN-safe)."""
    fresh = _init_block_aggregates(views, assignment, num_machines)
    col_dev = jnp.max(jnp.abs(aggs - fresh), axis=1)            # (S, K)
    colbad = ~(col_dev <= rtol)                                 # NaN → bad
    sel = repair_mask[:, None] & colbad
    patched = jnp.where(sel[:, None, :], fresh, aggs)
    drift = jnp.max(jnp.where(repair_mask[:, None], _inf_dev(col_dev), 0.0))
    cols = jnp.sum(sel.astype(jnp.int32))
    return patched, drift, cols


def _fault_closed_potentials(views: ShardViews, sq_weights: Array,
                             aggs: Array, assignment: Array, speeds: Array,
                             mu, total_b, num_machines: int):
    """Oracle loads + closed-form potentials from the (patched) aggregates
    — the repair-round resync of the traced driver's carried values."""
    load_partials = _shard_load_partials(views, views.weights, assignment,
                                         num_machines)
    fresh_loads = jnp.sum(load_partials, axis=0)
    sq_loads = jnp.sum(_shard_load_partials(views, sq_weights, assignment,
                                            num_machines), axis=0)
    cut_partials = jax.vmap(
        lambda agg, ids, v: protocol.shard_cut_partial_from_aggregate(
            agg, ids, v, assignment)
    )(aggs, views.ids, views.valid)
    cut = 0.5 * jnp.sum(cut_partials)
    c0, ct0 = agg_mod.potentials_closed_form(fresh_loads, sq_loads, cut,
                                             speeds, mu, total_b)
    return fresh_loads, c0, ct0


def _fault_final_audit(views: ShardViews, fault_plan, aggs: Array,
                       loads: Array, assignment: Array, last_round,
                       converged, rtol: float, num_machines: int):
    """Post-run oracle audit + unconditional guarded patch.

    ``final_drift`` is the worst carried-vs-recomputed deviation (columns
    and loads, NaN → inf) *before* patching; the patch then replaces bad
    columns of still-alive shards and bad load entries, and
    ``post_drift`` re-measures.  A shard down on the last executed round
    of a non-converged run is dead — its columns stay un-patched and the
    wrapper raises ``DeadShardError`` (a converged run necessarily ended
    on a fault-clear round, so ``converged`` gates the dead check)."""
    horizon = fault_plan.down.shape[0] - 1
    last = jnp.clip(last_round, 0, horizon)
    dead_row = fault_plan.down[last] & ~converged               # (S,)
    fresh = _init_block_aggregates(views, assignment, num_machines)
    col_dev = jnp.max(jnp.abs(aggs - fresh), axis=1)            # (S, K)
    fresh_loads = jnp.sum(_shard_load_partials(
        views, views.weights, assignment, num_machines), axis=0)
    load_dev = _inf_dev(jnp.abs(loads - fresh_loads))
    final_drift = jnp.maximum(jnp.max(_inf_dev(col_dev)),
                              jnp.max(load_dev))
    sel = (~dead_row)[:, None] & ~(col_dev <= rtol)
    aggs = jnp.where(sel[:, None, :], fresh, aggs)
    loads = jnp.where(~(load_dev <= rtol), fresh_loads, loads)
    post_col = jnp.max(jnp.abs(aggs - fresh), axis=1)
    post_drift = jnp.maximum(
        jnp.max(_inf_dev(post_col)),
        jnp.max(_inf_dev(jnp.abs(loads - fresh_loads))))
    cols = jnp.sum(sel.astype(jnp.int32))
    return aggs, loads, dead_row, final_drift, post_drift, cols


@partial(jax.jit, static_argnames=("framework", "num_shards", "max_rounds",
                                   "cost_fn", "degraded", "measure_wire"))
def _refine_distributed_faulty(problem: PartitionProblem, assignment: Array,
                               fault_plan,
                               framework: str = costs.C_FRAMEWORK,
                               num_shards: int | None = None,
                               max_rounds: int = 10_000,
                               tol: float = DEFAULT_TOL,
                               cost_fn: str = "jnp",
                               degraded=faults.DEFAULT_DEGRADED,
                               theta=None, measure_wire: bool = False):
    """Fault-injected round-robin driver (incremental protocol only).

    Same election/apply protocol as :func:`_refine_distributed`, plus the
    per-round degraded machinery described in the section comment above.
    Convergence idles only accumulate on fault-clear rounds (a blocked
    no-move round is not evidence of equilibrium).  Returns
    ``(result, outcome)`` — ``outcome`` is a
    :class:`repro.distributed.faults.FaultOutcome` of device scalars —
    plus a :class:`WireMeasurement` when ``measure_wire`` whose payload
    includes the per-round retry/duplicate/repair extra bytes."""
    k = problem.num_machines
    s = _resolve_shards(problem, num_shards)
    views = build_views(problem, s)
    state0 = make_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)
    theta_blocks = _shard_theta(theta, problem, s)
    measured: dict = {}
    rtol = degraded.repair_tol
    penalty = degraded.stale_penalty
    msg = faults.message_bytes(traced=False, simultaneous=False,
                               num_machines=k)
    aggs0 = _init_block_aggregates(views, state0.assignment, k)
    zero_i = jnp.zeros((), jnp.int32)
    zero_f = jnp.zeros((), jnp.float32)

    def cond(carry):
        return (carry[4] < k) & (carry[5] < max_rounds)

    def body(carry):
        (r, loads, aggs, machine, idle, turns, moves,
         fbytes, repairs, rcols, rdrift) = carry
        row = faults.plan_row(fault_plan, turns)
        aggs = _fault_inject(aggs, row, True, k)
        cands = _vmap_candidates_incremental(
            views, aggs, r, loads, problem.speeds, problem.mu, total_b,
            machine, framework, cost_fn, theta_blocks=theta_blocks)
        measured["turn"] = _nbytes(cands)
        blocked = row.down | row.quarantined | ~row.delivered
        cands = cands._replace(gain=jnp.where(blocked, -jnp.inf, cands.gain))
        winner = protocol.elect_degraded(cands, tol, row.lag, penalty)
        new_aggs = _update_block_aggregates(views, aggs, winner, machine)
        miss = (row.omit | row.down)[:, None, None]
        aggs = jnp.where(miss, aggs, new_aggs)
        r, loads = protocol.apply_move(r, loads, winner, machine)
        idle = jnp.where(winner.moved, 0,
                         jnp.where(row.clear, idle + 1, idle))
        do_repair = jnp.any(row.repair)
        aggs, rd, rc = jax.lax.cond(
            do_repair,
            lambda a: _fault_repair_cols(views, a, r, row.repair, rtol, k),
            lambda a: (a, zero_f, zero_i), aggs)
        fbytes = fbytes + faults.round_extra_bytes(row, msg)
        return (r, loads, aggs, (machine + 1) % k, idle, turns + 1,
                moves + winner.moved.astype(jnp.int32), fbytes,
                repairs + do_repair.astype(jnp.int32), rcols + rc,
                jnp.maximum(rdrift, rd))

    init = (state0.assignment, state0.loads, aggs0, zero_i, zero_i, zero_i,
            zero_i, zero_i, zero_i, zero_i, zero_f)
    (r, loads, aggs, _, idle, turns, moves,
     fbytes, repairs, rcols, rdrift) = jax.lax.while_loop(cond, body, init)
    converged = idle >= k
    aggs, loads, dead_row, final_drift, post_drift, fcols = \
        _fault_final_audit(views, fault_plan, aggs, loads, r, turns - 1,
                           converged, rtol, k)
    result = RefineResult(assignment=r, loads=loads, num_moves=moves,
                          num_turns=turns, converged=converged,
                          aggregate_drift=post_drift)
    outcome = faults.FaultOutcome(
        final_drift=final_drift, post_drift=post_drift,
        dead=jnp.any(dead_row), repairs=repairs,
        repaired_cols=rcols + fcols, max_repair_drift=rdrift)
    if not measure_wire:
        return result, outcome
    return result, outcome, WireMeasurement(
        rounds=turns, payload_bytes=turns * measured["turn"] + fbytes,
        setup_bytes=jnp.int32(_nbytes((state0.loads, total_b))))


@partial(jax.jit, static_argnames=("framework", "num_shards", "max_rounds",
                                   "cost_fn", "degraded", "measure_wire"))
def _refine_distributed_traced_faulty(problem: PartitionProblem,
                                      assignment: Array, fault_plan,
                                      framework: str = costs.C_FRAMEWORK,
                                      num_shards: int | None = None,
                                      max_rounds: int = 512,
                                      tol: float = DEFAULT_TOL,
                                      cost_fn: str = "jnp",
                                      degraded=faults.DEFAULT_DEGRADED,
                                      theta=None,
                                      measure_wire: bool = False):
    """Fault-injected traced driver (incremental protocol only).

    Carried C_0/Ct_0 follow the winner's exact-potential deltas between
    repairs; a repair round recomputes them closed-form from the patched
    aggregates and guard-patches the carried values (relative tolerance,
    so fault-free float noise never triggers a patch).  Returns
    ``(result, trace, ftrace, outcome)`` (+ wire)."""
    k = problem.num_machines
    s = _resolve_shards(problem, num_shards)
    views = build_views(problem, s)
    state0 = make_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)
    theta_blocks = _shard_theta(theta, problem, s)
    measured: dict = {}
    setup_base = _nbytes((state0.loads, total_b))
    rtol = degraded.repair_tol
    penalty = degraded.stale_penalty
    msg = faults.message_bytes(traced=True, simultaneous=False,
                               num_machines=k)
    sq_weights = views.weights * views.weights
    aggs0 = _init_block_aggregates(views, state0.assignment, k)
    c0_init, ct0_init, init_pot_bytes = _vmap_potentials(
        views, state0.assignment, problem.speeds, problem.mu,
        total_b, k, fresh_loads=state0.loads)
    zero_i = jnp.zeros((), jnp.int32)
    zero_f = jnp.zeros((), jnp.float32)

    def step(carry, t):
        r, loads, aggs, c0, ct0, machine, idle, fbytes = carry
        active = idle < k
        row = faults.plan_row(fault_plan, t)
        aggs = _fault_inject(aggs, row, active, k)
        cands, dc0s, dct0s = _vmap_candidates_incremental(
            views, aggs, r, loads, problem.speeds, problem.mu, total_b,
            machine, framework, cost_fn, with_deltas=True,
            theta_blocks=theta_blocks)
        measured["turn"] = _nbytes((cands, dc0s, dct0s))
        blocked = row.down | row.quarantined | ~row.delivered
        cands = cands._replace(gain=jnp.where(blocked, -jnp.inf, cands.gain))
        winner = protocol.elect_degraded(cands, tol, row.lag, penalty)
        moved = winner.moved & active
        gated = winner._replace(moved=moved)
        new_aggs = _update_block_aggregates(views, aggs, gated, machine)
        miss = (row.omit | row.down)[:, None, None]
        new_aggs = jnp.where(miss, aggs, new_aggs)
        new_r, new_loads = protocol.apply_move(r, loads, gated, machine)
        new_c0 = jnp.where(moved, c0 + dc0s[winner.shard], c0)
        new_ct0 = jnp.where(moved, ct0 + dct0s[winner.shard], ct0)
        idle = jnp.where(moved, 0, jnp.where(row.clear, idle + 1, idle))
        do_repair = jnp.any(row.repair) & active

        def with_repair(ops):
            aggs_, loads_, c0_, ct0_ = ops
            patched, rd, rc = _fault_repair_cols(views, aggs_, new_r,
                                                 row.repair, rtol, k)
            fl, c0f, ct0f = _fault_closed_potentials(
                views, sq_weights, patched, new_r, problem.speeds,
                problem.mu, total_b, k)

            def guard(x, fresh):
                bad = ~(jnp.abs(x - fresh)
                        <= rtol * jnp.maximum(1.0, jnp.abs(fresh)))
                return jnp.where(bad, fresh, x)

            loads2 = jnp.where(~(jnp.abs(loads_ - fl) <= rtol), fl, loads_)
            return patched, loads2, guard(c0_, c0f), guard(ct0_, ct0f), rd, rc

        def without(ops):
            aggs_, loads_, c0_, ct0_ = ops
            return aggs_, loads_, c0_, ct0_, zero_f, zero_i

        new_aggs, new_loads, new_c0, new_ct0, rd, rc = jax.lax.cond(
            do_repair, with_repair, without,
            (new_aggs, new_loads, new_c0, new_ct0))
        fbytes = fbytes + jnp.where(
            active, faults.round_extra_bytes(row, msg), 0)
        out = (Trace(moved=moved,
                     node=jnp.where(winner.moved, winner.node, -1),
                     source=jnp.where(winner.moved, machine, -1),
                     dest=jnp.where(winner.moved, winner.dest, -1),
                     gain=jnp.where(winner.moved, winner.gain, 0.0),
                     c0=new_c0, ct0=new_ct0, active=active),
               FaultTrace(repaired=do_repair, repair_drift=rd,
                          repaired_cols=rc))
        return (new_r, new_loads, new_aggs, new_c0, new_ct0,
                (machine + 1) % k, idle, fbytes), out

    init = (state0.assignment, state0.loads, aggs0, c0_init, ct0_init,
            zero_i, zero_i, zero_i)
    (r, loads, aggs, _, _, _, idle, fbytes), (trace, ftrace) = jax.lax.scan(
        step, init, jnp.arange(max_rounds))
    moves = jnp.sum(trace.moved.astype(jnp.int32))
    turns = jnp.sum(trace.active.astype(jnp.int32))
    converged = idle >= k
    aggs, loads, dead_row, final_drift, post_drift, fcols = \
        _fault_final_audit(views, fault_plan, aggs, loads, r, turns - 1,
                           converged, rtol, k)
    result = RefineResult(assignment=r, loads=loads, num_moves=moves,
                          num_turns=turns, converged=converged,
                          aggregate_drift=post_drift)
    outcome = faults.FaultOutcome(
        final_drift=final_drift, post_drift=post_drift,
        dead=jnp.any(dead_row),
        repairs=jnp.sum(ftrace.repaired.astype(jnp.int32)),
        repaired_cols=jnp.sum(ftrace.repaired_cols) + fcols,
        max_repair_drift=jnp.max(ftrace.repair_drift))
    if not measure_wire:
        return result, trace, ftrace, outcome
    return result, trace, ftrace, outcome, WireMeasurement(
        rounds=turns, payload_bytes=turns * measured["turn"] + fbytes,
        setup_bytes=jnp.int32(setup_base + init_pot_bytes))


@partial(jax.jit, static_argnames=("framework", "num_shards", "max_rounds",
                                   "cost_fn", "degraded", "measure_wire"))
def _refine_distributed_simultaneous_faulty(problem: PartitionProblem,
                                            assignment: Array, fault_plan,
                                            framework: str = costs.C_FRAMEWORK,
                                            num_shards: int | None = None,
                                            max_rounds: int = 256,
                                            tol: float = DEFAULT_TOL,
                                            cost_fn: str = "jnp",
                                            degraded=faults.DEFAULT_DEGRADED,
                                            theta=None,
                                            measure_wire: bool = False):
    """Fault-injected §4.5 sweep driver (incremental protocol only).

    The sweep can only latch ``done`` on a fault-clear no-move round — a
    blocked round proves nothing about equilibrium.  Wire counts the
    executed (non-done) rounds: ``counted = ~done & (any_move | ~clear)``
    reduces to the fault-free active-sweep count on a zero plan, and the
    counted rounds always form a prefix, which is what keeps the host-side
    ledger (``faults.plan_extra_bytes``) byte-exact against the device
    accumulator.  Returns ``(result, (c0s, ct0s, counted), ftrace,
    outcome)`` (+ wire)."""
    k = problem.num_machines
    s = _resolve_shards(problem, num_shards)
    views = build_views(problem, s)
    state0 = make_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)
    sq_weights = views.weights * views.weights
    theta_blocks = _shard_theta(theta, problem, s)
    measured: dict = {}
    rtol = degraded.repair_tol
    penalty = degraded.stale_penalty
    msg = faults.message_bytes(traced=False, simultaneous=True,
                               num_machines=k)
    dissat_fn = _shard_dissat_fn(cost_fn)
    aggs0 = _init_block_aggregates(views, state0.assignment, k)
    zero_i = jnp.zeros((), jnp.int32)
    zero_f = jnp.zeros((), jnp.float32)

    def _sweep_cands(aggs, r, loads):
        def one(agg, b, ids, v, th):
            return protocol.local_candidates_all_machines_from_aggregate(
                agg, b, ids, v, r, loads, problem.speeds, problem.mu,
                total_b, framework, dissat_fn=dissat_fn, theta_local=th)

        return _vmap_shards(one, theta_blocks, aggs, views.weights,
                            views.ids, views.valid)              # (S, K)

    def sweep(carry, t):
        r, loads, aggs, done, moves, fbytes = carry
        row = faults.plan_row(fault_plan, t)
        aggs = _fault_inject(aggs, row, ~done, k)
        cands = _sweep_cands(aggs, r, loads)
        blocked = row.down | row.quarantined | ~row.delivered
        cands = cands._replace(
            gain=jnp.where(blocked[:, None], -jnp.inf, cands.gain))
        winners = jax.vmap(protocol.elect_degraded,
                           in_axes=(1, None, None, None),
                           out_axes=0)(cands, tol, row.lag, penalty)  # (K,)
        any_move = jnp.any(winners.moved) & ~done
        safe_picks = jnp.where(winners.moved, winners.node,
                               jnp.int32(problem.num_nodes))
        new_r = r.at[safe_picks].set(winners.dest, mode="drop")
        new_r = jnp.where(any_move, new_r, r)
        new_aggs = jax.vmap(
            lambda agg, rb: protocol.update_block_aggregate_sweep(
                agg, rb, winners.node, winners.dest, winners.moved)
        )(aggs, views.row_block)
        new_aggs = jnp.where(any_move, new_aggs, aggs)
        miss = (row.omit | row.down)[:, None, None]
        new_aggs = jnp.where(miss, aggs, new_aggs)
        do_repair = jnp.any(row.repair) & ~done
        new_aggs, rd, rc = jax.lax.cond(
            do_repair,
            lambda a: _fault_repair_cols(views, a, new_r, row.repair,
                                         rtol, k),
            lambda a: (a, zero_f, zero_i), new_aggs)
        load_partials = jax.vmap(
            lambda b, ids, v: protocol.shard_load_partial(
                b, ids, v, new_r, k)
        )(views.weights, views.ids, views.valid)
        new_loads = jnp.sum(load_partials, axis=0)
        sq_partials = jax.vmap(
            lambda b2, ids, v: protocol.shard_load_partial(
                b2, ids, v, new_r, k)
        )(sq_weights, views.ids, views.valid)
        sq_loads = jnp.sum(sq_partials, axis=0)
        cut_partials = jax.vmap(
            lambda agg, ids, v: protocol.shard_cut_partial_from_aggregate(
                agg, ids, v, new_r)
        )(new_aggs, views.ids, views.valid)
        measured["sweep"] = _nbytes(
            (cands, load_partials, sq_partials, cut_partials))
        cut = 0.5 * jnp.sum(cut_partials)
        c0, ct0 = agg_mod.potentials_closed_form(
            new_loads, sq_loads, cut, problem.speeds, problem.mu, total_b)
        moves = moves + jnp.where(
            any_move, jnp.sum(winners.moved.astype(jnp.int32)), 0)
        counted = ~done & (any_move | ~row.clear)
        fbytes = fbytes + jnp.where(
            counted, faults.round_extra_bytes(row, msg), 0)
        new_done = done | (~any_move & row.clear)
        return ((new_r, new_loads, new_aggs, new_done, moves, fbytes),
                ((c0, ct0, counted),
                 FaultTrace(repaired=do_repair, repair_drift=rd,
                            repaired_cols=rc)))

    (r, loads, aggs, done, moves, fbytes), ((c0s, ct0s, active), ftrace) = \
        jax.lax.scan(sweep, (state0.assignment, state0.loads, aggs0,
                             jnp.zeros((), bool), zero_i, zero_i),
                     jnp.arange(max_rounds))
    sweeps = jnp.sum(active.astype(jnp.int32))
    aggs, loads, dead_row, final_drift, post_drift, fcols = \
        _fault_final_audit(views, fault_plan, aggs, loads, r,
                           max_rounds - 1, done, rtol, k)
    result = RefineResult(assignment=r, loads=loads, num_moves=moves,
                          num_turns=sweeps, converged=done,
                          aggregate_drift=post_drift)
    outcome = faults.FaultOutcome(
        final_drift=final_drift, post_drift=post_drift,
        dead=jnp.any(dead_row),
        repairs=jnp.sum(ftrace.repaired.astype(jnp.int32)),
        repaired_cols=jnp.sum(ftrace.repaired_cols) + fcols,
        max_repair_drift=jnp.max(ftrace.repair_drift))
    if not measure_wire:
        return result, (c0s, ct0s, active), ftrace, outcome
    return result, (c0s, ct0s, active), ftrace, outcome, WireMeasurement(
        rounds=sweeps, payload_bytes=sweeps * measured["sweep"] + fbytes,
        setup_bytes=jnp.int32(_nbytes((state0.loads, total_b))))


# ---------------------------------------------------------------------------
# Real-mesh driver: shard_map + lax.all_gather
# ---------------------------------------------------------------------------

def refine_distributed_shard_map(problem: PartitionProblem, assignment: Array,
                                 framework: str = costs.C_FRAMEWORK,
                                 num_shards: int | None = None,
                                 max_turns: int = 10_000,
                                 tol: float = DEFAULT_TOL,
                                 devices=None, theta=None,
                                 measure_wire: bool = False,
                                 recorder=None, fault_plan=None,
                                 degraded=None):
    """Sequential-turn refinement with each shard on its own device.

    Row blocks are placed along a 1-D ``Mesh`` axis ``"shards"``; the
    per-turn exchange is a real ``lax.all_gather`` of the 16-byte
    candidates; every device then elects/applies the identical delta to
    its replicated mirror (``check_rep=False`` because the replication
    invariant is ours, established by construction, not inferable by the
    partitioner).  Each device also carries its (Ns, K) block aggregate —
    built once at entry, updated by the same rank-1 delta every turn — so
    per-turn device compute is O(Ns·K), not O(Ns·N·K).  Requires
    ``num_shards`` addressable devices — the bench forces a multi-device
    host platform via ``XLA_FLAGS``; on one device it degenerates to a
    1-shard mesh (still the collective code path).

    ``measure_wire=True`` returns ``(result, wire)`` with a
    :class:`WireMeasurement` whose payload counts the real
    ``lax.all_gather`` output buffers per turn (DESIGN.md §14.5).
    ``recorder`` (a :class:`repro.obs.Recorder`) opts into run telemetry:
    a phase-timed ``run_start``/``wire``/``run_end`` stream with the
    measured bytes reconciled against the analytic ledger.
    """
    from jax.experimental.shard_map import shard_map

    k = problem.num_machines
    if devices is None:
        devices = jax.devices()
    s = _resolve_shards(problem, num_shards)
    if len(devices) < s:
        raise ValueError(
            f"refine_distributed_shard_map: need {s} devices for {s} shards "
            f"but only {len(devices)} are available; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={s} or use "
            f"the emulated refine_distributed driver")
    mesh = Mesh(np.asarray(devices[:s]), ("shards",))
    views = build_views(problem, s)
    state0 = make_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)
    # theta is a shard-local per-node input (DESIGN.md §11): placed on the
    # shard axis like the weights, never exchanged.  A zero block is the
    # exact no-threshold game (the subtraction of 0 is lossless in f32).
    theta_blocks = _shard_theta(theta, problem, s)
    if theta_blocks is None:
        theta_blocks = jnp.zeros((s, views.shard_size), jnp.float32)

    if fault_plan is not None:
        return _shard_map_faulty_run(
            problem, assignment, fault_plan, framework, s, mesh, views,
            state0, total_b, theta_blocks, theta, max_turns, tol,
            degraded or faults.DEFAULT_DEGRADED, measure_wire, recorder)

    measured: dict = {}

    def spmd(rb, b, ids, valid, th, r0, loads0, speeds, mu, tot):
        rb, b, ids, valid, th = rb[0], b[0], ids[0], valid[0], th[0]
        agg0 = protocol.block_aggregate(rb, r0, k)   # once, O(Ns·N·K)

        def cond(carry):
            _, _, _, _, idle, turns, _ = carry
            return (idle < k) & (turns < max_turns)

        def body(carry):
            r, loads, agg, machine, idle, turns, moves = carry
            cand = protocol.local_candidate_from_aggregate(
                agg, b, ids, valid, r, loads, speeds, mu, tot, machine,
                framework, theta_local=th)
            cands = protocol.Candidate(
                gain=jax.lax.all_gather(cand.gain, "shards"),
                node=jax.lax.all_gather(cand.node, "shards"),
                dest=jax.lax.all_gather(cand.dest, "shards"),
                weight=jax.lax.all_gather(cand.weight, "shards"))
            measured["turn"] = _nbytes(cands)
            winner = protocol.elect(cands, tol)
            agg = protocol.update_block_aggregate(
                agg, rb, winner.node, machine, winner.dest, winner.moved)
            r, loads = protocol.apply_move(r, loads, winner, machine)
            idle = jnp.where(winner.moved, 0, idle + 1)
            return (r, loads, agg, (machine + 1) % k, idle, turns + 1,
                    moves + winner.moved.astype(jnp.int32))

        init = (r0, loads0, agg0, jnp.zeros((), jnp.int32),
                jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                jnp.zeros((), jnp.int32))
        r, loads, _, _, idle, turns, moves = jax.lax.while_loop(
            cond, body, init)
        return r, loads, moves, turns, idle >= k

    sharded = P("shards")
    rep = P()
    fn = shard_map(spmd, mesh=mesh,
                   in_specs=(sharded, sharded, sharded, sharded, sharded,
                             rep, rep, rep, rep, rep),
                   out_specs=(rep, rep, rep, rep, rep),
                   check_rep=False)
    run = (None if recorder is None else
           _open_run(recorder, "shard_map", problem, assignment, framework,
                     theta, num_shards=s))
    args = (views.row_block, views.weights, views.ids, views.valid,
            theta_blocks, state0.assignment, state0.loads, problem.speeds,
            problem.mu, total_b)
    t0 = time.perf_counter()
    if recorder is None:
        r, loads, moves, turns, converged = jax.jit(fn)(*args)
    else:
        with recorder.phase("distributed.shard_map", run):
            out = jax.jit(fn)(*args)
            jax.block_until_ready(out)
        r, loads, moves, turns, converged = out
    wall = time.perf_counter() - t0
    result = RefineResult(assignment=r, loads=loads, num_moves=moves,
                          num_turns=turns, converged=converged)
    if not (measure_wire or recorder is not None):
        return result
    # jax.jit(fn) is freshly constructed above, so tracing always ran
    # this call and populated measured["turn"] with the gathered
    # candidates' buffer size.
    rounds = int(np.asarray(turns))
    wire = WireMeasurement(
        rounds=jnp.int32(rounds),
        payload_bytes=jnp.int32(rounds * measured["turn"]),
        setup_bytes=jnp.int32(_nbytes((state0.loads, total_b))))
    if recorder is not None:
        _record_wire(recorder, run, problem, s, wire)
        recorder.record_result(run, result, wall=wall)
    return (result, wire) if measure_wire else result


def _shard_map_faulty_run(problem: PartitionProblem, assignment: Array,
                          fault_plan, framework: str, s: int, mesh, views,
                          state0, total_b, theta_blocks, theta,
                          max_turns: int, tol: float, degraded,
                          measure_wire: bool, recorder):
    """Real-mesh faulty path (DESIGN.md §15.3): the FaultPlan rides
    replicated (one ``P()`` spec covers the whole pytree); each device
    masks/injects/repairs only its *own* block (``lax.axis_index``), the
    outcome scalars reduce with ``pmax``/``psum``, and the wrapper-level
    recover-or-raise audit is identical to the emulated drivers."""
    from jax.experimental.shard_map import shard_map

    k = problem.num_machines
    rtol = degraded.repair_tol
    penalty = degraded.stale_penalty
    msg = faults.message_bytes(traced=False, simultaneous=False,
                               num_machines=k)
    horizon = int(np.asarray(fault_plan.down).shape[0]) - 1
    measured: dict = {}

    def spmd(rb, b, ids, valid, th, r0, loads0, speeds, mu, tot, plan):
        rb, b, ids, valid, th = rb[0], b[0], ids[0], valid[0], th[0]
        idx = jax.lax.axis_index("shards")
        agg0 = protocol.block_aggregate(rb, r0, k)
        zero_i = jnp.zeros((), jnp.int32)
        zero_f = jnp.zeros((), jnp.float32)

        def cond(carry):
            return (carry[4] < k) & (carry[5] < max_turns)

        def body(carry):
            (r, loads, agg, machine, idle, turns, moves,
             fbytes, repairs, rcols, rdrift) = carry
            row = faults.plan_row(plan, turns)
            colmask = (jnp.arange(k, dtype=jnp.int32)
                       == row.corrupt_col[idx])
            agg = jnp.where(row.corrupt[idx] & colmask[None, :],
                            row.corrupt_val[idx], agg)
            cand = protocol.local_candidate_from_aggregate(
                agg, b, ids, valid, r, loads, speeds, mu, tot, machine,
                framework, theta_local=th)
            cands = protocol.Candidate(
                gain=jax.lax.all_gather(cand.gain, "shards"),
                node=jax.lax.all_gather(cand.node, "shards"),
                dest=jax.lax.all_gather(cand.dest, "shards"),
                weight=jax.lax.all_gather(cand.weight, "shards"))
            measured["turn"] = _nbytes(cands)
            blocked = row.down | row.quarantined | ~row.delivered
            cands = cands._replace(
                gain=jnp.where(blocked, -jnp.inf, cands.gain))
            winner = protocol.elect_degraded(cands, tol, row.lag, penalty)
            new_agg = protocol.update_block_aggregate(
                agg, rb, winner.node, machine, winner.dest, winner.moved)
            agg = jnp.where(row.omit[idx] | row.down[idx], agg, new_agg)
            r, loads = protocol.apply_move(r, loads, winner, machine)
            idle = jnp.where(winner.moved, 0,
                             jnp.where(row.clear, idle + 1, idle))

            def with_repair(a):
                fresh = protocol.block_aggregate(rb, r, k)
                col_dev = jnp.max(jnp.abs(a - fresh), axis=0)    # (K,)
                colbad = ~(col_dev <= rtol)
                patched = jnp.where(colbad[None, :], fresh, a)
                return (patched, jnp.max(_inf_dev(col_dev)),
                        jnp.sum(colbad.astype(jnp.int32)))

            agg, rd, rc = jax.lax.cond(
                row.repair[idx], with_repair,
                lambda a: (a, zero_f, zero_i), agg)
            fbytes = fbytes + faults.round_extra_bytes(row, msg)
            return (r, loads, agg, (machine + 1) % k, idle, turns + 1,
                    moves + winner.moved.astype(jnp.int32), fbytes,
                    repairs + row.repair[idx].astype(jnp.int32),
                    rcols + rc, jnp.maximum(rdrift, rd))

        init = (r0, loads0, agg0) + tuple(
            jnp.zeros((), jnp.int32) for _ in range(7)) + (
            jnp.zeros((), jnp.float32),)
        (r, loads, agg, _, idle, turns, moves, fbytes,
         repairs, rcols, rdrift) = jax.lax.while_loop(cond, body, init)
        converged = idle >= k
        last = jnp.clip(turns - 1, 0, horizon)
        dead_row = plan.down[last] & ~converged
        fresh = protocol.block_aggregate(rb, r, k)
        col_dev = jnp.max(jnp.abs(agg - fresh), axis=0)
        part = protocol.shard_load_partial(b, ids, valid, r, k)
        fresh_loads = jax.lax.psum(part, "shards")
        load_dev = _inf_dev(jnp.abs(loads - fresh_loads))
        final_drift = jax.lax.pmax(
            jnp.maximum(jnp.max(_inf_dev(col_dev)), jnp.max(load_dev)),
            "shards")
        sel = ~dead_row[idx] & ~(col_dev <= rtol)
        agg = jnp.where(sel[None, :], fresh, agg)
        loads = jnp.where(~(load_dev <= rtol), fresh_loads, loads)
        post_col = jnp.max(jnp.abs(agg - fresh), axis=0)
        post_drift = jax.lax.pmax(
            jnp.maximum(jnp.max(_inf_dev(post_col)),
                        jnp.max(_inf_dev(jnp.abs(loads - fresh_loads)))),
            "shards")
        fcols = jax.lax.psum(jnp.sum(sel.astype(jnp.int32)), "shards")
        return (r, loads, moves, turns, converged, fbytes,
                final_drift, post_drift, jnp.any(dead_row),
                jax.lax.psum(repairs, "shards"),
                jax.lax.psum(rcols, "shards") + fcols,
                jax.lax.pmax(rdrift, "shards"))

    sharded, rep = P("shards"), P()
    fn = shard_map(spmd, mesh=mesh,
                   in_specs=(sharded,) * 5 + (rep,) * 6,
                   out_specs=(rep,) * 12, check_rep=False)
    run = (None if recorder is None else
           _open_run(recorder, "shard_map", problem, assignment, framework,
                     theta, num_shards=s, faults=True))
    args = (views.row_block, views.weights, views.ids, views.valid,
            theta_blocks, state0.assignment, state0.loads, problem.speeds,
            problem.mu, total_b, fault_plan)
    t0 = time.perf_counter()
    if recorder is None:
        out = jax.jit(fn)(*args)
        jax.block_until_ready(out)
    else:
        with recorder.phase("distributed.shard_map", run):
            out = jax.jit(fn)(*args)
            jax.block_until_ready(out)
    wall = time.perf_counter() - t0
    (r, loads, moves, turns, converged, fbytes, final_drift, post_drift,
     dead, repairs, rcols, rdrift) = out
    result = RefineResult(assignment=r, loads=loads, num_moves=moves,
                          num_turns=turns, converged=converged,
                          aggregate_drift=post_drift)
    outcome = faults.FaultOutcome(
        final_drift=final_drift, post_drift=post_drift, dead=dead,
        repairs=repairs, repaired_cols=rcols, max_repair_drift=rdrift)
    rounds = int(np.asarray(turns))
    report = faults.build_report(fault_plan, outcome, rounds,
                                 budget=degraded.repair_tol,
                                 raise_on_failure=False)
    wire = None
    if measure_wire or recorder is not None:
        wire = WireMeasurement(
            rounds=jnp.int32(rounds),
            payload_bytes=jnp.int32(rounds * measured["turn"]
                                    + int(np.asarray(fbytes))),
            setup_bytes=jnp.int32(_nbytes((state0.loads, total_b))))
    if recorder is not None:
        faults.emit_fault_events(recorder, run, fault_plan, rounds)
        _record_wire(recorder, run, problem, s, wire,
                     fault_extra=faults.plan_extra_bytes(
                         fault_plan, rounds, msg))
        recorder.record_result(run, result, wall=wall,
                               recovered=report.recovered,
                               recovery_drift=report.recovery_drift)
    faults.raise_if_failed(report, budget=degraded.repair_tol)
    if measure_wire:
        return result, wire, report
    return result, report


# ---------------------------------------------------------------------------
# Telemetry wrappers (DESIGN.md §14)
# ---------------------------------------------------------------------------

def _record_wire(recorder, run: str, problem: PartitionProblem,
                 num_shards: int, wire: WireMeasurement, *,
                 traced: bool = False, simultaneous: bool = False,
                 incremental: bool = True, fault_extra: int = 0) -> None:
    """Reconcile a driver's measured wire counters against the analytic
    ledger for the same executed run and emit the ``wire`` event.
    ``fault_extra`` is the plan-derived retry/repair byte total of a
    fault-injected run (``faults.plan_extra_bytes``)."""
    stats = boundary_stats(problem, num_shards)
    ledger = accounting.ledger_for_run(
        stats, problem.num_machines, int(wire.rounds), traced=traced,
        simultaneous=simultaneous, incremental=incremental,
        fault_bytes=fault_extra)
    recorder.record_wire(run, accounting.reconcile(ledger, wire))


def _run_faulty_emulated(mode: str, problem: PartitionProblem,
                         assignment: Array, fault_plan, framework,
                         num_shards, max_rounds: int, tol: float,
                         cost_fn: str, incremental: bool, theta, degraded,
                         measure_wire: bool, recorder):
    """Shared recover-or-raise harness behind the three emulated public
    wrappers: run the faulty driver, audit its FaultOutcome into a
    :class:`faults.FaultReport`, stream telemetry when asked, and raise
    the typed error on a dead shard / blown recovery budget."""
    if not incremental:
        raise ValueError(
            "fault injection requires the incremental protocol: the "
            "carried block aggregates are what faults corrupt and what "
            "repair heals (DESIGN.md §15)")
    dm = degraded or faults.DEFAULT_DEGRADED
    s = _resolve_shards(problem, num_shards)
    k = problem.num_machines
    traced = mode == "traced"
    simultaneous = mode == "sweep"
    impl = {"plain": _refine_distributed_faulty,
            "traced": _refine_distributed_traced_faulty,
            "sweep": _refine_distributed_simultaneous_faulty}[mode]
    phase = {"plain": "distributed.refine",
             "traced": "distributed.refine_traced",
             "sweep": "distributed.refine_simultaneous"}[mode]
    runtime_name = {"plain": "distributed", "traced": "distributed_traced",
                    "sweep": "distributed_sweep"}[mode]
    mw = measure_wire or recorder is not None
    run = None
    if recorder is not None:
        run = _open_run(recorder, runtime_name, problem, assignment,
                        framework, theta, num_shards=s, incremental=True,
                        faults=True)
    ctx = (recorder.phase(phase, run) if recorder is not None
           else contextlib.nullcontext())
    t0 = time.perf_counter()
    with ctx:
        out = impl(problem, assignment, fault_plan, framework,
                   num_shards=s, max_rounds=max_rounds, tol=tol,
                   cost_fn=cost_fn, degraded=dm, theta=theta,
                   measure_wire=mw)
        jax.block_until_ready(out)
    wall = time.perf_counter() - t0
    wire = out[-1] if mw else None
    core = out[:-1] if mw else out
    ftrace = None
    if mode == "plain":
        result, outcome = core
        extras = ()
    elif mode == "traced":
        result, trace, ftrace, outcome = core
        extras = (trace,)
    else:
        result, outs, ftrace, outcome = core
        extras = (outs,)
    rounds = int(result.num_turns)
    report = faults.build_report(fault_plan, outcome, rounds,
                                 budget=dm.repair_tol,
                                 raise_on_failure=False)
    if recorder is not None:
        if ftrace is not None:
            faults.emit_fault_events(
                recorder, run, fault_plan, rounds,
                repair_drift=ftrace.repair_drift,
                repaired_cols=ftrace.repaired_cols,
                repaired=ftrace.repaired)
        else:
            faults.emit_fault_events(recorder, run, fault_plan, rounds)
        last = max(rounds - 1, 0)
        c0 = ct0 = None
        if mode == "traced":
            recorder.record_trace(run, extras[0], problem.node_weights, k)
            if rounds:
                c0 = float(np.asarray(extras[0].c0)[last])
                ct0 = float(np.asarray(extras[0].ct0)[last])
        elif mode == "sweep":
            recorder.record_sweeps(run, *extras[0])
            if rounds:
                c0 = float(np.asarray(extras[0][0])[last])
                ct0 = float(np.asarray(extras[0][1])[last])
        _record_wire(recorder, run, problem, s, wire, traced=traced,
                     simultaneous=simultaneous, incremental=True,
                     fault_extra=faults.plan_extra_bytes(
                         fault_plan, rounds, faults.message_bytes(
                             traced=traced, simultaneous=simultaneous,
                             num_machines=k)))
        recorder.record_result(run, result, wall=wall, c0=c0, ct0=ct0,
                               recovered=report.recovered,
                               recovery_drift=report.recovery_drift)
    faults.raise_if_failed(report, budget=dm.repair_tol)
    if measure_wire:
        return (result, *extras, wire, report)
    return (result, *extras, report)


def refine_distributed(problem: PartitionProblem, assignment: Array,
                       framework: str = costs.C_FRAMEWORK,
                       num_shards: int | None = None,
                       max_turns: int = 10_000, tol: float = DEFAULT_TOL,
                       cost_fn: str = "jnp",
                       incremental: bool = True,
                       theta=None, measure_wire: bool = False,
                       recorder=None, fault_plan=None, degraded=None):
    """Distributed round-robin refinement (see :func:`_refine_distributed`
    for the protocol).  ``recorder`` (a :class:`repro.obs.Recorder`) opts
    into run telemetry: the run is phase-timed, its measured wire bytes
    are reconciled against ``accounting.ledger_for_run``, and the stream
    closes with drift + ``run_end`` events.  ``recorder=None`` dispatches
    straight to the identical jitted program — same cache entry.

    ``fault_plan`` (a :class:`repro.distributed.faults.FaultPlan`) opts
    into the fault-injected driver under ``degraded``-mode rules
    (DESIGN.md §15): returns ``(result, report[, wire in between])`` with
    a :class:`faults.FaultReport` appended, raising ``DeadShardError`` /
    ``RecoveryFailedError`` when the run cannot recover to the drift
    budget — never silently diverging."""
    if fault_plan is not None:
        return _run_faulty_emulated(
            "plain", problem, assignment, fault_plan, framework,
            num_shards, max_turns, tol, cost_fn, incremental, theta,
            degraded, measure_wire, recorder)
    if recorder is None:
        return _refine_distributed(
            problem, assignment, framework, num_shards=num_shards,
            max_turns=max_turns, tol=tol, cost_fn=cost_fn,
            incremental=incremental, theta=theta, measure_wire=measure_wire)
    s = _resolve_shards(problem, num_shards)
    run = _open_run(recorder, "distributed", problem, assignment, framework,
                    theta, num_shards=s, incremental=incremental)
    t0 = time.perf_counter()
    with recorder.phase("distributed.refine", run):
        result, wire = _refine_distributed(
            problem, assignment, framework, num_shards=s,
            max_turns=max_turns, tol=tol, cost_fn=cost_fn,
            incremental=incremental, theta=theta, measure_wire=True)
        jax.block_until_ready(result)
    wall = time.perf_counter() - t0
    _record_wire(recorder, run, problem, s, wire, incremental=incremental)
    recorder.record_result(run, result, wall=wall)
    return (result, wire) if measure_wire else result


def refine_distributed_traced(problem: PartitionProblem, assignment: Array,
                              framework: str = costs.C_FRAMEWORK,
                              num_shards: int | None = None,
                              max_turns: int = 512,
                              tol: float = DEFAULT_TOL,
                              cost_fn: str = "jnp",
                              incremental: bool = True,
                              theta=None, measure_wire: bool = False,
                              recorder=None, fault_plan=None,
                              degraded=None):
    """Traced distributed refinement (see :func:`_refine_distributed_traced`).
    ``recorder`` additionally streams one ``turn`` event per active turn
    (from the returned trace — the carried exact-potential values ride
    along) and the measured-vs-ledger ``wire`` reconciliation.
    ``fault_plan`` as in :func:`refine_distributed` — the return tuple
    gains a trailing :class:`faults.FaultReport`."""
    if fault_plan is not None:
        return _run_faulty_emulated(
            "traced", problem, assignment, fault_plan, framework,
            num_shards, max_turns, tol, cost_fn, incremental, theta,
            degraded, measure_wire, recorder)
    if recorder is None:
        return _refine_distributed_traced(
            problem, assignment, framework, num_shards=num_shards,
            max_turns=max_turns, tol=tol, cost_fn=cost_fn,
            incremental=incremental, theta=theta, measure_wire=measure_wire)
    s = _resolve_shards(problem, num_shards)
    run = _open_run(recorder, "distributed_traced", problem, assignment,
                    framework, theta, num_shards=s, incremental=incremental)
    t0 = time.perf_counter()
    with recorder.phase("distributed.refine_traced", run):
        result, trace, wire = _refine_distributed_traced(
            problem, assignment, framework, num_shards=s,
            max_turns=max_turns, tol=tol, cost_fn=cost_fn,
            incremental=incremental, theta=theta, measure_wire=True)
        jax.block_until_ready(result)
    wall = time.perf_counter() - t0
    recorder.record_trace(run, trace, problem.node_weights,
                          problem.num_machines)
    _record_wire(recorder, run, problem, s, wire, traced=True,
                 incremental=incremental)
    turns = int(result.num_turns)
    last = max(turns - 1, 0)
    recorder.record_result(
        run, result, wall=wall,
        c0=float(np.asarray(trace.c0)[last]) if turns else None,
        ct0=float(np.asarray(trace.ct0)[last]) if turns else None)
    return (result, trace, wire) if measure_wire else (result, trace)


def refine_distributed_simultaneous(problem: PartitionProblem,
                                    assignment: Array,
                                    framework: str = costs.C_FRAMEWORK,
                                    num_shards: int | None = None,
                                    max_sweeps: int = 256,
                                    tol: float = DEFAULT_TOL,
                                    cost_fn: str = "jnp",
                                    incremental: bool = True,
                                    theta=None, measure_wire: bool = False,
                                    recorder=None, fault_plan=None,
                                    degraded=None):
    """Distributed §4.5 sweeps (see :func:`_refine_distributed_simultaneous`).
    ``recorder`` streams one ``sweep`` event per active sweep plus the
    measured-vs-ledger ``wire`` reconciliation.  ``fault_plan`` as in
    :func:`refine_distributed` — the return tuple gains a trailing
    :class:`faults.FaultReport`."""
    if fault_plan is not None:
        return _run_faulty_emulated(
            "sweep", problem, assignment, fault_plan, framework,
            num_shards, max_sweeps, tol, cost_fn, incremental, theta,
            degraded, measure_wire, recorder)
    if recorder is None:
        return _refine_distributed_simultaneous(
            problem, assignment, framework, num_shards=num_shards,
            max_sweeps=max_sweeps, tol=tol, cost_fn=cost_fn,
            incremental=incremental, theta=theta, measure_wire=measure_wire)
    s = _resolve_shards(problem, num_shards)
    run = _open_run(recorder, "distributed_sweep", problem, assignment,
                    framework, theta, num_shards=s, incremental=incremental)
    t0 = time.perf_counter()
    with recorder.phase("distributed.refine_simultaneous", run):
        result, (c0s, ct0s, active), wire = _refine_distributed_simultaneous(
            problem, assignment, framework, num_shards=s,
            max_sweeps=max_sweeps, tol=tol, cost_fn=cost_fn,
            incremental=incremental, theta=theta, measure_wire=True)
        jax.block_until_ready(result)
    wall = time.perf_counter() - t0
    recorder.record_sweeps(run, c0s, ct0s, active)
    _record_wire(recorder, run, problem, s, wire, simultaneous=True,
                 incremental=incremental)
    sweeps = int(result.num_turns)
    last = max(sweeps - 1, 0)
    recorder.record_result(
        run, result, wall=wall,
        c0=float(np.asarray(c0s)[last]) if sweeps else None,
        ct0=float(np.asarray(ct0s)[last]) if sweeps else None)
    return ((result, (c0s, ct0s, active), wire) if measure_wire
            else (result, (c0s, ct0s, active)))
