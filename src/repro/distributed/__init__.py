"""``repro.distributed`` — sharded multi-machine refinement runtime.

Executes the round-robin refinement game of :mod:`repro.core.refine` as a
genuinely distributed program (DESIGN.md §9): node state lives sharded
across machines, every machine computes candidate moves from its local
shard plus a replicated O(K) load vector, and machines exchange only O(K)
aggregate messages per turn — the paper's central scalability claim
("aggregate state information required to be exchanged between the
machines is independent of the size of the simulated network model").

Modules:
  * :mod:`~repro.distributed.views`      — per-machine local views and
    ghost/boundary summaries.
  * :mod:`~repro.distributed.protocol`   — the O(K) message types, shard-
    local candidate computation, deterministic election, delta application.
  * :mod:`~repro.distributed.runtime`    — the drivers: emulated SPMD
    (vmap over shards, runs on 1 device), real ``shard_map`` over a device
    mesh, sequential-turn and §4.5 simultaneous-sweep modes.
  * :mod:`~repro.distributed.accounting` — bytes-exchanged ledgers proving
    the O(K + boundary) bound empirically.
  * :mod:`~repro.distributed.faults`     — seeded fault injection
    (FaultPlan), degraded-mode policy (DegradedMode) and the
    recover-or-raise report types (DESIGN.md §15).
"""
from .accounting import ExchangeLedger, WireCheck, ledger_for_run, reconcile
from .faults import (DeadShardError, DegradedMode, FaultPlan, FaultReport,
                     FaultToleranceError, RecoveryFailedError,
                     make_fault_plan, zero_fault_plan)
from .runtime import (WireMeasurement, refine_distributed,
                      refine_distributed_shard_map,
                      refine_distributed_simultaneous,
                      refine_distributed_traced, shard_problem)
from .views import ShardViews, boundary_stats, build_views

__all__ = [
    "DeadShardError",
    "DegradedMode",
    "ExchangeLedger",
    "FaultPlan",
    "FaultReport",
    "FaultToleranceError",
    "RecoveryFailedError",
    "ShardViews",
    "WireCheck",
    "WireMeasurement",
    "boundary_stats",
    "build_views",
    "ledger_for_run",
    "make_fault_plan",
    "zero_fault_plan",
    "reconcile",
    "refine_distributed",
    "refine_distributed_shard_map",
    "refine_distributed_simultaneous",
    "refine_distributed_traced",
    "shard_problem",
]
