"""Bytes-exchanged accounting for the distributed protocol (DESIGN.md §9.3).

The protocol is deterministic, so the inter-machine payload of a run is an
exact function of what actually executed: number of turns/sweeps taken,
shard count S, machine count K, and the one-time ghost sync sized by the
sharding's boundary structure.  :func:`ledger_for_run` builds the ledger
from those measured quantities; the key property it exposes — and that
``benchmarks/distributed_bench.py`` verifies empirically across N = 256 →
4096 — is that **per-round payload contains no O(N) term**:

Incremental protocol (the default, DESIGN.md §10):

    sequential turn : S * 16 B                     (candidate all-gather)
    traced turn     : + S * 8 B                    (ΔC_0/ΔCt_0 exact-
                                                    potential deltas riding
                                                    on each candidate)
    §4.5 sweep      : S * (16K + 8K + 4) B         (K candidates + load &
                                                    sq-load partials + cut
                                                    partial per shard)
    one-time setup  : 8 * sum_s ghost_s  +  4K + 4 (ghost sync, loads, B)
    traced setup    : + S * 8                      (initial-potential
                                                    C_0/cut partial pair;
                                                    the loads are already
                                                    replicated by the 4K+4
                                                    setup allreduce)

Recompute protocol (``incremental=False`` drivers — pass
``incremental=False`` here too, the wire shapes differ):

    traced turn     : + S * (8 + 4K) B             (per-turn C_0/cut
                                                    partials + fresh O(K)
                                                    load partial)
    §4.5 sweep      : S * (16K + 4K + 8) B         (K candidates + load
                                                    partial + C_0/cut
                                                    partials per shard)

For contrast, :func:`naive_broadcast_bytes` gives the per-round cost of
the strawman protocol that re-broadcasts the full assignment vector —
O(N) per round — which the bench prints side by side.
"""
from __future__ import annotations

import dataclasses

from . import protocol
from .views import BoundaryStats


@dataclasses.dataclass(frozen=True)
class ExchangeLedger:
    """Inter-machine byte counters for one refinement run."""
    num_shards: int
    num_machines: int
    rounds: int                 # turns (sequential) or sweeps (§4.5)
    candidate_bytes: int        # per-candidate all-gathers, whole run
    trace_bytes: int            # potential partials (0 for untraced runs)
    ghost_sync_bytes: int       # one-time boundary-assignment sync
    setup_bytes: int            # one-time loads allreduce + total-B scalar
    fault_bytes: int = 0        # retry/duplicate re-sends + repair traffic
                                # (0 for fault-free runs; DESIGN.md §15.4)

    @property
    def per_round_bytes(self) -> float:
        """Steady-state payload per round — the O(K) quantity the paper
        claims is independent of N.  Fault traffic is excluded: retries
        are O(K) bursts and repair is amortized, reported separately."""
        if self.rounds == 0:
            return 0.0
        return (self.candidate_bytes + self.trace_bytes) / self.rounds

    @property
    def total_bytes(self) -> int:
        return (self.candidate_bytes + self.trace_bytes + self.fault_bytes
                + self.ghost_sync_bytes + self.setup_bytes)

    def summary(self) -> str:
        return (f"S={self.num_shards} K={self.num_machines} "
                f"rounds={self.rounds}: {self.per_round_bytes:.0f} B/round "
                f"steady-state, {self.ghost_sync_bytes} B ghost sync, "
                f"{self.total_bytes} B total")


def turn_payload_bytes(num_shards: int, num_machines: int,
                       traced: bool = False,
                       incremental: bool = True) -> int:
    """Wire bytes of ONE sequential turn (all machines combined).

    Incremental traced turns attach the two exact-potential-identity
    deltas to each candidate (8 B) — no per-turn partial reduction; the
    potentials are replicated state updated by the winner's deltas
    (DESIGN.md §10).  Recompute traced turns instead reduce per-shard
    C_0/cut partials plus a fresh O(K) load partial every turn."""
    bytes_ = num_shards * protocol.CANDIDATE_BYTES
    if traced:
        bytes_ += num_shards * protocol.TRACE_PARTIAL_BYTES
        if not incremental:
            bytes_ += num_shards * protocol.load_partial_bytes(num_machines)
    return bytes_


def sweep_payload_bytes(num_shards: int, num_machines: int,
                        incremental: bool = True) -> int:
    """Wire bytes of ONE §4.5 simultaneous sweep: K candidates per shard,
    plus — incrementally — the fresh O(K) load and sq-load partials and
    the f32 cut partial for the closed-form potentials (simultaneous
    moves are not unilateral, so the identity deltas do not apply).  The
    recompute sweep ships one load partial and the 8-byte C_0/cut
    partial pair per shard instead."""
    per_shard = num_machines * protocol.CANDIDATE_BYTES
    if incremental:
        per_shard += 2 * protocol.load_partial_bytes(num_machines) + 4
    else:
        per_shard += (protocol.load_partial_bytes(num_machines)
                      + protocol.TRACE_PARTIAL_BYTES)
    return num_shards * per_shard


def ghost_sync_bytes(stats: BoundaryStats) -> int:
    """One-time boundary sync: each shard receives (node id, assignment)
    pairs for its ghost nodes — 8 bytes per ghost."""
    return 8 * stats.total_ghosts


def setup_bytes(num_machines: int) -> int:
    """One-time replicated aggregates: the O(K) load vector + scalar B."""
    return 4 * num_machines + 4


def init_potential_bytes(num_shards: int, num_machines: int) -> int:
    """One-time traced-run setup: the initial-potential partial reduction
    (C_0 partial + cut partial per shard).

    No load partial rides along: the traced driver seeds the reduction
    with the loads the 4K+4 setup allreduce already replicated
    (``fresh_loads=state0.loads`` in ``runtime._vmap_potentials``), so
    charging an O(K) block per shard here would over-count — the
    measured-wire cross-check of DESIGN.md §14.5 is what caught the
    discrepancy (``num_machines`` stays in the signature for call-site
    symmetry with the other formulas)."""
    del num_machines
    return num_shards * protocol.TRACE_PARTIAL_BYTES


def ledger_for_run(stats: BoundaryStats, num_machines: int, rounds: int,
                   *, traced: bool = False, simultaneous: bool = False,
                   incremental: bool = True,
                   fault_bytes: int = 0) -> ExchangeLedger:
    """Ledger for an executed run (``rounds`` = its measured turn count).

    ``incremental`` must match the driver flag the run used — the traced
    and sweep wire shapes differ between the two protocols (see the
    module docstring).  ``fault_bytes`` is the degraded-mode extra
    traffic (candidate re-sends + repair payloads) of a fault-injected
    run, computed from its :class:`repro.distributed.faults.FaultPlan`
    via ``faults.plan_extra_bytes`` — the drivers accumulate the same
    per-round sum on device, so :func:`reconcile` stays byte-exact."""
    s = stats.num_shards
    setup = setup_bytes(num_machines)
    if simultaneous:
        per_round = sweep_payload_bytes(s, num_machines,
                                        incremental=incremental)
        trace = 0
    else:
        per_round = s * protocol.CANDIDATE_BYTES
        trace = rounds * (turn_payload_bytes(s, num_machines, traced,
                                             incremental=incremental)
                          - per_round)
        if traced and incremental:
            setup += init_potential_bytes(s, num_machines)
    return ExchangeLedger(
        num_shards=s,
        num_machines=num_machines,
        rounds=rounds,
        candidate_bytes=rounds * per_round,
        trace_bytes=trace,
        ghost_sync_bytes=ghost_sync_bytes(stats),
        setup_bytes=setup,
        fault_bytes=int(fault_bytes),
    )


def naive_broadcast_bytes(num_nodes: int, num_shards: int) -> int:
    """Per-round cost of the O(N) strawman: every shard re-receives the
    full int32 assignment vector each round."""
    return 4 * num_nodes * num_shards


@dataclasses.dataclass(frozen=True)
class WireCheck:
    """Measured-vs-analytic reconciliation of one run's exchange bytes.

    ``measured_*`` comes from a driver's ``measure_wire=True`` counters
    (``runtime.WireMeasurement`` — byte sizes of the actual exchanged
    device buffers times the rounds the run executed); ``predicted_*``
    from :func:`ledger_for_run`.  The payload comparison covers the
    per-round candidate + trace traffic; setup covers the one-time
    loads/total-B allreduce plus, for incremental traced runs, the
    initial-potential partials.  The ghost sync is excluded on both
    sides: it is a property of the *sharding's boundary structure*, not
    of anything the emulated drivers exchange at runtime, so it stays
    analytic-only (DESIGN.md §14.5).
    """
    rounds: int
    measured_payload: int
    predicted_payload: int
    measured_setup: int
    predicted_setup: int

    @property
    def ok(self) -> bool:
        return (self.measured_payload == self.predicted_payload
                and self.measured_setup == self.predicted_setup)

    def summary(self) -> str:
        verdict = "OK" if self.ok else "MISMATCH"
        return (f"wire [{verdict}] rounds={self.rounds}: payload "
                f"{self.measured_payload} B measured vs "
                f"{self.predicted_payload} B predicted, setup "
                f"{self.measured_setup} vs {self.predicted_setup} B")


def reconcile(ledger: ExchangeLedger, measurement) -> WireCheck:
    """Cross-check a ``runtime.WireMeasurement`` against its ledger.

    Build the ledger with ``rounds=int(measurement.rounds)`` (both sides
    must describe the same executed run) and matching ``traced`` /
    ``simultaneous`` / ``incremental`` flags — the O(K)-wire claim then
    becomes the runtime assertion ``reconcile(...).ok``.
    """
    rounds = int(measurement.rounds)
    if rounds != ledger.rounds:
        raise ValueError(
            f"measurement covers {rounds} rounds but the ledger was built "
            f"for {ledger.rounds}; pass rounds=int(measurement.rounds) to "
            "ledger_for_run")
    return WireCheck(
        rounds=rounds,
        measured_payload=int(measurement.payload_bytes),
        predicted_payload=(ledger.candidate_bytes + ledger.trace_bytes
                          + ledger.fault_bytes),
        measured_setup=int(measurement.setup_bytes),
        predicted_setup=ledger.setup_bytes,
    )
