"""complexity-family analyzers: static asymptotics certification (DESIGN.md §18).

The paper's feasibility claim is that per-round exchange is independent
of the simulated network's size (arXiv 1111.0875 §5), and the repo's
scaling story rests on asymptotic promises — O(E) sparse aggregates,
O(N*K) cost assembly, O(K) wire — that runtime benches only sample at a
few sizes.  This family certifies them *at trace time*: every
registered entry point is retraced over a geometric grid of problem
sizes (nothing executes — ``jax.make_jaxpr`` is shape-symbolic), the
jaxprs are walked recursively through scan/while/cond/pjit/shard_map
sub-jaxprs, and

  * **mem/ops budgets** — peak single-equation intermediate bytes and a
    per-primitive op-count proxy are fitted to power laws in N, K and
    (on sparse paths) degree; a fitted exponent above the budget the
    owning module declares (``SPARSE_COMPLEXITY`` et al.) is a finding.
    A stray dense ``(N, N)`` intermediate on a sparse path shows up as
    an N-exponent near 2 against a budget of 1.
  * **collective audit** — psum/all_gather-family primitives are
    classified as recurring (inside the refinement loop) or setup, and
    their per-shard operand bytes must be independent of N and equal to
    the declared ledger constants (§9.2/§14.5) — generalizing
    ``wire_rules`` from protocol buffers to the full traced program.
  * **expectation table** — fitted exponents and collective schedules
    are diffed against the checked-in ``complexity.json`` (analogous to
    ``baseline.json``), making this a complexity-*regression* gate:
    CI sees exponent drift even while it stays under budget.

Findings functions take explicit inputs so the seeded-violation tests
can drive them with deliberately quadratic fixtures, mirroring the
other families (DESIGN.md §16.2).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from functools import lru_cache
from typing import Callable

import numpy as np

import jax

from .registry import AnalysisContext, Finding, rule
from .jaxpr_rules import _sub_jaxprs, iter_eqns
from ..launch.jaxpr_flops import _dot_flops as dot_flops
from . import entrypoints

__all__ = [
    "Grid", "GRIDS", "EXPONENT_TOL", "EXPECTATION_TOL",
    "Measurement", "measure_jaxpr", "collective_schedule", "fit_exponent",
    "profile_trace", "profile_entry_point", "declared_budget",
    "budget_findings", "exponent_findings", "collective_findings",
    "expectation_findings", "default_table_path", "load_table",
    "build_table_entry", "update_table", "all_profiles",
]

# A fitted exponent may exceed its declared budget by this much before
# it is a finding: absorbs padding noise (EDGE_PAD_MULTIPLE=128 edge
# rounding, DEGREE_PAD_MULTIPLE=8 max-degree growth under stitching)
# while staying far below the +1.0 jump of a genuine dense
# materialization on a sparse path.
EXPONENT_TOL = 0.35

# Allowed drift of a re-fitted exponent against the checked-in
# complexity.json before the regression gate fires.  Fits are exact
# shape arithmetic, so same-toolchain refits reproduce bit-identically;
# the slack absorbs jaxpr changes across jax versions.
EXPECTATION_TOL = 0.1

_LOOP_PRIMS = frozenset({"while", "scan"})
_COLLECTIVE_TOKENS = ("psum", "all_gather", "ppermute", "all_to_all",
                      "pmax", "pmin", "pbroadcast", "reduce_scatter",
                      "pgather", "pshuffle")


# -- size grids -------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Grid:
    """One geometric sweep layout: N varied at fixed K (and fixed degree
    on sparse paths), K varied at a fixed N, degree varied at a fixed N
    (sparse only — it scales E independently of N)."""
    name: str
    n: tuple[int, ...]
    k_fixed: int
    k: tuple[int, ...]
    n_for_k: int
    degree: tuple[int, ...]
    n_for_degree: int
    degree_fixed: int = 8


GRIDS = {
    "full": Grid("full", n=(64, 256, 1024, 4096), k_fixed=4,
                 k=(2, 4, 8), n_for_k=256,
                 degree=(4, 8, 16), n_for_degree=1024),
    "quick": Grid("quick", n=(32, 64, 128, 256), k_fixed=4,
                  k=(2, 4, 8), n_for_k=64,
                  degree=(4, 8, 16), n_for_degree=128),
}


# -- jaxpr measurement ------------------------------------------------------

def _aval_bytes(aval) -> int:
    dtype = getattr(aval, "dtype", None)
    shape = getattr(aval, "shape", None)
    if dtype is None or shape is None:
        return 0
    try:
        if jax.dtypes.issubdtype(dtype, jax.dtypes.extended):
            itemsize = 4              # PRNG key words
        else:
            itemsize = np.dtype(dtype).itemsize
    except TypeError:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * itemsize


def _aval_elems(aval) -> int:
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    return int(np.prod(shape, dtype=np.int64))


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Static byte/op profile of one traced program."""
    peak_bytes: int          # largest single equation-output aval
    peak_shape: tuple        # its shape (the "(N, N) intermediate" story)
    peak_primitive: str
    arg_bytes: int           # top-level inputs + closed-over constants
    ops: int                 # element-count proxy; dot_general counted exactly


def measure_jaxpr(closed) -> Measurement:
    """Walk every equation (incl. nested sub-jaxprs, each body once) and
    record the peak intermediate and the op-count proxy: dot_general
    contributes exact FLOPs, everything else its output element count —
    a scaling proxy, not a cost model (the fits only need exponents)."""
    peak, peak_shape, peak_prim, ops = 0, (), "", 0
    for eqn in iter_eqns(closed):
        if eqn.primitive.name == "dot_general":
            ops += dot_flops(eqn)
        for v in eqn.outvars:
            b = _aval_bytes(v.aval)
            if eqn.primitive.name != "dot_general":
                ops += _aval_elems(v.aval)
            if b > peak:
                peak = b
                peak_shape = tuple(getattr(v.aval, "shape", ()))
                peak_prim = eqn.primitive.name
    jaxpr = getattr(closed, "jaxpr", closed)
    consts = getattr(jaxpr, "constvars", ())
    arg_bytes = sum(_aval_bytes(v.aval) for v in (*jaxpr.invars, *consts))
    return Measurement(peak_bytes=peak, peak_shape=peak_shape,
                       peak_primitive=peak_prim, arg_bytes=arg_bytes,
                       ops=ops)


def collective_schedule(closed) -> tuple[tuple[str, str, int], ...]:
    """Every psum/all_gather-family equation as (primitive, phase,
    per-shard operand bytes), phase = "recurring" when the equation sits
    inside a while/scan body (once per refinement round) else "setup".
    Operand avals inside shard_map bodies are per-shard by construction,
    which is exactly the ledger's unit (§14.5)."""
    out: list[tuple[str, str, int]] = []

    def walk(jaxpr, in_loop: bool):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if any(tok in name for tok in _COLLECTIVE_TOKENS):
                in_b = sum(_aval_bytes(getattr(v, "aval", None))
                           for v in eqn.invars)
                out.append((name, "recurring" if in_loop else "setup", in_b))
            child_in_loop = in_loop or name in _LOOP_PRIMS
            for sub in _sub_jaxprs(eqn.params):
                walk(sub, child_in_loop)

    jaxpr = getattr(closed, "jaxpr", closed)
    walk(jaxpr, False)
    return tuple(out)


# -- power-law fitting ------------------------------------------------------

def fit_exponent(sizes, values) -> float:
    """Least-squares slope of log2(value) against log2(size): the fitted
    exponent of the best power law through the grid points."""
    xs = np.log2(np.asarray(sizes, dtype=np.float64))
    ys = np.log2(np.maximum(np.asarray(values, dtype=np.float64), 1.0))
    if xs.size < 2 or np.ptp(xs) == 0.0:
        return 0.0
    a = np.stack([xs, np.ones_like(xs)], axis=1)
    slope = np.linalg.lstsq(a, ys, rcond=None)[0][0]
    return float(slope)


def profile_trace(trace_fn: Callable[..., object], grid: Grid, *,
                  sparse: bool = False, max_n: int | None = None) -> dict:
    """Fit the mem/ops exponents of ``trace_fn(n, k, degree)`` over a
    grid, and summarize its collective schedule across the N sweep.

    Returns ``{"fits": {"mem": {dim: exp}, "ops": {...}}, "peak_shape",
    "peak_primitive", "collectives": {"n_independent", "recurring_bytes",
    "setup_bytes", "schedule"}}``.  The seeded-violation tests call this
    directly with fixture trace functions.
    """
    deg = grid.degree_fixed if sparse else None
    ns = tuple(n for n in grid.n if max_n is None or n <= max_n)
    n_traces = [trace_fn(n, grid.k_fixed, deg) for n in ns]
    n_meas = [measure_jaxpr(tr) for tr in n_traces]
    scheds = [collective_schedule(tr) for tr in n_traces]

    n_for_k = min((grid.n_for_k, *(m for m in (max_n,) if m is not None)))
    k_meas = [measure_jaxpr(trace_fn(n_for_k, k, deg)) for k in grid.k]

    fits = {
        "mem": {"n": fit_exponent(ns, [m.peak_bytes for m in n_meas]),
                "k": fit_exponent(grid.k, [m.peak_bytes for m in k_meas])},
        "ops": {"n": fit_exponent(ns, [m.ops for m in n_meas]),
                "k": fit_exponent(grid.k, [m.ops for m in k_meas])},
    }
    if sparse:
        n_for_d = min((grid.n_for_degree,
                       *(m for m in (max_n,) if m is not None)))
        d_meas = [measure_jaxpr(trace_fn(n_for_d, grid.k_fixed, d))
                  for d in grid.degree]
        fits["mem"]["e"] = fit_exponent(grid.degree,
                                        [m.peak_bytes for m in d_meas])
        fits["ops"]["e"] = fit_exponent(grid.degree,
                                        [m.ops for m in d_meas])

    top = n_meas[-1]
    return {
        "fits": fits,
        "peak_shape": top.peak_shape,
        "peak_primitive": top.peak_primitive,
        "collectives": {
            "n_independent": all(s == scheds[0] for s in scheds),
            "recurring_bytes": sum(b for _, ph, b in scheds[-1]
                                   if ph == "recurring"),
            "setup_bytes": sum(b for _, ph, b in scheds[-1]
                               if ph == "setup"),
            "schedule": scheds[-1],
        },
    }


@lru_cache(maxsize=None)
def profile_entry_point(name: str, grid_name: str) -> dict:
    """Grid profile of a registered entry point (cached per process —
    the CLI, CI and the test suite share the tracing work)."""
    ep = entrypoints.entry_point(name)
    return profile_trace(
        lambda n, k, degree: entrypoints.trace_entry_point_sized(
            name, n, k, degree),
        GRIDS[grid_name], sparse=(ep.rep == "sparse"), max_n=ep.max_n)


# -- declared budgets -------------------------------------------------------

_ZERO_COLLECTIVES = {"recurring_bytes": 0, "setup_bytes": 0}


def _module_attr(modname: str, attr: str):
    import importlib
    return getattr(importlib.import_module(modname), attr, None)


def declared_budget(ep) -> dict | None:
    """The complexity budget the owning module declares for ``ep``, or
    None when nothing is declared (a finding: every registered entry
    point must carry a budget).

    Budgets live next to the code they constrain — ``SPARSE_COMPLEXITY``
    beside the COO layout, ``KERNEL_COMPLEXITY`` beside the Pallas
    wrappers, ``DISTRIBUTED_COLLECTIVES`` beside the drivers — the same
    ownership rule as the §16.4 dispatch arms.
    """
    kernel = _module_attr("repro.kernels.ops", "KERNEL_COMPLEXITY") or {}
    if ep.name in kernel:
        base = kernel[ep.name]
    elif ep.runtime == "des":
        base = _module_attr("repro.des.engine", "DES_COMPLEXITY")
    elif ep.runtime == "distributed":
        base = _module_attr("repro.distributed.runtime",
                            "DISTRIBUTED_COMPLEXITY")
    elif ep.rep == "sparse":
        base = _module_attr("repro.core.sparse", "SPARSE_COMPLEXITY")
    else:
        base = _module_attr("repro.core.costs", "DENSE_COMPLEXITY")
    if base is None:
        return None
    coll = _ZERO_COLLECTIVES
    if ep.runtime == "distributed":
        table = _module_attr("repro.distributed.runtime",
                             "DISTRIBUTED_COLLECTIVES") or {}
        coll = table.get(ep.name)
        if coll is None:
            return None
    return {"mem": dict(base["mem"]), "ops": dict(base["ops"]),
            "collectives": dict(coll)}


# -- findings ---------------------------------------------------------------

def budget_findings(eps, lookup: Callable = declared_budget) -> list[Finding]:
    out = []
    for ep in eps:
        if lookup(ep) is None:
            out.append(Finding(
                "complexity-budget-declared", ep.name,
                f"entry point {ep.name!r} ({ep.runtime}/{ep.rep}) has no "
                f"declared complexity budget — add it to the owning "
                f"module's *_COMPLEXITY registry (DESIGN.md §18)"))
    return out


def exponent_findings(name: str, profile: dict, budget: dict, metric: str,
                      tol: float = EXPONENT_TOL) -> list[Finding]:
    """Fitted exponents of ``metric`` ("mem" | "ops") against the budget."""
    out = []
    rule_name = f"complexity-{metric}-budget"
    for dim, fitted in sorted(profile["fits"][metric].items()):
        limit = budget[metric].get(dim)
        if limit is None or fitted <= limit + tol:
            continue
        shape = profile.get("peak_shape", ())
        prim = profile.get("peak_primitive", "")
        hint = (f"; peak intermediate {tuple(shape)} from {prim!r}"
                if metric == "mem" and shape else "")
        out.append(Finding(
            rule_name, f"{name}:{dim}",
            f"{name}: fitted {metric} exponent {fitted:.2f} in {dim!r} "
            f"exceeds declared budget {limit:.2f} (+{tol} tolerance)"
            f"{hint}"))
    return out


def collective_findings(name: str, coll: dict, declared: dict) -> list[Finding]:
    """The collective schedule against the declared per-round ledger:
    N-independence plus exact recurring/setup per-shard byte totals."""
    out = []
    if not coll["n_independent"]:
        out.append(Finding(
            "complexity-collectives", f"{name}:n-dependent",
            f"{name}: collective schedule changes across the N grid — "
            f"per-round exchange must be independent of network size "
            f"(arXiv 1111.0875 §5); top-size schedule: "
            f"{list(coll['schedule'])}"))
    for phase in ("recurring", "setup"):
        got, want = coll[f"{phase}_bytes"], declared[f"{phase}_bytes"]
        if got != want:
            out.append(Finding(
                "complexity-collectives", f"{name}:{phase}-bytes",
                f"{name}: {phase} collective operand bytes {got} != "
                f"declared ledger constant {want} (§9.2/§14.5); "
                f"schedule: {list(coll['schedule'])}"))
    return out


# -- expectation table (complexity.json) ------------------------------------

def default_table_path() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parent / "complexity.json"


def load_table(path: pathlib.Path | str | None = None) -> dict:
    p = pathlib.Path(path) if path else default_table_path()
    if not p.is_file():
        return {}
    return json.loads(p.read_text())


def build_table_entry(profile: dict) -> dict:
    coll = profile["collectives"]
    return {
        "fits": {m: {d: round(v, 3) for d, v in sorted(dims.items())}
                 for m, dims in sorted(profile["fits"].items())},
        "peak_shape": list(profile["peak_shape"]),
        "peak_primitive": profile["peak_primitive"],
        "collectives": {
            "n_independent": coll["n_independent"],
            "recurring_bytes": coll["recurring_bytes"],
            "setup_bytes": coll["setup_bytes"],
            "schedule": [list(c) for c in coll["schedule"]],
        },
    }


def expectation_findings(profiles: dict, table: dict, grid_name: str,
                         tol: float = EXPECTATION_TOL) -> list[Finding]:
    """Diff re-fitted exponents and collective schedules against the
    checked-in expectation table — the cross-PR regression gate."""
    out = []
    grid_tab = table.get("grids", {}).get(grid_name)
    if grid_tab is None:
        out.append(Finding(
            "complexity-expectations", f"table:{grid_name}",
            f"complexity.json has no expectation entries for grid "
            f"{grid_name!r} — regenerate with --update-complexity"))
        return out
    for name, prof in sorted(profiles.items()):
        exp = grid_tab.get(name)
        if exp is None:
            out.append(Finding(
                "complexity-expectations", f"missing:{name}",
                f"{name}: no expectation entry for grid {grid_name!r} — "
                f"regenerate with --update-complexity"))
            continue
        for metric, dims in sorted(prof["fits"].items()):
            for dim, fitted in sorted(dims.items()):
                want = exp.get("fits", {}).get(metric, {}).get(dim)
                if want is None or abs(fitted - want) > tol:
                    out.append(Finding(
                        "complexity-expectations",
                        f"{name}:{metric}.{dim}",
                        f"{name}: fitted {metric} exponent in {dim!r} is "
                        f"{fitted:.3f}, expectation table says {want} "
                        f"(drift tolerance {tol})"))
        got_c = build_table_entry(prof)["collectives"]
        want_c = exp.get("collectives")
        if got_c != want_c:
            out.append(Finding(
                "complexity-expectations", f"{name}:collectives",
                f"{name}: collective schedule {got_c} != expectation "
                f"table entry {want_c}"))
    for name in sorted(set(grid_tab) - set(profiles)):
        out.append(Finding(
            "complexity-expectations", f"stale:{name}",
            f"expectation table entry {name!r} matches no registered "
            f"entry point — regenerate with --update-complexity"))
    return out


def update_table(grid_name: str,
                 path: pathlib.Path | str | None = None) -> pathlib.Path:
    """Re-fit every budgeted entry point on ``grid_name`` and rewrite
    that grid's section of complexity.json (other grids preserved)."""
    p = pathlib.Path(path) if path else default_table_path()
    table = load_table(p)
    table.setdefault("grids", {})
    profiles = all_profiles(grid_name)
    table["grids"][grid_name] = {name: build_table_entry(prof)
                                 for name, prof in sorted(profiles.items())}
    p.write_text(json.dumps(table, indent=2, sort_keys=True) + "\n")
    return p


# -- rule wiring ------------------------------------------------------------

def all_profiles(grid_name: str) -> dict:
    """name -> grid profile for every entry point with a declared budget
    (budget-less entries are the budget rule's findings, not crashes)."""
    return {ep.name: profile_entry_point(ep.name, grid_name)
            for ep in entrypoints.registered_entry_points()
            if declared_budget(ep) is not None}


def _ctx_profiles(ctx: AnalysisContext) -> tuple[str, dict]:
    grid_name = getattr(ctx, "complexity_grid", "full")
    profiles = all_profiles(grid_name)
    ctx.reports.setdefault("complexity", {
        "grid": grid_name,
        "entry_points": {name: build_table_entry(prof)
                         for name, prof in sorted(profiles.items())},
    })
    return grid_name, profiles


@rule("complexity-budget-declared", "complexity")
def complexity_budget_declared(ctx: AnalysisContext) -> list[Finding]:
    """Every registered entry point must carry a declared budget."""
    return budget_findings(entrypoints.registered_entry_points())


@rule("complexity-mem-budget", "complexity")
def complexity_mem_budget(ctx: AnalysisContext) -> list[Finding]:
    """Peak-intermediate-bytes exponents within the declared budgets."""
    _, profiles = _ctx_profiles(ctx)
    out = []
    for name, prof in sorted(profiles.items()):
        budget = declared_budget(entrypoints.entry_point(name))
        out.extend(exponent_findings(name, prof, budget, "mem"))
    return out


@rule("complexity-ops-budget", "complexity")
def complexity_ops_budget(ctx: AnalysisContext) -> list[Finding]:
    """Per-primitive op-count exponents within the declared budgets."""
    _, profiles = _ctx_profiles(ctx)
    out = []
    for name, prof in sorted(profiles.items()):
        budget = declared_budget(entrypoints.entry_point(name))
        out.extend(exponent_findings(name, prof, budget, "ops"))
    return out


@rule("complexity-collectives", "complexity")
def complexity_collectives(ctx: AnalysisContext) -> list[Finding]:
    """Collective schedules: N-independent, matching ledger constants."""
    _, profiles = _ctx_profiles(ctx)
    out = []
    for name, prof in sorted(profiles.items()):
        budget = declared_budget(entrypoints.entry_point(name))
        out.extend(collective_findings(name, prof["collectives"],
                                       budget["collectives"]))
    return out


@rule("complexity-expectations", "complexity")
def complexity_expectations(ctx: AnalysisContext) -> list[Finding]:
    """Fitted exponents agree with the checked-in complexity.json."""
    grid_name, profiles = _ctx_profiles(ctx)
    return expectation_findings(profiles, load_table(), grid_name)
