"""Static wire-contract checker (DESIGN.md §16.5, §14.5).

Sizes the distributed exchange buffers *symbolically* — ``jax.eval_shape``
over the :mod:`repro.distributed.protocol` reducers, so nothing executes —
and proves two properties without running a driver:

  * the per-turn payload a shard ships (its :class:`protocol.Candidate`,
    the traced identity deltas, the O(K) load partial) has a byte size
    that does not depend on N: evaluated over an N grid the symbolic
    sizes are constant and equal to the PR-6 measured-wire constants
    (``CANDIDATE_BYTES`` = 16, ``TRACE_PARTIAL_BYTES`` = 8,
    ``load_partial_bytes(K)`` = 4K);
  * the analytic ledger (:func:`accounting.ledger_for_run`) charges
    per-round bytes that are independent of N for every driver flag
    combination — only the ONE-TIME ghost sync may scale with the
    boundary size.

Both checks take the sizing/ledger callables as injectable arguments so
the seeded-violation tests can prove the rule fires on an N-dependent
payload.
"""
from __future__ import annotations

import math
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from .registry import AnalysisContext, Finding, rule

__all__ = ["tree_bytes", "symbolic_candidate_bytes", "symbolic_delta_bytes",
           "symbolic_load_partial_bytes", "candidate_findings",
           "ledger_findings", "N_GRID"]

N_GRID = (32, 256, 4096)
_K_GRID = (2, 4, 7)


def tree_bytes(tree) -> int:
    """Total byte size of a pytree of ShapeDtypeStructs (or arrays)."""
    return sum(int(math.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(tree))


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def symbolic_candidate_bytes(n: int, k: int, *, with_deltas: bool = False,
                             candidate_fn: Callable | None = None):
    """(candidate_bytes, delta_bytes) a shard of ``n // 4`` rows ships,
    sized by abstract evaluation — no FLOP runs."""
    from ..distributed import protocol
    fn = candidate_fn or protocol.local_candidate_from_aggregate
    rows = max(n // 4, 1)
    out = jax.eval_shape(
        lambda agg, b, ids, valid, r, loads, speeds, mu, total_b, m:
        fn(agg, b, ids, valid, r, loads, speeds, mu, total_b, m, "c",
           with_deltas=with_deltas),
        _struct((rows, k), jnp.float32), _struct((rows,), jnp.float32),
        _struct((rows,), jnp.int32), _struct((rows,), jnp.bool_),
        _struct((n,), jnp.int32), _struct((k,), jnp.float32),
        _struct((k,), jnp.float32), _struct((), jnp.float32),
        _struct((), jnp.float32), _struct((), jnp.int32))
    if with_deltas:
        cand, dc0, dct0 = out
        return tree_bytes(cand), tree_bytes((dc0, dct0))
    return tree_bytes(out), 0


def symbolic_delta_bytes(n: int, k: int,
                         candidate_fn: Callable | None = None) -> int:
    return symbolic_candidate_bytes(n, k, with_deltas=True,
                                    candidate_fn=candidate_fn)[1]


def symbolic_load_partial_bytes(n: int, k: int) -> int:
    from ..distributed import protocol
    rows = max(n // 4, 1)
    out = jax.eval_shape(
        lambda b, ids, valid, r: protocol.shard_load_partial(
            b, ids, valid, r, k),
        _struct((rows,), jnp.float32), _struct((rows,), jnp.int32),
        _struct((rows,), jnp.bool_), _struct((n,), jnp.int32))
    return tree_bytes(out)


def candidate_findings(candidate_fn: Callable | None = None) -> list[Finding]:
    """Per-exchange buffers: constant over N, equal to the ledger constants."""
    from ..distributed import protocol
    findings: list[Finding] = []
    for k in _K_GRID:
        cand_sizes = {symbolic_candidate_bytes(n, k,
                                               candidate_fn=candidate_fn)[0]
                      for n in N_GRID}
        delta_sizes = {symbolic_delta_bytes(n, k, candidate_fn=candidate_fn)
                       for n in N_GRID}
        load_sizes = {symbolic_load_partial_bytes(n, k) for n in N_GRID}
        if len(cand_sizes) > 1:
            findings.append(Finding(
                rule="wire-candidate-bytes", key=f"candidate-n-dep:k{k}",
                message=f"candidate payload depends on N at K={k}: "
                        f"sizes {sorted(cand_sizes)} over N grid {N_GRID} "
                        f"— the O(K) wire contract is broken"))
        elif cand_sizes != {protocol.CANDIDATE_BYTES}:
            findings.append(Finding(
                rule="wire-candidate-bytes", key=f"candidate-const:k{k}",
                message=f"symbolic candidate size {cand_sizes} != "
                        f"protocol.CANDIDATE_BYTES="
                        f"{protocol.CANDIDATE_BYTES} at K={k}"))
        if len(delta_sizes) > 1 or \
                delta_sizes != {protocol.TRACE_PARTIAL_BYTES}:
            findings.append(Finding(
                rule="wire-candidate-bytes", key=f"deltas:k{k}",
                message=f"traced identity-delta payload {sorted(delta_sizes)}"
                        f" != TRACE_PARTIAL_BYTES="
                        f"{protocol.TRACE_PARTIAL_BYTES} (or varies with N) "
                        f"at K={k}"))
        if len(load_sizes) > 1 or \
                load_sizes != {protocol.load_partial_bytes(k)}:
            findings.append(Finding(
                rule="wire-candidate-bytes", key=f"load-partial:k{k}",
                message=f"load partial {sorted(load_sizes)} != "
                        f"load_partial_bytes({k})="
                        f"{protocol.load_partial_bytes(k)} (or varies "
                        f"with N)"))
    return findings


@rule("wire-candidate-bytes", "wire")
def _rule_candidate_bytes(ctx: AnalysisContext) -> list[Finding]:
    """Exchange buffers sized by eval_shape match the O(K) constants."""
    findings = candidate_findings()
    ctx.reports["wire-candidate-bytes"] = {
        "n_grid": list(N_GRID), "k_grid": list(_K_GRID),
        "violations": len(findings)}
    return findings


def _synthetic_stats(n: int, s: int = 4):
    """BoundaryStats whose every N-scalable field actually scales with N,
    so an N-dependent ledger term cannot hide."""
    from ..distributed.views import BoundaryStats
    return BoundaryStats(
        num_shards=s, num_nodes=n,
        boundary_nodes=np.full(s, n // 8, np.int64),
        ghost_nodes=np.full(s, n // 4, np.int64),
        cross_edges=np.full(s, n // 2, np.int64))


_FLAG_COMBOS = (
    # (traced, simultaneous, incremental) — the driver flag space
    (False, False, True), (False, False, False),
    (True, False, True), (True, False, False),
    (False, True, True), (False, True, False),
)


def ledger_findings(ledger_fn: Callable | None = None,
                    rounds: int = 10) -> list[Finding]:
    """Every recurring ledger term is independent of N (ghost sync is the
    one documented one-time N-scaling term and is excluded)."""
    from ..distributed import accounting
    fn = ledger_fn or accounting.ledger_for_run
    findings: list[Finding] = []
    for k in _K_GRID:
        for traced, simultaneous, incremental in _FLAG_COMBOS:
            recurring = {}
            for n in N_GRID:
                led = fn(_synthetic_stats(n), k, rounds, traced=traced,
                         simultaneous=simultaneous, incremental=incremental)
                recurring[n] = (led.candidate_bytes + led.trace_bytes
                                + led.setup_bytes)
            if len(set(recurring.values())) > 1:
                flags = f"traced={traced},simult={simultaneous}," \
                        f"incr={incremental}"
                findings.append(Finding(
                    rule="wire-ledger-n-independent",
                    key=f"k{k}:{flags}",
                    message=f"ledger recurring bytes depend on N at K={k} "
                            f"({flags}): {recurring} — per-round wire "
                            f"must be O(K), not O(N) (DESIGN.md §14.5)"))
    return findings


@rule("wire-ledger-n-independent", "wire")
def _rule_ledger(ctx: AnalysisContext) -> list[Finding]:
    """ledger_for_run recurring bytes are N-independent for all flags."""
    findings = ledger_findings()
    ctx.reports["wire-ledger-n-independent"] = {
        "n_grid": list(N_GRID), "flag_combos": len(_FLAG_COMBOS),
        "violations": len(findings)}
    return findings


@rule("wire-ledger-formulas", "wire")
def _rule_formulas(ctx: AnalysisContext) -> list[Finding]:
    """Ledger formulas reconcile with the symbolically sized buffers."""
    from ..distributed import accounting, protocol
    findings: list[Finding] = []
    for k in _K_GRID:
        cand = symbolic_candidate_bytes(256, k)[0]
        delta = symbolic_delta_bytes(256, k)
        load = symbolic_load_partial_bytes(256, k)
        for s in (2, 5):
            # sequential-turn payloads, re-derived from symbolic sizes
            expect = {
                (False, True): s * cand,
                (False, False): s * cand,
                (True, True): s * (cand + delta),
                (True, False): s * (cand + delta + load),
            }
            for (traced, incremental), want in expect.items():
                got = accounting.turn_payload_bytes(
                    s, k, traced=traced, incremental=incremental)
                if got != want:
                    findings.append(Finding(
                        rule="wire-ledger-formulas",
                        key=f"turn:s{s}:k{k}:traced{traced}:"
                            f"incr{incremental}",
                        message=f"turn_payload_bytes(S={s}, K={k}, "
                                f"traced={traced}, incr={incremental})="
                                f"{got} != {want} derived from the "
                                f"eval_shape buffer sizes"))
        if accounting.setup_bytes(k) != load + 4:
            findings.append(Finding(
                rule="wire-ledger-formulas", key=f"setup:k{k}",
                message=f"setup_bytes({k})={accounting.setup_bytes(k)} != "
                        f"load partial + scalar B = {load + 4}"))
    if protocol.CANDIDATE_BYTES != symbolic_candidate_bytes(256, 4)[0]:
        findings.append(Finding(
            rule="wire-ledger-formulas", key="candidate-const",
            message="CANDIDATE_BYTES no longer matches the Candidate "
                    "NamedTuple's symbolic size"))
    return findings
