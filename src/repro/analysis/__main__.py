"""CLI for the contract linter: ``python -m repro.analysis --check``.

Runs the rule registry (all five families by default), diffs the
findings against the checked-in baseline, prints the dispatch matrix and
a findings report, and optionally dumps everything as JSON.  Exit code:
0 when every finding is baselined, 2 when NEW findings exist (only under
``--check``; without it the run is informational).

The JSON report is deterministic modulo provenance (findings sorted by
id, sorted keys) and stamped with the same ``provenance()`` block the
benchmarks write, so CI artifacts diff cleanly across runs.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from . import (AnalysisContext, FAMILIES, complexity_rules,
               default_baseline_path, load_baseline, registered_rules,
               run_rules, split_findings)


def _print_matrix(report: dict) -> None:
    cells = report.get("cells", {})
    if not cells:
        return
    print("\ndispatch-coverage matrix:")
    width = max(len(c) for c in cells) + 2
    for cell, info in cells.items():
        status = "covered" if info["covered"] else "MISSING"
        print(f"  {cell:<{width}}{status}")
        for m in info["missing"]:
            print(f"  {'':<{width}}  wants: {m}")


def _rewrite_baseline(path: str, entries: list[dict]) -> None:
    # dedupe on identity and keep a stable order so the file diffs cleanly
    unique = {(e["rule"], e["key"]): e for e in entries}
    ordered = [unique[k] for k in sorted(unique)]
    pathlib.Path(path).write_text(
        json.dumps({"findings": ordered}, indent=2) + "\n")


def _prune_stale(path: str, stale: set[str]) -> int:
    """Drop baseline entries no current finding matches; returns the
    number removed (the file is only rewritten when something changed)."""
    p = pathlib.Path(path)
    if not stale or not p.is_file():
        return 0
    data = json.loads(p.read_text())
    entries = data.get("findings", [])
    kept = [e for e in entries if f"{e['rule']}:{e['key']}" not in stale]
    if len(kept) != len(entries):
        _rewrite_baseline(path, kept)
    return len(entries) - len(kept)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="contract linter: jaxpr / AST / wire / docs / "
                    "complexity analyzers (DESIGN.md §16, §18)")
    ap.add_argument("--check", action="store_true",
                    help="exit 2 if any finding is not in the baseline")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full findings report as JSON")
    ap.add_argument("--baseline", metavar="PATH",
                    default=str(default_baseline_path()),
                    help="baseline file (default: the checked-in one)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to accept ALL current "
                         "findings (review the diff!); stale entries are "
                         "pruned automatically")
    ap.add_argument("--prune-stale", action="store_true",
                    help="rewrite the baseline dropping entries no "
                         "current finding matches")
    ap.add_argument("--families", nargs="+", choices=FAMILIES,
                    default=None, metavar="FAMILY",
                    help=f"run only these rule families {FAMILIES}")
    ap.add_argument("--complexity-grid", choices=sorted(
                        complexity_rules.GRIDS), default="full",
                    help="size grid for the complexity family "
                         "(default: full)")
    ap.add_argument("--update-complexity", action="store_true",
                    help="re-fit the active grid and rewrite its section "
                         "of the complexity.json expectation table")
    ap.add_argument("--complexity-table", metavar="PATH",
                    default=str(complexity_rules.default_table_path()),
                    help="expectation table written by --update-complexity "
                         "(default: the checked-in one)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: autodetected)")
    args = ap.parse_args(argv)

    if args.update_complexity:
        path = complexity_rules.update_table(args.complexity_grid,
                                             args.complexity_table)
        n = len(complexity_rules.load_table(path)
                .get("grids", {}).get(args.complexity_grid, {}))
        print(f"complexity table {path}: grid {args.complexity_grid!r} "
              f"rewritten with {n} entries")
        return 0

    ctx = AnalysisContext(repo_root=args.root,
                          complexity_grid=args.complexity_grid)
    rules = registered_rules(args.families)
    t0 = time.perf_counter()
    findings = sorted(run_rules(ctx, args.families), key=lambda f: f.id)
    elapsed = time.perf_counter() - t0

    baseline = load_baseline(args.baseline)
    new, known, stale = split_findings(findings, baseline)

    fams = sorted({r.family for r in rules})
    print(f"repro.analysis: {len(rules)} rules "
          f"({', '.join(fams)}) in {elapsed:.1f}s")
    if "jaxpr-zero-callback" in ctx.reports:
        eps = ctx.reports["jaxpr-zero-callback"]["entry_points"]
        print(f"  traced entry points: {len(eps)}")
    if "sweep-compile-groups" in ctx.reports:
        r = ctx.reports["sweep-compile-groups"]
        print(f"  sweep compile audit: {r['cases']} cases in "
              f"{r['groups']} groups, {r['violations']} violations")
    if "complexity" in ctx.reports:
        r = ctx.reports["complexity"]
        print(f"  complexity audit: {len(r['entry_points'])} entry points "
              f"fitted on grid {r['grid']!r}")
    _print_matrix(ctx.reports.get("dispatch-coverage", {}))

    print(f"\nfindings: {len(findings)} total — {len(known)} baselined, "
          f"{len(new)} new")
    for f in known:
        print(f"  [baselined] {f.id}")
    for f in new:
        loc = f" ({f.file}:{f.line})" if f.file else ""
        print(f"  [NEW] {f.id}{loc}\n        {f.message}")
    for sid in sorted(stale):
        print(f"  [stale baseline entry] {sid}")

    if args.json:
        payload = {
            "provenance": _provenance(),
            "rules": sorted(({"name": r.name, "family": r.family,
                              "doc": r.doc} for r in rules),
                            key=lambda r: r["name"]),
            "findings": [f.to_json() for f in findings],
            "new": sorted(f.id for f in new),
            "baselined": sorted(f.id for f in known),
            "stale_baseline": sorted(stale),
            "reports": ctx.reports,
            "elapsed_seconds": elapsed,
        }
        path = pathlib.Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(payload, indent=2, sort_keys=True,
                                   default=str) + "\n")
        print(f"\nwrote {path}")

    if args.update_baseline:
        _rewrite_baseline(args.baseline,
                          [{"rule": f.rule, "key": f.key} for f in findings])
        print(f"baseline rewritten with "
              f"{len({f.id for f in findings})} entries")
        return 0

    if args.prune_stale:
        pruned = _prune_stale(args.baseline, stale)
        if pruned:
            print(f"pruned {pruned} stale baseline entr"
                  f"{'y' if pruned == 1 else 'ies'}")

    if args.check and new:
        print(f"\nFAIL: {len(new)} new finding(s) not in baseline "
              f"({args.baseline})")
        return 2
    return 0


def _provenance() -> dict:
    from ..provenance import provenance
    return provenance()


if __name__ == "__main__":
    sys.exit(main())
