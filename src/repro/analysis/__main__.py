"""CLI for the contract linter: ``python -m repro.analysis --check``.

Runs the rule registry (all four families by default), diffs the
findings against the checked-in baseline, prints the dispatch matrix and
a findings report, and optionally dumps everything as JSON.  Exit code:
0 when every finding is baselined, 2 when NEW findings exist (only under
``--check``; without it the run is informational).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from . import (AnalysisContext, FAMILIES, default_baseline_path,
               load_baseline, registered_rules, run_rules, split_findings)


def _print_matrix(report: dict) -> None:
    cells = report.get("cells", {})
    if not cells:
        return
    print("\ndispatch-coverage matrix:")
    width = max(len(c) for c in cells) + 2
    for cell, info in cells.items():
        status = "covered" if info["covered"] else "MISSING"
        print(f"  {cell:<{width}}{status}")
        for m in info["missing"]:
            print(f"  {'':<{width}}  wants: {m}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="contract linter: jaxpr / AST / wire / docs analyzers "
                    "(DESIGN.md §16)")
    ap.add_argument("--check", action="store_true",
                    help="exit 2 if any finding is not in the baseline")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full findings report as JSON")
    ap.add_argument("--baseline", metavar="PATH",
                    default=str(default_baseline_path()),
                    help="baseline file (default: the checked-in one)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to accept ALL current "
                         "findings (review the diff!)")
    ap.add_argument("--families", nargs="+", choices=FAMILIES,
                    default=None, metavar="FAMILY",
                    help=f"run only these rule families {FAMILIES}")
    ap.add_argument("--root", default=None,
                    help="repo root (default: autodetected)")
    args = ap.parse_args(argv)

    ctx = AnalysisContext(repo_root=args.root)
    rules = registered_rules(args.families)
    t0 = time.perf_counter()
    findings = run_rules(ctx, args.families)
    elapsed = time.perf_counter() - t0

    baseline = load_baseline(args.baseline)
    new, known, stale = split_findings(findings, baseline)

    fams = sorted({r.family for r in rules})
    print(f"repro.analysis: {len(rules)} rules "
          f"({', '.join(fams)}) in {elapsed:.1f}s")
    if "jaxpr-zero-callback" in ctx.reports:
        eps = ctx.reports["jaxpr-zero-callback"]["entry_points"]
        print(f"  traced entry points: {len(eps)}")
    if "sweep-compile-groups" in ctx.reports:
        r = ctx.reports["sweep-compile-groups"]
        print(f"  sweep compile audit: {r['cases']} cases in "
              f"{r['groups']} groups, {r['violations']} violations")
    _print_matrix(ctx.reports.get("dispatch-coverage", {}))

    print(f"\nfindings: {len(findings)} total — {len(known)} baselined, "
          f"{len(new)} new")
    for f in known:
        print(f"  [baselined] {f.id}")
    for f in new:
        loc = f" ({f.file}:{f.line})" if f.file else ""
        print(f"  [NEW] {f.id}{loc}\n        {f.message}")
    for sid in sorted(stale):
        print(f"  [stale baseline entry — delete it] {sid}")

    if args.json:
        payload = {
            "rules": [{"name": r.name, "family": r.family, "doc": r.doc}
                      for r in rules],
            "findings": [f.to_json() for f in findings],
            "new": [f.id for f in new],
            "baselined": [f.id for f in known],
            "stale_baseline": sorted(stale),
            "reports": ctx.reports,
            "elapsed_seconds": elapsed,
        }
        path = pathlib.Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(payload, indent=2, default=str) + "\n")
        print(f"\nwrote {path}")

    if args.update_baseline:
        entries = sorted(({"rule": f.rule, "key": f.key} for f in findings),
                        key=lambda e: (e["rule"], e["key"]))
        pathlib.Path(args.baseline).write_text(
            json.dumps({"findings": entries}, indent=2) + "\n")
        print(f"baseline rewritten with {len(entries)} entries")
        return 0

    if args.check and new:
        print(f"\nFAIL: {len(new)} new finding(s) not in baseline "
              f"({args.baseline})")
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
