"""Docs-consistency rules (formerly inlined in ``tests/test_docs.py``).

Two rot modes are caught, now as registry rules so the CLI and CI get
them alongside the contract lint (``tests/test_docs.py`` remains as a
thin wrapper so tier-1 behavior is unchanged):

  * ``docs-design-refs`` — every ``DESIGN.md §N[.M]`` citation in
    ``src/`` resolves to an actual DESIGN.md header; the extraction
    itself is guarded (≥ 10 citing files, the anchor sections exist).
  * ``docs-file-refs`` — every all-caps doc-file mention under
    ``src``/``tests``/``benchmarks``/``examples`` names a file that is
    actually in the repo root.
"""
from __future__ import annotations

import re

from .registry import AnalysisContext, Finding, rule

__all__ = ["REF_RE", "HEADER_RE", "DOCFILE_RE", "DOCFILE_SCAN_DIRS",
           "design_sections", "design_ref_findings", "doc_file_findings"]

REF_RE = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)")
HEADER_RE = re.compile(r"^#{1,6}\s.*?§(\d+(?:\.\d+)?)", re.MULTILINE)
DOCFILE_RE = re.compile(r"\b([A-Z][A-Z0-9_]*\.md)\b")
DOCFILE_SCAN_DIRS = ("src", "tests", "benchmarks", "examples")
# files that legitimately name nonexistent docs (as examples/messages)
_DOCFILE_EXEMPT = ("tests/test_docs.py",)
_MIN_CITING_FILES = 10


def design_sections(ctx: AnalysisContext) -> set[str]:
    return set(HEADER_RE.findall(ctx.source("DESIGN.md")))


def design_ref_findings(ctx: AnalysisContext) -> list[Finding]:
    findings: list[Finding] = []
    sections = design_sections(ctx)
    for anchor in ("1", "12"):
        if anchor not in sections:
            findings.append(Finding(
                rule="docs-design-refs", key=f"anchor:{anchor}",
                file="DESIGN.md",
                message=f"DESIGN.md anchor section §{anchor} is gone — "
                        f"header extraction is likely broken"))
    citing = 0
    for path in ctx.py_files("src"):
        found = set(REF_RE.findall(ctx.source(path)))
        if found:
            citing += 1
        for ref in sorted(found - sections):
            findings.append(Finding(
                rule="docs-design-refs", key=f"{path}:§{ref}", file=path,
                message=f"{path} cites DESIGN.md §{ref}, which has no "
                        f"header (valid: {sorted(sections)})"))
    if citing < _MIN_CITING_FILES:
        findings.append(Finding(
            rule="docs-design-refs", key="too-few-citing-files",
            message=f"only {citing} files under src/ cite DESIGN.md "
                    f"sections (expected ≥ {_MIN_CITING_FILES}) — the "
                    f"reference extraction is probably matching nothing"))
    return findings


@rule("docs-design-refs", "docs")
def _rule_design_refs(ctx: AnalysisContext) -> list[Finding]:
    """Every DESIGN.md § citation in src/ resolves to a real header."""
    return design_ref_findings(ctx)


def doc_file_findings(ctx: AnalysisContext) -> list[Finding]:
    findings: list[Finding] = []
    for d in DOCFILE_SCAN_DIRS:
        for path in ctx.py_files(d):
            if path in _DOCFILE_EXEMPT:
                continue
            for name in sorted(set(DOCFILE_RE.findall(ctx.source(path)))):
                if not (ctx.repo / name).is_file():
                    findings.append(Finding(
                        rule="docs-file-refs", key=f"{path}:{name}",
                        file=path,
                        message=f"{path} references repo doc {name!r}, "
                                f"which does not exist in the repo root"))
    return findings


@rule("docs-file-refs", "docs")
def _rule_doc_files(ctx: AnalysisContext) -> list[Finding]:
    """Every all-caps doc-file mention in code names an existing root doc."""
    return doc_file_findings(ctx)
