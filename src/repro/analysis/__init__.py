"""repro.analysis — the contract linter (DESIGN.md §16).

Statically verifies the invariants the whole stack rests on: zero
callbacks on telemetry-disabled paths, an f32-only dataflow, one-lowering
sweep groups, the canonical 9-arg ``dissat_fn`` convention, the single
Eq.-4 θ-subtraction site, trace-safe jitted bodies, the dense/sparse ×
runtime dispatch matrix, and the O(K) wire contract — all before any
driver runs.

CLI::

    python -m repro.analysis --check [--json out.json]

Known gaps live in the checked-in ``baseline.json``; ``--check`` fails
only on NEW findings.
"""
from .registry import (AnalysisContext, FAMILIES, Finding, Rule,
                       default_baseline_path, load_baseline,
                       registered_rules, rule, run_rules, split_findings)

# importing the rule modules populates the registry
from . import (ast_rules, complexity_rules, docs_rules,  # noqa: E402,F401
               jaxpr_rules, wire_rules)
from . import entrypoints  # noqa: E402,F401

__all__ = [
    "AnalysisContext", "FAMILIES", "Finding", "Rule", "rule",
    "registered_rules", "run_rules", "load_baseline", "split_findings",
    "default_baseline_path", "entrypoints", "ast_rules", "complexity_rules",
    "docs_rules", "jaxpr_rules", "wire_rules",
]
