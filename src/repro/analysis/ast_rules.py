"""AST-family lint rules (stdlib ``ast``, DESIGN.md §16.4).

Four rules over the source tree — no imports of the linted modules, so
they run in milliseconds and catch violations before anything traces:

  * **dissat-signature** — every ``dissat_fn`` produced by a factory
    annotated ``-> DissatFn`` (the Protocol of ``core/refine.py``) has
    exactly the canonical 9 parameters, in order, with the canonical
    names; every ``dissat_fn(...)`` call site passes exactly 9
    positionals.  The rule anchors on the Protocol annotation, not on a
    magic arity, so unrelated 9-arg functions are never dragged in.
  * **theta-single-site** — the Eq.-4 net-of-price subtraction
    ``dissat - theta`` happens in exactly ONE jnp function
    (``costs.dissatisfaction_from_cost``); the two Pallas kernels that
    mirror it inside fused reductions are a fixed, documented allowlist
    (they are bitwise-compared against the jnp path by the kernel
    tests).  Any new subtraction site is a finding.
  * **trace-unsafe** — inside jitted bodies: no ``np.random``, no
    ``float()``/``int()`` host casts of dynamic arguments, no ``if``
    statements on dynamic (tracer) arguments.  ``is None`` tests and
    tests over ``static_argnames`` parameters are trace-time constants
    and exempt.
  * **dispatch-coverage** — rebuild the dense/sparse × runtime ×
    kernel dispatch matrix from the ``isinstance(..., SparseProblem)``
    arms; missing cells are findings (today exactly
    ``sparse-distributed`` — ROADMAP item 5 — absorbed by the
    baseline), and removing any registered arm uncovers a cell.
"""
from __future__ import annotations

import ast

from .registry import AnalysisContext, Finding, rule

__all__ = ["CANONICAL_DISSAT_PARAMS", "dissat_signature_findings",
           "theta_site_findings", "trace_unsafe_findings",
           "dispatch_matrix", "dispatch_findings"]

CANONICAL_DISSAT_PARAMS = (
    "aggregate", "assignment", "node_weights", "loads", "speeds", "mu",
    "framework", "total_weight", "theta")

_SRC_DIR = "src/repro"


def _walk_functions(tree: ast.Module):
    """Yield ``(qualname, node)`` for every (async) function def, with
    class / enclosing-function qualification."""
    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield qual, child
                yield from walk(child, qual + ".")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)
    yield from walk(tree, "")


def _param_names(fn: ast.FunctionDef) -> tuple[str, ...]:
    a = fn.args
    return tuple(p.arg for p in (*a.posonlyargs, *a.args))


# -- rule: dissat-signature ------------------------------------------------

def _mentions(node: ast.AST | None, name: str) -> bool:
    return node is not None and name in ast.unparse(node)


def dissat_signature_findings(ctx: AnalysisContext) -> list[Finding]:
    findings: list[Finding] = []
    factories = 0
    for path in ctx.py_files(_SRC_DIR):
        tree = ctx.tree(path)
        for qual, fn in _walk_functions(tree):
            # Protocol itself: DissatFn.__call__ pins the canonical names
            if qual.endswith("DissatFn.__call__"):
                params = _param_names(fn)[1:]        # drop self
                if params != CANONICAL_DISSAT_PARAMS:
                    findings.append(Finding(
                        rule="dissat-signature", key=f"protocol:{path}",
                        file=path, line=fn.lineno,
                        message=f"DissatFn.__call__ params {params} != "
                                f"canonical {CANONICAL_DISSAT_PARAMS}"))
                continue
            if not _mentions(fn.returns, "DissatFn"):
                continue
            factories += 1
            for inner_qual, inner in _walk_functions(
                    ast.Module(body=fn.body, type_ignores=[])):
                if inner.args.vararg is not None:
                    continue   # pass-through wrapper (*args, **kwargs)
                params = _param_names(inner)
                if params != CANONICAL_DISSAT_PARAMS:
                    findings.append(Finding(
                        rule="dissat-signature",
                        key=f"def:{path}::{qual}.{inner_qual}",
                        file=path, line=inner.lineno,
                        message=f"dissat_fn factory {qual!r} returns a "
                                f"function with params {params}; the "
                                f"canonical convention is "
                                f"{CANONICAL_DISSAT_PARAMS} "
                                f"(repro.core.refine)"))
        # call sites: dissat_fn(...) must pass exactly 9 positionals
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            name = func.id if isinstance(func, ast.Name) else (
                func.attr if isinstance(func, ast.Attribute) else None)
            if name != "dissat_fn":
                continue
            if any(isinstance(a, ast.Starred) for a in node.args):
                continue   # pass-through wrapper
            if len(node.args) != len(CANONICAL_DISSAT_PARAMS) or \
                    node.keywords:
                findings.append(Finding(
                    rule="dissat-signature",
                    key=f"call:{path}:{node.lineno}",
                    file=path, line=node.lineno,
                    message=f"dissat_fn call passes {len(node.args)} "
                            f"positional + {len(node.keywords)} keyword "
                            f"args; the convention is exactly "
                            f"{len(CANONICAL_DISSAT_PARAMS)} positionals"))
    if factories == 0:
        findings.append(Finding(
            rule="dissat-signature", key="no-factories",
            message="no `-> DissatFn`-annotated factory found under src/ "
                    "— the lint anchor (core.refine.DissatFn) is gone"))
    return findings


@rule("dissat-signature", "ast")
def _rule_dissat_signature(ctx: AnalysisContext) -> list[Finding]:
    """Canonical 9-arg dissat_fn signature at every def/call site."""
    return dissat_signature_findings(ctx)


# -- rule: theta-single-site -----------------------------------------------

_THETA_CANONICAL = ("src/repro/core/costs.py", "dissatisfaction_from_cost")
# Pallas kernels mirroring the subtraction inside fused reductions; each
# is bitwise-pinned against the jnp path by tests/test_kernels.py
_THETA_MIRRORS = frozenset({
    ("src/repro/kernels/dissatisfaction.py", "reduce_dissat_tile"),
    ("src/repro/kernels/dissatisfaction.py", "_dissat_kernel_batched"),
})


def _is_theta_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        return node.id.startswith("theta")
    if isinstance(node, ast.Subscript):
        return _is_theta_expr(node.value)
    if isinstance(node, ast.Attribute):
        return node.attr.startswith("theta")
    return False


def theta_site_findings(ctx: AnalysisContext) -> list[Finding]:
    findings: list[Finding] = []
    sites: set[tuple[str, str]] = set()
    lines: dict[tuple[str, str], int] = {}
    for path in ctx.py_files(_SRC_DIR):
        for qual, fn in _walk_functions(ctx.tree(path)):
            for node in ast.walk(fn):
                if isinstance(node, ast.BinOp) and \
                        isinstance(node.op, ast.Sub) and \
                        _is_theta_expr(node.right):
                    sites.add((path, qual))
                    lines.setdefault((path, qual), node.lineno)
    for site in sorted(sites):
        if site == _THETA_CANONICAL or site in _THETA_MIRRORS:
            continue
        findings.append(Finding(
            rule="theta-single-site", key=f"{site[0]}::{site[1]}",
            file=site[0], line=lines[site],
            message=f"theta is subtracted in {site[1]!r} ({site[0]}); "
                    f"the Eq.-4 net-of-price subtraction must happen "
                    f"ONLY in costs.dissatisfaction_from_cost (plus the "
                    f"two pinned Pallas mirrors) — DESIGN.md §11"))
    if _THETA_CANONICAL not in sites:
        findings.append(Finding(
            rule="theta-single-site", key="canonical-missing",
            file=_THETA_CANONICAL[0],
            message="the canonical theta-subtraction site "
                    "costs.dissatisfaction_from_cost no longer subtracts "
                    "theta — the hysteresis contract moved or vanished"))
    return findings


@rule("theta-single-site", "ast")
def _rule_theta_site(ctx: AnalysisContext) -> list[Finding]:
    """Eq.-4 theta subtraction occurs in exactly one jnp function."""
    return theta_site_findings(ctx)


# -- rule: trace-unsafe ----------------------------------------------------

def _jit_static_names(fn: ast.FunctionDef) -> tuple[bool, set[str]]:
    """(is_jitted, static_argnames) from the decorator list."""
    for deco in fn.decorator_list:
        text = ast.unparse(deco)
        if "jit" not in text.split("(")[0] and ".jit" not in text:
            continue
        statics: set[str] = set()
        if isinstance(deco, ast.Call):
            for kw in deco.keywords:
                if kw.arg in ("static_argnames", "static_argnums"):
                    for node in ast.walk(kw.value):
                        if isinstance(node, ast.Constant) and \
                                isinstance(node.value, str):
                            statics.add(node.value)
        return True, statics
    return False, set()


def _is_none_test(test: ast.AST) -> bool:
    """True for tests that are pure `x is (not) None` (possibly and/or
    combined, possibly negated) — trace-time constants for optional
    operands."""
    if isinstance(test, ast.BoolOp):
        return all(_is_none_test(v) for v in test.values)
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _is_none_test(test.operand)
    return (isinstance(test, ast.Compare)
            and all(isinstance(op, (ast.Is, ast.IsNot))
                    for op in test.ops))


def trace_unsafe_findings(ctx: AnalysisContext) -> list[Finding]:
    findings: list[Finding] = []
    for path in ctx.py_files(_SRC_DIR):
        for qual, fn in _walk_functions(ctx.tree(path)):
            jitted, statics = _jit_static_names(fn)
            if not jitted:
                continue
            dynamic = set(_param_names(fn)) - statics
            for node in ast.walk(fn):
                if isinstance(node, ast.Attribute) and \
                        node.attr == "random" and \
                        isinstance(node.value, ast.Name) and \
                        node.value.id in ("np", "numpy"):
                    findings.append(Finding(
                        rule="trace-unsafe",
                        key=f"np-random:{path}:{node.lineno}",
                        file=path, line=node.lineno,
                        message=f"np.random inside jitted {qual!r}: host "
                                f"randomness is drawn once at trace time "
                                f"and baked into the program"))
                elif isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name) and \
                        node.func.id in ("float", "int", "bool") and \
                        any(isinstance(a, ast.Name) and a.id in dynamic
                            for a in node.args):
                    findings.append(Finding(
                        rule="trace-unsafe",
                        key=f"host-cast:{path}:{node.lineno}",
                        file=path, line=node.lineno,
                        message=f"{node.func.id}() on a dynamic argument "
                                f"inside jitted {qual!r}: forces a trace-"
                                f"time concretization (TracerError at "
                                f"best, silent staleness at worst)"))
                elif isinstance(node, ast.If) and \
                        not _is_none_test(node.test):
                    names = {n.id for n in ast.walk(node.test)
                             if isinstance(n, ast.Name)}
                    hit = sorted(names & dynamic)
                    if hit:
                        findings.append(Finding(
                            rule="trace-unsafe",
                            key=f"if-tracer:{path}:{node.lineno}",
                            file=path, line=node.lineno,
                            message=f"`if` on dynamic argument(s) {hit} "
                                    f"inside jitted {qual!r}: branch is "
                                    f"resolved at trace time, not per "
                                    f"call — use lax.cond/jnp.where or "
                                    f"mark the arg static"))
    return findings


@rule("trace-unsafe", "ast")
def _rule_trace_unsafe(ctx: AnalysisContext) -> list[Finding]:
    """No np.random / host casts / tracer `if`s inside jitted bodies."""
    return trace_unsafe_findings(ctx)


# -- rule: dispatch-coverage -----------------------------------------------

# every isinstance(..., SparseProblem) dispatch arm must be registered
# here; the cells below declare which arms make each matrix cell covered
_REGISTERED_ARMS = frozenset({
    ("src/repro/core/costs.py", "problem_aggregate"),
    ("src/repro/core/costs.py", "problem_cut"),
    ("src/repro/core/costs.py", "global_cost_c0"),
    ("src/repro/core/aggregate.py", "apply_move"),
    ("src/repro/core/aggregate.py", "apply_sweep"),
    ("src/repro/core/aggregate.py", "apply_moves"),
    ("src/repro/core/aggregate.py", "apply_cluster_move"),
    ("src/repro/core/cluster.py", "h_hop_mask"),
    ("src/repro/core/batch.py", "problem_shape_key"),
})

_CORE_SPARSE_ARMS = frozenset(a for a in _REGISTERED_ARMS
                              if a[0] != "src/repro/core/batch.py")

# (file, function) definitions whose presence covers the dense cells
_DENSE_DEFS = {
    "dense-controller": (("src/repro/core/refine.py", "refine"),
                         ("src/repro/core/refine.py", "refine_traced"),
                         ("src/repro/core/refine.py", "refine_simultaneous")),
    "dense-batched": (("src/repro/core/batch.py", "refine_batched"),
                      ("src/repro/core/batch.py", "refine_traced_batched"),
                      ("src/repro/core/batch.py",
                       "refine_simultaneous_batched")),
    "dense-distributed": (
        ("src/repro/distributed/runtime.py", "_refine_distributed"),
        ("src/repro/distributed/runtime.py", "_refine_distributed_traced"),
        ("src/repro/distributed/runtime.py",
         "_refine_distributed_simultaneous"),
        ("src/repro/distributed/runtime.py",
         "refine_distributed_shard_map")),
    "dense-kernel": (("src/repro/kernels/ops.py",
                      "make_aggregate_dissat_fn"),),
    "sparse-kernel": (("src/repro/kernels/ops.py", "make_edge_dissat_fn"),),
}

CELL_ORDER = ("dense-controller", "dense-batched", "dense-distributed",
              "dense-kernel", "sparse-controller", "sparse-batched",
              "sparse-distributed", "sparse-kernel")


def _sparse_isinstance_sites(ctx: AnalysisContext) -> set[tuple[str, str]]:
    sites: set[tuple[str, str]] = set()
    for path in ctx.py_files(_SRC_DIR):
        for qual, fn in _walk_functions(ctx.tree(path)):
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Name) and \
                        node.func.id == "isinstance" and \
                        len(node.args) == 2 and \
                        "SparseProblem" in ast.unparse(node.args[1]):
                    sites.add((path, qual))
    return sites


def _defined_functions(ctx: AnalysisContext, path: str) -> set[str]:
    try:
        tree = ctx.tree(path)
    except FileNotFoundError:
        return set()
    return {qual for qual, _ in _walk_functions(tree)}


def dispatch_matrix(ctx: AnalysisContext) -> dict[str, dict]:
    """cell -> {"covered": bool, "missing": [what would cover it]}."""
    sites = _sparse_isinstance_sites(ctx)
    matrix: dict[str, dict] = {}
    for cell, defs in _DENSE_DEFS.items():
        missing = [f"{p}::{name}" for p, name in defs
                   if name not in _defined_functions(ctx, p)]
        matrix[cell] = {"covered": not missing, "missing": missing}
    core_missing = sorted(f"{p}::{f}" for p, f in _CORE_SPARSE_ARMS
                          if (p, f) not in sites)
    matrix["sparse-controller"] = {"covered": not core_missing,
                                   "missing": core_missing}
    batch_arm = ("src/repro/core/batch.py", "problem_shape_key")
    batched_missing = core_missing + (
        [] if batch_arm in sites else ["::".join(batch_arm)])
    matrix["sparse-batched"] = {"covered": not batched_missing,
                                "missing": sorted(batched_missing)}
    dist_sites = sorted(f"{p}::{f}" for p, f in sites
                        if p.startswith("src/repro/distributed/"))
    matrix["sparse-distributed"] = {
        "covered": bool(dist_sites),
        "missing": [] if dist_sites else
        ["an isinstance(problem, SparseProblem) dispatch arm anywhere "
         "under src/repro/distributed/ (ROADMAP item 5)"]}
    return {cell: matrix[cell] for cell in CELL_ORDER}


def dispatch_findings(ctx: AnalysisContext) -> list[Finding]:
    matrix = dispatch_matrix(ctx)
    ctx.reports["dispatch-coverage"] = {"cells": matrix}
    findings = []
    for cell, info in matrix.items():
        if not info["covered"]:
            findings.append(Finding(
                rule="dispatch-coverage", key=cell,
                message=f"dispatch matrix cell {cell!r} is uncovered; "
                        f"missing: {info['missing']}"))
    for path, qual in sorted(_sparse_isinstance_sites(ctx)):
        if (path, qual) not in _REGISTERED_ARMS and \
                not path.startswith("src/repro/distributed/"):
            findings.append(Finding(
                rule="dispatch-coverage", key=f"arm:{path}::{qual}",
                file=path,
                message=f"unregistered SparseProblem dispatch arm in "
                        f"{qual!r} — register it in "
                        f"repro.analysis.ast_rules._REGISTERED_ARMS so "
                        f"the matrix stays authoritative"))
    return findings


@rule("dispatch-coverage", "ast")
def _rule_dispatch(ctx: AnalysisContext) -> list[Finding]:
    """dense/sparse × runtime dispatch matrix has no unknown holes."""
    return dispatch_findings(ctx)
