"""Registry of public entry points the jaxpr analyzers trace (DESIGN.md §16.3).

Every public execution path of the stack — controller refinement (three
modes, dense and sparse, jnp and fused-kernel reductions), the batched
drivers, all four distributed drivers, and the DES tick — is registered
here with a thunk that traces it on a small canonical problem with
telemetry disabled (``recorder=None`` / ``emit_*=None``).  The analyzers
then make one statement over ALL of them: the disabled-telemetry
programs contain zero host callbacks and never leave the f32 dataflow.
This replaces the single hand-written jaxpr pin that used to live in
``tests/test_obs.py`` with registry-driven coverage: a new driver gets
the same guarantees by adding one entry here.

Tracing is cached per process (``lru_cache``), so the CLI and the test
suite share the work.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["EntryPoint", "registered_entry_points", "trace_entry_point",
           "trace_all", "canonical_problem", "canonical_sparse",
           "canonical_batch", "canonical_assignment"]

_N, _K = 16, 3
_MAX_TURNS = 32
_MAX_SWEEPS = 12


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """One traced public execution path.

    ``trace`` returns the ClosedJaxpr of the path on its canonical small
    problem, with telemetry disabled — exactly the program the
    ``recorder=None`` fast path stages.
    """
    name: str
    runtime: str   # "controller" | "batched" | "distributed" | "des"
    trace: Callable[[], object]


@lru_cache(maxsize=None)
def canonical_problem(n: int = _N, k: int = _K, seed: int = 3):
    """The canonical small dense problem every analyzer traces on."""
    from ..core.problem import make_problem
    from ..graphs.generators import random_degree_graph, random_weights
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    return make_problem(c, b, np.ones(k) / k, mu=8.0)


@lru_cache(maxsize=None)
def canonical_sparse(n: int = _N, k: int = _K, seed: int = 3):
    from ..core.sparse import sparse_from_dense
    return sparse_from_dense(canonical_problem(n, k, seed))


def canonical_assignment(n: int = _N, k: int = _K):
    return jnp.asarray(np.arange(n) % k, jnp.int32)


@lru_cache(maxsize=None)
def canonical_batch(b: int = 2, n: int = _N, k: int = _K):
    """A stacked pair of same-shape problems + (B, N) assignments."""
    from ..core.batch import stack_problems
    probs = stack_problems([canonical_problem(n, k, seed=3 + i)
                            for i in range(b)])
    r0 = jnp.stack([canonical_assignment(n, k)] * b)
    return probs, r0


@lru_cache(maxsize=None)
def _canonical_des():
    """A tiny DES scenario (config, adjacency, initial state)."""
    from ..des.engine import DESConfig, make_initial_state
    from ..des.workload import flooded_packet_workload
    from ..graphs.generators import preferential_attachment
    n, k, threads = 12, 2, 4
    adj = preferential_attachment(n, 5, m=2)
    spec = flooded_packet_workload(adj, 9, num_threads=threads,
                                   num_windows=1, scope=2,
                                   window_sim_time=20.0, max_per_lp=2)
    cfg = DESConfig(num_lps=n, num_machines=k, num_threads=threads,
                    event_capacity=32, history_capacity=64,
                    inter_delay=6, intra_delay=1, trace_stride=10,
                    max_ticks=1_000, machine_speeds=(1.0, 0.7),
                    refine_freq=40, refine_theta_scale=5.0,
                    migration_freeze=0.25)
    m0 = jnp.asarray(np.arange(n) % k, jnp.int32)
    state0 = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
    return cfg, jnp.asarray(adj, jnp.float32), state0


# -- the individual trace thunks (one per registered path) -----------------

def _controller(fn_name: str, sparse: bool = False, **kwargs):
    import importlib
    # attribute access would find the re-exported refine() function, not
    # the module, so resolve the submodule explicitly
    refine_mod = importlib.import_module("repro.core.refine")
    fn = getattr(refine_mod, fn_name)
    prob = canonical_sparse() if sparse else canonical_problem()
    return jax.make_jaxpr(lambda r: fn(prob, r, **kwargs))(
        canonical_assignment())


def _kernel_dissat():
    from ..core.refine import refine
    from ..kernels.ops import make_aggregate_dissat_fn
    prob = canonical_problem()
    dfn = make_aggregate_dissat_fn(interpret=True)
    return jax.make_jaxpr(
        lambda r: refine(prob, r, "c", max_turns=_MAX_TURNS, dissat_fn=dfn)
    )(canonical_assignment())


def _edge_kernel_dissat():
    from ..core.refine import refine
    from ..kernels.ops import make_edge_dissat_fn
    sp = canonical_sparse()
    dfn = make_edge_dissat_fn(sp, interpret=True)
    return jax.make_jaxpr(
        lambda r: refine(sp, r, "c", max_turns=_MAX_TURNS, dissat_fn=dfn)
    )(canonical_assignment())


def _sweeps_prob(sparse: bool = False, **kwargs):
    """Probabilistic refine_sweeps configs: the PRNG key rides as a
    traced argument (its extended key dtype is exempt from the f32
    dataflow rule, like every other key)."""
    import importlib
    refine_mod = importlib.import_module("repro.core.refine")
    prob = canonical_sparse() if sparse else canonical_problem()
    return jax.make_jaxpr(
        lambda r, k: refine_mod.refine_sweeps(
            prob, r, max_sweeps=_MAX_SWEEPS, key=k, **kwargs)
    )(canonical_assignment(), jax.random.PRNGKey(0))


def _batched(fn_name: str, **kwargs):
    from ..core import batch as batch_mod
    fn = getattr(batch_mod, fn_name)
    probs, r0 = canonical_batch()
    return jax.make_jaxpr(lambda r: fn(probs, r, "c", **kwargs))(r0)


def _distributed(fn_name: str, **kwargs):
    from ..distributed import runtime as rt
    fn = getattr(rt, fn_name)
    prob = canonical_problem()
    return jax.make_jaxpr(
        lambda r: fn(prob, r, "c", num_shards=3, **kwargs)
    )(canonical_assignment())


def _shard_map():
    from ..distributed.runtime import refine_distributed_shard_map
    prob = canonical_problem()
    # num_shards=1 so the real collective path traces on any host; the
    # mesh degenerates but the all_gather program is the same code path.
    return jax.make_jaxpr(
        lambda r: refine_distributed_shard_map(prob, r, "c", num_shards=1,
                                               max_turns=_MAX_TURNS)
    )(canonical_assignment())


def _des_tick():
    from ..des.engine import des_tick
    cfg, adj, state0 = _canonical_des()
    return jax.make_jaxpr(lambda s: des_tick(cfg, adj, s))(state0)


_ENTRY_POINTS: tuple[EntryPoint, ...] = (
    EntryPoint("refine", "controller",
               lambda: _controller("refine", max_turns=_MAX_TURNS)),
    EntryPoint("refine.recompute", "controller",
               lambda: _controller("refine", max_turns=_MAX_TURNS,
                                   incremental=False)),
    EntryPoint("refine.theta", "controller",
               lambda: _controller("refine", framework="ct",
                                   max_turns=_MAX_TURNS, theta=0.25)),
    EntryPoint("refine.kernel", "controller", _kernel_dissat),
    EntryPoint("refine_traced", "controller",
               lambda: _controller("refine_traced", max_turns=_MAX_TURNS)),
    EntryPoint("refine_simultaneous", "controller",
               lambda: _controller("refine_simultaneous",
                                   max_sweeps=_MAX_SWEEPS)),
    EntryPoint("refine.sparse", "controller",
               lambda: _controller("refine", sparse=True,
                                   max_turns=_MAX_TURNS)),
    EntryPoint("refine_traced.sparse", "controller",
               lambda: _controller("refine_traced", sparse=True,
                                   max_turns=_MAX_TURNS)),
    EntryPoint("refine.sparse.edge_kernel", "controller",
               _edge_kernel_dissat),
    EntryPoint("refine_sweeps", "controller",
               lambda: _controller("refine_sweeps",
                                   max_sweeps=_MAX_SWEEPS)),
    EntryPoint("refine_sweeps.multi", "controller",
               lambda: _sweeps_prob(moves_per_machine=2, move_prob=0.5,
                                    epsilon=1e-3)),
    EntryPoint("refine_sweeps.sparse.unbounded", "controller",
               lambda: _sweeps_prob(sparse=True, moves_per_machine=None,
                                    move_prob=0.5, epsilon=1e-3)),
    EntryPoint("batch.refine", "batched",
               lambda: _batched("refine_batched", max_turns=_MAX_TURNS)),
    EntryPoint("batch.refine_traced", "batched",
               lambda: _batched("refine_traced_batched",
                                max_turns=_MAX_TURNS)),
    EntryPoint("batch.refine_simultaneous", "batched",
               lambda: _batched("refine_simultaneous_batched",
                                max_sweeps=_MAX_SWEEPS)),
    EntryPoint("batch.refine_sweeps", "batched",
               lambda: _batched("refine_sweeps_batched",
                                max_sweeps=_MAX_SWEEPS)),
    EntryPoint("distributed.refine", "distributed",
               lambda: _distributed("refine_distributed",
                                    max_turns=_MAX_TURNS)),
    EntryPoint("distributed.refine_traced", "distributed",
               lambda: _distributed("refine_distributed_traced",
                                    max_turns=_MAX_TURNS)),
    EntryPoint("distributed.refine_simultaneous", "distributed",
               lambda: _distributed("refine_distributed_simultaneous",
                                    max_sweeps=_MAX_SWEEPS)),
    EntryPoint("distributed.shard_map", "distributed", _shard_map),
    EntryPoint("des.tick", "des", _des_tick),
)


def registered_entry_points() -> tuple[EntryPoint, ...]:
    return _ENTRY_POINTS


@lru_cache(maxsize=None)
def trace_entry_point(name: str):
    """ClosedJaxpr of the named entry point (cached per process)."""
    for ep in _ENTRY_POINTS:
        if ep.name == name:
            return ep.trace()
    raise KeyError(f"unknown entry point {name!r}; registered: "
                   f"{[e.name for e in _ENTRY_POINTS]}")


def trace_all() -> dict[str, object]:
    return {ep.name: trace_entry_point(ep.name) for ep in _ENTRY_POINTS}
