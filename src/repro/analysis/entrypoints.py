"""Registry of public entry points the jaxpr analyzers trace (DESIGN.md §16.3).

Every public execution path of the stack — controller refinement (three
modes, dense and sparse, jnp and fused-kernel reductions), the batched
drivers, all four distributed drivers, and the DES tick — is registered
here with a thunk that traces it on a small canonical problem with
telemetry disabled (``recorder=None`` / ``emit_*=None``).  The analyzers
then make one statement over ALL of them: the disabled-telemetry
programs contain zero host callbacks and never leave the f32 dataflow.
This replaces the single hand-written jaxpr pin that used to live in
``tests/test_obs.py`` with registry-driven coverage: a new driver gets
the same guarantees by adding one entry here.

Every thunk is *size-parameterized* (``n``, ``k``, and — on sparse
paths — ``degree``): the complexity family (DESIGN.md §18) retraces each
entry point over a geometric grid of problem sizes and fits byte/op
power laws, so the same registry row yields both the canonical-size
jaxpr pins and the asymptotics audit.  ``trace_entry_point`` keeps its
historic meaning (the canonical small problem); sized traces go through
:func:`trace_entry_point_sized`.

Tracing is cached per process (``lru_cache``), so the CLI and the test
suite share the work.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["EntryPoint", "registered_entry_points", "entry_point",
           "trace_entry_point", "trace_entry_point_sized",
           "trace_all", "canonical_problem", "canonical_sparse",
           "canonical_sparse_degree", "canonical_batch",
           "canonical_assignment"]

_N, _K = 16, 3
_MAX_TURNS = 32
_MAX_SWEEPS = 12


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """One traced public execution path.

    ``trace`` returns the ClosedJaxpr of the path, with telemetry
    disabled — exactly the program the ``recorder=None`` fast path
    stages.  Called with no arguments it traces the canonical small
    problem; the complexity analyzers call it as ``trace(n=..., k=...,
    degree=...)`` to retrace at grid sizes (``degree`` only varies the
    sparse representations and is ignored by dense paths).

    ``rep`` records which representation the path consumes ("dense" or
    "sparse") — the complexity registry keys its declared budgets on it.
    ``max_n`` caps the N grid for paths whose *spec construction* is
    quadratic-or-worse on the host (batched stacks, the DES scenario);
    tracing itself never executes anything.
    """
    name: str
    runtime: str   # "controller" | "batched" | "distributed" | "des"
    trace: Callable[..., object]
    rep: str = "dense"
    max_n: int | None = None


@lru_cache(maxsize=None)
def canonical_problem(n: int = _N, k: int = _K, seed: int = 3):
    """The canonical small dense problem every analyzer traces on."""
    from ..core.problem import make_problem
    from ..graphs.generators import random_degree_graph, random_weights
    adj = random_degree_graph(n, seed=seed)
    b, c = random_weights(adj, seed=seed + 1, mean=5.0)
    return make_problem(c, b, np.ones(k) / k, mu=8.0)


@lru_cache(maxsize=None)
def canonical_sparse(n: int = _N, k: int = _K, seed: int = 3):
    from ..core.sparse import sparse_from_dense
    return sparse_from_dense(canonical_problem(n, k, seed))


@lru_cache(maxsize=None)
def canonical_sparse_degree(n: int, k: int, degree: int, seed: int = 3):
    """A sparse problem with controlled per-node degree, built on the
    edge-list path (no (N, N) host array — the complexity N/E grids go
    up to N=4096 and must not pay the dense floor just to trace)."""
    from ..core.sparse import make_sparse_problem
    from ..graphs.generators import (random_degree_graph_edges,
                                     random_weights_edges)
    s, r = random_degree_graph_edges(n, seed=seed, dmin=degree, dmax=degree)
    b, w = random_weights_edges(n, s, seed=seed + 1, mean=5.0)
    return make_sparse_problem(s, r, w, b, np.ones(k) / k, mu=8.0)


def _sparse_problem(n: int, k: int, degree: int | None):
    if degree is None:
        return canonical_sparse(n, k)
    return canonical_sparse_degree(n, k, degree)


def canonical_assignment(n: int = _N, k: int = _K):
    return jnp.asarray(np.arange(n) % k, jnp.int32)


@lru_cache(maxsize=None)
def canonical_batch(b: int = 2, n: int = _N, k: int = _K):
    """A stacked pair of same-shape problems + (B, N) assignments."""
    from ..core.batch import stack_problems
    probs = stack_problems([canonical_problem(n, k, seed=3 + i)
                            for i in range(b)])
    r0 = jnp.stack([canonical_assignment(n, k)] * b)
    return probs, r0


@lru_cache(maxsize=None)
def _canonical_des(n: int = 12, k: int = 2):
    """A tiny DES scenario (config, adjacency, initial state)."""
    from ..des.engine import DESConfig, make_initial_state
    from ..des.workload import flooded_packet_workload
    from ..graphs.generators import preferential_attachment
    threads = 4
    adj = preferential_attachment(n, 5, m=2)
    spec = flooded_packet_workload(adj, min(9, n - 1), num_threads=threads,
                                   num_windows=1, scope=2,
                                   window_sim_time=20.0, max_per_lp=2)
    speeds = tuple(float(s) for s in np.linspace(1.0, 0.7, k).round(2))
    cfg = DESConfig(num_lps=n, num_machines=k, num_threads=threads,
                    event_capacity=32, history_capacity=64,
                    inter_delay=6, intra_delay=1, trace_stride=10,
                    max_ticks=1_000, machine_speeds=speeds,
                    refine_freq=40, refine_theta_scale=5.0,
                    migration_freeze=0.25)
    m0 = jnp.asarray(np.arange(n) % k, jnp.int32)
    state0 = make_initial_state(cfg, m0, spec.src, spec.time, spec.count)
    return cfg, jnp.asarray(adj, jnp.float32), state0


# -- the individual trace thunks (one per registered path) -----------------
#
# Each accepts (n, k, degree) so the complexity grids can retrace it at
# any size; ``degree`` selects the controlled-degree sparse problem and
# is ignored on dense paths.

def _controller(fn_name: str, sparse: bool = False, n: int = _N,
                k: int = _K, degree: int | None = None, **kwargs):
    import importlib
    # attribute access would find the re-exported refine() function, not
    # the module, so resolve the submodule explicitly
    refine_mod = importlib.import_module("repro.core.refine")
    fn = getattr(refine_mod, fn_name)
    prob = _sparse_problem(n, k, degree) if sparse else canonical_problem(n, k)
    return jax.make_jaxpr(lambda r: fn(prob, r, **kwargs))(
        canonical_assignment(n, k))


def _kernel_dissat(n: int = _N, k: int = _K, degree: int | None = None):
    from ..core.refine import refine
    from ..kernels.ops import make_aggregate_dissat_fn
    prob = canonical_problem(n, k)
    dfn = make_aggregate_dissat_fn(interpret=True)
    return jax.make_jaxpr(
        lambda r: refine(prob, r, "c", max_turns=_MAX_TURNS, dissat_fn=dfn)
    )(canonical_assignment(n, k))


def _edge_kernel_dissat(n: int = _N, k: int = _K, degree: int | None = None):
    from ..core.refine import refine
    from ..kernels.ops import make_edge_dissat_fn
    sp = _sparse_problem(n, k, degree)
    dfn = make_edge_dissat_fn(sp, interpret=True)
    return jax.make_jaxpr(
        lambda r: refine(sp, r, "c", max_turns=_MAX_TURNS, dissat_fn=dfn)
    )(canonical_assignment(n, k))


def _sweeps_prob(sparse: bool = False, n: int = _N, k: int = _K,
                 degree: int | None = None, **kwargs):
    """Probabilistic refine_sweeps configs: the PRNG key rides as a
    traced argument (its extended key dtype is exempt from the f32
    dataflow rule, like every other key)."""
    import importlib
    refine_mod = importlib.import_module("repro.core.refine")
    prob = _sparse_problem(n, k, degree) if sparse else canonical_problem(n, k)
    return jax.make_jaxpr(
        lambda r, key: refine_mod.refine_sweeps(
            prob, r, max_sweeps=_MAX_SWEEPS, key=key, **kwargs)
    )(canonical_assignment(n, k), jax.random.PRNGKey(0))


def _batched(fn_name: str, n: int = _N, k: int = _K,
             degree: int | None = None, **kwargs):
    from ..core import batch as batch_mod
    fn = getattr(batch_mod, fn_name)
    probs, r0 = canonical_batch(2, n, k)
    return jax.make_jaxpr(lambda r: fn(probs, r, "c", **kwargs))(r0)


def _distributed(fn_name: str, n: int = _N, k: int = _K,
                 degree: int | None = None, **kwargs):
    from ..distributed import runtime as rt
    fn = getattr(rt, fn_name)
    prob = canonical_problem(n, k)
    return jax.make_jaxpr(
        lambda r: fn(prob, r, "c", num_shards=3, **kwargs)
    )(canonical_assignment(n, k))


def _shard_map(n: int = _N, k: int = _K, degree: int | None = None):
    from ..distributed.runtime import refine_distributed_shard_map
    prob = canonical_problem(n, k)
    # num_shards=1 so the real collective path traces on any host; the
    # mesh degenerates but the all_gather program is the same code path.
    return jax.make_jaxpr(
        lambda r: refine_distributed_shard_map(prob, r, "c", num_shards=1,
                                               max_turns=_MAX_TURNS)
    )(canonical_assignment(n, k))


def _des_tick(n: int = 12, k: int = 2, degree: int | None = None):
    from ..des.engine import des_tick
    cfg, adj, state0 = _canonical_des(n, k)
    return jax.make_jaxpr(lambda s: des_tick(cfg, adj, s))(state0)


def _sized(fn: Callable[..., object], **fixed) -> Callable[..., object]:
    """Bind an entry point's non-size arguments, leaving (n, k, degree)
    open for the complexity grids (defaults = the canonical problem)."""
    def thunk(n: int = _N, k: int = _K, degree: int | None = None):
        return fn(n=n, k=k, degree=degree, **fixed)
    return thunk


_ENTRY_POINTS: tuple[EntryPoint, ...] = (
    EntryPoint("refine", "controller",
               _sized(_controller, fn_name="refine", max_turns=_MAX_TURNS)),
    EntryPoint("refine.recompute", "controller",
               _sized(_controller, fn_name="refine", max_turns=_MAX_TURNS,
                      incremental=False)),
    EntryPoint("refine.theta", "controller",
               _sized(_controller, fn_name="refine", framework="ct",
                      max_turns=_MAX_TURNS, theta=0.25)),
    EntryPoint("refine.kernel", "controller", _kernel_dissat),
    EntryPoint("refine_traced", "controller",
               _sized(_controller, fn_name="refine_traced",
                      max_turns=_MAX_TURNS)),
    EntryPoint("refine_simultaneous", "controller",
               _sized(_controller, fn_name="refine_simultaneous",
                      max_sweeps=_MAX_SWEEPS)),
    EntryPoint("refine.sparse", "controller",
               _sized(_controller, fn_name="refine", sparse=True,
                      max_turns=_MAX_TURNS), rep="sparse"),
    EntryPoint("refine_traced.sparse", "controller",
               _sized(_controller, fn_name="refine_traced", sparse=True,
                      max_turns=_MAX_TURNS), rep="sparse"),
    EntryPoint("refine.sparse.edge_kernel", "controller",
               _edge_kernel_dissat, rep="sparse"),
    EntryPoint("refine_sweeps", "controller",
               _sized(_controller, fn_name="refine_sweeps",
                      max_sweeps=_MAX_SWEEPS)),
    EntryPoint("refine_sweeps.multi", "controller",
               _sized(_sweeps_prob, moves_per_machine=2, move_prob=0.5,
                      epsilon=1e-3)),
    EntryPoint("refine_sweeps.sparse.unbounded", "controller",
               _sized(_sweeps_prob, sparse=True, moves_per_machine=None,
                      move_prob=0.5, epsilon=1e-3), rep="sparse"),
    EntryPoint("batch.refine", "batched",
               _sized(_batched, fn_name="refine_batched",
                      max_turns=_MAX_TURNS), max_n=1024),
    EntryPoint("batch.refine_traced", "batched",
               _sized(_batched, fn_name="refine_traced_batched",
                      max_turns=_MAX_TURNS), max_n=1024),
    EntryPoint("batch.refine_simultaneous", "batched",
               _sized(_batched, fn_name="refine_simultaneous_batched",
                      max_sweeps=_MAX_SWEEPS), max_n=1024),
    EntryPoint("batch.refine_sweeps", "batched",
               _sized(_batched, fn_name="refine_sweeps_batched",
                      max_sweeps=_MAX_SWEEPS), max_n=1024),
    EntryPoint("distributed.refine", "distributed",
               _sized(_distributed, fn_name="refine_distributed",
                      max_turns=_MAX_TURNS)),
    EntryPoint("distributed.refine_traced", "distributed",
               _sized(_distributed, fn_name="refine_distributed_traced",
                      max_turns=_MAX_TURNS)),
    EntryPoint("distributed.refine_simultaneous", "distributed",
               _sized(_distributed, fn_name="refine_distributed_simultaneous",
                      max_sweeps=_MAX_SWEEPS)),
    EntryPoint("distributed.shard_map", "distributed", _shard_map),
    EntryPoint("des.tick", "des", _des_tick, max_n=1024),
)


def registered_entry_points() -> tuple[EntryPoint, ...]:
    return _ENTRY_POINTS


def entry_point(name: str) -> EntryPoint:
    for ep in _ENTRY_POINTS:
        if ep.name == name:
            return ep
    raise KeyError(f"unknown entry point {name!r}; registered: "
                   f"{[e.name for e in _ENTRY_POINTS]}")


@lru_cache(maxsize=None)
def trace_entry_point(name: str):
    """ClosedJaxpr of the named entry point (cached per process)."""
    return entry_point(name).trace()


@lru_cache(maxsize=None)
def trace_entry_point_sized(name: str, n: int, k: int,
                            degree: int | None = None):
    """ClosedJaxpr of the named entry point retraced at (n, k, degree)
    — the complexity grids' workhorse (cached per process; nothing
    executes, tracing cost is size-independent)."""
    return entry_point(name).trace(n=n, k=k, degree=degree)


def trace_all() -> dict[str, object]:
    return {ep.name: trace_entry_point(ep.name) for ep in _ENTRY_POINTS}
