"""jaxpr-family analyzers: walk the traced entry points (DESIGN.md §16.3).

Three statically checkable properties of the staged programs:

  * **zero-callback** — the ``recorder=None`` / ``emit_*=None`` program
    of every registered entry point contains no host-callback primitive
    (the telemetry seams of DESIGN.md §14 must stage NOTHING when
    disabled; this generalizes the one-off jaxpr pin that used to live
    in ``tests/test_obs.py``).
  * **dtype-drift** — no equation output anywhere in any entry-point
    jaxpr leaves the f32 dataflow (no f64/weak-f64 promotion, no f16/
    bf16 truncation, no complex, no 64-bit ints): the bitwise contracts
    (sparse==dense, batched==looped, distributed==controller) are only
    meaningful if every path computes in the same precision.
  * **compile-cache audit** — over the canonical sweep grouping grid,
    every case inside one ``sweeps.runtime._group_key`` group must
    present the identical jit signature (pytree structure + per-element
    leaf shapes/dtypes), i.e. each group lowers exactly once.  A case
    that would silently trigger recompilation inside its group is a
    finding — the runtime gate for this is the compile-count assert in
    ``benchmarks/sweep_bench.py``.
"""
from __future__ import annotations

import numpy as np

import jax

from .registry import AnalysisContext, Finding, rule
from .entrypoints import (canonical_assignment, canonical_problem,
                          canonical_sparse)

__all__ = ["iter_eqns", "callback_primitives", "dtype_drift",
           "canonical_sweep_cases", "case_signature",
           "group_signature_findings", "compiled_group_count"]

# the only dtypes the potential/dissatisfaction dataflow may stage;
# everything else (f64, f16/bf16, complex, 64-bit ints) is drift
_ALLOWED_DTYPES = frozenset({
    "bool", "int8", "uint8", "int16", "uint16", "int32", "uint32",
    "float32",
})


def _sub_jaxprs(params: dict):
    for v in params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for item in vals:
            if hasattr(item, "eqns"):                 # Jaxpr
                yield item
            elif hasattr(item, "jaxpr"):              # ClosedJaxpr
                yield item.jaxpr


def iter_eqns(jaxpr):
    """All equations of ``jaxpr`` including nested sub-jaxprs
    (scan/while/cond bodies, pjit calls, custom_vmap, shard_map...)."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)   # accept ClosedJaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def callback_primitives(jaxpr) -> list[str]:
    """Names of every host-callback primitive staged in ``jaxpr``."""
    return [eqn.primitive.name for eqn in iter_eqns(jaxpr)
            if "callback" in eqn.primitive.name]


def _aval_dtype_name(aval) -> str | None:
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return None                       # tokens etc.
    try:
        if jax.dtypes.issubdtype(dtype, jax.dtypes.extended):
            return None                   # PRNG key dtypes
    except TypeError:
        return None
    return np.dtype(dtype).name


def dtype_drift(jaxpr) -> list[tuple[str, str]]:
    """Sorted ``(dtype, primitive)`` pairs for every off-contract dtype
    staged by any equation output (one representative primitive each)."""
    seen: dict[str, str] = {}
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            name = _aval_dtype_name(getattr(var, "aval", None))
            if name is not None and name not in _ALLOWED_DTYPES:
                seen.setdefault(name, eqn.primitive.name)
    return sorted(seen.items())


@rule("jaxpr-zero-callback", "jaxpr")
def _rule_zero_callback(ctx: AnalysisContext) -> list[Finding]:
    """recorder=None programs stage zero host callbacks (every entry point)."""
    findings = []
    for name, jaxpr in ctx.entry_jaxprs().items():
        for prim in sorted(set(callback_primitives(jaxpr))):
            findings.append(Finding(
                rule="jaxpr-zero-callback", key=f"{name}:{prim}",
                message=f"entry point {name!r} stages host callback "
                        f"primitive {prim!r} on its telemetry-disabled "
                        f"path (must be identical to the pre-telemetry "
                        f"program — DESIGN.md §14.2)"))
    ctx.reports["jaxpr-zero-callback"] = {
        "entry_points": sorted(ctx.entry_jaxprs())}
    return findings


@rule("jaxpr-dtype-drift", "jaxpr")
def _rule_dtype_drift(ctx: AnalysisContext) -> list[Finding]:
    """No equation output leaves the f32 dataflow (any entry point)."""
    findings = []
    for name, jaxpr in ctx.entry_jaxprs().items():
        for dtype, prim in dtype_drift(jaxpr):
            findings.append(Finding(
                rule="jaxpr-dtype-drift", key=f"{name}:{dtype}",
                message=f"entry point {name!r} stages a {dtype} value "
                        f"(first seen at primitive {prim!r}); the "
                        f"bitwise contracts require the f32 dataflow"))
    return findings


# -- compile-cache audit over the sweep grouping grid ----------------------

def canonical_sweep_cases():
    """The canonical grouping grid: (framework, theta-ness, problem shape)
    with two same-shape dense problems per combination, a second dense
    shape, and a sparse problem — 16 cases in 12 groups."""
    from ..sweeps.runtime import SweepCase
    probs = [canonical_problem(16, 3, seed=3),
             canonical_problem(16, 3, seed=11),
             canonical_problem(24, 3, seed=5),
             canonical_sparse(16, 3, seed=3)]
    cases = []
    for p in probs:
        n = p.num_nodes
        r0 = canonical_assignment(n, 3)
        for fw in ("c", "ct"):
            for theta in (None, 0.3):
                cases.append(SweepCase(problem=p, assignment=r0,
                                       framework=fw, theta=theta,
                                       label=f"n{n}-{fw}-{theta}"))
    return cases


def case_signature(case):
    """The jit-signature surrogate of one case: the pytree structure and
    per-element leaf (shape, dtype) of its single-case stack.  Two cases
    in the same group stack into one program iff these agree (the static
    argnames — framework, theta-ness, mode knobs — are already part of
    ``_group_key`` / the spec)."""
    from ..sweeps.runtime import _stack_group
    operands = _stack_group([case])
    leaves, treedef = jax.tree_util.tree_flatten(operands)
    return (str(treedef),
            tuple((leaf.shape[1:], str(leaf.dtype)) for leaf in leaves))


def group_signature_findings(cases) -> tuple[list[Finding], dict]:
    """Audit: every ``_group_key`` group must hold exactly one signature."""
    from ..sweeps.runtime import _group_key
    groups: dict = {}
    for case in cases:
        groups.setdefault(_group_key(case), []).append(case)
    findings = []
    for gkey, gcases in groups.items():
        sigs = {}
        for case in gcases:
            sigs.setdefault(case_signature(case), []).append(case.label)
        if len(sigs) > 1:
            fw, theta_none, shape = gkey
            labels = sorted(l for ls in sigs.values() for l in ls)
            findings.append(Finding(
                rule="sweep-compile-groups",
                key=f"{fw}:{'nothet' if theta_none else 'theta'}:{shape}",
                message=f"sweep group {gkey} holds {len(sigs)} distinct "
                        f"jit signatures across cases {labels} — the "
                        f"group would lower {len(sigs)} times instead of "
                        f"once (recompilation trigger)"))
    report = {"cases": len(cases), "groups": len(groups),
              "violations": len(findings)}
    return findings, report


@rule("sweep-compile-groups", "jaxpr")
def _rule_compile_groups(ctx: AnalysisContext) -> list[Finding]:
    """Each canonical sweep group presents exactly one jit signature."""
    findings, report = group_signature_findings(canonical_sweep_cases())
    ctx.reports["sweep-compile-groups"] = report
    return findings


def compiled_group_count(fn) -> int:
    """Current jit-cache entry count of a jitted callable — the runtime
    counterpart of the static audit; ``benchmarks/sweep_bench.py`` takes
    the delta across a sweep and asserts it equals the group count."""
    return fn._cache_size()
