"""Rule registry + analysis context for the contract linter (DESIGN.md §16).

The linter is a flat registry of named rules grouped into five families:

  * ``jaxpr`` — trace the registered public entry points
    (:mod:`repro.analysis.entrypoints`) and walk the jaxprs: zero host
    callbacks on ``recorder=None`` paths, no dtype drift out of the f32
    potential dataflow, and a compile-cache audit over the sweep
    grouping grid.
  * ``ast``   — stdlib-``ast`` lint over ``src/``: the canonical 9-arg
    ``dissat_fn`` signature, the single Eq.-4 θ-subtraction site,
    trace-unsafe patterns inside jitted bodies, and the
    dense/sparse × runtime dispatch-coverage matrix.
  * ``wire``  — size the exchange buffers symbolically
    (``jax.eval_shape`` over :mod:`repro.distributed.protocol`) and
    prove the per-round ledger bytes are independent of N.
  * ``docs``  — the DESIGN.md-§ and doc-file reference scans
    (formerly inlined in ``tests/test_docs.py``).
  * ``complexity`` — retrace every entry point over a geometric size
    grid, fit peak-bytes/op-count power laws against per-module declared
    budgets, audit collective schedules, and diff fitted exponents
    against the checked-in ``complexity.json`` (DESIGN.md §18).

Findings carry a stable id ``rule:key``.  A checked-in baseline file
(:func:`load_baseline`) absorbs *known* gaps — today exactly the
missing sparse×distributed dispatch cell — so CI fails only on NEW
findings, never on the documented ones.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import pathlib
from typing import Callable, Iterable

__all__ = [
    "Finding", "Rule", "AnalysisContext", "rule", "registered_rules",
    "run_rules", "load_baseline", "split_findings", "default_baseline_path",
    "FAMILIES",
]

FAMILIES = ("jaxpr", "ast", "wire", "docs", "complexity")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation (or documented gap) with a stable identity."""
    rule: str
    key: str          # stable within the rule — the baseline matches on it
    message: str
    file: str = ""    # repo-relative path, when the finding has a location
    line: int = 0

    @property
    def id(self) -> str:
        return f"{self.rule}:{self.key}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "key": self.key, "message": self.message,
                "file": self.file, "line": self.line, "id": self.id}


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    family: str
    doc: str
    fn: Callable[["AnalysisContext"], list[Finding]]


_RULES: dict[str, Rule] = {}


def rule(name: str, family: str):
    """Register ``fn(ctx) -> list[Finding]`` under ``name``.

    Adding a rule is: write the function, decorate it, done — the CLI,
    the baseline machinery and ``tests/test_contracts.py`` pick it up
    from the registry (DESIGN.md §16.2).
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown rule family {family!r}; "
                         f"expected one of {FAMILIES}")

    def deco(fn):
        if name in _RULES:
            raise ValueError(f"duplicate rule name {name!r}")
        _RULES[name] = Rule(name=name, family=family,
                            doc=(fn.__doc__ or "").strip().splitlines()[0]
                            if fn.__doc__ else "", fn=fn)
        return fn
    return deco


def registered_rules(families: Iterable[str] | None = None) -> list[Rule]:
    fams = set(families) if families is not None else set(FAMILIES)
    return [r for r in _RULES.values() if r.family in fams]


def _default_repo_root() -> pathlib.Path:
    # src/repro/analysis/registry.py -> repo root is three levels above src
    return pathlib.Path(__file__).resolve().parents[3]


class AnalysisContext:
    """Shared state for one analysis run: cached sources/ASTs, lazily
    traced entry-point jaxprs, and the per-rule report stash.

    ``source_overrides`` maps repo-relative paths to replacement source
    text — the seeded-violation tests use it to lint a deliberately
    broken copy of a module without touching the tree on disk.

    ``complexity_grid`` selects the size grid the complexity family
    retraces on ("full" for CI/CLI, "quick" for the test suite — see
    ``complexity_rules.GRIDS``).
    """

    def __init__(self, repo_root: pathlib.Path | str | None = None,
                 source_overrides: dict[str, str] | None = None,
                 complexity_grid: str = "full"):
        self.repo = pathlib.Path(repo_root) if repo_root else \
            _default_repo_root()
        self.source_overrides = dict(source_overrides or {})
        self.complexity_grid = complexity_grid
        self._sources: dict[str, str] = {}
        self._trees: dict[str, ast.Module] = {}
        self._jaxprs = None
        self.reports: dict[str, dict] = {}

    # -- sources / ASTs ----------------------------------------------------
    def source(self, relpath: str) -> str:
        if relpath not in self._sources:
            if relpath in self.source_overrides:
                self._sources[relpath] = self.source_overrides[relpath]
            else:
                self._sources[relpath] = (self.repo / relpath).read_text()
        return self._sources[relpath]

    def tree(self, relpath: str) -> ast.Module:
        if relpath not in self._trees:
            self._trees[relpath] = ast.parse(self.source(relpath),
                                             filename=relpath)
        return self._trees[relpath]

    def py_files(self, *dirs: str) -> list[str]:
        """Repo-relative paths of every .py file under the given dirs,
        plus any override paths that fall under them."""
        out: set[str] = set()
        for d in dirs:
            base = self.repo / d
            if base.is_dir():
                out.update(str(p.relative_to(self.repo))
                           for p in base.rglob("*.py"))
            out.update(p for p in self.source_overrides
                       if p.startswith(d.rstrip("/") + "/"))
        return sorted(out)

    # -- entry-point jaxprs ------------------------------------------------
    def entry_jaxprs(self) -> dict[str, object]:
        """name -> ClosedJaxpr for every registered entry point (lazy;
        tracing happens once per context, and once per process thanks to
        the ``entrypoints`` module cache)."""
        if self._jaxprs is None:
            from . import entrypoints
            self._jaxprs = entrypoints.trace_all()
        return self._jaxprs


def run_rules(ctx: AnalysisContext,
              families: Iterable[str] | None = None) -> list[Finding]:
    findings: list[Finding] = []
    for r in registered_rules(families):
        findings.extend(r.fn(ctx))
    return findings


# -- baseline --------------------------------------------------------------

def default_baseline_path() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parent / "baseline.json"


def load_baseline(path: pathlib.Path | str | None = None) -> set[str]:
    """The set of finding ids (``rule:key``) that are known and accepted."""
    p = pathlib.Path(path) if path else default_baseline_path()
    if not p.is_file():
        return set()
    data = json.loads(p.read_text())
    return {f"{e['rule']}:{e['key']}" for e in data.get("findings", [])}


def split_findings(findings: list[Finding], baseline: set[str]):
    """Partition into (new, known) and report stale baseline ids.

    Returns ``(new, known, stale)`` where ``stale`` is the set of
    baseline ids no current finding matches — the gap got fixed, so the
    baseline entry should be deleted (reported, never fatal).
    """
    new = [f for f in findings if f.id not in baseline]
    known = [f for f in findings if f.id in baseline]
    stale = baseline - {f.id for f in findings}
    return new, known, stale
