"""Coordinated cluster transfers (paper §7 future work, §4.4 discussion).

Single-node best-response converges to a Nash equilibrium that may be a poor
local optimum of the potential.  The paper proposes moving *groups of
connected nodes* to escape such equilibria.  Exhaustive joint search is
exponential, so — following the §4.4 suggestion of restricting the joint
strategy space — we evaluate, for the most dissatisfied node of each
machine, the joint transfer of its h-hop same-machine neighborhood to each
destination machine, accepting the best potential-decreasing move.

Sparse problems (DESIGN.md §17.3): :func:`cluster_move_pass` accepts a
:class:`~repro.core.sparse.SparseProblem` in place of the dense problem.
The only dense-only step was the h-hop mask's O(N^2) ``mask @ adjacency``
frontier; :func:`h_hop_mask` dispatches it to the O(E) CSR frontier
expansion of :func:`repro.core.sparse.frontier_expand` (a masked
``segment_max`` over the sender slabs per hop), and everything else —
cost matrix, dissatisfaction, candidate global costs — was already
representation-polymorphic through :mod:`repro.core.costs`.  Every
accepted move strictly descends the global potential (the pass compares
full global costs, so the Thm. 3.1/5.1 descent argument applies to the
joint move exactly as to a unilateral one); ``tests/test_cluster.py``
asserts it on both representations.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import costs
from .problem import make_state
from .sparse import SparseProblem, frontier_expand

Array = jax.Array

AnyProblem = costs.AnyProblem


class ClusterMoveResult(NamedTuple):
    assignment: Array
    moved: Array       # bool — whether any cluster move was applied
    gain: Array        # potential decrease achieved (>= 0)


def _h_hop_mask(adj: Array, seed_node: Array, hops: int) -> Array:
    """Boolean mask of nodes within ``hops`` of ``seed_node`` (inclusive)."""
    n = adj.shape[0]
    nbr = adj > 0
    mask = jnp.zeros((n,), bool).at[seed_node].set(True)

    def body(_, m):
        return m | (m @ nbr)

    return jax.lax.fori_loop(0, hops, body, mask)


def h_hop_mask(problem: AnyProblem, seed_node: Array, hops: int) -> Array:
    """Nodes within ``hops`` of ``seed_node`` (inclusive), either
    representation: dense walks the O(N^2) adjacency (one boolean
    matvec per hop), sparse expands the CSR frontier in O(E) per hop
    (:func:`repro.core.sparse.frontier_expand`).  Identical masks on
    converted problems — ``tests/test_cluster.py`` asserts it."""
    if isinstance(problem, SparseProblem):
        n = problem.num_nodes
        mask = jnp.zeros((n,), bool).at[seed_node].set(True)

        def body(_, m):
            return frontier_expand(problem, m)

        return jax.lax.fori_loop(0, hops, body, mask)
    return _h_hop_mask(problem.adjacency, seed_node, hops)


@partial(jax.jit, static_argnames=("framework", "hops"))
def cluster_move_pass(problem: AnyProblem, assignment: Array,
                      framework: str = costs.C_FRAMEWORK,
                      hops: int = 1) -> ClusterMoveResult:
    """One pass: for every machine's most dissatisfied node, try moving its
    h-hop owned neighborhood jointly to every machine; apply the single best
    strictly-improving move found across all machines (sequential semantics
    keep the potential-descent property).

    Accepts dense and sparse problems alike — the candidate costs are
    full :func:`repro.core.costs.global_cost` evaluations (O(N^2) dense,
    O(E) sparse per candidate), so an accepted move descends the global
    potential by construction.
    """
    K = problem.num_machines
    state = make_state(problem, assignment)
    cost = costs.cost_matrix(problem, state, framework)
    dissat, _ = costs.dissatisfaction(problem, state, framework, cost=cost)
    base = costs.global_cost(problem, assignment, framework)

    owned = jax.nn.one_hot(assignment, K, dtype=cost.dtype)          # (N, K)
    masked = jnp.where(owned.T > 0, dissat[None, :], -jnp.inf)       # (K, N)
    seeds = jnp.argmax(masked, axis=1).astype(jnp.int32)             # (K,)

    def eval_machine(m):
        seed = seeds[m]
        cluster = h_hop_mask(problem, seed, hops)
        cluster = cluster & (assignment == assignment[seed])

        def eval_dest(k):
            cand = jnp.where(cluster, k, assignment).astype(jnp.int32)
            return costs.global_cost(problem, cand, framework)

        dest_costs = jax.vmap(eval_dest)(jnp.arange(K, dtype=jnp.int32))
        dest_costs = dest_costs.at[assignment[seed]].set(jnp.inf)
        best_k = jnp.argmin(dest_costs).astype(jnp.int32)
        return dest_costs[best_k], best_k, cluster

    dest_cost, dest_k, clusters = jax.vmap(eval_machine)(
        jnp.arange(K, dtype=jnp.int32))
    best_m = jnp.argmin(dest_cost).astype(jnp.int32)
    gain = base - dest_cost[best_m]
    moved = gain > 1e-6
    new_assignment = jnp.where(
        moved & clusters[best_m],
        dest_k[best_m],
        assignment,
    ).astype(jnp.int32)
    return ClusterMoveResult(assignment=new_assignment, moved=moved,
                             gain=jnp.maximum(gain, 0.0))
