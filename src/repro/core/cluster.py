"""Coordinated cluster transfers (paper §7 future work, §4.4 discussion).

Single-node best-response converges to a Nash equilibrium that may be a poor
local optimum of the potential.  The paper proposes moving *groups of
connected nodes* to escape such equilibria.  Exhaustive joint search is
exponential, so — following the §4.4 suggestion of restricting the joint
strategy space — we evaluate, for the most dissatisfied node of each
machine, the joint transfer of its h-hop same-machine neighborhood to each
destination machine, accepting the best potential-decreasing move.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import costs
from .problem import PartitionProblem, make_state

Array = jax.Array


class ClusterMoveResult(NamedTuple):
    assignment: Array
    moved: Array       # bool — whether any cluster move was applied
    gain: Array        # potential decrease achieved (>= 0)


def _h_hop_mask(adj: Array, seed_node: Array, hops: int) -> Array:
    """Boolean mask of nodes within ``hops`` of ``seed_node`` (inclusive)."""
    n = adj.shape[0]
    nbr = adj > 0
    mask = jnp.zeros((n,), bool).at[seed_node].set(True)

    def body(_, m):
        return m | (m @ nbr)

    return jax.lax.fori_loop(0, hops, body, mask)


@partial(jax.jit, static_argnames=("framework", "hops"))
def cluster_move_pass(problem: PartitionProblem, assignment: Array,
                      framework: str = costs.C_FRAMEWORK,
                      hops: int = 1) -> ClusterMoveResult:
    """One pass: for every machine's most dissatisfied node, try moving its
    h-hop owned neighborhood jointly to every machine; apply the single best
    strictly-improving move found across all machines (sequential semantics
    keep the potential-descent property).
    """
    K = problem.num_machines
    state = make_state(problem, assignment)
    cost = costs.cost_matrix(problem, state, framework)
    dissat, _ = costs.dissatisfaction(problem, state, framework, cost=cost)
    base = costs.global_cost(problem, assignment, framework)

    owned = jax.nn.one_hot(assignment, K, dtype=cost.dtype)          # (N, K)
    masked = jnp.where(owned.T > 0, dissat[None, :], -jnp.inf)       # (K, N)
    seeds = jnp.argmax(masked, axis=1).astype(jnp.int32)             # (K,)

    def eval_machine(m):
        seed = seeds[m]
        cluster = _h_hop_mask(problem.adjacency, seed, hops)
        cluster = cluster & (assignment == assignment[seed])

        def eval_dest(k):
            cand = jnp.where(cluster, k, assignment).astype(jnp.int32)
            return costs.global_cost(problem, cand, framework)

        dest_costs = jax.vmap(eval_dest)(jnp.arange(K, dtype=jnp.int32))
        dest_costs = dest_costs.at[assignment[seed]].set(jnp.inf)
        best_k = jnp.argmin(dest_costs).astype(jnp.int32)
        return dest_costs[best_k], best_k, cluster

    dest_cost, dest_k, clusters = jax.vmap(eval_machine)(
        jnp.arange(K, dtype=jnp.int32))
    best_m = jnp.argmin(dest_cost).astype(jnp.int32)
    gain = base - dest_cost[best_m]
    moved = gain > 1e-6
    new_assignment = jnp.where(
        moved & clusters[best_m],
        dest_k[best_m],
        assignment,
    ).astype(jnp.int32)
    return ClusterMoveResult(assignment=new_assignment, moved=moved,
                             gain=jnp.maximum(gain, 0.0))
