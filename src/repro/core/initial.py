"""Initial partitioning (paper §4.1 + Appendix A).

1. Focal-node selection: find K nodes approximately maximizing the minimum
   pairwise geodesic distance (Eq. 11) via the paper's round-robin local
   improvement over neighbors, restarted from several random seeds.
2. Hop-by-hop expansion: every machine grows a BFS cluster from its focal
   node; contested frontier nodes are arbitrated deterministically (the
   paper uses random back-off + semaphores — DESIGN.md §3.5 explains the
   substitution) with a per-round random priority so no machine is
   systematically favored.
3. Theorem A.1: the Erdős–Rényi expected-cluster-growth recursion, used as a
   property-test oracle for the expansion code.

Unit node/edge weights are assumed during initial partitioning (§4.1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array

_INF = jnp.int32(0x3FFFFFFF)


@partial(jax.jit, static_argnames=("max_hops",))
def bfs_distances(adj: Array, sources: Array, max_hops: int | None = None) -> Array:
    """Geodesic hop distances from each source via frontier matmuls.

    adj: (N, N) nonzero-where-edge matrix.  sources: (S,) int32.
    Returns (S, N) int32 distances (_INF where unreachable).
    """
    n = adj.shape[0]
    max_hops = n if max_hops is None else max_hops
    nbr = (adj > 0)

    def one(src):
        dist = jnp.full((n,), _INF, jnp.int32).at[src].set(0)
        frontier = jnp.zeros((n,), bool).at[src].set(True)

        def cond(c):
            _, frontier, hop = c
            return jnp.any(frontier) & (hop < max_hops)

        def body(c):
            dist, frontier, hop = c
            nxt = (frontier @ nbr) & (dist == _INF)
            dist = jnp.where(nxt, hop + 1, dist)
            return dist, nxt, hop + 1

        dist, _, _ = jax.lax.while_loop(cond, body, (dist, frontier, jnp.int32(0)))
        return dist

    return jax.vmap(one)(jnp.asarray(sources, jnp.int32))


def _min_pairwise(dist_fk: Array) -> Array:
    """Minimum pairwise distance among focal nodes given (K, K) distances."""
    K = dist_fk.shape[0]
    off = dist_fk + jnp.where(jnp.eye(K, dtype=bool), _INF, 0)
    return jnp.min(off)


@partial(jax.jit, static_argnames=("num_machines", "num_restarts", "max_rounds"))
def select_focal_nodes(adj: Array, num_machines: int, key: Array,
                       num_restarts: int = 4, max_rounds: int = 16) -> Array:
    """Appendix-A heuristic for Eq. 11 (max-min geodesic focal set)."""
    n = adj.shape[0]
    all_dist = bfs_distances(adj, jnp.arange(n))      # (N, N) — reused heavily
    nbr = adj > 0

    def objective(focals):
        d = all_dist[focals][:, focals]
        return _min_pairwise(d)

    def improve_round(focals, _):
        # Round-robin: each machine tries to move its focal to a neighbor that
        # increases the min distance to the other focals.
        def per_machine(m, focals):
            cur = focals[m]
            # distance of each candidate node to every other focal
            d_to_others = all_dist[:, focals]                      # (N, K)
            d_to_others = jnp.where(
                (jnp.arange(num_machines) == m)[None, :], _INF, d_to_others)
            score = jnp.min(d_to_others, axis=1)                   # (N,)
            cand_mask = nbr[cur] | (jnp.arange(n) == cur)
            score = jnp.where(cand_mask, score, -1)
            best = jnp.argmax(score).astype(jnp.int32)
            take = score[best] > score[cur]
            return focals.at[m].set(jnp.where(take, best, cur))

        focals = jax.lax.fori_loop(
            0, num_machines, lambda m, f: per_machine(m, f), focals)
        return focals, None

    def one_restart(k):
        focals = jax.random.choice(k, n, (num_machines,), replace=False).astype(jnp.int32)
        focals, _ = jax.lax.scan(improve_round, focals, None, length=max_rounds)
        return focals, objective(focals)

    keys = jax.random.split(key, num_restarts)
    focal_sets, scores = jax.vmap(one_restart)(keys)
    return focal_sets[jnp.argmax(scores)]


@partial(jax.jit, static_argnames=("num_machines", "max_hops"))
def expand_partitions(adj: Array, focals: Array, key: Array,
                      num_machines: int, max_hops: int | None = None) -> Array:
    """Hop-by-hop cluster growth from focal nodes with contention arbitration.

    Each round every machine claims unowned nodes adjacent to its cluster;
    a node claimed by several machines goes to the one with the highest
    random priority that round (stands in for the paper's random back-off).
    Disconnected leftovers are assigned to the smallest cluster.
    Returns (N,) int32 assignment.
    """
    n = adj.shape[0]
    max_hops = n if max_hops is None else max_hops
    nbr = adj > 0
    owner = jnp.full((n,), -1, jnp.int32).at[focals].set(
        jnp.arange(num_machines, dtype=jnp.int32))

    def cond(c):
        owner, hop, _ = c
        return jnp.any(owner < 0) & (hop < max_hops)

    def body(c):
        owner, hop, key = c
        key, sub = jax.random.split(key)
        prio = jax.random.uniform(sub, (num_machines,))
        member = jax.nn.one_hot(owner, num_machines, dtype=jnp.float32)   # (N,K), zero row if unowned
        member = jnp.where((owner >= 0)[:, None], member, 0.0)
        reach = (nbr.astype(jnp.float32).T @ member) > 0                  # (N,K) claimable by k
        claim_score = jnp.where(reach, prio[None, :], -1.0)
        best_k = jnp.argmax(claim_score, axis=1).astype(jnp.int32)
        claimable = jnp.max(claim_score, axis=1) >= 0
        grew = jnp.any(claimable & (owner < 0))
        new_owner = jnp.where((owner < 0) & claimable, best_k, owner)
        # If nothing grew but unowned nodes remain, the graph is disconnected:
        # dump remaining nodes on the smallest cluster and finish.
        sizes = jnp.zeros((num_machines,), jnp.int32).at[
            jnp.clip(new_owner, 0)].add((new_owner >= 0).astype(jnp.int32))
        smallest = jnp.argmin(sizes).astype(jnp.int32)
        new_owner = jnp.where(
            grew, new_owner,
            jnp.where(new_owner < 0, smallest, new_owner))
        return new_owner, hop + 1, key

    owner, _, _ = jax.lax.while_loop(cond, body, (owner, jnp.int32(0), key))
    return owner


def initial_partition(adj: Array, num_machines: int, key: Array,
                      num_restarts: int = 4) -> Array:
    """Full Appendix-A pipeline: focal selection + expansion."""
    k1, k2 = jax.random.split(jnp.asarray(key))
    focals = select_focal_nodes(adj, num_machines, k1, num_restarts=num_restarts)
    return expand_partitions(adj, focals, k2, num_machines)


def er_cluster_growth(num_nodes: int, p: float, hops: int):
    """Theorem A.1 recursion: expected BFS cluster size on G(n, p) per hop.

    N_{k+1} = N_k + (|V| - N_k) * (1 - (1-p)^(N_k - N_{k-1})),  N_1 = 1.
    Returns an array of expected cluster sizes for hops 0..hops.
    """
    sizes = [1.0]
    prev, cur = 0.0, 1.0
    for _ in range(hops):
        nxt = cur + (num_nodes - cur) * (1.0 - (1.0 - p) ** (cur - prev))
        prev, cur = cur, nxt
        sizes.append(cur)
    return jnp.asarray(sizes)
