"""Meta-heuristics for escaping poor local minima (paper §4.4 and §7).

  * ``simulated_annealing`` — Metropolis single-node moves over the chosen
    global potential with geometric cooling ([Kirkpatrick et al. 1983],
    cited in §4.4).  The paper reports ~5% cost improvements from annealing
    on comparable partitioning problems.
  * ``cluster_move_pass`` (in cluster.py) — the §7 "transfer groups of
    connected nodes" future-work idea, implemented as joint h-hop
    neighborhood transfers evaluated directly on the potential.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import costs
from .problem import PartitionProblem, machine_loads

Array = jax.Array


class AnnealResult(NamedTuple):
    assignment: Array
    cost: Array
    accepted: Array     # int32 — number of accepted proposals
    trace: Array        # (steps,) potential after each proposal


@partial(jax.jit, static_argnames=("framework", "steps"))
def simulated_annealing(problem: PartitionProblem, assignment: Array, key: Array,
                        framework: str = costs.C_FRAMEWORK,
                        steps: int = 2048, t0: float = 100.0,
                        cooling: float = 0.995) -> AnnealResult:
    """Metropolis search over single-node reassignments.

    Proposal: uniform (node, machine).  Accept if the potential decreases or
    with probability exp(-delta / T).  Tracks the best-so-far assignment so
    the output never regresses versus the input.
    """
    K = problem.num_machines
    N = problem.num_nodes
    cost_fn = lambda r: costs.global_cost(problem, r, framework)

    def step(carry, k):
        r, cur, best_r, best_c, temp, acc = carry
        k1, k2, k3 = jax.random.split(k, 3)
        node = jax.random.randint(k1, (), 0, N)
        dest = jax.random.randint(k2, (), 0, K).astype(jnp.int32)
        cand = r.at[node].set(dest)
        cand_cost = cost_fn(cand)
        delta = cand_cost - cur
        accept = (delta < 0) | (jax.random.uniform(k3) < jnp.exp(-delta / temp))
        r = jnp.where(accept, cand, r)
        cur = jnp.where(accept, cand_cost, cur)
        better = cur < best_c
        best_r = jnp.where(better, r, best_r)
        best_c = jnp.where(better, cur, best_c)
        acc = acc + accept.astype(jnp.int32)
        return (r, cur, best_r, best_c, temp * cooling, acc), cur

    r0 = jnp.asarray(assignment, jnp.int32)
    c0 = cost_fn(r0)
    keys = jax.random.split(key, steps)
    (r, cur, best_r, best_c, _, acc), trace = jax.lax.scan(
        step, (r0, c0, r0, c0, jnp.asarray(t0, jnp.float32), jnp.int32(0)), keys)
    return AnnealResult(assignment=best_r, cost=best_c, accepted=acc, trace=trace)
