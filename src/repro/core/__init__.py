"""Core contribution of Kurve et al. 2011: the partitioning game.

Public API:
  * PartitionProblem / PartitionState / make_problem / make_state
  * cost frameworks (costs.C_FRAMEWORK / costs.CT_FRAMEWORK), cost_matrix,
    dissatisfaction, global potentials C_0 / Ct_0
  * refine / refine_traced / refine_simultaneous — iterative improvement
    (incremental aggregate-state path by default, DESIGN.md §10)
  * refine_sweeps — multi-move probabilistic sweeps (top-M or unbounded
    elections, cs/0506098 acceptance coins, 1305.3354 ε-equilibrium
    stop; DESIGN.md §17)
  * batched variants (stack_problems + refine*_batched, DESIGN.md §12) —
    scenario fleets under one jax.vmap-compiled program
  * AggregateState / init_aggregate_state — the carried aggregate
  * SparseProblem / make_sparse_problem / sparse_from_dense /
    dense_from_sparse — padded edge-list problems (DESIGN.md §13); every
    refine/costs/aggregate entry point accepts either representation
  * initial_partition (focal nodes + hop expansion), er_cluster_growth
  * simulated_annealing, cluster_move_pass — §4.4/§7 meta-heuristics
"""
from . import aggregate, costs  # noqa: F401
from .aggregate import AggregateState, init_aggregate_state  # noqa: F401
from .batch import (  # noqa: F401
    batch_size,
    refine_batched,
    refine_simultaneous_batched,
    refine_sweeps_batched,
    refine_traced_batched,
    stack_problems,
    stack_pytrees,
    unstack_pytree,
)
from .annealing import AnnealResult, simulated_annealing  # noqa: F401
from .constrained import (  # noqa: F401
    contiguous_stage_dp,
    equalize_cardinality,
    make_contiguous,
)
from .cluster import ClusterMoveResult, cluster_move_pass  # noqa: F401
from .costs import (  # noqa: F401
    C_FRAMEWORK,
    CT_FRAMEWORK,
    FRAMEWORKS,
    adjacency_aggregate,
    adjacency_aggregate_sparse,
    cost_matrix,
    cost_matrix_from_aggregate,
    dissatisfaction,
    dissatisfaction_from_cost,
    global_cost,
    global_cost_c0,
    global_cost_ct0,
    load_imbalance,
    node_costs,
    problem_aggregate,
    total_cut,
    total_cut_sparse,
)
from .initial import (  # noqa: F401
    bfs_distances,
    er_cluster_growth,
    expand_partitions,
    initial_partition,
    select_focal_nodes,
)
from . import checkpoint  # noqa: F401
from .problem import (  # noqa: F401
    PartitionProblem,
    PartitionState,
    ProblemValidationError,
    machine_loads,
    make_problem,
    make_state,
    validate_assignment,
)
from .sparse import (  # noqa: F401
    SparseProblem,
    dense_from_sparse,
    make_sparse_problem,
    node_incident_edges,
    sparse_from_dense,
)
from .refine import (  # noqa: F401
    RefineResult,
    Trace,
    count_discrepancies,
    refine,
    refine_simultaneous,
    refine_sweeps,
    refine_traced,
)
