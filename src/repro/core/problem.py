"""Partition-game problem container.

The paper partitions an undirected weighted graph G = (V, E) of logical
processes among K machines.  ``PartitionProblem`` carries everything the
two cost frameworks (Eq. 1 and Eq. 6) need:

  * ``adjacency``  — dense symmetric (N, N) float matrix of edge weights
                     ``c_ij`` (zero diagonal).  Dense is the TPU-native
                     representation: the refinement hot spot is
                     ``adjacency @ one_hot(r)`` which maps onto the MXU.
  * ``node_weights`` — (N,) computational load ``b_i`` per LP.
  * ``speeds``       — (K,) normalized machine capacities ``w_k`` (sum 1).
  * ``mu``           — relative weight of the inter-machine potential
                       rollback-delay cost (paper §3.1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class ProblemValidationError(ValueError):
    """Typed error for malformed problem inputs (DESIGN.md §15.7).

    Raised by the ``validate()`` methods and
    :func:`validate_assignment` instead of letting bad inputs fail deep
    inside jit as shape errors or NaN-poisoned results.  Value checks
    (NaN, negativity, symmetry, range) run only on concrete arrays —
    under a trace only the shape checks apply."""


def _is_concrete(*arrays) -> bool:
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionProblem:
    adjacency: Array      # (N, N) float, symmetric, zero diagonal
    node_weights: Array   # (N,)  float
    speeds: Array         # (K,)  float, sums to 1
    mu: Array             # scalar float

    @property
    def num_nodes(self) -> int:
        return self.adjacency.shape[0]

    @property
    def num_machines(self) -> int:
        return self.speeds.shape[0]

    def validate(self) -> None:
        """Raise :class:`ProblemValidationError` on malformed fields."""
        import numpy as np
        n = self.num_nodes
        if self.adjacency.ndim != 2 \
                or self.adjacency.shape != (n, n):
            raise ProblemValidationError(
                f"adjacency must be square (N, N); got "
                f"{self.adjacency.shape}")
        if self.node_weights.shape != (n,):
            raise ProblemValidationError(
                f"node_weights shape {self.node_weights.shape} does not "
                f"match N={n}")
        if self.speeds.ndim != 1:
            raise ProblemValidationError(
                f"speeds must be (K,); got shape {self.speeds.shape}")
        if not _is_concrete(self.adjacency, self.node_weights, self.speeds):
            return
        adj = np.asarray(self.adjacency)
        if np.isnan(adj).any():
            raise ProblemValidationError("adjacency contains NaN edge "
                                         "weights")
        if (adj < 0).any():
            raise ProblemValidationError("adjacency contains negative edge "
                                         "weights")
        if not np.array_equal(adj, adj.T):
            raise ProblemValidationError("adjacency is not symmetric (the "
                                         "graph is undirected; use "
                                         "make_problem to symmetrize)")
        b = np.asarray(self.node_weights)
        if np.isnan(b).any() or (b < 0).any():
            raise ProblemValidationError("node_weights must be finite and "
                                         "non-negative")
        w = np.asarray(self.speeds)
        if np.isnan(w).any() or (w <= 0).any():
            raise ProblemValidationError("speeds must be finite and "
                                         "positive")


def make_problem(
    adjacency,
    node_weights,
    speeds,
    mu: float = 8.0,
    *,
    normalize_speeds: bool = True,
    dtype=jnp.float32,
) -> PartitionProblem:
    """Build a :class:`PartitionProblem`, symmetrizing and normalizing inputs."""
    adjacency = jnp.asarray(adjacency, dtype)
    # Symmetrize and clear the diagonal: the paper's graph is undirected and
    # self-edges are meaningless for a cut.
    adjacency = 0.5 * (adjacency + adjacency.T)
    adjacency = adjacency * (1.0 - jnp.eye(adjacency.shape[0], dtype=dtype))
    node_weights = jnp.asarray(node_weights, dtype)
    speeds = jnp.asarray(speeds, dtype)
    if normalize_speeds:
        speeds = speeds / jnp.sum(speeds)
    prob = PartitionProblem(adjacency, node_weights, speeds, jnp.asarray(mu, dtype))
    prob.validate()
    return prob


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionState:
    """Assignment vector plus the machine-level aggregate the paper exchanges.

    ``loads`` is the only *global* state a machine needs (paper §4.5): the
    per-machine sums ``L_k = sum_{j: r_j = k} b_j``.  Keeping it in the state
    (instead of recomputing) mirrors the paper's ``common variable array``.
    """
    assignment: Array  # (N,) int32 in [0, K)
    loads: Array       # (K,) float

    @property
    def num_machines(self) -> int:
        return self.loads.shape[0]


def validate_assignment(assignment, num_machines: int,
                        num_nodes: int | None = None) -> None:
    """Raise :class:`ProblemValidationError` on a malformed assignment
    vector: wrong dtype/shape, or (concrete arrays only) machine ids
    outside ``[0, num_machines)``."""
    import numpy as np
    if getattr(assignment, "ndim", None) != 1:
        raise ProblemValidationError(
            f"assignment must be a 1-D vector; got "
            f"{getattr(assignment, 'shape', type(assignment))}")
    if not jnp.issubdtype(assignment.dtype, jnp.integer):
        raise ProblemValidationError(
            f"assignment must be integer-typed; got {assignment.dtype}")
    if num_nodes is not None and assignment.shape[0] != num_nodes:
        raise ProblemValidationError(
            f"assignment has {assignment.shape[0]} entries for "
            f"{num_nodes} nodes")
    if not _is_concrete(assignment):
        return
    r = np.asarray(assignment)
    if r.size and (r.min() < 0 or r.max() >= num_machines):
        raise ProblemValidationError(
            f"assignment entries must lie in [0, {num_machines}); got "
            f"range [{r.min()}, {r.max()}]")


def machine_loads(node_weights: Array, assignment: Array, num_machines: int) -> Array:
    """L_k = sum of b_j over nodes assigned to machine k."""
    return jnp.zeros((num_machines,), node_weights.dtype).at[assignment].add(node_weights)


def make_state(problem: PartitionProblem, assignment) -> PartitionState:
    assignment = jnp.asarray(assignment, jnp.int32)
    loads = machine_loads(problem.node_weights, assignment, problem.num_machines)
    return PartitionState(assignment=assignment, loads=loads)
