"""Partition-game problem container.

The paper partitions an undirected weighted graph G = (V, E) of logical
processes among K machines.  ``PartitionProblem`` carries everything the
two cost frameworks (Eq. 1 and Eq. 6) need:

  * ``adjacency``  — dense symmetric (N, N) float matrix of edge weights
                     ``c_ij`` (zero diagonal).  Dense is the TPU-native
                     representation: the refinement hot spot is
                     ``adjacency @ one_hot(r)`` which maps onto the MXU.
  * ``node_weights`` — (N,) computational load ``b_i`` per LP.
  * ``speeds``       — (K,) normalized machine capacities ``w_k`` (sum 1).
  * ``mu``           — relative weight of the inter-machine potential
                       rollback-delay cost (paper §3.1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionProblem:
    adjacency: Array      # (N, N) float, symmetric, zero diagonal
    node_weights: Array   # (N,)  float
    speeds: Array         # (K,)  float, sums to 1
    mu: Array             # scalar float

    @property
    def num_nodes(self) -> int:
        return self.adjacency.shape[0]

    @property
    def num_machines(self) -> int:
        return self.speeds.shape[0]

    def validate(self) -> None:
        n = self.num_nodes
        assert self.adjacency.shape == (n, n), self.adjacency.shape
        assert self.node_weights.shape == (n,), self.node_weights.shape
        assert self.speeds.ndim == 1


def make_problem(
    adjacency,
    node_weights,
    speeds,
    mu: float = 8.0,
    *,
    normalize_speeds: bool = True,
    dtype=jnp.float32,
) -> PartitionProblem:
    """Build a :class:`PartitionProblem`, symmetrizing and normalizing inputs."""
    adjacency = jnp.asarray(adjacency, dtype)
    # Symmetrize and clear the diagonal: the paper's graph is undirected and
    # self-edges are meaningless for a cut.
    adjacency = 0.5 * (adjacency + adjacency.T)
    adjacency = adjacency * (1.0 - jnp.eye(adjacency.shape[0], dtype=dtype))
    node_weights = jnp.asarray(node_weights, dtype)
    speeds = jnp.asarray(speeds, dtype)
    if normalize_speeds:
        speeds = speeds / jnp.sum(speeds)
    prob = PartitionProblem(adjacency, node_weights, speeds, jnp.asarray(mu, dtype))
    prob.validate()
    return prob


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionState:
    """Assignment vector plus the machine-level aggregate the paper exchanges.

    ``loads`` is the only *global* state a machine needs (paper §4.5): the
    per-machine sums ``L_k = sum_{j: r_j = k} b_j``.  Keeping it in the state
    (instead of recomputing) mirrors the paper's ``common variable array``.
    """
    assignment: Array  # (N,) int32 in [0, K)
    loads: Array       # (K,) float

    @property
    def num_machines(self) -> int:
        return self.loads.shape[0]


def machine_loads(node_weights: Array, assignment: Array, num_machines: int) -> Array:
    """L_k = sum of b_j over nodes assigned to machine k."""
    return jnp.zeros((num_machines,), node_weights.dtype).at[assignment].add(node_weights)


def make_state(problem: PartitionProblem, assignment) -> PartitionState:
    assignment = jnp.asarray(assignment, jnp.int32)
    loads = machine_loads(problem.node_weights, assignment, problem.num_machines)
    return PartitionState(assignment=assignment, loads=loads)
