"""Checkpoint/heal for the carried aggregate state (DESIGN.md §15.3).

The incremental drivers carry an :class:`~repro.core.aggregate
.AggregateState` across thousands of rank-1 updates.  ``verify_every``
(PR 2) *observes* drift; this module *acts* on it: a :class:`Checkpoint`
is a cheap snapshot of the carry (a pytree alias — zero copies until a
donation or an update forces one), and :func:`heal` is the recovery
step the ``repair_every`` boundary of :func:`repro.core.refine.refine`
runs inside a ``lax.cond``:

1. **Rollback** — if any float leaf of the live carry is non-finite
   (bit corruption, a NaN that leaked through the cost assembly), the
   whole carry is replaced by the last checkpoint.  A NaN cannot be
   patched column-wise because it poisons every reduction that reads
   it, so the only sound base state is the last known-good one.
2. **Column repair** — :func:`repro.core.aggregate.repair_columns`
   rebuilds the oracle state from the (possibly rolled-back)
   assignment and patches only the aggregate columns / load entries /
   potentials that deviate beyond ``tol``.  An undrifted carry passes
   through bitwise untouched.

Refinement then resumes from the repaired state: moves replayed since
the checkpoint are simply re-discovered by the game (every turn is a
best response to the *current* state, so rollback costs extra turns,
never correctness — Thm. 4.1 descent still holds from the repaired
state).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import aggregate as agg_mod
from .aggregate import AggregateState

Array = jax.Array

# Matches the repo-wide drift budget (obs.recorder.DRIFT_BUDGET and the
# recover-or-raise budget of repro.distributed.faults).
DEFAULT_REPAIR_TOL = 1e-3


class Checkpoint(NamedTuple):
    """A known-good carry snapshot plus the turn it was taken at."""
    state: AggregateState
    turn: Array                 # int32 — turn counter at snapshot time


def take(agg: AggregateState, turn) -> Checkpoint:
    """Snapshot the carry.  O(1) at trace time (pytree alias)."""
    return Checkpoint(state=agg, turn=jnp.asarray(turn, jnp.int32))


def restore(ckpt: Checkpoint) -> AggregateState:
    """The checkpointed carry (symmetry helper for :func:`take`)."""
    return ckpt.state


def is_healthy(agg: AggregateState) -> Array:
    """True iff every float leaf of the carry is finite."""
    ok = jnp.ones((), bool)
    for leaf in jax.tree.leaves(agg):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            ok = ok & jnp.all(jnp.isfinite(leaf))
    return ok


def heal(problem, agg: AggregateState, ckpt: Checkpoint,
         tol: float = DEFAULT_REPAIR_TOL
         ) -> tuple[AggregateState, Array, Array, Array]:
    """Rollback-if-poisoned, then column repair (module docstring).

    Returns ``(repaired, observed, cols, rolled_back)`` — the healed
    carry, the max pre-repair deviation (inf when the live carry was
    rolled back over a NaN), the number of aggregate columns patched,
    and whether the rollback branch fired.
    """
    healthy = is_healthy(agg)
    base = jax.tree.map(
        lambda live, saved: jnp.where(healthy, live, saved),
        agg, ckpt.state)
    repaired, observed, cols = agg_mod.repair_columns(problem, base, tol)
    observed = jnp.where(healthy, observed, jnp.inf)
    return repaired, observed, cols, ~healthy
