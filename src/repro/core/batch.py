"""Batched ("fleet") execution of the partition game (DESIGN.md §12).

The paper's claims are statistical — equilibria, potential descent and
load balance over *families* of topologies, seeds and cost frameworks —
so the natural unit of execution is not one ``PartitionProblem`` but a
stack of them.  This module provides the stacking primitives and the
batched refinement entry points: ``B`` same-shaped problems (same N and
K; adjacency, node weights, speeds, mu and theta all varying per
element) are stacked leaf-wise into one pytree with a leading batch
axis, and a single ``jax.vmap``-compiled program runs all ``B``
refinements at once.  Scenario coverage then scales with hardware
instead of with a Python loop's dispatch overhead.

Per-element semantics are the looped semantics (DESIGN.md §12): JAX's
batching rules turn ``lax.while_loop`` into a run-until-all-converge
loop that select-masks finished elements, and ``lax.scan`` into a scan
of the batched body, so every batch element reproduces the move
sequence, assignment, loads and gains of its own unbatched run
*bitwise*.  The one documented exception: the carried potentials
(``Trace.c0`` / ``Trace.ct0``) may differ from the looped run in the
last float32 ULP, because XLA may fuse the exact-potential-identity
update ``c0 + dc0`` differently in batched layouts; they stay within
the same ≤1e-3 relative budget the incremental path already carries
(``benchmarks/sweep_bench.py`` gates both properties in CI).

The higher-level ``SweepSpec → SweepResult`` API (grouping cases by
their static dims, reduction helpers) lives in :mod:`repro.sweeps`; the
batched DES engine entry point is
:func:`repro.des.engine.run_simulation_batch`.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from . import costs
from .problem import PartitionProblem
from .refine import (DEFAULT_TOL, refine, refine_simultaneous, refine_sweeps,
                     refine_traced)
from .sparse import SparseProblem

Array = jax.Array


# ---------------------------------------------------------------------------
# pytree stacking (DESIGN.md §12.1)
# ---------------------------------------------------------------------------

def stack_pytrees(trees: Sequence):
    """Stack same-structure, same-leaf-shape pytrees along a new leading
    batch axis.  The result has the SAME pytree type as the inputs, so a
    stack of ``PartitionProblem``\\ s is itself a ``PartitionProblem``
    whose leaves carry a leading ``(B, ...)`` dimension — exactly what
    ``jax.vmap`` with ``in_axes=0`` consumes (DESIGN.md §12.1)."""
    trees = list(trees)
    if not trees:
        raise ValueError("cannot stack an empty sequence of pytrees")
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *trees)


def unstack_pytree(tree, index: int):
    """Element ``index`` of a stacked pytree (inverse of one stack slot)."""
    return jax.tree.map(lambda leaf: leaf[index], tree)


def batch_size(tree) -> int:
    """Leading batch dimension of a stacked pytree."""
    return jax.tree.leaves(tree)[0].shape[0]


def problem_shape_key(problem) -> tuple:
    """The static shape signature a problem must share to stack/vmap.

    Dense problems stack by (N, K); sparse ones additionally by their
    padded edge count and static ``max_degree`` (DESIGN.md §13.4) — the
    edge arrays are leaves, so one compiled program needs one padded E,
    and ``max_degree`` is jit-static aux data."""
    key: tuple = (type(problem).__name__, problem.num_nodes,
                  problem.num_machines)
    if isinstance(problem, SparseProblem):
        key += (problem.num_edges, problem.max_degree)
    return key


def stack_problems(problems: Sequence[PartitionProblem]) -> PartitionProblem:
    """Stack ``B`` problems (same N, same K) into one batched problem.

    Adjacency (or edge list), node weights, speeds and mu may all differ
    per element; the *shapes* (and for :class:`SparseProblem`, padded
    edge count + ``max_degree``) must agree because one compiled program
    serves the whole stack (mixed sizes belong in separate stacks —
    ``repro.sweeps`` groups by shape automatically)."""
    problems = list(problems)
    shapes = {problem_shape_key(p) for p in problems}
    if len(shapes) != 1:
        raise ValueError(
            f"stack_problems needs one shape signature, got "
            f"{sorted(shapes)}; group differently-shaped problems into "
            "separate stacks")
    return stack_pytrees(problems)


def shard_across_devices(tree, devices=None):
    """Shard a stacked pytree's leading batch axis across devices.

    The batch axis is embarrassingly parallel, so on multi-device
    hardware (TPU slice, GPUs, or a CPU host forced to expose
    ``--xla_force_host_platform_device_count=N`` devices) placing each
    element's slab on its own device lets the vmapped program run
    batch-parallel — per-element results are unchanged (each element's
    program is untouched SPMD; DESIGN.md §12.5).  No-op on a single
    device or when the batch does not divide the device count.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    bsz = batch_size(tree)
    ndev = len(devices)
    if ndev <= 1 or bsz % ndev != 0:
        return tree
    mesh = jax.sharding.Mesh(np.asarray(devices), ("batch",))
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("batch"))
    return jax.tree.map(lambda leaf: jax.device_put(leaf, sharding), tree)


def _stack_theta(theta, num_problems: int, num_nodes: int):
    """Normalize a per-batch theta spec to None or a (B, N) f32 array."""
    if theta is None:
        return None
    theta = jnp.asarray(theta, jnp.float32)
    return jnp.broadcast_to(theta, (num_problems, num_nodes))


# ---------------------------------------------------------------------------
# batched refinement entry points
# ---------------------------------------------------------------------------

def _vmap_over_theta(fn, problems, assignments, theta):
    """vmap ``fn(problem, assignment, theta)`` with theta optionally absent.

    ``theta=None`` must stay a *literal* ``None`` inside every element
    (the threshold-free code path of DESIGN.md §11), so it cannot ride a
    vmapped zeros array — it is dispatched statically here instead."""
    if theta is None:
        return jax.vmap(lambda p, r: fn(p, r, None))(problems, assignments)
    return jax.vmap(fn)(problems, assignments, theta)


@partial(jax.jit, static_argnames=("framework", "max_turns", "incremental",
                                   "verify_every", "dissat_fn"))
def refine_batched(problems: PartitionProblem, assignments: Array,
                   framework: str = costs.C_FRAMEWORK,
                   max_turns: int = 10_000, tol: float = DEFAULT_TOL,
                   incremental: bool = True, verify_every: int = 0,
                   dissat_fn=None, theta=None):
    """:func:`repro.core.refine.refine` over a problem stack (DESIGN.md §12).

    ``problems`` is a stacked ``PartitionProblem`` (leaves ``(B, ...)``,
    see :func:`stack_problems`), ``assignments`` is ``(B, N)`` and
    ``theta`` is ``None`` or broadcastable to ``(B, N)``.  Returns a
    ``RefineResult`` whose leaves carry a leading batch axis.  The
    batched ``lax.while_loop`` runs until every element converges,
    select-masking the finished ones, so each element's result equals
    its unbatched run bitwise.  ``dissat_fn`` follows the convention of
    :mod:`repro.core.refine`; ``repro.kernels.ops.make_aggregate_dissat_fn``
    stays on the fused Pallas kernel under this vmap via its batch-grid
    variant (DESIGN.md §12.3)."""
    b, n = assignments.shape

    def one(problem, r0, th):
        return refine(problem, r0, framework, max_turns=max_turns, tol=tol,
                      incremental=incremental, verify_every=verify_every,
                      dissat_fn=dissat_fn, theta=th)

    return _vmap_over_theta(one, problems, assignments,
                            _stack_theta(theta, b, n))


@partial(jax.jit, static_argnames=("framework", "max_turns", "incremental",
                                   "verify_every"))
def refine_traced_batched(problems: PartitionProblem, assignments: Array,
                          framework: str = costs.C_FRAMEWORK,
                          max_turns: int = 512, tol: float = DEFAULT_TOL,
                          incremental: bool = True, verify_every: int = 0,
                          theta=None):
    """:func:`repro.core.refine.refine_traced` over a problem stack.

    Returns ``(RefineResult, Trace)`` with a leading batch axis on every
    leaf: ``Trace.moved`` is ``(B, T)``, etc.  Fixed-length scans batch
    trivially, so per-element move sequences are bitwise those of the
    looped runs; the carried potentials keep the ≤1e-3 relative budget
    (DESIGN.md §12.2)."""
    b, n = assignments.shape

    def one(problem, r0, th):
        return refine_traced(problem, r0, framework, max_turns=max_turns,
                             tol=tol, incremental=incremental,
                             verify_every=verify_every, theta=th)

    return _vmap_over_theta(one, problems, assignments,
                            _stack_theta(theta, b, n))


@partial(jax.jit, static_argnames=("framework", "max_sweeps"))
def refine_simultaneous_batched(problems: PartitionProblem,
                                assignments: Array,
                                framework: str = costs.C_FRAMEWORK,
                                max_sweeps: int = 256,
                                tol: float = DEFAULT_TOL, theta=None):
    """§4.5 simultaneous-sweep mode over a problem stack (DESIGN.md §12).

    Returns ``(RefineResult, (c0s, ct0s, active))`` with leading batch
    axes (the per-sweep potential traces are ``(B, max_sweeps)``)."""
    b, n = assignments.shape

    def one(problem, r0, th):
        return refine_simultaneous(problem, r0, framework,
                                   max_sweeps=max_sweeps, tol=tol, theta=th)

    return _vmap_over_theta(one, problems, assignments,
                            _stack_theta(theta, b, n))


@partial(jax.jit, static_argnames=("framework", "max_sweeps",
                                   "moves_per_machine", "move_prob",
                                   "epsilon"))
def refine_sweeps_batched(problems: PartitionProblem, assignments: Array,
                          framework: str = costs.C_FRAMEWORK,
                          max_sweeps: int = 256, tol: float = DEFAULT_TOL,
                          theta=None, moves_per_machine: int | None = 1,
                          move_prob: float = 1.0, epsilon: float = 0.0,
                          keys: Array | None = None):
    """:func:`repro.core.refine.refine_sweeps` over a problem stack
    (DESIGN.md §17): multi-move probabilistic sweep fleets.

    ``keys`` is a ``(B,)`` stack of PRNG keys (``jax.vmap``-able, e.g.
    ``jax.random.split(key, B)``), required exactly when
    ``move_prob < 1`` — each element folds its own key per sweep, so
    per-element coin sequences equal the looped runs'.  All sweep
    configuration (``moves_per_machine``/``move_prob``/``epsilon``) is
    static and shared across the batch, like ``framework``.  Returns
    ``(RefineResult, (c0s, ct0s, active))`` with leading batch axes."""
    b, n = assignments.shape
    if move_prob < 1.0:
        if keys is None:
            raise ValueError("refine_sweeps_batched(move_prob < 1) needs a "
                             "(B,) stack of PRNG `keys` (e.g. "
                             "jax.random.split)")

    def one(problem, r0, th, key=None):
        return refine_sweeps(problem, r0, framework, max_sweeps=max_sweeps,
                             tol=tol, theta=th,
                             moves_per_machine=moves_per_machine,
                             move_prob=move_prob, epsilon=epsilon, key=key)

    th = _stack_theta(theta, b, n)
    if keys is None:
        return _vmap_over_theta(one, problems, assignments, th)
    if th is None:
        return jax.vmap(lambda p, r, k: one(p, r, None, k))(
            problems, assignments, keys)
    return jax.vmap(one)(problems, assignments, th, keys)
