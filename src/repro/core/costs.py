"""The paper's two node-level cost frameworks and their global potentials.

Framework 1 (Eq. 1):
    C_i(r) = (b_i / w_{r_i}) * sum_{j != i, r_j = r_i} b_j
             + (mu/2) * sum_{j: r_j != r_i} c_ij
  Global potential (Thm. 3.1):  C_0(r) = sum_i C_i(r),
  with the exact-potential identity  Delta C_0 = 2 * Delta C_l  for a
  unilateral move of node l.

Framework 2 (Eq. 6):
    Ct_i(r) = b_i^2/w_{r_i}^2 + (2 b_i / w_{r_i}^2) * sum_{j != i, r_j=r_i} b_j
              - (2 b_i / w_{r_i}) * B + (mu/2) * sum_{j: r_j != r_i} c_ij
  Global objective (Eq. 8, centralized load-variance + cut):
    Ct_0(r) = sum_k (L_k / w_k - B)^2 + (mu/2) * cut(r)
  with the exact-potential identity  Delta Ct_0 = Delta Ct_l  (Thm. 5.1).

Convention note (DESIGN.md §8): Eq. 8 as printed sums ordered pairs, which
double-counts each cut edge and breaks the Thm. 5.1 identity by a factor of
two.  We use the (mu/2) * unordered-cut convention, under which the identity
is *exact*; tests/test_game_theory.py asserts both identities numerically.

Everything here is O(N*K) given the aggregate matrix A[i,k] = sum_j c_ij
1[r_j = k], itself an (N,N)x(N,K) matmul — the refinement hot spot that
``repro/kernels/dissatisfaction.py`` implements as a fused Pallas kernel.
The refinement engines avoid even that matmul after the first turn:
``repro.core.aggregate`` carries A through the loop and applies a rank-1
column update per move (DESIGN.md §10); :func:`cost_matrix_from_aggregate`
is the shared O(N*K) assembly both paths delegate to.

Sparse problems (DESIGN.md §13): every public entry point taking a
``problem`` also accepts a :class:`~repro.core.sparse.SparseProblem` —
the aggregate becomes an O(E*K) ``segment_sum`` over the edge list
(:func:`adjacency_aggregate_sparse`), the cut an O(E) edge sum
(:func:`total_cut_sparse`), and both global potentials the O(K) closed
forms of :func:`potentials_closed_form`, so nothing on the sparse path
ever touches an O(N^2) array.  Dispatch happens at trace time via
``isinstance`` — the dense op sequence is untouched.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .problem import PartitionProblem, PartitionState, machine_loads
from .sparse import SparseProblem

Array = jax.Array

AnyProblem = PartitionProblem | SparseProblem

C_FRAMEWORK = "c"     # Eq. 1
CT_FRAMEWORK = "ct"   # Eq. 6
FRAMEWORKS = (C_FRAMEWORK, CT_FRAMEWORK)

# Declared asymptotic budgets for the dense representation, consumed by
# the complexity analyzers (DESIGN.md §18).  Exponent caps per problem
# dimension: the (N, N) adjacency is the representation floor, so dense
# paths may stage O(N^2) intermediates and O(N^2 * K) work — anything
# steeper is a finding.
DENSE_COMPLEXITY = {
    "mem": {"n": 2.0, "k": 1.0},
    "ops": {"n": 2.0, "k": 1.0},
}


def adjacency_aggregate(adjacency: Array, assignment: Array, num_machines: int) -> Array:
    """A[i, k] = sum_j c_ij * 1[r_j = k]; computed as C @ one_hot(r)."""
    onehot = jax.nn.one_hot(assignment, num_machines, dtype=adjacency.dtype)
    return adjacency @ onehot


def adjacency_aggregate_sparse(sp: SparseProblem, assignment: Array) -> Array:
    """The same (N, K) aggregate from the edge list: one O(E)
    ``segment_sum`` keyed on the flattened ``sender * K + r[receiver]``
    slot id (DESIGN.md §13.2).  Each (row, machine) slot accumulates its
    slab's edges receiver-ascending — the same per-slot order as the
    per-edge one-hot formulation this replaces (whose skipped entries
    were exact ``+0.0``\\ s), so values are bitwise unchanged while the
    (E, K) intermediate and its K-fold memory traffic disappear.  Padded
    edges carry weight 0 and land on a real slot of the last row, an
    exact ``+0.0``.
    """
    slot = sp.senders * sp.num_machines + assignment[sp.receivers]
    flat = jax.ops.segment_sum(
        sp.edge_weights, slot,
        num_segments=sp.num_nodes * sp.num_machines,
        indices_are_sorted=False)
    return flat.reshape(sp.num_nodes, sp.num_machines)


def problem_aggregate(problem: AnyProblem, assignment: Array,
                      num_machines: int) -> Array:
    """Build the (N, K) aggregate for either problem representation."""
    if isinstance(problem, SparseProblem):
        return adjacency_aggregate_sparse(problem, assignment)
    return adjacency_aggregate(problem.adjacency, assignment, num_machines)


def cut_matrix(adjacency: Array, assignment: Array, num_machines: int,
               aggregate: Array | None = None) -> Array:
    """cut[i, k] = (1) * sum_{j: r_j != k} c_ij  (the mu/2 factor applied later)."""
    if aggregate is None:
        aggregate = adjacency_aggregate(adjacency, assignment, num_machines)
    degree = jnp.sum(aggregate, axis=-1, keepdims=True)       # = sum_j c_ij
    return degree - aggregate


def cost_matrix_from_aggregate(aggregate: Array, row_assignment: Array,
                               node_weights: Array, loads: Array,
                               speeds: Array, mu: Array, framework: str,
                               total_weight: Array | None = None) -> Array:
    """O(rows*K) cost assembly from an already-built adjacency aggregate.

    This is THE shared cost formula (DESIGN.md §10): the recompute path
    (:func:`cost_matrix`), the shard-local path
    (:func:`repro.distributed.protocol.shard_cost_matrix`) and the
    incremental path (:mod:`repro.core.aggregate`) all delegate here, so
    any two paths fed the same aggregate produce bitwise-identical costs.

    ``aggregate`` is the (rows, K) block A[i, k] = sum_j c_ij 1[r_j = k]
    (rows may be a shard's row block of a larger graph);
    ``row_assignment`` gives the rows' OWN machines; ``total_weight`` is
    the global weight sum B, required by the Ct framework (defaults to
    ``sum(node_weights)``, correct only when the rows are the full graph).
    """
    b = node_weights
    k = loads.shape[0]
    degree = jnp.sum(aggregate, axis=-1, keepdims=True)       # = sum_j c_ij
    cut_term = 0.5 * mu * (degree - aggregate)
    own = jax.nn.one_hot(row_assignment, k, dtype=b.dtype)    # (rows, K)
    # others[i, k] = sum_{j != i, r_j = k} b_j if i were moved to k: node
    # i's weight is subtracted only on its CURRENT machine — every other
    # machine's load already excludes i.
    others = loads[None, :] - b[:, None] * own
    if framework == C_FRAMEWORK:
        load_term = (b[:, None] / speeds[None, :]) * others
        return load_term + cut_term
    elif framework == CT_FRAMEWORK:
        if total_weight is None:
            total_weight = jnp.sum(b)
        inv_w = 1.0 / speeds[None, :]
        load_term = (b[:, None] ** 2) * inv_w**2 \
            + 2.0 * b[:, None] * inv_w**2 * others \
            - 2.0 * b[:, None] * inv_w * total_weight
        return load_term + cut_term
    raise ValueError(f"unknown framework {framework!r}")


def cost_matrix(problem: AnyProblem, state: PartitionState,
                framework: str = C_FRAMEWORK,
                aggregate: Array | None = None) -> Array:
    """(N, K) matrix of node costs: entry [i, k] = cost of node i if on machine k.

    Column r_i of row i is the node's *current* cost; other columns are the
    hypothetical post-move costs (all other assignments held fixed), exactly
    the quantities a machine needs to compute dissatisfaction (Eq. 4).
    """
    K = problem.num_machines
    if aggregate is None:
        aggregate = problem_aggregate(problem, state.assignment, K)
    return cost_matrix_from_aggregate(
        aggregate, state.assignment, problem.node_weights, state.loads,
        problem.speeds, problem.mu, framework,
        total_weight=jnp.sum(problem.node_weights))


def node_costs(problem: AnyProblem, state: PartitionState,
               framework: str = C_FRAMEWORK) -> Array:
    """(N,) current cost of every node under its current assignment."""
    cm = cost_matrix(problem, state, framework)
    return jnp.take_along_axis(cm, state.assignment[:, None], axis=1)[:, 0]


def dissatisfaction_from_cost(cost: Array, row_assignment: Array,
                              theta: Array | None = None):
    """Eq. 4 from an already-assembled cost block: I(i) and the arg-best
    machine.  Ties break toward the lowest machine index (DESIGN.md §7).

    ``theta`` is the per-node migration-price (hysteresis) threshold of
    DESIGN.md §11: the returned dissatisfaction is NET of it
    (``I(i) - theta_i``), so a node is movable only when its raw Eq.-4
    dissatisfaction exceeds its migration price.  This is THE one place
    theta is subtracted — core, distributed and kernel paths all route
    through it (or mirror its exact op order), preserving the bitwise
    core↔distributed contract.  ``theta=None`` skips the subtraction
    entirely and is bit-for-bit today's behavior.
    """
    current = jnp.take_along_axis(cost, row_assignment[:, None], axis=1)[:, 0]
    best_machine = jnp.argmin(cost, axis=1).astype(jnp.int32)
    best = jnp.min(cost, axis=1)
    dissat = current - best
    if theta is not None:
        dissat = dissat - theta
    return dissat, best_machine


def dissatisfaction(problem: AnyProblem, state: PartitionState,
                    framework: str = C_FRAMEWORK,
                    cost: Array | None = None,
                    theta: Array | None = None):
    """Eq. 4:  I(i) = C_i(r_i) - min_k C_i(k), with the arg-best machine.

    Returns (dissat (N,), best_machine (N,)).  Ties break toward the lowest
    machine index (deterministic, DESIGN.md §7).  ``theta`` as in
    :func:`dissatisfaction_from_cost` (net-of-migration-price Eq. 4).
    """
    if cost is None:
        cost = cost_matrix(problem, state, framework)
    return dissatisfaction_from_cost(cost, state.assignment, theta)


# ---------------------------------------------------------------------------
# Global potentials
# ---------------------------------------------------------------------------

def total_cut(adjacency: Array, assignment: Array) -> Array:
    """Unordered cut weight: (1/2) sum_{i,j} c_ij 1[r_i != r_j]."""
    diff = assignment[:, None] != assignment[None, :]
    return 0.5 * jnp.sum(adjacency * diff)


def total_cut_sparse(sp: SparseProblem, assignment: Array) -> Array:
    """Unordered cut from the edge list — O(E), no O(N^2) mask matrix.

    Each undirected edge appears in both directions, so summing the
    directed crossings and halving reproduces the unordered convention;
    padded edges (weight 0) contribute exactly 0.
    """
    crossing = assignment[sp.senders] != assignment[sp.receivers]
    return 0.5 * jnp.sum(jnp.where(crossing, sp.edge_weights,
                                   jnp.zeros((), sp.edge_weights.dtype)))


def problem_cut(problem: AnyProblem, assignment: Array) -> Array:
    """Unordered cut for either problem representation."""
    if isinstance(problem, SparseProblem):
        return total_cut_sparse(problem, assignment)
    return total_cut(problem.adjacency, assignment)


def potentials_closed_form(loads: Array, sq_loads: Array, cut: Array,
                           speeds: Array, mu: Array,
                           total_weight: Array) -> tuple[Array, Array]:
    """(C_0, Ct_0) as O(K) closed forms of machine-level sums.

    C_0 = sum_k (L_k^2 - S_k)/w_k + mu * cut, with S_k = sum_{i on k}
    b_i^2 (from summing Eq. 1 over i); Ct_0 = sum_k (L_k/w_k - B)^2 +
    mu/2 * cut (Eq. 8).  Used by the §4.5 sweep mode (simultaneous moves
    are not unilateral, so the exact-potential identities do not apply —
    DESIGN.md §10) and by the sparse path's global potentials, where the
    per-node Eq.-1 sum would need the O(N, K) cost matrix for a scalar.
    """
    c0 = jnp.sum((loads * loads - sq_loads) / speeds) + mu * cut
    ct0 = jnp.sum((loads / speeds - total_weight) ** 2) + 0.5 * mu * cut
    return c0, ct0


def global_cost_c0(problem: AnyProblem, assignment: Array) -> Array:
    """C_0(r) = sum_i C_i(r)  (Thm. 3.1 potential, social welfare).

    Sparse problems evaluate the O(K) closed form over (loads, sq_loads,
    cut) instead of summing N node costs — same value up to f32
    reassociation (within the ≤1e-3 budget of DESIGN.md §13.3).
    """
    b = problem.node_weights
    if isinstance(problem, SparseProblem):
        k = problem.num_machines
        loads = machine_loads(b, assignment, k)
        sq_loads = machine_loads(b * b, assignment, k)
        cut = total_cut_sparse(problem, assignment)
        return potentials_closed_form(loads, sq_loads, cut, problem.speeds,
                                      problem.mu, jnp.sum(b))[0]
    state = PartitionState(assignment,
                           machine_loads(b, assignment,
                                         problem.num_machines))
    return jnp.sum(node_costs(problem, state, C_FRAMEWORK))


def global_cost_ct0(problem: AnyProblem, assignment: Array) -> Array:
    """Ct_0(r) = sum_k (L_k / w_k - B)^2 + (mu/2) cut(r)  (Eq. 8, see note)."""
    b = problem.node_weights
    loads = machine_loads(b, assignment, problem.num_machines)
    total = jnp.sum(b)
    variance = jnp.sum((loads / problem.speeds - total) ** 2)
    return variance + 0.5 * problem.mu * problem_cut(problem, assignment)


def global_cost(problem: AnyProblem, assignment: Array, framework: str) -> Array:
    if framework == C_FRAMEWORK:
        return global_cost_c0(problem, assignment)
    if framework == CT_FRAMEWORK:
        return global_cost_ct0(problem, assignment)
    raise ValueError(f"unknown framework {framework!r}")


def load_imbalance(problem: AnyProblem, assignment: Array) -> Array:
    """max_k L_k/w_k divided by B — 1.0 means perfectly balanced."""
    loads = machine_loads(problem.node_weights, assignment, problem.num_machines)
    total = jnp.sum(problem.node_weights)
    return jnp.max(loads / problem.speeds) / total
