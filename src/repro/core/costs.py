"""The paper's two node-level cost frameworks and their global potentials.

Framework 1 (Eq. 1):
    C_i(r) = (b_i / w_{r_i}) * sum_{j != i, r_j = r_i} b_j
             + (mu/2) * sum_{j: r_j != r_i} c_ij
  Global potential (Thm. 3.1):  C_0(r) = sum_i C_i(r),
  with the exact-potential identity  Delta C_0 = 2 * Delta C_l  for a
  unilateral move of node l.

Framework 2 (Eq. 6):
    Ct_i(r) = b_i^2/w_{r_i}^2 + (2 b_i / w_{r_i}^2) * sum_{j != i, r_j=r_i} b_j
              - (2 b_i / w_{r_i}) * B + (mu/2) * sum_{j: r_j != r_i} c_ij
  Global objective (Eq. 8, centralized load-variance + cut):
    Ct_0(r) = sum_k (L_k / w_k - B)^2 + (mu/2) * cut(r)
  with the exact-potential identity  Delta Ct_0 = Delta Ct_l  (Thm. 5.1).

Convention note (DESIGN.md §8): Eq. 8 as printed sums ordered pairs, which
double-counts each cut edge and breaks the Thm. 5.1 identity by a factor of
two.  We use the (mu/2) * unordered-cut convention, under which the identity
is *exact*; tests/test_game_theory.py asserts both identities numerically.

Everything here is O(N*K) given the aggregate matrix A[i,k] = sum_j c_ij
1[r_j = k], itself an (N,N)x(N,K) matmul — the refinement hot spot that
``repro/kernels/dissatisfaction.py`` implements as a fused Pallas kernel.
The refinement engines avoid even that matmul after the first turn:
``repro.core.aggregate`` carries A through the loop and applies a rank-1
column update per move (DESIGN.md §10); :func:`cost_matrix_from_aggregate`
is the shared O(N*K) assembly both paths delegate to.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .problem import PartitionProblem, PartitionState, machine_loads

Array = jax.Array

C_FRAMEWORK = "c"     # Eq. 1
CT_FRAMEWORK = "ct"   # Eq. 6
FRAMEWORKS = (C_FRAMEWORK, CT_FRAMEWORK)


def adjacency_aggregate(adjacency: Array, assignment: Array, num_machines: int) -> Array:
    """A[i, k] = sum_j c_ij * 1[r_j = k]; computed as C @ one_hot(r)."""
    onehot = jax.nn.one_hot(assignment, num_machines, dtype=adjacency.dtype)
    return adjacency @ onehot


def cut_matrix(adjacency: Array, assignment: Array, num_machines: int,
               aggregate: Array | None = None) -> Array:
    """cut[i, k] = (1) * sum_{j: r_j != k} c_ij  (the mu/2 factor applied later)."""
    if aggregate is None:
        aggregate = adjacency_aggregate(adjacency, assignment, num_machines)
    degree = jnp.sum(aggregate, axis=-1, keepdims=True)       # = sum_j c_ij
    return degree - aggregate


def cost_matrix_from_aggregate(aggregate: Array, row_assignment: Array,
                               node_weights: Array, loads: Array,
                               speeds: Array, mu: Array, framework: str,
                               total_weight: Array | None = None) -> Array:
    """O(rows*K) cost assembly from an already-built adjacency aggregate.

    This is THE shared cost formula (DESIGN.md §10): the recompute path
    (:func:`cost_matrix`), the shard-local path
    (:func:`repro.distributed.protocol.shard_cost_matrix`) and the
    incremental path (:mod:`repro.core.aggregate`) all delegate here, so
    any two paths fed the same aggregate produce bitwise-identical costs.

    ``aggregate`` is the (rows, K) block A[i, k] = sum_j c_ij 1[r_j = k]
    (rows may be a shard's row block of a larger graph);
    ``row_assignment`` gives the rows' OWN machines; ``total_weight`` is
    the global weight sum B, required by the Ct framework (defaults to
    ``sum(node_weights)``, correct only when the rows are the full graph).
    """
    b = node_weights
    k = loads.shape[0]
    degree = jnp.sum(aggregate, axis=-1, keepdims=True)       # = sum_j c_ij
    cut_term = 0.5 * mu * (degree - aggregate)
    own = jax.nn.one_hot(row_assignment, k, dtype=b.dtype)    # (rows, K)
    # others[i, k] = sum_{j != i, r_j = k} b_j if i were moved to k: node
    # i's weight is subtracted only on its CURRENT machine — every other
    # machine's load already excludes i.
    others = loads[None, :] - b[:, None] * own
    if framework == C_FRAMEWORK:
        load_term = (b[:, None] / speeds[None, :]) * others
        return load_term + cut_term
    elif framework == CT_FRAMEWORK:
        if total_weight is None:
            total_weight = jnp.sum(b)
        inv_w = 1.0 / speeds[None, :]
        load_term = (b[:, None] ** 2) * inv_w**2 \
            + 2.0 * b[:, None] * inv_w**2 * others \
            - 2.0 * b[:, None] * inv_w * total_weight
        return load_term + cut_term
    raise ValueError(f"unknown framework {framework!r}")


def cost_matrix(problem: PartitionProblem, state: PartitionState,
                framework: str = C_FRAMEWORK,
                aggregate: Array | None = None) -> Array:
    """(N, K) matrix of node costs: entry [i, k] = cost of node i if on machine k.

    Column r_i of row i is the node's *current* cost; other columns are the
    hypothetical post-move costs (all other assignments held fixed), exactly
    the quantities a machine needs to compute dissatisfaction (Eq. 4).
    """
    K = problem.num_machines
    if aggregate is None:
        aggregate = adjacency_aggregate(problem.adjacency, state.assignment, K)
    return cost_matrix_from_aggregate(
        aggregate, state.assignment, problem.node_weights, state.loads,
        problem.speeds, problem.mu, framework,
        total_weight=jnp.sum(problem.node_weights))


def node_costs(problem: PartitionProblem, state: PartitionState,
               framework: str = C_FRAMEWORK) -> Array:
    """(N,) current cost of every node under its current assignment."""
    cm = cost_matrix(problem, state, framework)
    return jnp.take_along_axis(cm, state.assignment[:, None], axis=1)[:, 0]


def dissatisfaction_from_cost(cost: Array, row_assignment: Array,
                              theta: Array | None = None):
    """Eq. 4 from an already-assembled cost block: I(i) and the arg-best
    machine.  Ties break toward the lowest machine index (DESIGN.md §7).

    ``theta`` is the per-node migration-price (hysteresis) threshold of
    DESIGN.md §11: the returned dissatisfaction is NET of it
    (``I(i) - theta_i``), so a node is movable only when its raw Eq.-4
    dissatisfaction exceeds its migration price.  This is THE one place
    theta is subtracted — core, distributed and kernel paths all route
    through it (or mirror its exact op order), preserving the bitwise
    core↔distributed contract.  ``theta=None`` skips the subtraction
    entirely and is bit-for-bit today's behavior.
    """
    current = jnp.take_along_axis(cost, row_assignment[:, None], axis=1)[:, 0]
    best_machine = jnp.argmin(cost, axis=1).astype(jnp.int32)
    best = jnp.min(cost, axis=1)
    dissat = current - best
    if theta is not None:
        dissat = dissat - theta
    return dissat, best_machine


def dissatisfaction(problem: PartitionProblem, state: PartitionState,
                    framework: str = C_FRAMEWORK,
                    cost: Array | None = None,
                    theta: Array | None = None):
    """Eq. 4:  I(i) = C_i(r_i) - min_k C_i(k), with the arg-best machine.

    Returns (dissat (N,), best_machine (N,)).  Ties break toward the lowest
    machine index (deterministic, DESIGN.md §7).  ``theta`` as in
    :func:`dissatisfaction_from_cost` (net-of-migration-price Eq. 4).
    """
    if cost is None:
        cost = cost_matrix(problem, state, framework)
    return dissatisfaction_from_cost(cost, state.assignment, theta)


# ---------------------------------------------------------------------------
# Global potentials
# ---------------------------------------------------------------------------

def total_cut(adjacency: Array, assignment: Array) -> Array:
    """Unordered cut weight: (1/2) sum_{i,j} c_ij 1[r_i != r_j]."""
    diff = assignment[:, None] != assignment[None, :]
    return 0.5 * jnp.sum(adjacency * diff)


def global_cost_c0(problem: PartitionProblem, assignment: Array) -> Array:
    """C_0(r) = sum_i C_i(r)  (Thm. 3.1 potential, social welfare)."""
    state = PartitionState(assignment,
                           machine_loads(problem.node_weights, assignment,
                                         problem.num_machines))
    return jnp.sum(node_costs(problem, state, C_FRAMEWORK))


def global_cost_ct0(problem: PartitionProblem, assignment: Array) -> Array:
    """Ct_0(r) = sum_k (L_k / w_k - B)^2 + (mu/2) cut(r)  (Eq. 8, see note)."""
    b = problem.node_weights
    loads = machine_loads(b, assignment, problem.num_machines)
    total = jnp.sum(b)
    variance = jnp.sum((loads / problem.speeds - total) ** 2)
    return variance + 0.5 * problem.mu * total_cut(problem.adjacency, assignment)


def global_cost(problem: PartitionProblem, assignment: Array, framework: str) -> Array:
    if framework == C_FRAMEWORK:
        return global_cost_c0(problem, assignment)
    if framework == CT_FRAMEWORK:
        return global_cost_ct0(problem, assignment)
    raise ValueError(f"unknown framework {framework!r}")


def load_imbalance(problem: PartitionProblem, assignment: Array) -> Array:
    """max_k L_k/w_k divided by B — 1.0 means perfectly balanced."""
    loads = machine_loads(problem.node_weights, assignment, problem.num_machines)
    total = jnp.sum(problem.node_weights)
    return jnp.max(loads / problem.speeds) / total
