"""Capacity-constrained repair for game partitions.

The Nash refinement balances *weighted* load but leaves partition
cardinalities free.  Expert-parallel placement needs exactly E/K experts
per device group (the weight arrays are evenly sharded), and pipeline
stages need contiguous layer blocks.  These repairs project a refined
assignment onto the constraint set while disturbing the potential as little
as possible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import costs
from .problem import PartitionProblem, make_state

Array = jax.Array


def equalize_cardinality(problem: PartitionProblem, assignment: Array,
                         framework: str = costs.C_FRAMEWORK) -> Array:
    """Repair to exactly-equal partition sizes (N must divide by K).

    Greedy: while some machine is over-full, move its *least dissatisfied-
    to-stay* node (the one whose cost increases least) to the under-full
    machine that minimizes the node's cost.  O(N) moves, each O(NK).
    """
    n, k = problem.num_nodes, problem.num_machines
    assert n % k == 0, (n, k)
    target = n // k

    def cond(carry):
        r, moves = carry
        counts = jnp.zeros((k,), jnp.int32).at[r].add(1)
        return jnp.any(counts > target) & (moves < n)

    def body(carry):
        r, moves = carry
        counts = jnp.zeros((k,), jnp.int32).at[r].add(1)
        over = counts > target
        under = counts < target
        state = make_state(problem, r)
        cost = costs.cost_matrix(problem, state, framework)
        current = jnp.take_along_axis(cost, r[:, None], axis=1)[:, 0]
        # candidate destination cost restricted to under-full machines
        dest_cost = jnp.where(under[None, :], cost, jnp.inf)
        best_dest = jnp.argmin(dest_cost, axis=1).astype(jnp.int32)
        min_dest = jnp.min(dest_cost, axis=1)
        regret = min_dest - current          # cost increase if forced out
        movable = over[r]
        pick = jnp.argmin(jnp.where(movable, regret, jnp.inf)).astype(jnp.int32)
        r = r.at[pick].set(best_dest[pick])
        return r, moves + 1

    r, _ = jax.lax.while_loop(cond, body,
                              (jnp.asarray(assignment, jnp.int32),
                               jnp.zeros((), jnp.int32)))
    return r


def contiguous_stage_dp(weights, num_stages: int):
    """Optimal contiguous partition of a chain (minimize max stage load).

    Classic O(L^2 * K) interval DP — the oracle the game-based stage
    assignment is compared against in tests and benchmarks.  Host-side.
    """
    import numpy as np
    w = np.asarray(weights, np.float64)
    L = w.shape[0]
    K = num_stages
    prefix = np.concatenate([[0.0], np.cumsum(w)])

    def seg(i, j):                       # load of layers [i, j)
        return prefix[j] - prefix[i]

    dp = np.full((K + 1, L + 1), np.inf)
    cut = np.zeros((K + 1, L + 1), np.int64)
    dp[0, 0] = 0.0
    for k in range(1, K + 1):
        for j in range(1, L + 1):
            for i in range(k - 1, j):
                val = max(dp[k - 1, i], seg(i, j))
                if val < dp[k, j]:
                    dp[k, j] = val
                    cut[k, j] = i
    bounds = [L]
    j = L
    for k in range(K, 0, -1):
        j = int(cut[k, j])
        bounds.append(j)
    bounds = bounds[::-1]
    assignment = np.zeros(L, np.int32)
    for s in range(K):
        assignment[bounds[s]:bounds[s + 1]] = s
    return assignment, float(dp[K, L])


def make_contiguous(assignment: Array, num_stages: int) -> Array:
    """Project an arbitrary chain assignment onto contiguous stages by
    sorting stage ids along the chain (stable, preserves stage sizes)."""
    return jnp.sort(jnp.asarray(assignment, jnp.int32))
