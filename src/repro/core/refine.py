"""Iterative partition refinement (paper §4.2, Fig. 1/2).

Machines take sequential round-robin turns.  On its turn, a machine finds the
*most dissatisfied* node it owns (Eq. 4) and transfers it to that node's
best-response machine; if the node's dissatisfaction is zero the machine
forsakes its turn.  The algorithm converges (Thm. 4.1) because every transfer
strictly decreases the potential C_0 (or Ct_0 for the second framework);
convergence is declared after K consecutive forsaken turns.

Two execution modes:
  * ``refine``        — ``lax.while_loop`` until convergence (production use;
                        bounded by ``max_turns`` as a safety net).
  * ``refine_traced`` — fixed-length ``lax.scan`` that records per-turn moves
                        and BOTH global potentials; powers the Table I /
                        §5.1 discrepancy study and the convergence tests.

Two cost paths (DESIGN.md §10), selected by ``incremental``:

  * **incremental** (default) — an :class:`~repro.core.aggregate.AggregateState`
    lives in the loop carry; each turn assembles the (N, K) cost matrix from
    the carried aggregate in O(NK), and a move applies a rank-1 column
    update plus exact-potential-identity deltas (Thm. 3.1 / 5.1) — per-turn
    work O(NK), independent of the O(N^2 K) rebuild.  ``verify_every=M``
    cross-checks against a from-scratch rebuild every M turns (recording
    the observed drift in ``RefineResult.aggregate_drift``) and resyncs.
  * **recompute** — the original O(N^2 K)-per-turn path (also selected
    implicitly by passing ``cost_matrix_fn``, e.g. the fused Pallas cost
    kernel); ``refine_traced`` additionally pays two O(N^2) global-potential
    passes per turn.  Kept as the oracle the benchmarks and tests compare
    the incremental path against.

Also implements the paper-§4.5 *simultaneous transfer* mode (one move per
machine per sweep, descent not guaranteed — measured in benchmarks), which
applies a rank-K aggregate update per sweep and re-derives both potentials
via the O(K) closed forms of :mod:`repro.core.aggregate`.

Sparse problems (DESIGN.md §13): all three entry points accept a
:class:`~repro.core.sparse.SparseProblem` in place of the dense
``PartitionProblem`` — the per-turn math is unchanged (costs still
assemble from the carried (N, K) aggregate via the one shared formula),
but the aggregate is initialized by a ``segment_sum`` over the edge
list, a move scatters only the moved node's O(deg) incident-edge
window, and the traced potentials use the O(K) closed forms — so
nothing in the loop touches an O(N^2) array and N=10^5-10^6 graphs
refine on hardware where the dense adjacency cannot exist.

Migration-aware hysteresis (DESIGN.md §11): every entry point takes a
per-node threshold ``theta`` (scalar or (N,), the node's migration price).
A node is movable only when its Eq.-4 dissatisfaction EXCEEDS ``theta_i``;
the recorded gain is net of it.  Convergence (Thm. 4.1) is preserved
because every accepted move still strictly descends the potential — by at
least ``2*theta_i`` for C_0 (Thm. 3.1) and ``theta_i`` for Ct_0
(Thm. 5.1).  ``theta=None`` (default) and ``theta=0`` reproduce today's
move sequences bitwise.

The ``dissat_fn`` convention
----------------------------

THE canonical calling convention for a pluggable per-turn reduction —
everything that accepts a ``dissat_fn`` (``refine`` here, the shard
candidates of :mod:`repro.distributed`, the kernel adapters of
:mod:`repro.kernels.ops`) uses exactly this 9-argument signature::

    dissat_fn(aggregate, assignment, node_weights, loads, speeds, mu,
              framework, total_weight, theta) -> (dissat, best_machine)

1. ``aggregate``    — (rows, K) f32, ``A[i, k] = sum_j c_ij 1[r_j = k]``
   for the rows being evaluated (the full graph, or a shard's row block).
2. ``assignment``   — (rows,) i32, the rows' OWN current machines.
3. ``node_weights`` — (rows,) f32, the rows' computational loads ``b_i``.
4. ``loads``        — (K,) f32, GLOBAL machine loads ``L_k``.
5. ``speeds``       — (K,) f32, machine capacities ``w_k``.
6. ``mu``           — () f32, inter-machine cost weight (paper §3.1).
7. ``framework``    — static str, ``"c"`` (Eq. 1) or ``"ct"`` (Eq. 6).
8. ``total_weight`` — () f32, the global weight sum ``B``.  The Ct
   framework needs it and a row block cannot compute it locally.
9. ``theta``        — ``None`` or (rows,) f32 per-node migration price
   (DESIGN.md §11, added in PR 3).  The returned dissatisfaction is NET
   of it; ``None`` means no threshold and must match ``theta=0`` bitwise.

Returns ``(dissat (rows,), best_machine (rows,))``: the net Eq.-4
dissatisfaction and the LOWEST-INDEX arg-best machine (the DESIGN.md §7
tie-break).  On the jnp path the tie-break is ``jnp.argmin``'s
first-minimum; every Pallas implementation realizes the identical
semantics in ONE place — the shared ``reduce_dissat_tile`` epilogue of
:mod:`repro.kernels.dissatisfaction` (the iota-min trick), which all
three fused kernels (``_dissat_kernel``, the edge-block
``_edge_dissat_kernel`` and the sweep-candidate ``_edge_sweep_kernel``)
call as their final reduction step.  Reference implementation:
``costs.cost_matrix_from_aggregate`` followed by
``costs.dissatisfaction_from_cost`` (the default when
``dissat_fn=None``); fused implementation:
``repro.kernels.ops.make_aggregate_dissat_fn`` — which under ``jax.vmap``
(the batched sweeps of DESIGN.md §12) stays on the fused batch-grid
kernel rather than falling back.
"""
from __future__ import annotations

import time
from functools import partial
from typing import NamedTuple, Protocol

import numpy as np

import jax
import jax.numpy as jnp

from . import aggregate as agg_mod
from . import checkpoint as ckpt_mod
from . import costs
from .problem import PartitionProblem, PartitionState, make_state

Array = jax.Array


class DissatFn(Protocol):
    """THE canonical 9-argument ``dissat_fn`` convention (see "The
    ``dissat_fn`` convention" in the module docstring above).

    Every factory producing a pluggable per-turn reduction returns this
    Protocol (``repro.kernels.ops.make_aggregate_dissat_fn`` /
    ``make_edge_dissat_fn``, ``sweeps.runtime._kernel_dissat_fn``,
    ``distributed.runtime._shard_dissat_fn``), and every consumer
    (``refine`` here, ``protocol.local_candidate_from_aggregate``) calls
    it with exactly these 9 positionals.  The contract linter
    (``repro.analysis``, DESIGN.md §16) anchors its signature rule on
    this annotation — not on a magic arity — so annotate new factories
    with ``-> DissatFn``.
    """

    def __call__(self, aggregate: Array, assignment: Array,
                 node_weights: Array, loads: Array, speeds: Array,
                 mu, framework: str, total_weight,
                 theta=None) -> tuple[Array, Array]:
        """Returns ``(dissat (rows,), best_machine (rows,))``."""
        ...

# Dissatisfaction below this threshold counts as "satisfied" — guards float
# round-off from keeping the loop alive on a plateau.
DEFAULT_TOL = 1e-6

# Mover-buffer slots for the unbounded sweep apply (DESIGN.md §17): sets
# up to this size update through apply_moves' incident windows; larger
# sets fall back to the O(E) rebuild.
_UNBOUNDED_APPLY_CAP = 4096


class TurnResult(NamedTuple):
    moved: Array          # bool   — did this turn transfer a node?
    node: Array           # int32  — the node transferred (or -1)
    source: Array         # int32  — machine that owned it
    dest: Array           # int32  — machine it moved to
    gain: Array           # float  — dissatisfaction of the moved node
    c0: Array             # float  — C_0 after the turn
    ct0: Array            # float  — Ct_0 after the turn


def _resolve_theta(theta, num_nodes: int) -> Array | None:
    """Normalize the hysteresis threshold to None or an (N,) f32 array."""
    if theta is None:
        return None
    theta = jnp.asarray(theta, jnp.float32)
    return jnp.broadcast_to(theta, (num_nodes,))


def _raw_best_gain(dissat: Array, owned: Array, theta) -> Array:
    """Telemetry side quantity: the machine's best gain BEFORE the θ
    hysteresis netting (DESIGN.md §14.1).  ``dissat`` is net of theta
    (the one subtraction site, :func:`costs.dissatisfaction_from_cost`),
    so the raw value is recovered exactly as ``net + theta``.  Lets the
    recorder label a rejected turn "hysteresis" (raw gain cleared tol,
    net did not) vs "satisfied".  Only evaluated on telemetry paths."""
    raw = dissat if theta is None else dissat + theta
    return jnp.max(jnp.where(owned, raw, -jnp.inf))


def _turn(problem: PartitionProblem, state: PartitionState, machine: Array,
          framework: str, tol: float, cost_matrix_fn=None, theta=None,
          want_raw: bool = False):
    """One machine turn, recompute path: rebuild costs from scratch."""
    if cost_matrix_fn is None:
        cost = costs.cost_matrix(problem, state, framework)
    else:
        cost = cost_matrix_fn(problem, state, framework)
    dissat, best = costs.dissatisfaction(problem, state, framework, cost=cost,
                                         theta=theta)
    owned = state.assignment == machine
    masked = jnp.where(owned, dissat, -jnp.inf)
    node = jnp.argmax(masked).astype(jnp.int32)
    gain = masked[node]
    do_move = gain > tol

    dest = best[node]
    new_assignment = jnp.where(
        do_move, state.assignment.at[node].set(dest), state.assignment)
    b_node = problem.node_weights[node]
    new_loads = jnp.where(
        do_move,
        state.loads.at[machine].add(-b_node).at[dest].add(b_node),
        state.loads,
    )
    new_state = PartitionState(new_assignment, new_loads)
    res = TurnResult(
        moved=do_move,
        node=jnp.where(do_move, node, -1),
        source=jnp.where(do_move, machine, -1),
        dest=jnp.where(do_move, dest, -1),
        gain=jnp.where(do_move, gain, 0.0),
    c0=jnp.zeros(()), ct0=jnp.zeros(()))  # potentials filled by callers that want them
    if want_raw:
        return new_state, res, _raw_best_gain(dissat, owned, theta)
    return new_state, res


def _turn_incremental(problem: PartitionProblem, agg: agg_mod.AggregateState,
                      machine: Array, framework: str, tol: float,
                      total_b: Array, dissat_fn=None, theta=None,
                      want_raw: bool = False):
    """One machine turn, incremental path: O(NK) costs from the carried
    aggregate, O(N) rank-1 move (DESIGN.md §10).

    ``dissat_fn`` follows the canonical 9-argument convention (module
    docstring) and substitutes e.g. the fused Pallas kernel
    (``repro.kernels.ops.make_aggregate_dissat_fn``) for the jnp assembly.
    """
    if dissat_fn is None:
        cost = costs.cost_matrix_from_aggregate(
            agg.aggregate, agg.assignment, problem.node_weights, agg.loads,
            problem.speeds, problem.mu, framework, total_weight=total_b)
        dissat, best = costs.dissatisfaction_from_cost(cost, agg.assignment,
                                                       theta)
    else:
        dissat, best = dissat_fn(agg.aggregate, agg.assignment,
                                 problem.node_weights, agg.loads,
                                 problem.speeds, problem.mu, framework,
                                 total_b, theta)
    owned = agg.assignment == machine
    masked = jnp.where(owned, dissat, -jnp.inf)
    node = jnp.argmax(masked).astype(jnp.int32)
    gain = masked[node]
    do_move = gain > tol

    dest = best[node]
    new_agg = agg_mod.apply_move(problem, agg, node, machine, dest, do_move,
                                 total_b)
    res = TurnResult(
        moved=do_move,
        node=jnp.where(do_move, node, -1),
        source=jnp.where(do_move, machine, -1),
        dest=jnp.where(do_move, dest, -1),
        gain=jnp.where(do_move, gain, 0.0),
        c0=new_agg.c0, ct0=new_agg.ct0)
    if want_raw:
        return new_agg, res, _raw_best_gain(dissat, owned, theta)
    return new_agg, res


class RefineResult(NamedTuple):
    assignment: Array       # (N,) final assignment
    loads: Array            # (K,)
    num_moves: Array        # int32 — total node transfers ("iterations" in Table I)
    num_turns: Array        # int32 — total machine turns taken
    converged: Array        # bool
    # max deviation observed at verify_every cross-checks (0 when disabled
    # or on the recompute path — there is nothing to drift there)
    aggregate_drift: Array | float = 0.0


@partial(jax.jit, static_argnames=("framework", "max_turns", "cost_matrix_fn",
                                   "incremental", "verify_every",
                                   "repair_every", "dissat_fn", "on_turn"))
def _refine(problem: PartitionProblem, assignment: Array,
            framework: str = costs.C_FRAMEWORK,
            max_turns: int = 10_000, tol: float = DEFAULT_TOL,
            cost_matrix_fn=None, incremental: bool = True,
            verify_every: int = 0, repair_every: int = 0, dissat_fn=None,
            theta=None, on_turn=None) -> RefineResult:
    """Jitted while-loop body of :func:`refine`.

    ``on_turn`` (static; telemetry only) is a host callback fired once
    per turn via ``jax.debug.callback`` with the raw turn row — see
    ``repro.obs.recorder.Recorder._on_turn_row``.  ``on_turn=None``
    (the default) stages the exact pre-telemetry computation: no
    callback primitive and no raw-gain side quantity appear in the
    jaxpr, so the disabled path is bitwise-identical and callback-free
    (DESIGN.md §14.3).
    """
    K = problem.num_machines
    theta = _resolve_theta(theta, problem.num_nodes)
    if cost_matrix_fn is not None:
        incremental = False

    if not incremental:
        state0 = make_state(problem, assignment)

        def cond(carry):
            _, _, idle, turns, _ = carry
            return (idle < K) & (turns < max_turns)

        def body(carry):
            state, machine, idle, turns, moves = carry
            if on_turn is None:
                state, res = _turn(problem, state, machine, framework, tol,
                                   cost_matrix_fn, theta)
            else:
                state, res, raw_gain = _turn(problem, state, machine,
                                             framework, tol, cost_matrix_fn,
                                             theta, want_raw=True)
                jax.debug.callback(on_turn, turns, machine, res.moved,
                                   res.node, res.source, res.dest, res.gain,
                                   res.c0, res.ct0, raw_gain)
            idle = jnp.where(res.moved, 0, idle + 1)
            return (state, (machine + 1) % K, idle, turns + 1,
                    moves + res.moved.astype(jnp.int32))

        init = (state0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        state, _, idle, turns, moves = jax.lax.while_loop(cond, body, init)
        return RefineResult(assignment=state.assignment, loads=state.loads,
                            num_moves=moves, num_turns=turns,
                            converged=idle >= K,
                            aggregate_drift=jnp.zeros(()))

    agg0 = agg_mod.init_aggregate_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)

    def cond(carry):
        idle, turns = carry[2], carry[3]
        return (idle < K) & (turns < max_turns)

    def body(carry):
        agg, machine, idle, turns, moves, max_drift = carry[:6]
        if on_turn is None:
            agg, res = _turn_incremental(problem, agg, machine, framework,
                                         tol, total_b, dissat_fn, theta)
        else:
            agg, res, raw_gain = _turn_incremental(
                problem, agg, machine, framework, tol, total_b, dissat_fn,
                theta, want_raw=True)
            jax.debug.callback(on_turn, turns, machine, res.moved, res.node,
                               res.source, res.dest, res.gain, res.c0,
                               res.ct0, raw_gain)
        idle = jnp.where(res.moved, 0, idle + 1)
        turns = turns + 1
        moves = moves + res.moved.astype(jnp.int32)
        if verify_every:
            agg, max_drift = jax.lax.cond(
                turns % verify_every == 0,
                lambda a, d: _resync_max(problem, a, d),
                lambda a, d: (a, d), agg, max_drift)
        if repair_every:
            ckpt = carry[6]
            agg, max_drift, ckpt = jax.lax.cond(
                turns % repair_every == 0,
                lambda a, d, c: _heal_take(problem, a, d, c, turns),
                lambda a, d, c: (a, d, c), agg, max_drift, ckpt)
            return (agg, (machine + 1) % K, idle, turns, moves, max_drift,
                    ckpt)
        return (agg, (machine + 1) % K, idle, turns, moves, max_drift)

    init = (agg0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros(()))
    if repair_every:
        init = init + (ckpt_mod.take(agg0, jnp.zeros((), jnp.int32)),)
    out = jax.lax.while_loop(cond, body, init)
    agg, _, idle, turns, moves, max_drift = out[:6]
    return RefineResult(assignment=agg.assignment, loads=agg.loads,
                        num_moves=moves, num_turns=turns,
                        converged=idle >= K, aggregate_drift=max_drift)


def _open_run(recorder, runtime: str, problem, assignment, framework: str,
              theta, **extra) -> str:
    """Emit a ``run_start`` with the replay seed: initial (K,) machine
    loads (host-side scatter, O(N)) and the machine speeds."""
    b = np.asarray(problem.node_weights)
    r0 = np.asarray(assignment)
    k = problem.num_machines
    loads0 = np.zeros(k)
    np.add.at(loads0, r0, b)
    return recorder.new_run(
        runtime, framework=framework, n=problem.num_nodes, k=k,
        theta=theta is not None, loads=loads0,
        speeds=np.asarray(problem.speeds), **extra)


def refine(problem: PartitionProblem, assignment: Array,
           framework: str = costs.C_FRAMEWORK,
           max_turns: int = 10_000, tol: float = DEFAULT_TOL,
           cost_matrix_fn=None, incremental: bool = True,
           verify_every: int = 0, repair_every: int = 0,
           dissat_fn: DissatFn | None = None,
           theta=None, recorder=None) -> RefineResult:
    """Run round-robin refinement to convergence (K consecutive idle turns).

    ``incremental=True`` (default) carries the aggregate state; passing
    ``cost_matrix_fn`` forces the recompute path (a custom cost function
    rebuilds from the full adjacency).  ``verify_every=M > 0`` rebuilds the
    carry from scratch every M turns and records the drift (incremental
    path only).  ``repair_every=M > 0`` (DESIGN.md §15.3) goes further:
    every M turns the carry is *healed* — rolled back to the last
    checkpoint if any float leaf went non-finite, then column-repaired
    against the recompute oracle (only deviating columns are patched, so
    an undrifted carry is untouched bitwise) and re-checkpointed.  The
    default ``0`` stages the exact pre-repair program (same jaxpr).
    ``theta`` (scalar or (N,)) is the per-node migration-price
    hysteresis threshold (DESIGN.md §11); ``None``/``0`` reproduces the
    threshold-free move sequence bitwise.

    ``recorder`` (an :class:`repro.obs.Recorder`, DESIGN.md §14) opts
    into telemetry: per-turn events stream host-side through a buffered
    ``jax.debug.callback`` and the run closes with drift + ``run_end``
    events.  ``recorder=None`` (default) calls the identical jitted
    program as before — same cache entry, zero callbacks.
    """
    if recorder is None:
        return _refine(problem, assignment, framework, max_turns=max_turns,
                       tol=tol, cost_matrix_fn=cost_matrix_fn,
                       incremental=incremental, verify_every=verify_every,
                       repair_every=repair_every, dissat_fn=dissat_fn,
                       theta=theta)
    run = _open_run(recorder, "refine", problem, assignment, framework,
                    theta, incremental=incremental and cost_matrix_fn is None)
    recorder.begin_rows()
    t0 = time.perf_counter()
    with recorder.phase("core.refine", run):
        result = _refine(problem, assignment, framework,
                         max_turns=max_turns, tol=tol,
                         cost_matrix_fn=cost_matrix_fn,
                         incremental=incremental, verify_every=verify_every,
                         repair_every=repair_every, dissat_fn=dissat_fn,
                         theta=theta, on_turn=recorder._on_turn_row)
        jax.block_until_ready(result)
        jax.effects_barrier()
    wall = time.perf_counter() - t0
    carried = incremental and cost_matrix_fn is None
    rows = recorder.take_rows()
    recorder.record_turn_rows(run, rows, problem.node_weights,
                              carried=carried)
    last = max(rows, key=lambda r: int(r[0])) if rows else None
    recorder.record_result(
        run, result, wall=wall,
        c0=float(last[7]) if carried and last is not None else None,
        ct0=float(last[8]) if carried and last is not None else None)
    return result


def _resync_max(problem, agg, max_drift):
    fresh, observed = agg_mod.resync(problem, agg)
    return fresh, jnp.maximum(max_drift, observed)


def _heal_take(problem, agg, max_drift, ckpt, turn):
    """One ``repair_every`` boundary (DESIGN.md §15.3): heal the carry
    (rollback over NaN, then column repair against the recompute
    oracle), fold the observed pre-repair drift into the running max,
    and re-checkpoint the now-known-good state."""
    agg, observed, _cols, _rolled = ckpt_mod.heal(problem, agg, ckpt)
    return (agg, jnp.maximum(max_drift, observed), ckpt_mod.take(agg, turn))


class Trace(NamedTuple):
    """Per-turn record from ``refine_traced`` (fixed length = max_turns)."""
    moved: Array    # (T,) bool
    node: Array     # (T,) int32
    source: Array   # (T,) int32
    dest: Array     # (T,) int32
    gain: Array     # (T,) float
    c0: Array       # (T,) float — C_0 after each turn
    ct0: Array      # (T,) float — Ct_0 after each turn
    active: Array   # (T,) bool  — False once converged


@partial(jax.jit, static_argnames=("framework", "max_turns", "incremental",
                                   "verify_every", "telemetry"))
def _refine_traced(problem: PartitionProblem, assignment: Array,
                   framework: str = costs.C_FRAMEWORK,
                   max_turns: int = 512, tol: float = DEFAULT_TOL,
                   incremental: bool = True, verify_every: int = 0,
                   theta=None, telemetry: bool = False):
    """Jitted scan body of :func:`refine_traced`.

    Returns ``(RefineResult, Trace, raw_gains)`` where ``raw_gains`` is
    the (T,) telemetry side output (θ-free best gain per turn, for
    rejection labeling) when ``telemetry=True`` and ``None`` otherwise —
    the ``telemetry=False`` jaxpr is the exact pre-telemetry program.
    """
    K = problem.num_machines
    theta = _resolve_theta(theta, problem.num_nodes)

    if not incremental:
        state0 = make_state(problem, assignment)

        def step(carry, _):
            state, machine, idle = carry
            active = idle < K
            if telemetry:
                new_state, res, raw_gain = _turn(
                    problem, state, framework=framework, tol=tol,
                    machine=machine, theta=theta, want_raw=True)
            else:
                new_state, res = _turn(problem, state, framework=framework,
                                       tol=tol, machine=machine, theta=theta)
            new_state = jax.tree.map(
                lambda new, old: jnp.where(active, new, old), new_state, state)
            moved = res.moved & active
            idle = jnp.where(moved, 0, idle + 1)
            c0 = costs.global_cost_c0(problem, new_state.assignment)
            ct0 = costs.global_cost_ct0(problem, new_state.assignment)
            out = Trace(moved=moved, node=res.node, source=res.source,
                        dest=res.dest, gain=res.gain, c0=c0, ct0=ct0,
                        active=active)
            if telemetry:
                out = (out, raw_gain)
            return (new_state, (machine + 1) % K, idle), out

        (state, _, idle), trace = jax.lax.scan(
            step, (state0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)),
            None, length=max_turns)
        raw_gains = None
        if telemetry:
            trace, raw_gains = trace
        moves = jnp.sum(trace.moved.astype(jnp.int32))
        turns = jnp.sum(trace.active.astype(jnp.int32))
        result = RefineResult(assignment=state.assignment, loads=state.loads,
                              num_moves=moves, num_turns=turns,
                              converged=idle >= K,
                              aggregate_drift=jnp.zeros(()))
        return result, trace, raw_gains

    agg0 = agg_mod.init_aggregate_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)

    def step(carry, turn_idx):
        agg, machine, idle, max_drift = carry
        active = idle < K
        if telemetry:
            new_agg, res, raw_gain = _turn_incremental(
                problem, agg, machine, framework, tol, total_b, theta=theta,
                want_raw=True)
        else:
            new_agg, res = _turn_incremental(problem, agg, machine, framework,
                                             tol, total_b, theta=theta)
        new_agg = jax.tree.map(
            lambda new, old: jnp.where(active, new, old), new_agg, agg)
        moved = res.moved & active
        idle = jnp.where(moved, 0, idle + 1)
        if verify_every:
            new_agg, max_drift = jax.lax.cond(
                (turn_idx + 1) % verify_every == 0,
                lambda a, d: _resync_max(problem, a, d),
                lambda a, d: (a, d), new_agg, max_drift)
        out = Trace(moved=moved, node=res.node, source=res.source,
                    dest=res.dest, gain=res.gain, c0=new_agg.c0,
                    ct0=new_agg.ct0, active=active)
        if telemetry:
            out = (out, raw_gain)
        return (new_agg, (machine + 1) % K, idle, max_drift), out

    init = (agg0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros(()))
    (agg, _, idle, max_drift), trace = jax.lax.scan(
        init=init, f=step, xs=jnp.arange(max_turns, dtype=jnp.int32))
    raw_gains = None
    if telemetry:
        trace, raw_gains = trace
    moves = jnp.sum(trace.moved.astype(jnp.int32))
    turns = jnp.sum(trace.active.astype(jnp.int32))
    result = RefineResult(assignment=agg.assignment, loads=agg.loads,
                          num_moves=moves, num_turns=turns,
                          converged=idle >= K, aggregate_drift=max_drift)
    return result, trace, raw_gains


def refine_traced(problem: PartitionProblem, assignment: Array,
                  framework: str = costs.C_FRAMEWORK,
                  max_turns: int = 512, tol: float = DEFAULT_TOL,
                  incremental: bool = True, verify_every: int = 0,
                  theta=None, recorder=None):
    """Fixed-length scan variant recording both potentials after every turn.

    Returns (RefineResult, Trace).  Turns after convergence are no-ops with
    ``active=False`` so downstream statistics can mask them out.

    On the incremental path (default) the recorded potentials are the
    carried values, updated per move by the exact-potential identities —
    no O(N^2) pass per turn.  On the recompute path they are evaluated
    from scratch each turn (the oracle ``tests/test_incremental.py``
    compares against).  ``theta`` as in :func:`refine`; recorded gains are
    net of it, while the traced potentials remain the actual C_0/Ct_0
    values (which descend by at least 2*theta/theta per accepted move).

    ``recorder`` opts into telemetry (DESIGN.md §14): the returned trace
    is ingested host-side into per-turn events — plus a θ-free raw-gain
    side output for hysteresis-vs-satisfied rejection labels — and the
    run closes with drift + ``run_end`` events.  ``recorder=None``
    (default) runs the identical pre-telemetry program.
    """
    if recorder is None:
        result, trace, _ = _refine_traced(
            problem, assignment, framework, max_turns=max_turns, tol=tol,
            incremental=incremental, verify_every=verify_every, theta=theta)
        return result, trace
    run = _open_run(recorder, "refine_traced", problem, assignment,
                    framework, theta, incremental=incremental)
    t0 = time.perf_counter()
    with recorder.phase("core.refine_traced", run):
        result, trace, raw_gains = _refine_traced(
            problem, assignment, framework, max_turns=max_turns, tol=tol,
            incremental=incremental, verify_every=verify_every, theta=theta,
            telemetry=True)
        jax.block_until_ready(result)
    wall = time.perf_counter() - t0
    recorder.record_trace(run, trace, problem.node_weights,
                          problem.num_machines, raw_gain=raw_gains)
    turns = int(result.num_turns)
    last = max(turns - 1, 0)
    recorder.record_result(run, result, wall=wall,
                           c0=float(trace.c0[last]),
                           ct0=float(trace.ct0[last]))
    return result, trace


@partial(jax.jit, static_argnames=("framework", "max_sweeps", "telemetry"))
def _refine_simultaneous(problem: PartitionProblem, assignment: Array,
                         framework: str = costs.C_FRAMEWORK,
                         max_sweeps: int = 256, tol: float = DEFAULT_TOL,
                         theta=None, telemetry: bool = False):
    """Jitted scan body of :func:`refine_simultaneous`.

    Returns ``(RefineResult, (c0s, ct0s, active), movers)`` where
    ``movers`` is the (T,) per-sweep transfer count — a telemetry-only
    side output (``None`` unless ``telemetry=True``; the default jaxpr
    is the exact pre-telemetry program).
    """
    K = problem.num_machines
    theta = _resolve_theta(theta, problem.num_nodes)
    agg0 = agg_mod.init_aggregate_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)

    def sweep(carry, _):
        agg, done, moves = carry
        cost = costs.cost_matrix_from_aggregate(
            agg.aggregate, agg.assignment, problem.node_weights, agg.loads,
            problem.speeds, problem.mu, framework, total_weight=total_b)
        dissat, best = costs.dissatisfaction_from_cost(cost, agg.assignment,
                                                       theta)
        # Per machine: the most dissatisfied owned node.
        owned = jax.nn.one_hot(agg.assignment, K, dtype=cost.dtype)   # (N,K)
        masked = jnp.where(owned.T > 0, dissat[None, :], -jnp.inf)    # (K,N)
        pick = jnp.argmax(masked, axis=1).astype(jnp.int32)           # (K,)
        gains = jnp.max(masked, axis=1)
        will_move = gains > tol                                        # (K,)
        any_move = jnp.any(will_move) & ~done

        # Apply all K moves at once (moving machines pick disjoint nodes: a
        # node is owned by exactly one machine).  Idle machines' argmax over
        # an all--inf row falls back to node 0, which may collide with a
        # real move of node 0 — apply_sweep masks their columns to zero and
        # drops their assignment writes.
        new_agg = agg_mod.apply_sweep(problem, agg, pick, best[pick],
                                      will_move, total_b)
        new_agg = jax.tree.map(
            lambda new, old: jnp.where(any_move, new, old), new_agg, agg)
        sweep_movers = jnp.where(any_move,
                                 jnp.sum(will_move.astype(jnp.int32)), 0)
        moves = moves + sweep_movers
        out = (new_agg.c0, new_agg.ct0, any_move)
        if telemetry:
            out = out + (sweep_movers,)
        return (new_agg, done | ~any_move, moves), out

    (agg, done, moves), outs = jax.lax.scan(
        sweep, (agg0, jnp.zeros((), bool), jnp.zeros((), jnp.int32)),
        None, length=max_sweeps)
    movers = None
    if telemetry:
        c0s, ct0s, active, movers = outs
    else:
        c0s, ct0s, active = outs
    result = RefineResult(
        assignment=agg.assignment, loads=agg.loads,
        num_moves=moves,
        num_turns=jnp.sum(active.astype(jnp.int32)),
        converged=done, aggregate_drift=jnp.zeros(()))
    return result, (c0s, ct0s, active), movers


def refine_simultaneous(problem: PartitionProblem, assignment: Array,
                        framework: str = costs.C_FRAMEWORK,
                        max_sweeps: int = 256, tol: float = DEFAULT_TOL,
                        theta=None, recorder=None):
    """§4.5 asynchronous mode: every machine moves its most dissatisfied node
    in the same sweep.  Faster wall-clock (one cost evaluation per sweep
    serves all K machines) but descent is NOT guaranteed; ``refine_traced``
    style potentials are returned per sweep so benchmarks can count ascents.

    Incremental throughout: costs come from the carried aggregate (O(NK)
    per sweep), the K disjoint moves apply as one rank-K column update,
    and both potentials are re-derived via the O(K) closed forms of
    :func:`repro.core.aggregate.potentials_closed_form` (simultaneous
    moves are not unilateral, so the exact-potential identities do not
    apply — DESIGN.md §10).

    ``num_moves`` counts ACTUAL transfers (``sum(will_move)`` per sweep),
    not the ``K * sweeps`` upper bound.  ``theta`` as in :func:`refine`
    (each machine's pick maximizes — and its move gate tests — the
    dissatisfaction net of the node's migration price).

    Tie-breaks are deterministic throughout (DESIGN.md §7): each
    machine's pick is ``jnp.argmax``'s first maximum (lowest node
    index), and each node's destination is the lowest-index arg-best
    machine — the latter realized on every kernel path by the shared
    ``reduce_dissat_tile`` epilogue (see "The ``dissat_fn`` convention"
    in the module docstring; three fused kernels share it).

    ``recorder`` opts into telemetry (DESIGN.md §14): per-sweep events
    (with a movers-per-sweep side output) plus drift + ``run_end``;
    ``recorder=None`` (default) runs the identical pre-telemetry
    program.
    """
    if recorder is None:
        result, outs, _ = _refine_simultaneous(
            problem, assignment, framework, max_sweeps=max_sweeps, tol=tol,
            theta=theta)
        return result, outs
    run = _open_run(recorder, "refine_simultaneous", problem, assignment,
                    framework, theta)
    t0 = time.perf_counter()
    with recorder.phase("core.refine_simultaneous", run):
        result, outs, movers = _refine_simultaneous(
            problem, assignment, framework, max_sweeps=max_sweeps, tol=tol,
            theta=theta, telemetry=True)
        jax.block_until_ready(result)
    wall = time.perf_counter() - t0
    c0s, ct0s, active = outs
    recorder.record_sweeps(run, c0s, ct0s, active, movers=movers)
    turns = int(result.num_turns)
    last = max(turns - 1, 0)
    recorder.record_result(run, result, wall=wall, c0=float(c0s[last]),
                           ct0=float(ct0s[last]))
    return result, outs


class SweepCandidateFn(Protocol):
    """Fused sweep-election convention (DESIGN.md §17.4): the same 9
    positional arguments as :class:`DissatFn`, but returning the
    per-MACHINE election instead of the per-node reduction::

        sweep_fn(aggregate, assignment, node_weights, loads, speeds, mu,
                 framework, total_weight, theta)
            -> (gains (K,), picks (K,), dests (K,))

    ``gains[m]`` is the best net dissatisfaction among machine m's owned
    nodes, ``picks[m]`` that node (lowest index on ties — the same
    DESIGN.md §7 tie-break ``jnp.argmax`` applies) and ``dests[m]`` its
    lowest-index arg-best machine.  Factory:
    ``repro.kernels.ops.make_edge_sweep_fn`` (the edge-streaming Pallas
    kernel whose epilogue extends ``reduce_dissat_tile``).  Consumed by
    :func:`refine_sweeps` with ``moves_per_machine=1``.
    """

    def __call__(self, aggregate: Array, assignment: Array,
                 node_weights: Array, loads: Array, speeds: Array,
                 mu, framework: str, total_weight,
                 theta=None) -> tuple[Array, Array, Array]:
        """Returns ``(gains (K,), picks (K,), dests (K,))``."""
        ...


@partial(jax.jit, static_argnames=("framework", "max_sweeps",
                                   "moves_per_machine", "move_prob",
                                   "epsilon", "dissat_fn", "sweep_fn",
                                   "telemetry"))
def _refine_sweeps(problem: PartitionProblem, assignment: Array, key=None,
                   framework: str = costs.C_FRAMEWORK,
                   max_sweeps: int = 256, tol: float = DEFAULT_TOL,
                   theta=None, moves_per_machine: int | None = 1,
                   move_prob: float = 1.0, epsilon: float = 0.0,
                   dissat_fn=None, sweep_fn=None, telemetry: bool = False):
    """Jitted scan body of :func:`refine_sweeps`.

    Returns ``(RefineResult, (c0s, ct0s, active), movers)`` exactly like
    :func:`_refine_simultaneous` (``movers`` is ``None`` unless
    ``telemetry=True``; the default jaxpr is the pre-telemetry program).
    """
    K = problem.num_machines
    n = problem.num_nodes
    theta = _resolve_theta(theta, n)
    agg0 = agg_mod.init_aggregate_state(problem, assignment)
    total_b = jnp.sum(problem.node_weights)

    def sweep(carry, sweep_idx):
        agg, done, moves = carry
        # ε-gain threshold (arXiv:1305.3354, approximate congestion
        # games): a configuration is an ε-equilibrium once no player can
        # improve by more than ε times the per-node average potential,
        # so the acceptance floor scales with the CARRIED potential and
        # the loop stops at an ε-Nash point instead of chasing O(tol)
        # tail gains.  epsilon=0 is statically elided: thresh is the
        # same python float ``tol`` that _refine_simultaneous compares
        # against, keeping the degenerate config bitwise.
        if epsilon:
            pot = agg.c0 if framework == costs.C_FRAMEWORK else agg.ct0
            thresh = tol + epsilon * jnp.abs(pot) / n
        else:
            thresh = tol

        if sweep_fn is not None:
            # fused election: gains/picks/dests straight off the kernel
            gains, pick, dest_k = sweep_fn(
                agg.aggregate, agg.assignment, problem.node_weights,
                agg.loads, problem.speeds, problem.mu, framework, total_b,
                theta)
        else:
            if dissat_fn is None:
                cost = costs.cost_matrix_from_aggregate(
                    agg.aggregate, agg.assignment, problem.node_weights,
                    agg.loads, problem.speeds, problem.mu, framework,
                    total_weight=total_b)
                dissat, best = costs.dissatisfaction_from_cost(
                    cost, agg.assignment, theta)
            else:
                dissat, best = dissat_fn(agg.aggregate, agg.assignment,
                                         problem.node_weights, agg.loads,
                                         problem.speeds, problem.mu,
                                         framework, total_b, theta)

        if sweep_fn is not None or moves_per_machine == 1:
            if sweep_fn is None:
                owned = jax.nn.one_hot(agg.assignment, K,
                                       dtype=dissat.dtype)           # (N,K)
                masked = jnp.where(owned.T > 0, dissat[None, :],
                                   -jnp.inf)                         # (K,N)
                pick = jnp.argmax(masked, axis=1).astype(jnp.int32)  # (K,)
                gains = jnp.max(masked, axis=1)
                dest_k = best[pick]
            cand = gains > thresh                                    # (K,)
        elif moves_per_machine is not None:
            owned = jax.nn.one_hot(agg.assignment, K, dtype=dissat.dtype)
            masked = jnp.where(owned.T > 0, dissat[None, :], -jnp.inf)
            gains, pick = jax.lax.top_k(masked, moves_per_machine)   # (K,M)
            gains = gains.reshape(-1)                                # (K·M,)
            pick = pick.reshape(-1).astype(jnp.int32)
            dest_k = best[pick]
            cand = gains > thresh
        else:
            # unbounded: every node clearing the threshold is a candidate
            cand = dissat > thresh                                   # (N,)

        # Probabilistic acceptance (arXiv:cs/0506098, Berenbrink et al.,
        # distributed selfish load balancing): simultaneous best
        # responses can overshoot their destinations, so each candidate
        # migrates only with an independent per-candidate coin.  With
        # unilateral gains g_i, the accepted set drops the potential by
        # Σp_i·g_i in expectation while the collision overshoot scales
        # as Σ_{i≠j sharing a dest} p_i·p_j·b_i·b_j, so E[ΔΦ] < 0
        # whenever each destination's EXPECTED accepted inflow stays
        # below its load deficit — the expected-drop bound.  In the
        # unbounded mode (where overshoot is O(N)-wide) the coin rate is
        # DERIVED from that bound per candidate:
        #     p_i = move_prob · min(1, gap_i / W_{d_i}),
        # gap_i being half the source→destination normalized-load
        # imbalance (the weight that equalizes the pair) and W_d the
        # total candidate weight targeting d, so each destination's
        # expected inflow is at most move_prob · its absorbable weight.
        # The elected modes (≤ K·M movers) keep the flat ``move_prob``
        # coin — their overshoot is already bounded by the election.
        # ``move_prob >= 1`` is statically elided: ``accept`` IS
        # ``cand`` (same tensor, no PRNG op staged), which is what makes
        # the degenerate config bitwise-reproduce
        # :func:`_refine_simultaneous`.
        if move_prob < 1.0:
            coin_key = jax.random.fold_in(key, sweep_idx)
            if sweep_fn is None and moves_per_machine is None:
                norm = agg.loads / problem.speeds                    # (K,)
                gap = 0.5 * (norm[agg.assignment] - norm[best]) \
                    * problem.speeds[best]                           # (N,)
                w_dest = jax.ops.segment_sum(
                    jnp.where(cand, problem.node_weights,
                              jnp.zeros((), dissat.dtype)),
                    best, num_segments=K)                            # (K,)
                frac = gap / jnp.maximum(w_dest[best],
                                         jnp.asarray(1e-30, dissat.dtype))
                coin = jax.random.bernoulli(
                    coin_key, move_prob * jnp.clip(frac, 0.0, 1.0))
                # A candidate whose destination gap is non-positive has
                # acceptance probability 0 on every future sweep too (its
                # coin rate only rises if loads change, and loads only
                # change through moves) — once ALL candidates are in that
                # state the chain is absorbed, so they must not keep the
                # convergence test alive.
                cand = cand & (frac > 0)
            else:
                coin = jax.random.bernoulli(coin_key, move_prob,
                                            cand.shape)
            accept = cand & coin
        else:
            accept = cand

        any_cand = jnp.any(cand) & ~done

        if sweep_fn is not None or moves_per_machine == 1:
            new_agg = agg_mod.apply_sweep(problem, agg, pick, dest_k,
                                          accept, total_b)
        elif moves_per_machine is not None:
            new_agg = agg_mod.apply_moves(problem, agg, pick, dest_k,
                                          accept, total_b)
        else:
            # Unbounded apply: the adaptive coin keeps accepted sets small
            # after the first sweeps, so gather the movers into a fixed
            # R-slot buffer and reuse apply_moves' O(R·max_degree·K)
            # incident-window update; only a sweep whose accepted set
            # overflows the buffer pays the O(E) from-scratch rebuild
            # (lax.cond, so the cheap branch is the one executed).
            r_cap = min(_UNBOUNDED_APPLY_CAP, n)
            n_acc = jnp.sum(accept.astype(jnp.int32))
            idx = jnp.nonzero(accept, size=r_cap, fill_value=0)[0] \
                .astype(jnp.int32)
            valid = jnp.arange(r_cap) < n_acc
            new_agg = jax.lax.cond(
                n_acc <= r_cap,
                lambda: agg_mod.apply_moves(problem, agg, idx, best[idx],
                                            valid, total_b),
                lambda: agg_mod.rebuild_state(
                    problem, jnp.where(accept, best, agg.assignment),
                    total_b))
        new_agg = jax.tree.map(
            lambda new, old: jnp.where(any_cand, new, old), new_agg, agg)
        sweep_movers = jnp.where(any_cand,
                                 jnp.sum(accept.astype(jnp.int32)), 0)
        moves = moves + sweep_movers
        out = (new_agg.c0, new_agg.ct0, any_cand)
        if telemetry:
            out = out + (sweep_movers,)
        return (new_agg, done | ~any_cand, moves), out

    (agg, done, moves), outs = jax.lax.scan(
        sweep, (agg0, jnp.zeros((), bool), jnp.zeros((), jnp.int32)),
        jnp.arange(max_sweeps, dtype=jnp.int32))
    movers = None
    if telemetry:
        c0s, ct0s, active, movers = outs
    else:
        c0s, ct0s, active = outs
    result = RefineResult(
        assignment=agg.assignment, loads=agg.loads,
        num_moves=moves,
        num_turns=jnp.sum(active.astype(jnp.int32)),
        converged=done, aggregate_drift=jnp.zeros(()))
    return result, (c0s, ct0s, active), movers


def refine_sweeps(problem: PartitionProblem, assignment: Array,
                  framework: str = costs.C_FRAMEWORK,
                  max_sweeps: int = 256, tol: float = DEFAULT_TOL,
                  theta=None, moves_per_machine: int | None = 1,
                  move_prob: float = 1.0, epsilon: float = 0.0, key=None,
                  dissat_fn: DissatFn | None = None,
                  sweep_fn: SweepCandidateFn | None = None, recorder=None):
    """Multi-move probabilistic sweeps (DESIGN.md §17): the §4.5
    simultaneous mode generalized so convergence is O(sweeps), not
    O(moves).

    Per sweep, candidates are elected by the static ``moves_per_machine``:

      * ``1`` (default) — each machine's single most dissatisfied node,
        exactly :func:`refine_simultaneous`'s election;
      * ``M > 1`` — each machine's top-M owned nodes (``lax.top_k``),
        applied as one rank-K·M update
        (:func:`repro.core.aggregate.apply_moves`);
      * ``None`` — unbounded: EVERY node whose net dissatisfaction
        clears the threshold migrates to its best response.  Accepted
        sets are gathered into a fixed mover buffer and applied through
        :func:`repro.core.aggregate.apply_moves`' incident-edge windows
        (O(R·max_degree·K) per sweep); a sweep whose accepted set
        overflows the buffer falls back to the drift-free O(E·K) rebuild
        (:func:`repro.core.aggregate.rebuild_state`) — the
        million-node-in-seconds mode of ROADMAP item 1.

    ``move_prob < 1`` then thins the candidates with independent coins:
    a flat ``move_prob`` rate in the elected modes, and in the
    unbounded mode per-candidate rates DERIVED from the cs/0506098
    expected-drop bound — ``move_prob · min(1, gap_i / W_dest)``, so
    each destination's expected inflow never overshoots its load
    deficit (see the derivation comment in the sweep body).
    ``epsilon`` raises the acceptance floor to ``tol + ε·|Φ|/N`` — the
    ε-equilibrium threshold of 1305.3354.  Convergence is declared when
    no CANDIDATE clears the threshold (coin luck never extends or ends
    the run); the unbounded adaptive mode additionally drops candidates
    whose destination gap is non-positive — their coin rate is 0 on this
    and every future sweep, so a sweep where ALL candidates are in that
    state is an absorbing stochastic fixed point and counts as
    converged.

    The degenerate config — ``moves_per_machine=1, move_prob=1.0,
    epsilon=0`` — stages the same per-sweep op sequence as
    :func:`refine_simultaneous` and reproduces its accepted-move
    sequence, potentials and mover counts BITWISE on dense and sparse
    problems alike (CI-gated by ``benchmarks/sparse_bench.py``).

    ``key`` (a ``jax.random`` PRNG key) is required when
    ``move_prob < 1``; per-sweep coins derive via ``fold_in(key, sweep)``
    so results are reproducible per (key, config).  ``dissat_fn`` is the
    canonical 9-argument seam (module docstring) — e.g.
    ``repro.kernels.ops.make_edge_dissat_fn`` streams the candidate
    pass's edges once per sweep; ``sweep_fn``
    (:class:`SweepCandidateFn`) fuses the per-machine election into the
    kernel epilogue itself (``moves_per_machine=1`` only).

    Returns ``(RefineResult, (c0s, ct0s, active))`` like
    :func:`refine_simultaneous`; ``recorder`` opts into the identical
    telemetry shape (per-sweep potentials + movers).
    """
    if move_prob < 1.0 and key is None:
        raise ValueError("refine_sweeps(move_prob < 1) needs a PRNG `key` "
                         "for the per-sweep acceptance coins")
    if sweep_fn is not None and moves_per_machine != 1:
        raise ValueError("sweep_fn fuses the one-move-per-machine election "
                         "(moves_per_machine=1); use dissat_fn for the "
                         "other modes")
    if sweep_fn is not None and dissat_fn is not None:
        raise ValueError("pass sweep_fn or dissat_fn, not both (sweep_fn "
                         "subsumes the per-node reduction)")
    if recorder is None:
        result, outs, _ = _refine_sweeps(
            problem, assignment, key, framework, max_sweeps=max_sweeps,
            tol=tol, theta=theta, moves_per_machine=moves_per_machine,
            move_prob=move_prob, epsilon=epsilon, dissat_fn=dissat_fn,
            sweep_fn=sweep_fn)
        return result, outs
    run = _open_run(recorder, "refine_sweeps", problem, assignment,
                    framework, theta,
                    moves_per_machine=(-1 if moves_per_machine is None
                                       else moves_per_machine),
                    move_prob=move_prob, epsilon=epsilon)
    t0 = time.perf_counter()
    with recorder.phase("core.refine_sweeps", run):
        result, outs, movers = _refine_sweeps(
            problem, assignment, key, framework, max_sweeps=max_sweeps,
            tol=tol, theta=theta, moves_per_machine=moves_per_machine,
            move_prob=move_prob, epsilon=epsilon, dissat_fn=dissat_fn,
            sweep_fn=sweep_fn, telemetry=True)
        jax.block_until_ready(result)
    wall = time.perf_counter() - t0
    c0s, ct0s, active = outs
    recorder.record_sweeps(run, c0s, ct0s, active, movers=movers)
    turns = int(result.num_turns)
    last = max(turns - 1, 0)
    recorder.record_result(run, result, wall=wall, c0=float(c0s[last]),
                           ct0=float(ct0s[last]))
    return result, outs


def count_discrepancies(trace: Trace, framework: str, initial_other: Array,
                        rel_tol: float = 1e-4) -> Array:
    """§5.1: a C_0-discrepancy is a move that *increases* C_0 while using
    Ct_i as the local criterion (and vice versa).  ``framework`` names the
    criterion that *was* used; we count ascents of the OTHER potential.
    ``initial_other`` is that potential's value before the first turn.

    ``rel_tol`` sets what counts as an ascent: the potentials are O(1e6)
    f32 sums over N^2 terms, so sub-1e-5-relative deltas are accumulation
    noise; 1e-4 keeps every O(0.01%)-or-larger true ascent (measured
    ascents under the wrong criterion are 0.03-0.3% relative) while
    rejecting noise.  The paper does not publish its counting rule; the
    claim we reproduce is the ORDERING: Ct_0-discrepancies >> C_0-ones.
    """
    other = trace.c0 if framework == costs.CT_FRAMEWORK else trace.ct0
    prev = jnp.concatenate([initial_other[None], other[:-1]])
    ascent = (other - prev > rel_tol * jnp.abs(prev)) & trace.moved
    return jnp.sum(ascent.astype(jnp.int32))
