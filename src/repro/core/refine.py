"""Iterative partition refinement (paper §4.2, Fig. 1/2).

Machines take sequential round-robin turns.  On its turn, a machine finds the
*most dissatisfied* node it owns (Eq. 4) and transfers it to that node's
best-response machine; if the node's dissatisfaction is zero the machine
forsakes its turn.  The algorithm converges (Thm. 4.1) because every transfer
strictly decreases the potential C_0 (or Ct_0 for the second framework);
convergence is declared after K consecutive forsaken turns.

Two execution modes:
  * ``refine``        — ``lax.while_loop`` until convergence (production use;
                        bounded by ``max_turns`` as a safety net).
  * ``refine_traced`` — fixed-length ``lax.scan`` that records per-turn moves
                        and BOTH global potentials; powers the Table I /
                        §5.1 discrepancy study and the convergence tests.

Also implements the paper-§4.5 *simultaneous transfer* mode (one move per
machine per sweep, descent not guaranteed — measured in benchmarks).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import costs
from .problem import PartitionProblem, PartitionState, machine_loads, make_state

Array = jax.Array

# Dissatisfaction below this threshold counts as "satisfied" — guards float
# round-off from keeping the loop alive on a plateau.
DEFAULT_TOL = 1e-6


class TurnResult(NamedTuple):
    moved: Array          # bool   — did this turn transfer a node?
    node: Array           # int32  — the node transferred (or -1)
    source: Array         # int32  — machine that owned it
    dest: Array           # int32  — machine it moved to
    gain: Array           # float  — dissatisfaction of the moved node
    c0: Array             # float  — C_0 after the turn
    ct0: Array            # float  — Ct_0 after the turn


def _turn(problem: PartitionProblem, state: PartitionState, machine: Array,
          framework: str, tol: float, cost_matrix_fn=None):
    """One machine turn: move the most dissatisfied owned node (if any)."""
    if cost_matrix_fn is None:
        cost = costs.cost_matrix(problem, state, framework)
    else:
        cost = cost_matrix_fn(problem, state, framework)
    dissat, best = costs.dissatisfaction(problem, state, framework, cost=cost)
    owned = state.assignment == machine
    masked = jnp.where(owned, dissat, -jnp.inf)
    node = jnp.argmax(masked).astype(jnp.int32)
    gain = masked[node]
    do_move = gain > tol

    dest = best[node]
    new_assignment = jnp.where(
        do_move, state.assignment.at[node].set(dest), state.assignment)
    b_node = problem.node_weights[node]
    new_loads = jnp.where(
        do_move,
        state.loads.at[machine].add(-b_node).at[dest].add(b_node),
        state.loads,
    )
    new_state = PartitionState(new_assignment, new_loads)
    return new_state, TurnResult(
        moved=do_move,
        node=jnp.where(do_move, node, -1),
        source=jnp.where(do_move, machine, -1),
        dest=jnp.where(do_move, dest, -1),
        gain=jnp.where(do_move, gain, 0.0),
    c0=jnp.zeros(()), ct0=jnp.zeros(()))  # potentials filled by callers that want them


class RefineResult(NamedTuple):
    assignment: Array       # (N,) final assignment
    loads: Array            # (K,)
    num_moves: Array        # int32 — total node transfers ("iterations" in Table I)
    num_turns: Array        # int32 — total machine turns taken
    converged: Array        # bool


@partial(jax.jit, static_argnames=("framework", "max_turns", "cost_matrix_fn"))
def refine(problem: PartitionProblem, assignment: Array,
           framework: str = costs.C_FRAMEWORK,
           max_turns: int = 10_000, tol: float = DEFAULT_TOL,
           cost_matrix_fn=None) -> RefineResult:
    """Run round-robin refinement to convergence (K consecutive idle turns)."""
    K = problem.num_machines
    state0 = make_state(problem, assignment)

    def cond(carry):
        _, _, idle, turns, _ = carry
        return (idle < K) & (turns < max_turns)

    def body(carry):
        state, machine, idle, turns, moves = carry
        state, res = _turn(problem, state, machine, framework, tol,
                           cost_matrix_fn)
        idle = jnp.where(res.moved, 0, idle + 1)
        return (state, (machine + 1) % K, idle, turns + 1,
                moves + res.moved.astype(jnp.int32))

    init = (state0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    state, _, idle, turns, moves = jax.lax.while_loop(cond, body, init)
    return RefineResult(assignment=state.assignment, loads=state.loads,
                        num_moves=moves, num_turns=turns, converged=idle >= K)


class Trace(NamedTuple):
    """Per-turn record from ``refine_traced`` (fixed length = max_turns)."""
    moved: Array    # (T,) bool
    node: Array     # (T,) int32
    source: Array   # (T,) int32
    dest: Array     # (T,) int32
    gain: Array     # (T,) float
    c0: Array       # (T,) float — C_0 after each turn
    ct0: Array      # (T,) float — Ct_0 after each turn
    active: Array   # (T,) bool  — False once converged


@partial(jax.jit, static_argnames=("framework", "max_turns"))
def refine_traced(problem: PartitionProblem, assignment: Array,
                  framework: str = costs.C_FRAMEWORK,
                  max_turns: int = 512, tol: float = DEFAULT_TOL):
    """Fixed-length scan variant recording both potentials after every turn.

    Returns (RefineResult, Trace).  Turns after convergence are no-ops with
    ``active=False`` so downstream statistics can mask them out.
    """
    K = problem.num_machines
    state0 = make_state(problem, assignment)

    def step(carry, _):
        state, machine, idle = carry
        active = idle < K
        new_state, res = _turn(problem, state, machine, framework, tol)
        new_state = jax.tree.map(
            lambda new, old: jnp.where(active, new, old), new_state, state)
        moved = res.moved & active
        idle = jnp.where(moved, 0, idle + 1)
        c0 = costs.global_cost_c0(problem, new_state.assignment)
        ct0 = costs.global_cost_ct0(problem, new_state.assignment)
        out = Trace(moved=moved, node=res.node, source=res.source,
                    dest=res.dest, gain=res.gain, c0=c0, ct0=ct0,
                    active=active)
        return (new_state, (machine + 1) % K, idle), out

    (state, _, idle), trace = jax.lax.scan(
        step, (state0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)),
        None, length=max_turns)
    moves = jnp.sum(trace.moved.astype(jnp.int32))
    turns = jnp.sum(trace.active.astype(jnp.int32))
    result = RefineResult(assignment=state.assignment, loads=state.loads,
                          num_moves=moves, num_turns=turns,
                          converged=idle >= K)
    return result, trace


@partial(jax.jit, static_argnames=("framework", "max_sweeps"))
def refine_simultaneous(problem: PartitionProblem, assignment: Array,
                        framework: str = costs.C_FRAMEWORK,
                        max_sweeps: int = 256, tol: float = DEFAULT_TOL):
    """§4.5 asynchronous mode: every machine moves its most dissatisfied node
    in the same sweep.  Faster wall-clock (one cost evaluation per sweep
    serves all K machines) but descent is NOT guaranteed; ``refine_traced``
    style potentials are returned per sweep so benchmarks can count ascents.
    """
    K = problem.num_machines
    state0 = make_state(problem, assignment)

    def sweep(carry, _):
        state, done = carry
        cost = costs.cost_matrix(problem, state, framework)
        dissat, best = costs.dissatisfaction(problem, state, framework,
                                             cost=cost)
        # Per machine: the most dissatisfied owned node.
        owned = jax.nn.one_hot(state.assignment, K, dtype=cost.dtype)  # (N,K)
        masked = jnp.where(owned.T > 0, dissat[None, :], -jnp.inf)    # (K,N)
        pick = jnp.argmax(masked, axis=1).astype(jnp.int32)           # (K,)
        gains = jnp.max(masked, axis=1)
        will_move = gains > tol                                        # (K,)
        any_move = jnp.any(will_move) & ~done

        # Apply all K moves at once (moving machines pick disjoint nodes: a
        # node is owned by exactly one machine).  Idle machines' argmax over
        # an all--inf row falls back to node 0, which may collide with a
        # real move of node 0 — route non-moves to an out-of-range index so
        # the scatter drops them instead of racing the real update.
        safe_pick = jnp.where(will_move, pick, jnp.int32(problem.num_nodes))
        new_assignment = state.assignment.at[safe_pick].set(
            best[pick], mode="drop")
        new_assignment = jnp.where(any_move, new_assignment, state.assignment)
        new_loads = machine_loads(problem.node_weights, new_assignment, K)
        new_state = PartitionState(new_assignment, new_loads)
        c0 = costs.global_cost_c0(problem, new_state.assignment)
        ct0 = costs.global_cost_ct0(problem, new_state.assignment)
        return (new_state, done | ~any_move), (c0, ct0, any_move)

    (state, done), (c0s, ct0s, active) = jax.lax.scan(
        sweep, (state0, jnp.zeros((), bool)), None, length=max_sweeps)
    result = RefineResult(
        assignment=state.assignment, loads=state.loads,
        num_moves=jnp.sum(active.astype(jnp.int32)) * K,  # upper bound
        num_turns=jnp.sum(active.astype(jnp.int32)),
        converged=done)
    return result, (c0s, ct0s, active)


def count_discrepancies(trace: Trace, framework: str, initial_other: Array,
                        rel_tol: float = 1e-4) -> Array:
    """§5.1: a C_0-discrepancy is a move that *increases* C_0 while using
    Ct_i as the local criterion (and vice versa).  ``framework`` names the
    criterion that *was* used; we count ascents of the OTHER potential.
    ``initial_other`` is that potential's value before the first turn.

    ``rel_tol`` sets what counts as an ascent: the potentials are O(1e6)
    f32 sums over N^2 terms, so sub-1e-5-relative deltas are accumulation
    noise; 1e-4 keeps every O(0.01%)-or-larger true ascent (measured
    ascents under the wrong criterion are 0.03-0.3% relative) while
    rejecting noise.  The paper does not publish its counting rule; the
    claim we reproduce is the ORDERING: Ct_0-discrepancies >> C_0-ones.
    """
    other = trace.c0 if framework == costs.CT_FRAMEWORK else trace.ct0
    prev = jnp.concatenate([initial_other[None], other[:-1]])
    ascent = (other - prev > rel_tol * jnp.abs(prev)) & trace.moved
    return jnp.sum(ascent.astype(jnp.int32))
